"""L2: the quantized CNN forward pass in JAX, composed from the L1
Pallas kernels.

``cnn_forward`` mirrors the Rust ``small_cnn`` network node-for-node
(conv → BN → ReLU → quant → maxpool → conv → ReLU → quant → avgpool)
with identical integer semantics, so the AOT artifact's outputs must be
bit-identical to both the Rust golden executor and the PIM functional
simulator. All trained parameters (weights, BN, quantizer constants)
are runtime inputs, so one compiled artifact serves any parameter set.
"""

import jax.numpy as jnp

from .kernels import pooling, quantize as qk
from .kernels.bitwise_conv import bitwise_conv

# Shapes of the SmallCNN functional network (must match
# rust/src/cnn/network.rs::small_cnn).
INPUT_SHAPE = (2, 14, 22)
W1_SHAPE = (4, 2, 3, 3)
W2_SHAPE = (6, 4, 3, 3)
IBITS = 4
WBITS = 4
BN_SHIFT = 8


def cnn_forward(x, w1, bn_mul, bn_add, q1, w2, q2):
    """Forward pass of the SmallCNN.

    Args:
      x: (2, 14, 22) int32 in [0, 2^4).
      w1: (4, 2, 3, 3) int32 weights in [0, 2^4).
      bn_mul, bn_add: (4,) int32 folded BN parameters (shift = 8).
      q1: (4,) int32 [mul, add, shift, maxv] quantizer after conv1.
      w2: (6, 4, 3, 3) int32 weights.
      q2: (4,) int32 quantizer after conv2.

    Returns:
      (6, 1, 2) int32 — the network output.
    """
    # conv1 (bit-serial Pallas kernel) → BN → ReLU → quantize.
    y = bitwise_conv(x, w1, ibits=IBITS, wbits=WBITS, stride=1)
    y = qk.batchnorm(y, bn_mul, bn_add, BN_SHIFT)
    y = jnp.maximum(y, 0)
    y = qk.quantize(y, q1[0], q1[1], q1[2], q1[3])
    # maxpool 2/2.
    y = pooling.maxpool(y, k=2, stride=2)
    # conv2 → ReLU → quantize.
    y = bitwise_conv(y, w2, ibits=IBITS, wbits=WBITS, stride=1)
    y = jnp.maximum(y, 0)
    y = qk.quantize(y, q2[0], q2[1], q2[2], q2[3])
    # global-ish avgpool 3/3.
    y = pooling.avgpool(y, k=3, stride=3)
    return (y,)


def bitconv_entry(x, w):
    """Standalone bit-serial conv artifact (runtime cross-check shape)."""
    return (bitwise_conv(x, w, ibits=3, wbits=3, stride=1),)


def quantize_entry(x, params):
    """Standalone quantizer artifact on a flat vector."""
    return (qk.quantize(x, params[0], params[1], params[2], params[3]),)


def maxpool_entry(x):
    """Standalone 2×2/2 maxpool artifact."""
    return (pooling.maxpool(x, k=2, stride=2),)
