"""AOT lowering: JAX/Pallas → HLO *text* artifacts for the Rust runtime.

HLO text (not serialized HloModuleProto) is the interchange format: jax
≥ 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version the published ``xla`` crate binds) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Run once at build time (``make artifacts``); Python never executes on
the inference path.
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """Convert a jax lowering to XLA HLO text."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def i32(shape):
    """int32 ShapeDtypeStruct helper."""
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def entries():
    """(name, fn, example_args) for every artifact."""
    return [
        (
            "cnn_forward",
            model.cnn_forward,
            (
                i32(model.INPUT_SHAPE),
                i32(model.W1_SHAPE),
                i32((4,)),
                i32((4,)),
                i32((4,)),
                i32(model.W2_SHAPE),
                i32((4,)),
            ),
        ),
        ("bitconv", model.bitconv_entry, (i32((2, 8, 12)), i32((3, 2, 3, 3)))),
        ("quantize", model.quantize_entry, (i32((64,)), i32((4,)))),
        ("maxpool", model.maxpool_entry, (i32((4, 12, 20)),)),
    ]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    for name, fn, ex_args in entries():
        lowered = jax.jit(fn).lower(*ex_args)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")


if __name__ == "__main__":
    main()
