"""Pure-jnp oracles for every Pallas kernel.

These are the correctness anchors of the L1 layer: each kernel in this
package must agree exactly (integer ops) with its reference here, and the
references themselves mirror the Rust golden executor
(``rust/src/cnn/ref_exec.rs``) bit-for-bit.
"""

import jax.numpy as jnp


def bitplanes(x, bits):
    """Decompose an integer array into ``bits`` 0/1 planes (LSB first).

    Returns an array of shape ``(bits, *x.shape)`` with dtype int32.
    """
    x = x.astype(jnp.int32)
    planes = [(x >> n) & 1 for n in range(bits)]
    return jnp.stack(planes, axis=0)


def from_bitplanes(planes):
    """Recompose integer values from 0/1 bit-planes (LSB first)."""
    bits = planes.shape[0]
    weights = (2 ** jnp.arange(bits, dtype=jnp.int32)).reshape(
        (bits,) + (1,) * (planes.ndim - 1)
    )
    return jnp.sum(planes.astype(jnp.int32) * weights, axis=0)


def bitwise_conv2d(x, w, ibits, wbits, stride=1):
    """Eq. 1 bit-serial convolution, reference implementation.

    x: (C, H, W) unsigned ints < 2**ibits
    w: (OC, C, KH, KW) unsigned ints < 2**wbits
    Returns (OC, OH, OW) int32 — identical to a plain integer conv.
    """
    xp = bitplanes(x, ibits)  # (N, C, H, W)
    wp = bitplanes(w, wbits)  # (M, OC, C, KH, KW)
    kh, kw = w.shape[2], w.shape[3]
    oh = (x.shape[1] - kh) // stride + 1
    ow = (x.shape[2] - kw) // stride + 1
    out = jnp.zeros((w.shape[0], oh, ow), dtype=jnp.int32)
    for n in range(ibits):
        for m in range(wbits):
            # AND of bit-planes == product of 0/1 values; bitcount == sum.
            acc = jnp.zeros((w.shape[0], oh, ow), dtype=jnp.int32)
            for dy in range(kh):
                for dx in range(kw):
                    patch = xp[
                        n,
                        :,
                        dy : dy + oh * stride : stride,
                        dx : dx + ow * stride : stride,
                    ]  # (C, OH, OW)
                    wbit = wp[m, :, :, dy, dx]  # (OC, C)
                    acc = acc + jnp.einsum(
                        "chw,oc->ohw", patch, wbit, preferred_element_type=jnp.int32
                    )
            out = out + (acc << (n + m))
    return out


def conv2d_int(x, w, stride=1):
    """Plain integer convolution (the value-level truth)."""
    kh, kw = w.shape[2], w.shape[3]
    oh = (x.shape[1] - kh) // stride + 1
    ow = (x.shape[2] - kw) // stride + 1
    out = jnp.zeros((w.shape[0], oh, ow), dtype=jnp.int32)
    for dy in range(kh):
        for dx in range(kw):
            patch = x[:, dy : dy + oh * stride : stride, dx : dx + ow * stride : stride]
            out = out + jnp.einsum(
                "chw,oc->ohw",
                patch.astype(jnp.int32),
                w[:, :, dy, dx].astype(jnp.int32),
                preferred_element_type=jnp.int32,
            )
    return out


def quantize_ref(x, mul, add, shift, bits):
    """Eq. 2 folded fixed-point quantization (matches QuantParams::apply)."""
    y = (x.astype(jnp.int64) * jnp.int64(mul) + jnp.int64(add)) >> jnp.int64(shift)
    return jnp.clip(y, 0, (1 << bits) - 1).astype(jnp.int32)


def batchnorm_ref(x, mul, add, shift):
    """Eq. 3 folded per-channel BN (matches BnParams::apply); x: (C, H, W)."""
    m = mul.astype(jnp.int64).reshape(-1, 1, 1)
    a = add.astype(jnp.int64).reshape(-1, 1, 1)
    y = (x.astype(jnp.int64) * m + a) >> jnp.int64(shift)
    return jnp.maximum(y, 0).astype(jnp.int32)


def maxpool_ref(x, k, stride):
    """Max pooling; x: (C, H, W)."""
    c, h, w = x.shape
    oh = (h - k) // stride + 1
    ow = (w - k) // stride + 1
    out = jnp.full((c, oh, ow), jnp.iinfo(jnp.int32).min, dtype=jnp.int32)
    for dy in range(k):
        for dx in range(k):
            out = jnp.maximum(
                out,
                x[:, dy : dy + oh * stride : stride, dx : dx + ow * stride : stride].astype(
                    jnp.int32
                ),
            )
    return out


def avgpool_ref(x, k, stride, shift=16):
    """Fixed-point average pooling (matches avg_pool_scale)."""
    mul = jnp.int64(round((1 << shift) / (k * k)))
    c, h, w = x.shape
    oh = (h - k) // stride + 1
    ow = (w - k) // stride + 1
    s = jnp.zeros((c, oh, ow), dtype=jnp.int64)
    for dy in range(k):
        for dx in range(k):
            s = s + x[:, dy : dy + oh * stride : stride, dx : dx + ow * stride : stride].astype(
                jnp.int64
            )
    return ((s * mul + (1 << (shift - 1))) >> shift).astype(jnp.int32)


def relu_ref(x):
    """ReLU."""
    return jnp.maximum(x, 0)
