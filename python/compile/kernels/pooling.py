"""L1 Pallas kernels for pooling.

The paper's max pooling is the iterative in-memory comparison of
Fig. 11; average pooling is window addition plus a fixed-point 1/k²
multiply. On TPU both are element-wise max/add reductions over k²
shifted views of a VMEM-resident tile.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _maxpool_kernel(x_ref, o_ref, *, k, stride, oh, ow):
    x = x_ref[...]
    out = None
    for dy in range(k):
        for dx in range(k):
            v = x[:, dy : dy + oh * stride : stride, dx : dx + ow * stride : stride]
            out = v if out is None else jnp.maximum(out, v)
    o_ref[...] = out.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("k", "stride"))
def maxpool(x, k, stride):
    """Max pooling on x (C, H, W); matches ``ref.maxpool_ref``."""
    c, h, w = x.shape
    oh = (h - k) // stride + 1
    ow = (w - k) // stride + 1
    return pl.pallas_call(
        functools.partial(_maxpool_kernel, k=k, stride=stride, oh=oh, ow=ow),
        out_shape=jax.ShapeDtypeStruct((c, oh, ow), jnp.int32),
        interpret=True,
    )(x.astype(jnp.int32))


def _avgpool_kernel(x_ref, o_ref, *, k, stride, oh, ow, shift):
    mul = jnp.int64(round((1 << shift) / (k * k)))
    x = x_ref[...].astype(jnp.int64)
    s = None
    for dy in range(k):
        for dx in range(k):
            v = x[:, dy : dy + oh * stride : stride, dx : dx + ow * stride : stride]
            s = v if s is None else s + v
    o_ref[...] = ((s * mul + (1 << (shift - 1))) >> shift).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("k", "stride", "shift"))
def avgpool(x, k, stride, shift=16):
    """Fixed-point average pooling; matches ``ref.avgpool_ref`` and the
    Rust ``avg_pool_scale`` semantics."""
    c, h, w = x.shape
    oh = (h - k) // stride + 1
    ow = (w - k) // stride + 1
    return pl.pallas_call(
        functools.partial(_avgpool_kernel, k=k, stride=stride, oh=oh, ow=ow, shift=shift),
        out_shape=jax.ShapeDtypeStruct((c, oh, ow), jnp.int32),
        interpret=True,
    )(x.astype(jnp.int32))
