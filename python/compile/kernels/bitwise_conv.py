"""L1 Pallas kernel: Eq. 1 bit-serial convolution as a bit-plane matmul.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's
subarray performs a row-parallel AND against 128 sense amplifiers and
bit-counts per column. On TPU the same computation is a *matmul over
{0,1} bit-planes*: with the input bit-plane im2col'ed into a patch
matrix ``P[n] ∈ {0,1}^(L×K)`` and the weight bit-plane ``W[m] ∈
{0,1}^(OC×K)``,

    popcount(AND(P, W)) == P @ Wᵀ,

so the MXU's systolic array plays the role of the 128 SAs + bit-counters
and the grid over (n, m) bit-plane pairs plays the role of the paper's
sequential row activations. The 2^(n+m) significance scale is folded in
the accumulation epilogue, exactly like the paper's shifted row writes.

The kernel tiles L (output positions) into ``block_l``-row blocks so a
P-block (block_l × K) and a W-block (OC × K) are VMEM residents; on a
real TPU the dot runs on the MXU in f32 (exact for counts < 2^24).
CPU execution uses interpret=True (Mosaic custom-calls cannot run on the
CPU PJRT plugin).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _kernel(p_ref, w_ref, o_ref):
    """One (n, m, l-tile) grid step: o += (P[n,l] @ W[m]ᵀ) << (n+m)."""
    n = pl.program_id(0)
    m = pl.program_id(1)

    @pl.when((n == 0) & (m == 0))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # f32 dot is exact for these 0/1 operands (counts ≤ K < 2^24) and is
    # the MXU-native path on TPU.
    prod = jnp.dot(
        p_ref[0].astype(jnp.float32),
        w_ref[0].T.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ).astype(jnp.int32)
    o_ref[...] += prod << (n + m)


def _im2col_planes(x, ibits, kh, kw, stride):
    """Bit-planes of x im2col'ed: (N, L, K) with L=OH·OW, K=C·KH·KW."""
    c, h, w = x.shape
    oh = (h - kh) // stride + 1
    ow = (w - kw) // stride + 1
    planes = ref.bitplanes(x, ibits)  # (N, C, H, W)
    cols = []
    for dy in range(kh):
        for dx in range(kw):
            patch = planes[:, :, dy : dy + oh * stride : stride, dx : dx + ow * stride : stride]
            cols.append(patch.reshape(ibits, c, oh * ow))  # (N, C, L)
    # (N, L, C·KH·KW) with K ordered (c, ky, kx) to match the weight layout.
    stacked = jnp.stack(cols, axis=2)  # (N, C, KH·KW, L)
    return stacked.transpose(0, 3, 1, 2).reshape(ibits, oh * ow, c * kh * kw), oh, ow


@functools.partial(jax.jit, static_argnames=("ibits", "wbits", "stride", "block_l"))
def bitwise_conv(x, w, ibits, wbits, stride=1, block_l=128):
    """Bit-serial convolution of x (C,H,W) with w (OC,C,KH,KW).

    Integer-exact: equals ``ref.conv2d_int(x, w, stride)``.
    """
    oc, c, kh, kw = w.shape
    p, oh, ow = _im2col_planes(x, ibits, kh, kw, stride)  # (N, L, K)
    k = c * kh * kw
    length = oh * ow
    # Weight bit-planes: (M, OC, K), K ordered (c, ky, kx).
    wp = ref.bitplanes(w, wbits).reshape(wbits, oc, k)

    # Pad L to the block size (the paper pads feature maps to the 128
    # subarray columns the same way).
    lt = -(-length // block_l)
    pad = lt * block_l - length
    p = jnp.pad(p, ((0, 0), (0, pad), (0, 0)))

    out = pl.pallas_call(
        _kernel,
        grid=(ibits, wbits, lt),
        in_specs=[
            pl.BlockSpec((1, block_l, k), lambda n, m, l: (n, l, 0)),
            pl.BlockSpec((1, oc, k), lambda n, m, l: (m, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_l, oc), lambda n, m, l: (l, 0)),
        out_shape=jax.ShapeDtypeStruct((lt * block_l, oc), jnp.int32),
        interpret=True,
    )(p, wp)
    return out[:length].T.reshape(oc, oh, ow)
