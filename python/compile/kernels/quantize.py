"""L1 Pallas kernels for the element-wise transforms: Eq. 2 quantization
and Eq. 3 batch normalisation in the folded fixed-point form
``y = clamp((x·mul + add) >> shift, 0, 2^bits − 1)``.

The paper executes these with in-memory multiplication/addition
(Figs. 9–10); on TPU they are VPU element-wise ops over VMEM-resident
tiles. Parameters arrive as runtime scalars so one compiled artifact
serves any trained model.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quant_kernel(x_ref, params_ref, o_ref):
    x = x_ref[...].astype(jnp.int64)
    mul = params_ref[0].astype(jnp.int64)
    add = params_ref[1].astype(jnp.int64)
    shift = params_ref[2].astype(jnp.int64)
    maxv = params_ref[3].astype(jnp.int64)
    y = jnp.right_shift(x * mul + add, shift)
    o_ref[...] = jnp.clip(y, 0, maxv).astype(jnp.int32)


@jax.jit
def quantize(x, mul, add, shift, maxv):
    """Quantize a flat int32 array with runtime fixed-point parameters.

    Matches ``ref.quantize_ref`` (and Rust ``QuantParams::apply``).
    """
    params = jnp.stack(
        [
            jnp.asarray(mul, jnp.int32),
            jnp.asarray(add, jnp.int32),
            jnp.asarray(shift, jnp.int32),
            jnp.asarray(maxv, jnp.int32),
        ]
    )
    return pl.pallas_call(
        _quant_kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.int32),
        interpret=True,
    )(x.astype(jnp.int32), params)


def _bn_kernel(x_ref, mul_ref, add_ref, shift_ref, o_ref):
    # x: (C, HW); per-channel mul/add broadcast along HW.
    x = x_ref[...].astype(jnp.int64)
    mul = mul_ref[...].astype(jnp.int64)[:, None]
    add = add_ref[...].astype(jnp.int64)[:, None]
    shift = shift_ref[0].astype(jnp.int64)
    y = jnp.right_shift(x * mul + add, shift)
    o_ref[...] = jnp.maximum(y, 0).astype(jnp.int32)


@jax.jit
def batchnorm(x, mul, add, shift):
    """Per-channel folded BN on x (C, H, W); matches ``ref.batchnorm_ref``."""
    c, h, w = x.shape
    flat = x.reshape(c, h * w).astype(jnp.int32)
    out = pl.pallas_call(
        _bn_kernel,
        out_shape=jax.ShapeDtypeStruct((c, h * w), jnp.int32),
        interpret=True,
    )(
        flat,
        mul.astype(jnp.int32),
        add.astype(jnp.int32),
        jnp.asarray(shift, jnp.int32).reshape(1),
    )
    return out.reshape(c, h, w)
