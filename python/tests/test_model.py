"""L2 correctness: the SmallCNN forward pass — shapes, determinism, and
agreement with a hand-rolled numpy execution of the same integer
pipeline."""

import numpy as np

import jax.numpy as jnp

from compile import model
from compile.kernels import ref


def make_params(seed=0):
    rng = np.random.default_rng(seed)
    x = rng.integers(0, 16, size=model.INPUT_SHAPE, dtype=np.int32)
    w1 = rng.integers(0, 16, size=model.W1_SHAPE, dtype=np.int32)
    # Identity-ish BN at shift 8.
    bn_mul = np.full((4,), 256, dtype=np.int32)
    bn_add = np.full((4,), 128, dtype=np.int32)
    q1 = np.array([1, 1 << 6, 7, 15], dtype=np.int32)  # >>7 with rounding → 4 bits
    w2 = rng.integers(0, 16, size=model.W2_SHAPE, dtype=np.int32)
    q2 = np.array([1, 1 << 7, 8, 15], dtype=np.int32)
    return x, w1, bn_mul, bn_add, q1, w2, q2


def numpy_forward(x, w1, bn_mul, bn_add, q1, w2, q2):
    y = np.asarray(ref.conv2d_int(jnp.asarray(x), jnp.asarray(w1)))
    y = ((y.astype(np.int64) * bn_mul[:, None, None] + bn_add[:, None, None]) >> 8).clip(min=0)
    y = np.maximum(y, 0)
    y = ((y * q1[0] + q1[1]) >> q1[2]).clip(0, q1[3]).astype(np.int32)
    # maxpool 2/2
    c, h, w = y.shape
    y = y[:, : h // 2 * 2, : w // 2 * 2].reshape(c, h // 2, 2, w // 2, 2).max(axis=(2, 4))
    y = np.asarray(ref.conv2d_int(jnp.asarray(y), jnp.asarray(w2)))
    y = np.maximum(y, 0)
    y = ((y.astype(np.int64) * q2[0] + q2[1]) >> q2[2]).clip(0, q2[3]).astype(np.int32)
    # avgpool 3/3 fixed point
    mul = round((1 << 16) / 9)
    c, h, w = y.shape
    oh, ow = (h - 3) // 3 + 1, (w - 3) // 3 + 1
    out = np.zeros((c, oh, ow), dtype=np.int64)
    for dy in range(3):
        for dx in range(3):
            out += y[:, dy : dy + oh * 3 : 3, dx : dx + ow * 3 : 3]
    return ((out * mul + (1 << 15)) >> 16).astype(np.int32)


def test_forward_shapes():
    args = make_params()
    (y,) = model.cnn_forward(*(jnp.asarray(a) for a in args))
    assert y.shape == (6, 1, 2)
    assert y.dtype == jnp.int32


def test_forward_matches_numpy_pipeline():
    for seed in [0, 1, 7]:
        args = make_params(seed)
        (got,) = model.cnn_forward(*(jnp.asarray(a) for a in args))
        want = numpy_forward(*args)
        np.testing.assert_array_equal(np.asarray(got), want, err_msg=f"seed {seed}")


def test_forward_deterministic():
    args = make_params(3)
    (a,) = model.cnn_forward(*(jnp.asarray(x) for x in args))
    (b,) = model.cnn_forward(*(jnp.asarray(x) for x in args))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_entry_points_lower():
    """Every AOT entry must lower to HLO text without error."""
    from compile import aot

    for name, fn, ex_args in aot.entries():
        import jax

        text = aot.to_hlo_text(jax.jit(fn).lower(*ex_args))
        assert "ENTRY" in text, name
        assert len(text) > 100, name
