"""L1 correctness: Pallas kernels vs pure-jnp oracles (and vs plain
integer convolution, the value-level truth).

Hypothesis sweeps shapes / bit-widths / strides; every comparison is
exact (integer semantics), so assert_array_equal rather than allclose.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import pooling, quantize as qk, ref
from compile.kernels.bitwise_conv import bitwise_conv


def rand_ints(rng, shape, bits):
    return rng.integers(0, 2**bits, size=shape, dtype=np.int32)


# ----------------------------------------------------------------------
# bitwise conv
# ----------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    c=st.integers(1, 3),
    oc=st.integers(1, 4),
    k=st.integers(1, 3),
    extra=st.integers(0, 5),
    stride=st.integers(1, 2),
    ibits=st.integers(1, 5),
    wbits=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_bitwise_conv_matches_integer_conv(c, oc, k, extra, stride, ibits, wbits, seed):
    rng = np.random.default_rng(seed)
    h = k + extra
    w = k + extra + 2
    x = rand_ints(rng, (c, h, w), ibits)
    wts = rand_ints(rng, (oc, c, k, k), wbits)
    got = bitwise_conv(jnp.asarray(x), jnp.asarray(wts), ibits=ibits, wbits=wbits, stride=stride)
    want = ref.conv2d_int(jnp.asarray(x), jnp.asarray(wts), stride=stride)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_bitwise_conv_matches_eq1_reference():
    rng = np.random.default_rng(7)
    x = rand_ints(rng, (2, 8, 12), 3)
    w = rand_ints(rng, (3, 2, 3, 3), 3)
    got = bitwise_conv(jnp.asarray(x), jnp.asarray(w), ibits=3, wbits=3)
    want = ref.bitwise_conv2d(jnp.asarray(x), jnp.asarray(w), ibits=3, wbits=3)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_bitwise_conv_blocks_larger_than_l():
    # L < block_l exercises the padding path.
    rng = np.random.default_rng(9)
    x = rand_ints(rng, (1, 4, 5), 2)
    w = rand_ints(rng, (2, 1, 2, 2), 2)
    got = bitwise_conv(jnp.asarray(x), jnp.asarray(w), ibits=2, wbits=2, block_l=256)
    want = ref.conv2d_int(jnp.asarray(x), jnp.asarray(w))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_bitwise_conv_multi_block():
    # L > block_l exercises the L-tiling grid dimension.
    rng = np.random.default_rng(10)
    x = rand_ints(rng, (2, 20, 30), 4)
    w = rand_ints(rng, (4, 2, 3, 3), 4)
    got = bitwise_conv(jnp.asarray(x), jnp.asarray(w), ibits=4, wbits=4, block_l=64)
    want = ref.conv2d_int(jnp.asarray(x), jnp.asarray(w))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ----------------------------------------------------------------------
# quantize / batchnorm
# ----------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 200),
    mul=st.integers(1, 1 << 16),
    shift=st.integers(0, 20),
    bits=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_quantize_matches_ref(n, mul, shift, bits, seed):
    rng = np.random.default_rng(seed)
    x = rng.integers(0, 1 << 12, size=(n,), dtype=np.int32)
    add = (1 << shift) // 2
    maxv = (1 << bits) - 1
    got = qk.quantize(jnp.asarray(x), mul, add, shift, maxv)
    want = ref.quantize_ref(jnp.asarray(x), mul, add, shift, bits)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_batchnorm_matches_ref():
    rng = np.random.default_rng(3)
    x = rng.integers(0, 4096, size=(4, 6, 8), dtype=np.int32)
    mul = rng.integers(1, 512, size=(4,), dtype=np.int32)
    add = rng.integers(0, 1 << 10, size=(4,), dtype=np.int32)
    got = qk.batchnorm(jnp.asarray(x), jnp.asarray(mul), jnp.asarray(add), 8)
    want = ref.batchnorm_ref(jnp.asarray(x), jnp.asarray(mul), jnp.asarray(add), 8)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ----------------------------------------------------------------------
# pooling
# ----------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    c=st.integers(1, 4),
    k=st.integers(1, 3),
    extra=st.integers(0, 6),
    stride=st.integers(1, 3),
    seed=st.integers(0, 2**31 - 1),
)
def test_maxpool_matches_ref(c, k, extra, stride, seed):
    rng = np.random.default_rng(seed)
    h = k + extra
    w = k + extra + 1
    x = rng.integers(0, 256, size=(c, h, w), dtype=np.int32)
    got = pooling.maxpool(jnp.asarray(x), k=k, stride=stride)
    want = ref.maxpool_ref(jnp.asarray(x), k, stride)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=15, deadline=None)
@given(
    c=st.integers(1, 3),
    k=st.integers(1, 4),
    extra=st.integers(0, 5),
    seed=st.integers(0, 2**31 - 1),
)
def test_avgpool_matches_ref(c, k, extra, seed):
    rng = np.random.default_rng(seed)
    h = k + extra
    w = k + extra + 2
    x = rng.integers(0, 1024, size=(c, h, w), dtype=np.int32)
    got = pooling.avgpool(jnp.asarray(x), k=k, stride=k)
    want = ref.avgpool_ref(jnp.asarray(x), k, k)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_avgpool_rounds_half_up():
    # (1+5+3+1)/4 = 2.5 → 3, matching Rust avg_pool_scale semantics.
    x = jnp.asarray([[[1, 5], [3, 1]]], dtype=jnp.int32)
    got = pooling.avgpool(x, k=2, stride=2)
    assert int(got[0, 0, 0]) == 3


def test_bitwise_conv_rectangular_kernel():
    # kh != kw exercises the im2col ordering.
    rng = np.random.default_rng(31)
    x = rand_ints(rng, (2, 9, 14), 3)
    w = rand_ints(rng, (3, 2, 2, 4), 3)
    got = bitwise_conv(jnp.asarray(x), jnp.asarray(w), ibits=3, wbits=3)
    want = ref.conv2d_int(jnp.asarray(x), jnp.asarray(w))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_bitwise_conv_single_bit_planes():
    # Binary network case <1:1>: pure AND-popcount conv.
    rng = np.random.default_rng(33)
    x = rand_ints(rng, (3, 10, 10), 1)
    w = rand_ints(rng, (2, 3, 3, 3), 1)
    got = bitwise_conv(jnp.asarray(x), jnp.asarray(w), ibits=1, wbits=1)
    want = ref.conv2d_int(jnp.asarray(x), jnp.asarray(w))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
