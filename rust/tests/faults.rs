//! End-to-end fault injection and recovery: the acceptance criteria of
//! the fault subsystem. A zero-rate plan must be bit-identical to no
//! plan at all; a fixed seed with nonzero rates must be bit-identical
//! run to run and at every host worker count; retries must show up in
//! the charged energy and the `verify()`-checked ledgers; and a serve
//! with one unhealthy chip must still answer every request.

use nandspin::arch::config::ArchConfig;
use nandspin::cnn::network::{micro_cnn, small_cnn, Network};
use nandspin::cnn::ref_exec::{self, ModelParams};
use nandspin::cnn::tensor::QTensor;
use nandspin::coordinator::engine::{EngineKind, PoolSpec};
use nandspin::coordinator::serve::{
    serve, serve_pool, EngineMode, Request, ServeConfig, ServedNetwork,
};
use nandspin::coordinator::FunctionalEngine;
use nandspin::device::{FaultPlan, FaultRates};

fn requests(net: &Network, n: usize, seed: u64) -> Vec<Request> {
    Request::stream(
        (0..n)
            .map(|i| {
                QTensor::random(net.input.0, net.input.1, net.input.2, net.input_bits, seed + i as u64)
            })
            .collect(),
    )
}

/// Flatten a report into comparable per-request records.
fn fingerprint(report: &nandspin::coordinator::ServeReport) -> Vec<(u64, usize, String)> {
    let mut v: Vec<(u64, usize, String)> = report
        .completions
        .iter()
        .map(|c| (c.id, c.chip, format!("{:?}|{:?}", c.stats, c.output)))
        .collect();
    v.sort_unstable();
    v
}

#[test]
fn zero_rate_plan_is_bit_identical_to_no_plan_at_every_worker_count() {
    let net = small_cnn(3);
    let params = ModelParams::random(&net, 3, 5);
    for workers in [1usize, 2, 8] {
        let run = |fault: Option<FaultPlan>| {
            let scfg = ServeConfig {
                chips: 2,
                max_batch: 2,
                host_workers: Some(workers),
                fault,
                ..ServeConfig::default()
            };
            serve(&ArchConfig::paper(), &scfg, &net, Some(&params), requests(&net, 6, 100))
        };
        let clean = run(None);
        let zeroed = run(Some(FaultPlan::new(9, FaultRates::zero())));
        clean.verify().expect("clean identities");
        zeroed.verify().expect("zero-rate identities");
        assert_eq!(fingerprint(&clean), fingerprint(&zeroed), "workers={workers}");
        assert!(zeroed.faults.ledger.is_zero());
        assert!(!zeroed.faults.active, "a zero-rate plan is the fault-free path");
    }
}

#[test]
fn fixed_seed_nonzero_rates_are_bit_identical_run_to_run_and_across_workers() {
    let net = small_cnn(3);
    let params = ModelParams::random(&net, 3, 7);
    let run = |workers: usize| {
        let scfg = ServeConfig {
            chips: 2,
            max_batch: 2,
            host_workers: Some(workers),
            fault: Some(FaultPlan::new(7, FaultRates::uniform(0.02))),
            ..ServeConfig::default()
        };
        serve(&ArchConfig::paper(), &scfg, &net, Some(&params), requests(&net, 6, 300))
    };
    let first = run(1);
    first.verify().expect("faulted identities");
    assert!(first.faults.active);
    assert!(first.faults.ledger.injected() > 0, "2% per-op rate must inject");
    let again = run(1);
    assert_eq!(fingerprint(&first), fingerprint(&again), "same seed, same faults");
    assert_eq!(first.faults.ledger, again.faults.ledger);
    for workers in [2usize, 4] {
        let wide = run(workers);
        assert_eq!(fingerprint(&first), fingerprint(&wide), "workers={workers}");
        assert_eq!(first.faults.ledger, wide.faults.ledger, "workers={workers}");
    }
}

#[test]
fn retries_and_recovery_are_charged_as_real_energy_and_latency() {
    let net = micro_cnn(3);
    let params = ModelParams::random(&net, 2, 1);
    let input = QTensor::random(net.input.0, net.input.1, net.input.2, net.input_bits, 4);
    let mut clean = FunctionalEngine::new(ArchConfig::paper());
    clean.run(&net, &params, &input);
    let mut faulty = FunctionalEngine::new(ArchConfig::paper());
    faulty.set_fault_plan(FaultPlan::new(3, FaultRates {
        program_fail: 0.05,
        read_flip: 0.0,
        stuck_at: 0.0,
    }));
    faulty.run(&net, &params, &input);
    let ledger = faulty.stats.faults;
    assert!(ledger.program_faults > 0, "5% program-fail rate must inject");
    assert!(ledger.write_retries > 0, "transient failures must be retried");
    assert_eq!(ledger.read_flips + ledger.and_flips, 0, "only programs fault here");
    assert!(
        faulty.stats.total_energy_fj() > clean.stats.total_energy_fj(),
        "every retry is charged as a real rewrite"
    );
    assert!(
        faulty.stats.total_latency_ns() > clean.stats.total_latency_ns(),
        "retry latency is charged too"
    );
    assert!(clean.stats.faults.is_zero());
}

#[test]
fn failover_drains_the_unhealthy_chip_and_serves_every_request() {
    // Three functional chips; only chip 0 carries a (high-rate) fault
    // plan, installed through its own factory. Its injected-fault rate
    // trips the default health threshold, so the serve drains it and
    // re-routes its batches to the two clean survivors — every request
    // is still answered, and (because only clean chips' rounds are
    // retired) every answer is bit-exact.
    let net = small_cnn(3);
    let params = ModelParams::random(&net, 3, 21);
    let reqs = requests(&net, 9, 700);
    let images: Vec<QTensor> = reqs.iter().map(|r| r.image.clone()).collect();
    let mut pool = PoolSpec::homogeneous(ArchConfig::paper(), EngineKind::Functional, 3);
    pool.factory_mut(0).set_fault_plan(FaultPlan::new(13, FaultRates::uniform(0.2)));
    let scfg = ServeConfig { chips: 3, max_batch: 1, ..ServeConfig::default() };
    let nets = [ServedNetwork { net: &net, params: Some(&params) }];
    let report = serve_pool(&pool, &scfg, &nets, reqs);
    report.verify().expect("failover identities");
    assert_eq!(report.served(), 9, "every request is served despite the bad chip");
    assert!(report.faults.active);
    assert_eq!(report.faults.unhealthy_chips, 1);
    assert!(!report.chips[0].healthy, "chip 0 tripped the health threshold");
    assert!(report.chips[1].healthy && report.chips[2].healthy);
    assert!(report.faults.failover_rounds >= 1);
    assert!(report.faults.failed_over_batches > 0);
    assert!(report.faults.failed_over_requests > 0);
    assert_eq!(report.chips[0].served, 0, "nothing retired from the drained chip");
    for c in &report.completions {
        assert_ne!(c.chip, 0, "request {} retired from the drained chip", c.id);
        let golden = ref_exec::execute(&net, &params, &images[c.id as usize]);
        let output = c.output.as_ref().expect("functional outputs");
        assert_eq!(output, golden.last().expect("output"), "request {}", c.id);
    }
    let text = format!("{report}");
    assert!(text.contains("UNHEALTHY"), "{text}");
    assert!(text.contains("faults:"), "{text}");
}

#[test]
fn failover_is_skipped_when_no_healthy_chip_would_remain() {
    // Every chip serves under the same high-rate plan, so all of them
    // trip — draining them all would leave nobody. The serve must keep
    // the results instead and still answer every request.
    let net = micro_cnn(3);
    let params = ModelParams::random(&net, 2, 2);
    let scfg = ServeConfig {
        chips: 2,
        max_batch: 1,
        fault: Some(FaultPlan::new(5, FaultRates::uniform(0.2))),
        ..ServeConfig::default()
    };
    let report =
        serve(&ArchConfig::paper(), &scfg, &net, Some(&params), requests(&net, 4, 50));
    report.verify().expect("identities with every chip faulty");
    assert_eq!(report.served(), 4, "requests are served even when no chip is clean");
    assert!(report.faults.active);
    assert!(report.faults.ledger.injected() > 0);
    assert_eq!(report.faults.failed_over_batches, 0, "nowhere to fail over to");
    assert_eq!(report.faults.unhealthy_chips, 0, "chips are kept in rotation");
}

#[test]
fn hybrid_serve_escalates_its_spot_check_stride_under_faults() {
    // Hybrid serves analytically (no faults injected in the serving
    // path), but its functional replays carry the chips' fault plans.
    // When the replays' injected-fault rate trips the health threshold
    // the spot-check stride is halved: reserve samples fold in.
    let net = small_cnn(3);
    let params = ModelParams::random(&net, 3, 17);
    let scfg = ServeConfig {
        chips: 2,
        max_batch: 2,
        engine: EngineMode::Hybrid { check_every: 4 },
        fault: Some(FaultPlan::new(7, FaultRates::uniform(0.2))),
        ..ServeConfig::default()
    };
    let report =
        serve(&ArchConfig::paper(), &scfg, &net, Some(&params), requests(&net, 8, 60));
    report.verify().expect("hybrid fault identities");
    assert_eq!(report.served(), 8);
    assert!(report.faults.active);
    assert!(report.faults.spot_check_escalated, "degraded replays must escalate");
    let sc = report.spot_check.expect("replays ran");
    assert_eq!(sc.checked, 4, "positions 0, 4 plus escalated 2, 6");
    assert!(sc.passed(), "latency {:?} energy {:?}", sc.latency_ratio, sc.energy_ratio);
    assert!(
        report.faults.ledger.is_zero(),
        "analytic completions inject nothing — replay faults stay out of the ledger"
    );
}
