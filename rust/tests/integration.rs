//! Cross-module integration: functional PIM simulation vs the golden
//! executor across networks/precisions/seeds, and architecture-level
//! invariants of the analytic model and baselines.

use nandspin::arch::config::ArchConfig;
use nandspin::arch::stats::Phase;
use nandspin::baselines::designs::BaselineKind;
use nandspin::cnn::network::{micro_cnn, resnet50, small_cnn, vgg19};
use nandspin::cnn::ref_exec::{self, ModelParams};
use nandspin::cnn::tensor::QTensor;
use nandspin::coordinator::{AnalyticModel, Coordinator};

fn check_bit_exact(bits: u8, wbits: u8, seed: u64) {
    let net = small_cnn(bits);
    let params = ModelParams::random(&net, wbits, seed);
    let input = QTensor::random(net.input.0, net.input.1, net.input.2, bits, seed ^ 0xabc);
    let golden = ref_exec::execute(&net, &params, &input);
    let (outs, stats) = Coordinator::paper().functional_run(&net, &params, &input);
    for (i, (a, b)) in outs.iter().zip(&golden).enumerate() {
        assert_eq!(a, b, "bits={bits} wbits={wbits} seed={seed} node {i}");
    }
    // The functional run must exercise all op classes.
    assert!(stats.ops.ands > 0 && stats.ops.erases > 0 && stats.ops.program_steps > 0);
    assert!(stats.ops.reads > 0 && stats.ops.bitcounts > 0);
    assert!(stats[Phase::Convolution].latency_ns > 0.0);
    assert!(stats[Phase::Pooling].latency_ns > 0.0);
}

#[test]
fn functional_matches_golden_across_precisions() {
    for (bits, wbits, seed) in [(2u8, 2u8, 1u64), (3, 4, 2), (4, 4, 3), (4, 2, 4), (5, 3, 5)] {
        check_bit_exact(bits, wbits, seed);
    }
}

#[test]
fn functional_matches_golden_many_seeds_micro() {
    for seed in 0..8 {
        let net = micro_cnn(4);
        let params = ModelParams::random(&net, 3, seed);
        let input = QTensor::random(1, 4, 6, 4, seed + 50);
        let golden = ref_exec::execute(&net, &params, &input);
        let (outs, _) = Coordinator::paper().functional_run(&net, &params, &input);
        assert_eq!(outs.last(), golden.last(), "seed {seed}");
    }
}

#[test]
fn analytic_capacity_monotonicity() {
    // Fig. 13a invariant: more capacity never slows inference down.
    let net = resnet50(8);
    let mut last = f64::INFINITY;
    for cap in [8usize, 16, 32, 64, 128] {
        let mut cfg = ArchConfig::paper();
        cfg.capacity_mb = cap;
        let lat = AnalyticModel::new(cfg).network_stats(&net, 8).total_latency_ns();
        assert!(lat <= last * 1.001, "capacity {cap} slower than smaller config");
        last = lat;
    }
}

#[test]
fn analytic_bus_monotonicity() {
    // Fig. 13b invariant: wider bus never slows inference down.
    let net = vgg19(8);
    let mut last = f64::INFINITY;
    for bus in [32usize, 64, 128, 256, 512] {
        let mut cfg = ArchConfig::paper();
        cfg.bus_width_bits = bus;
        let lat = AnalyticModel::new(cfg).network_stats(&net, 8).total_latency_ns();
        assert!(lat <= last * 1.001, "bus {bus} slower than narrower config");
        last = lat;
    }
}

#[test]
fn proposed_beats_all_baselines_in_throughput() {
    // Table 3 headline: the proposed design has the highest FPS.
    let net = resnet50(8);
    let ours = Coordinator::paper().analytic_metrics(&net, 8).fps();
    for kind in BaselineKind::ALL {
        let theirs = kind.model().metrics(&net, 8).fps();
        assert!(
            ours > theirs,
            "proposed ({ours:.1} FPS) must beat {} ({theirs:.1} FPS)",
            kind.model().name
        );
    }
}

#[test]
fn proposed_beats_stt_and_dram_normalised_to_area() {
    // Figs. 14–15 headline ratios (shape, not absolute): proposed wins
    // in perf/area and efficiency/area against DRAM- and STT-based.
    let net = resnet50(8);
    let coord = Coordinator::paper();
    let ours = coord.analytic_metrics(&net, 8);
    for kind in [BaselineKind::Drisa, BaselineKind::SttCim, BaselineKind::Imce, BaselineKind::Prime]
    {
        let m = kind.model().metrics(&net, 8);
        assert!(
            ours.gops_per_mm2() > m.gops_per_mm2(),
            "perf/area vs {}",
            kind.model().name
        );
        assert!(
            ours.efficiency_per_mm2() > m.efficiency_per_mm2(),
            "eff/area vs {}",
            kind.model().name
        );
    }
}

#[test]
fn fig16_breakdown_shape_holds() {
    // Load + conv are the top-2 latency shares; pooling is the next
    // biggest computational share; transfer is small (Fig. 16a).
    let st = Coordinator::paper().analytic_stats(&resnet50(8), 8);
    let lat = |p: Phase| st[p].latency_ns;
    assert!(lat(Phase::LoadData) > lat(Phase::Pooling));
    assert!(lat(Phase::Convolution) > lat(Phase::Pooling));
    assert!(lat(Phase::Pooling) > lat(Phase::BatchNorm));
    assert!(lat(Phase::DataTransfer) < lat(Phase::Convolution));
    // Energy: conv and load dominate (Fig. 16b).
    let en = |p: Phase| st[p].energy_fj;
    assert!(en(Phase::Convolution) > en(Phase::Pooling));
    assert!(en(Phase::LoadData) > en(Phase::DataTransfer));
}

#[test]
fn precision_grid_monotone_for_proposed() {
    // Figs. 14–15: cost grows with ⟨W:I⟩ for the bit-serial design.
    let coord = Coordinator::paper();
    let mut last = 0.0;
    for (w, i) in [(1u8, 1u8), (2, 2), (4, 4), (8, 8)] {
        let lat = coord.analytic_stats(&resnet50(i), w).total_latency_ns();
        assert!(lat > last, "⟨{w}:{i}⟩ must cost more than the previous point");
        last = lat;
    }
}

#[test]
fn functional_small_resnet_with_padding_and_residual() {
    // Exercises zero padding (free in erased cells) and the Residual
    // merge in the bit-accurate functional path.
    use nandspin::cnn::network::small_resnet;
    for seed in [1u64, 9, 77] {
        let net = small_resnet(4);
        let params = ModelParams::random(&net, 3, seed);
        let input = QTensor::random(net.input.0, net.input.1, net.input.2, 4, seed + 5);
        let golden = ref_exec::execute(&net, &params, &input);
        let (outs, stats) = Coordinator::paper().functional_run(&net, &params, &input);
        for (i, (a, b)) in outs.iter().zip(&golden).enumerate() {
            assert_eq!(a, b, "seed {seed} node {i}");
        }
        assert!(stats.ops.ands > 0);
    }
}
