//! Integration tests pinning the serve runtime to the closed-form
//! batching laws it schedules by, plus the per-network SLO acceptance
//! condition of the heterogeneous-pool scheduler:
//!
//! * the simulated latency/energy aggregates of an analytic serve land
//!   back on the [`BatchLaw`] curves (`cold + (n−1)·warm`) for batch
//!   sizes 1, 4 and 16 — the law and the engine share one closed form,
//!   so the tolerance is floating-point summation order only;
//! * per-request energy amortises monotonically toward the warm floor
//!   as the batch grows (the paper's Table 3 steady-state condition);
//! * a mixed AlexNet + small_cnn stream over a heterogeneous two-chip
//!   pool meets both networks' lane deadlines with zero violations;
//! * the cost-aware router's chip assignment over a heterogeneous pool
//!   is exactly reproduced by replaying the public [`ShardRouter`]
//!   against laws derived from each chip's own operating point.

use nandspin::arch::config::ArchConfig;
use nandspin::cnn::network::{alexnet, micro_cnn, small_cnn, Network};
use nandspin::cnn::tensor::QTensor;
use nandspin::coordinator::engine::{EngineKind, PoolSpec};
use nandspin::coordinator::serve::{
    serve_pool, serving_wbits, BatchLaw, CostTable, EngineMode, Request, ServeConfig,
    ServeReport, ServedNetwork, ShardRouter, SloPolicy,
};

/// Relative tolerance for "measured == closed form" assertions. The
/// serve's analytic engine synthesizes per-request stats from the same
/// two closed-form evaluations `BatchLaw::derive` folds, so the only
/// slack needed is floating-point summation order (n ≤ 16 terms of an
/// f64 sum: relative error ≪ 1e-12).
const REL_TOL: f64 = 1e-9;

fn assert_close(measured: f64, law: f64, what: &str) {
    assert!(
        (measured - law).abs() <= REL_TOL * law.abs().max(1.0),
        "{what}: measured {measured} vs closed form {law}"
    );
}

fn burst(net: &Network, n: usize, seed: u64) -> Vec<Request> {
    Request::stream(
        (0..n)
            .map(|i| {
                QTensor::random(net.input.0, net.input.1, net.input.2, net.input_bits, seed + i as u64)
            })
            .collect(),
    )
}

/// Serve `n` requests of `net` as ONE analytic batch on one chip (the
/// closed-burst default flushes on size as soon as the lane fills).
fn serve_one_batch(net: &Network, n: usize, seed: u64) -> ServeReport {
    let pool = PoolSpec::homogeneous(ArchConfig::paper(), EngineKind::Analytic, 1);
    let scfg = ServeConfig {
        chips: 1,
        max_batch: n,
        engine: EngineMode::Analytic,
        ..ServeConfig::default()
    };
    let nets = [ServedNetwork { net, params: None }];
    let report = serve_pool(&pool, &scfg, &nets, burst(net, n, seed));
    report.verify().expect("aggregation identities");
    assert_eq!(report.served(), n);
    assert_eq!(report.counters.batches, 1, "one lane fill => one batch");
    report
}

#[test]
fn batch_latency_follows_the_closed_form_law() {
    // latency(n) = cold + (n − 1) · warm, per network, per batch size:
    // the sum of per-request simulated latencies of one served batch is
    // the law evaluated at the batch size, and so is the makespan (one
    // batch flushed at t = 0 runs back-to-back on one chip).
    for net in [small_cnn(3), micro_cnn(3)] {
        let law = BatchLaw::derive(&ArchConfig::paper(), &net, serving_wbits(&net, None));
        for n in [1usize, 4, 16] {
            let report = serve_one_batch(&net, n, 1000 + n as u64);
            let measured: f64 =
                report.completions.iter().map(|c| c.stats.total_latency_ns()).sum();
            assert_close(measured, law.batch_latency_ns(n), &format!("{} latency n={n}", net.name));
            assert_close(
                report.makespan_ns(),
                law.batch_latency_ns(n),
                &format!("{} makespan n={n}", net.name),
            );
        }
    }
}

#[test]
fn batch_energy_amortises_on_the_closed_form_curve() {
    // energy(n) = cold_e + (n − 1) · warm_e, and energy per request
    // decreases monotonically toward (but never reaches) the warm
    // floor: the one-time weight stream spreads across the batch.
    let net = small_cnn(3);
    let law = BatchLaw::derive(&ArchConfig::paper(), &net, serving_wbits(&net, None));
    let mut per_request = Vec::new();
    for n in [1usize, 4, 16] {
        let report = serve_one_batch(&net, n, 2000 + n as u64);
        let measured: f64 = report.completions.iter().map(|c| c.stats.total_energy_fj()).sum();
        assert_close(measured, law.batch_energy_fj(n), &format!("energy n={n}"));
        let amortised = measured / n as f64;
        assert_close(amortised, law.energy_per_request_fj(n), &format!("energy/req n={n}"));
        per_request.push(amortised);
    }
    assert!(
        per_request[0] > per_request[1] && per_request[1] > per_request[2],
        "amortisation must be monotone: {per_request:?}"
    );
    assert!(per_request[2] > law.warm_energy_fj, "warm floor is an infimum, not attained");
}

#[test]
fn mixed_stream_meets_both_deadlines_on_a_heterogeneous_pool() {
    // The acceptance condition: AlexNet (relaxed SLO) and small_cnn
    // (tight SLO) share one serve over a heterogeneous pool — the paper
    // operating point next to a narrow-bus variant — and BOTH lanes
    // finish with zero deadline violations, per the report's own
    // re-derived per-network accounts.
    let big = alexnet(8);
    let small = small_cnn(3);
    let mut narrow = ArchConfig::paper();
    narrow.bus_width_bits = 32;
    let pool = PoolSpec::heterogeneous(vec![ArchConfig::paper(), narrow], EngineKind::Analytic);
    let scfg = ServeConfig {
        chips: 2,
        max_batch: 8,
        deadline_us: 500.0,
        slo: SloPolicy::global().with_deadline_us(1, 40.0),
        arrival_interval_ns: 10_000.0,
        engine: EngineMode::Analytic,
        ..ServeConfig::default()
    };
    let n = 12usize;
    let streams = vec![
        (0..n)
            .map(|i| QTensor::random(big.input.0, big.input.1, big.input.2, 8, 3000 + i as u64))
            .collect(),
        (0..n)
            .map(|i| {
                QTensor::random(
                    small.input.0,
                    small.input.1,
                    small.input.2,
                    small.input_bits,
                    4000 + i as u64,
                )
            })
            .collect(),
    ];
    let nets = [
        ServedNetwork { net: &big, params: None },
        ServedNetwork { net: &small, params: None },
    ];
    let report = serve_pool(&pool, &scfg, &nets, Request::interleave(streams));
    report.verify().expect("per-network roll-up identities");
    assert_eq!(report.served(), 2 * n);
    assert_eq!(report.networks.len(), 2);
    for nr in &report.networks {
        assert_eq!(nr.served, n as u64, "net {} ({})", nr.net, nr.name);
        assert_eq!(
            nr.deadline_violations, 0,
            "net {} ({}) broke its {} µs SLO (max lane wait {} µs)",
            nr.net,
            nr.name,
            nr.deadline_ns * 1e-3,
            nr.max_batcher_wait_ns * 1e-3
        );
    }
    // Both lanes really carry different deadlines.
    assert!((report.networks[0].deadline_ns - 500.0e3).abs() < 1e-9);
    assert!((report.networks[1].deadline_ns - 40.0e3).abs() < 1e-9);
}

#[test]
fn cost_aware_routing_matches_a_router_replay_of_the_laws() {
    // The serve's chip assignment over a heterogeneous pool must be
    // exactly the assignment the public ShardRouter computes from laws
    // derived per chip operating point — i.e. routing is driven by the
    // analytic cost model, not by input size or round-robin position.
    let net = small_cnn(3);
    let mut narrow = ArchConfig::paper();
    narrow.bus_width_bits = 32;
    let law_fast = BatchLaw::derive(&ArchConfig::paper(), &net, serving_wbits(&net, None));
    let law_slow = BatchLaw::derive(&narrow, &net, serving_wbits(&net, None));
    assert!(
        law_slow.cold_latency_ns > law_fast.cold_latency_ns,
        "narrowing the bus must slow the weight stream"
    );

    let pool = PoolSpec::heterogeneous(vec![ArchConfig::paper(), narrow], EngineKind::Analytic);
    let scfg = ServeConfig {
        chips: 2,
        max_batch: 1,
        engine: EngineMode::Analytic,
        ..ServeConfig::default()
    };
    let n = 12usize;
    let nets = [ServedNetwork { net: &net, params: None }];
    let report = serve_pool(&pool, &scfg, &nets, burst(&net, n, 5000));
    report.verify().expect("aggregation identities");
    assert_eq!(report.served(), n);

    // Replay the same singleton stream through a standalone router
    // loaded with the same per-chip laws.
    let costs = CostTable::new(vec![
        vec![(law_fast.cold_latency_ns, law_fast.warm_latency_ns)],
        vec![(law_slow.cold_latency_ns, law_slow.warm_latency_ns)],
    ]);
    let mut router = ShardRouter::new(costs);
    let mut expected = [0u64; 2];
    for _ in 0..n {
        expected[router.route(0, 1)] += 1;
    }
    assert_eq!(
        [report.chips[0].served, report.chips[1].served],
        expected,
        "serve must route exactly as the law-driven router does"
    );
    assert!(
        expected[0] >= expected[1],
        "the faster chip never serves less than the slower one: {expected:?}"
    );

    // With identical chips the same stream reduces to an even split.
    let even_pool = PoolSpec::homogeneous(ArchConfig::paper(), EngineKind::Analytic, 2);
    let even = serve_pool(&even_pool, &scfg, &nets, burst(&net, n, 5000));
    assert_eq!(even.chips[0].served, even.chips[1].served, "identical chips split evenly");
}
