//! Property-style sweeps over the in-memory primitives and coordinator
//! invariants (hand-rolled generator: the build is offline, so proptest
//! is replaced by seeded random sweeps with shrink-friendly reporting),
//! plus packed-vs-scalar equivalence properties: the word-parallel host
//! representation must be bit-identical — outputs *and* [`Stats`] — to
//! a faithful scalar per-column emulation of the pre-refactor path
//! issuing the same device-op sequence.

use nandspin::arch::config::ArchConfig;
use nandspin::arch::stats::{Phase, Stats};
use nandspin::cnn::layer::Layer;
use nandspin::cnn::network::{small_cnn, Network, Node};
use nandspin::cnn::ref_exec::{self, ModelParams, WideTensor};
use nandspin::cnn::tensor::QTensor;
use nandspin::coordinator::serve::{CostTable, ShardRouter};
use nandspin::coordinator::FunctionalEngine;
use nandspin::device::energy::DeviceCosts;
use nandspin::mapping::tiling::{plan_axis, AxisTile};
use nandspin::mapping::{ConvMapping, TilePlan, Tiling};
use nandspin::subarray::conv::{
    bitplane_conv_counts, window_sums, BitKernel, ConvGeometry,
};
use nandspin::subarray::primitives::{
    add_columns, add_result_width, compare_columns, multiply_columns, CompareScratch,
};
use nandspin::subarray::Subarray;
use nandspin::util::Rng;

/// Seed for a property sweep: the test's `default`, unless the
/// `NANDSPIN_TEST_SEED` environment variable overrides it (decimal or
/// `0x`-prefixed hex). The chosen seed is printed; `cargo test` only
/// surfaces captured stdout for *failing* tests, so a red sweep always
/// names the seed to replay it with.
fn sweep_seed(default: u64) -> u64 {
    let seed = match std::env::var("NANDSPIN_TEST_SEED") {
        Ok(v) => {
            let t = v.trim();
            let parsed = match t.strip_prefix("0x").or_else(|| t.strip_prefix("0X")) {
                Some(hex) => u64::from_str_radix(hex, 16),
                None => t.parse::<u64>(),
            };
            parsed.unwrap_or_else(|_| {
                panic!("NANDSPIN_TEST_SEED must be a u64 (decimal or 0x-hex), got '{t}'")
            })
        }
        Err(_) => default,
    };
    println!("property sweep seed: {seed:#x} (override with NANDSPIN_TEST_SEED)");
    seed
}

fn sub() -> Subarray {
    Subarray::new(256, 128, 16, DeviceCosts::default())
}

fn store_vertical(s: &mut Subarray, base: usize, bits: usize, vals: &[u32]) {
    let mut st = Stats::default();
    for b in 0..bits {
        let mut row = 0u128;
        for (col, &v) in vals.iter().enumerate() {
            row |= (((v >> b) & 1) as u128) << col;
        }
        s.write_row(base + b, row, &mut st, Phase::LoadData);
    }
}

fn load_vertical(s: &Subarray, base: usize, bits: usize, cols: usize) -> Vec<u64> {
    (0..cols)
        .map(|col| {
            (0..bits).fold(0u64, |acc, b| {
                acc | ((((s.peek_row(base + b) >> col) & 1) as u64) << b)
            })
        })
        .collect()
}

#[test]
fn property_addition_random_operand_sets() {
    // 60 random cases: k operands of b bits each, all 128 columns.
    let mut rng = Rng::seed_from_u64(sweep_seed(0xADD));
    for case in 0..60 {
        let k = rng.gen_usize(2, 9);
        let bits = rng.gen_usize(1, 9);
        let mut s = sub();
        let mut operands = Vec::new();
        for i in 0..k {
            let vals: Vec<u32> =
                (0..128).map(|_| rng.gen_range_inclusive((1u32 << bits) - 1)).collect();
            store_vertical(&mut s, i * bits, bits, &vals);
            operands.push(vals);
        }
        let mut st = Stats::default();
        let bases: Vec<usize> = (0..k).map(|i| i * bits).collect();
        let result_base = ((k * bits).div_ceil(8) + 1) * 8;
        let width = add_columns(&mut s, &bases, bits, result_base, &mut st, Phase::Pooling);
        let sums = load_vertical(&s, result_base, width, 128);
        for col in 0..128 {
            let expect: u64 = operands.iter().map(|o| o[col] as u64).sum();
            assert_eq!(sums[col], expect, "case {case} k={k} bits={bits} col={col}");
        }
    }
}

#[test]
fn property_multiplication_random_widths() {
    let mut rng = Rng::seed_from_u64(sweep_seed(0x301));
    for case in 0..40 {
        let abits = rng.gen_usize(1, 9);
        let bbits = rng.gen_usize(1, 9);
        let mut s = sub();
        let a: Vec<u32> = (0..128).map(|_| rng.gen_range_inclusive((1u32 << abits) - 1)).collect();
        let b: Vec<u32> = (0..128).map(|_| rng.gen_range_inclusive((1u32 << bbits) - 1)).collect();
        store_vertical(&mut s, 0, abits, &a);
        let mut st = Stats::default();
        let mut buf_rows = Vec::new();
        for j in 0..bbits {
            let mut word = 0u128;
            for (col, &v) in b.iter().enumerate() {
                word |= (((v >> j) & 1) as u128) << col;
            }
            s.buffer_write(j, word, &mut st, Phase::LoadData);
            buf_rows.push(j);
        }
        let result_base = (abits.div_ceil(8) + 1) * 8;
        let width =
            multiply_columns(&mut s, 0, abits, &buf_rows, result_base, &mut st, Phase::BatchNorm);
        let prods = load_vertical(&s, result_base, width, 128);
        for col in 0..128 {
            assert_eq!(
                prods[col],
                a[col] as u64 * b[col] as u64,
                "case {case} a={abits}b b={bbits}b col={col}"
            );
        }
    }
}

#[test]
fn property_comparison_random_widths() {
    let mut rng = Rng::seed_from_u64(sweep_seed(0xC0));
    for case in 0..40 {
        let bits = rng.gen_usize(1, 11);
        let mut s = sub();
        let a: Vec<u32> = (0..128).map(|_| rng.gen_range_inclusive((1u32 << bits) - 1)).collect();
        let b: Vec<u32> = (0..128).map(|_| rng.gen_range_inclusive((1u32 << bits) - 1)).collect();
        store_vertical(&mut s, 0, bits, &a);
        store_vertical(&mut s, bits, bits, &b);
        let scratch_strip = (2 * bits).div_ceil(8);
        let scratch = CompareScratch {
            tag_row: scratch_strip * 8,
            result_row: scratch_strip * 8 + 1,
            buf_tag: 0,
            buf_diff: 1,
        };
        let mut st = Stats::default();
        let result = compare_columns(&mut s, 0, bits, bits, scratch, &mut st, Phase::Pooling);
        for col in 0..128 {
            assert_eq!(
                (result >> col) & 1 == 1,
                a[col] > b[col],
                "case {case} bits={bits} col={col}: a={} b={}",
                a[col],
                b[col]
            );
        }
    }
}

// ---------------------------------------------------------------------
// Packed-vs-scalar equivalence: the pre-refactor scalar per-column host
// path, re-issued op for op, must agree with the packed implementation
// in outputs AND accumulated Stats.
// ---------------------------------------------------------------------

/// Faithful scalar emulation of the pre-refactor conv stepper: same
/// device ops in the same order (buffer loads per period, AND+count per
/// kernel row, bit-serial drain), but per-column `u32` bookkeeping on
/// the host. Returns (period, out_row, per-column counts).
fn scalar_conv_reference(
    sub: &mut Subarray,
    base: usize,
    geo: ConvGeometry,
    kernel: &BitKernel,
    stats: &mut Stats,
) -> Vec<(usize, usize, Vec<u32>)> {
    let out_h = geo.out_h(kernel.kh);
    let out_w = geo.out_w(kernel.kw);
    let mut used = vec![false; kernel.kw];
    for oc in 0..out_w {
        used[(oc * geo.stride) % kernel.kw] = true;
    }
    let count_bits = 32 - (kernel.kh as u32).leading_zeros();
    let mut results = Vec::new();
    for (p, _) in used.iter().enumerate().filter(|(_, &u)| u) {
        for kr in 0..kernel.kh {
            sub.buffer_write(kr, kernel.tile_row(kr, p, geo.in_w), stats, Phase::Convolution);
        }
        for or in 0..out_h {
            sub.counters.reset();
            let r0 = base + or * geo.stride;
            for kr in 0..kernel.kh {
                sub.and_count(r0 + kr, kr, stats, Phase::Convolution);
            }
            let mut counts = vec![0u32; geo.in_w];
            for bitpos in 0..count_bits {
                let lsbs = sub.counter_lsbs_shift(stats, Phase::Convolution);
                for (j, c) in counts.iter_mut().enumerate() {
                    *c |= (((lsbs >> j) & 1) as u32) << bitpos;
                }
            }
            results.push((p, or, counts));
        }
    }
    results
}

#[test]
fn property_conv_stepper_matches_scalar_reference_bit_and_stats() {
    let mut rng = Rng::seed_from_u64(sweep_seed(0xC077));
    for case in 0..25 {
        // Randomized geometry, including the 128-column boundary.
        let w = [8, 17, 33, 64, 127, 128][rng.gen_usize(0, 6)];
        let h = rng.gen_usize(3, 24);
        let kh = rng.gen_usize(1, h.min(8) + 1);
        let kw = rng.gen_usize(1, w.min(7) + 1);
        let stride = rng.gen_usize(1, 4);
        let geo = ConvGeometry { in_h: h, in_w: w, stride };
        let kernel = BitKernel::new(
            kh,
            kw,
            (0..kh * kw).map(|_| rng.gen_bool()).collect(),
        );
        // Two identical subarrays, same stored bit-plane.
        let mut sa = sub();
        let mut sb = sub();
        let mut st_load = Stats::default();
        for r in 0..h {
            let word = ((rng.next_u64() as u128) << 64 | rng.next_u64() as u128)
                & if w == 128 { u128::MAX } else { (1u128 << w) - 1 };
            sa.write_row(r, word, &mut st_load, Phase::LoadData);
            sb.write_row(r, word, &mut st_load, Phase::LoadData);
        }
        let mut st_packed = Stats::default();
        let mut st_scalar = Stats::default();
        let packed =
            bitplane_conv_counts(&mut sa, 0, geo, &kernel, &mut st_packed, Phase::Convolution);
        let scalar = scalar_conv_reference(&mut sb, 0, geo, &kernel, &mut st_scalar);
        assert_eq!(
            st_packed, st_scalar,
            "case {case}: device-op stream diverged ({h}x{w} k{kh}x{kw} s{stride})"
        );
        assert_eq!(packed.len(), scalar.len(), "case {case}");
        for (pc, (p, or, counts)) in packed.iter().zip(&scalar) {
            assert_eq!((pc.period, pc.out_row), (*p, *or), "case {case}");
            assert_eq!(&pc.counts(), counts, "case {case} p={p} or={or}");
        }
        // The window fold agrees with the scalar fold of scalar counts.
        let out_w = geo.out_w(kw);
        let out_h = geo.out_h(kh);
        let mut expect = vec![vec![0u32; out_w]; out_h];
        for (p, or, counts) in &scalar {
            for oc in 0..out_w {
                let c0 = oc * stride;
                if c0 % kw != *p {
                    continue;
                }
                expect[*or][oc] = (0..kw).map(|kc| counts[c0 + kc]).sum();
            }
        }
        assert_eq!(window_sums(&packed, geo, &kernel), expect, "case {case}");
    }
}

/// Scalar emulation of the pre-refactor addition: identical op stream,
/// per-column `u32` counters on the host, each drained LSB word
/// cross-checked against the packed counter bank's.
fn scalar_add_reference(
    sub: &mut Subarray,
    operand_bases: &[usize],
    bits: usize,
    result_base: usize,
    cols: usize,
    stats: &mut Stats,
) -> usize {
    sub.counters.reset();
    assert_eq!(result_base % 8, 0);
    let width = add_result_width(operand_bases.len(), bits);
    let first = result_base / 8;
    for s in first..first + width.div_ceil(8) {
        sub.erase_strip(s, stats, Phase::Pooling);
    }
    let mut scalar = vec![0u32; cols];
    let mut written = 0;
    fn drain(sub: &mut Subarray, scalar: &mut [u32], stats: &mut Stats) -> u128 {
        let mut expect = 0u128;
        for (col, c) in scalar.iter_mut().enumerate() {
            expect |= ((*c & 1) as u128) << col;
            *c >>= 1;
        }
        let lsb = sub.counter_lsbs_shift(stats, Phase::Pooling);
        assert_eq!(lsb, expect, "packed counter bank diverged from scalar counters");
        lsb
    }
    for b in 0..bits {
        for &base in operand_bases {
            let row = sub.peek_row(base + b);
            for (col, c) in scalar.iter_mut().enumerate() {
                *c += ((row >> col) & 1) as u32;
            }
            sub.read_count(base + b, stats, Phase::Pooling);
        }
        let lsb = drain(sub, &mut scalar, stats);
        let row = result_base + written;
        sub.program_row(row / 8, row % 8, lsb, stats, Phase::Pooling);
        written += 1;
    }
    while scalar.iter().any(|&c| c != 0) {
        let lsb = drain(sub, &mut scalar, stats);
        let row = result_base + written;
        sub.program_row(row / 8, row % 8, lsb, stats, Phase::Pooling);
        written += 1;
    }
    assert!(sub.counters.is_zero(), "bank must drain exactly when scalar drains");
    written
}

#[test]
fn property_addition_matches_scalar_reference_bit_and_stats() {
    // Randomized widths (incl. the 128-column boundary and narrow
    // subarrays) and non-strip-aligned operand bases.
    let mut rng = Rng::seed_from_u64(sweep_seed(0xADD2));
    for case in 0..20 {
        let cols = [8, 23, 64, 127, 128][rng.gen_usize(0, 5)];
        let k = rng.gen_usize(2, 7);
        let bits = rng.gen_usize(1, 8);
        // Operands packed back to back from a random, possibly
        // non-strip-aligned starting row.
        let start = rng.gen_usize(0, 5);
        let bases: Vec<usize> = (0..k).map(|i| start + i * bits).collect();
        let result_base = ((start + k * bits).div_ceil(8) + 1) * 8;

        let mut sa = Subarray::new(256, cols, 16, DeviceCosts::default());
        let mut sb = Subarray::new(256, cols, 16, DeviceCosts::default());
        let mut st_load = Stats::default();
        let mut operands: Vec<Vec<u32>> = Vec::new();
        for &base in &bases {
            let vals: Vec<u32> =
                (0..cols).map(|_| rng.gen_range_inclusive((1u32 << bits) - 1)).collect();
            for b in 0..bits {
                let mut row = 0u128;
                for (col, &v) in vals.iter().enumerate() {
                    row |= (((v >> b) & 1) as u128) << col;
                }
                sa.write_row(base + b, row, &mut st_load, Phase::LoadData);
                sb.write_row(base + b, row, &mut st_load, Phase::LoadData);
            }
            operands.push(vals);
        }

        let mut st_packed = Stats::default();
        let mut st_scalar = Stats::default();
        let w_packed =
            add_columns(&mut sa, &bases, bits, result_base, &mut st_packed, Phase::Pooling);
        let w_scalar =
            scalar_add_reference(&mut sb, &bases, bits, result_base, cols, &mut st_scalar);
        assert_eq!(w_packed, w_scalar, "case {case}");
        assert_eq!(st_packed, st_scalar, "case {case}: Stats diverged");
        // Same rows programmed, same sums read back.
        for b in 0..w_packed {
            assert_eq!(
                sa.peek_row(result_base + b),
                sb.peek_row(result_base + b),
                "case {case} row {b}"
            );
        }
        let sums = load_vertical(&sa, result_base, w_packed, cols);
        for col in 0..cols {
            let expect: u64 = operands.iter().map(|o| o[col] as u64).sum();
            assert_eq!(sums[col], expect, "case {case} col {col}");
        }
    }
}

/// Scalar emulation of the pre-refactor multiplication inner loop:
/// identical op stream, per-column scalar counters.
fn scalar_multiply_reference(
    sub: &mut Subarray,
    a_base: usize,
    a_bits: usize,
    b_buf_rows: &[usize],
    result_base: usize,
    cols: usize,
    stats: &mut Stats,
) -> usize {
    let b_bits = b_buf_rows.len();
    sub.counters.reset();
    assert_eq!(result_base % 8, 0);
    let width = a_bits + b_bits + 1;
    for s in result_base / 8..result_base / 8 + width.div_ceil(8) {
        sub.erase_strip(s, stats, Phase::BatchNorm);
    }
    let mut scalar = vec![0u32; cols];
    let mut written = 0;
    for p in 0..a_bits + b_bits {
        for i in 0..a_bits {
            let Some(j) = p.checked_sub(i) else { continue };
            if j >= b_bits {
                continue;
            }
            let partial = sub.peek_row(a_base + i) & sub.buffer.read(b_buf_rows[j]);
            for (col, c) in scalar.iter_mut().enumerate() {
                *c += ((partial >> col) & 1) as u32;
            }
            sub.and_count(a_base + i, b_buf_rows[j], stats, Phase::BatchNorm);
        }
        let mut expect = 0u128;
        for (col, c) in scalar.iter_mut().enumerate() {
            expect |= ((*c & 1) as u128) << col;
            *c >>= 1;
        }
        let lsb = sub.counter_lsbs_shift(stats, Phase::BatchNorm);
        assert_eq!(lsb, expect, "packed bank diverged in multiply");
        let row = result_base + written;
        sub.program_row(row / 8, row % 8, lsb, stats, Phase::BatchNorm);
        written += 1;
    }
    while scalar.iter().any(|&c| c != 0) {
        let mut expect = 0u128;
        for (col, c) in scalar.iter_mut().enumerate() {
            expect |= ((*c & 1) as u128) << col;
            *c >>= 1;
        }
        let lsb = sub.counter_lsbs_shift(stats, Phase::BatchNorm);
        assert_eq!(lsb, expect);
        let row = result_base + written;
        sub.program_row(row / 8, row % 8, lsb, stats, Phase::BatchNorm);
        written += 1;
    }
    assert!(sub.counters.is_zero());
    written
}

#[test]
fn property_multiplication_matches_scalar_reference_bit_and_stats() {
    let mut rng = Rng::seed_from_u64(sweep_seed(0x3012));
    for case in 0..15 {
        let cols = [16, 64, 128][rng.gen_usize(0, 3)];
        let abits = rng.gen_usize(1, 7);
        let bbits = rng.gen_usize(1, 7);
        // Non-strip-aligned A operand.
        let a_base = rng.gen_usize(0, 6);
        let result_base = ((a_base + abits).div_ceil(8) + 1) * 8;
        let mut sa = Subarray::new(256, cols, 16, DeviceCosts::default());
        let mut sb = Subarray::new(256, cols, 16, DeviceCosts::default());
        let mut st_load = Stats::default();
        let a: Vec<u32> =
            (0..cols).map(|_| rng.gen_range_inclusive((1u32 << abits) - 1)).collect();
        for b in 0..abits {
            let mut row = 0u128;
            for (col, &v) in a.iter().enumerate() {
                row |= (((v >> b) & 1) as u128) << col;
            }
            sa.write_row(a_base + b, row, &mut st_load, Phase::LoadData);
            sb.write_row(a_base + b, row, &mut st_load, Phase::LoadData);
        }
        let bvals: Vec<u32> =
            (0..cols).map(|_| rng.gen_range_inclusive((1u32 << bbits) - 1)).collect();
        let mut buf_rows = Vec::new();
        for j in 0..bbits {
            let mut word = 0u128;
            for (col, &v) in bvals.iter().enumerate() {
                word |= (((v >> j) & 1) as u128) << col;
            }
            sa.buffer_write(j, word, &mut st_load, Phase::LoadData);
            sb.buffer_write(j, word, &mut st_load, Phase::LoadData);
            buf_rows.push(j);
        }
        let mut st_packed = Stats::default();
        let mut st_scalar = Stats::default();
        let w_packed = multiply_columns(
            &mut sa,
            a_base,
            abits,
            &buf_rows,
            result_base,
            &mut st_packed,
            Phase::BatchNorm,
        );
        let w_scalar = scalar_multiply_reference(
            &mut sb,
            a_base,
            abits,
            &buf_rows,
            result_base,
            cols,
            &mut st_scalar,
        );
        assert_eq!(w_packed, w_scalar, "case {case}");
        assert_eq!(st_packed, st_scalar, "case {case}: Stats diverged");
        let prods = load_vertical(&sa, result_base, w_packed, cols);
        for col in 0..cols {
            assert_eq!(
                prods[col],
                a[col] as u64 * bvals[col] as u64,
                "case {case} col {col}"
            );
        }
    }
}

#[test]
fn property_unipolar_program_only_sets_bits() {
    let mut rng = Rng::seed_from_u64(sweep_seed(0x11));
    for _ in 0..50 {
        let mut s = sub();
        let mut st = Stats::default();
        let strip = rng.gen_usize(0, 32);
        let pos = rng.gen_usize(0, 8);
        let p1 = (rng.next_u64() as u128) << 64 | rng.next_u64() as u128;
        let p2 = (rng.next_u64() as u128) << 64 | rng.next_u64() as u128;
        s.program_row(strip, pos, p1, &mut st, Phase::LoadData);
        s.program_row(strip, pos, p2, &mut st, Phase::LoadData);
        assert_eq!(s.peek_row(strip * 8 + pos), p1 | p2, "program must OR");
        s.erase_strip(strip, &mut st, Phase::LoadData);
        assert_eq!(s.peek_row(strip * 8 + pos), 0);
    }
}

#[test]
fn property_stats_are_monotone_nonnegative() {
    // Any op sequence only grows stats; energies/latencies stay finite.
    let mut rng = Rng::seed_from_u64(sweep_seed(0x57));
    let mut s = sub();
    let mut st = Stats::default();
    let mut last_e = 0.0;
    let mut last_t = 0.0;
    for _ in 0..500 {
        match rng.gen_usize(0, 4) {
            0 => s.erase_strip(rng.gen_usize(0, 32), &mut st, Phase::LoadData),
            1 => {
                let strip = rng.gen_usize(0, 32);
                let pos = rng.gen_usize(0, 8);
                s.program_row(strip, pos, rng.next_u64() as u128, &mut st, Phase::LoadData)
            }
            2 => {
                s.read_row(rng.gen_usize(0, 256), &mut st, Phase::Other);
            }
            _ => {
                let _ = s.and_row(
                    rng.gen_usize(0, 256),
                    rng.next_u64() as u128,
                    &mut st,
                    Phase::Convolution,
                );
            }
        }
        let e = st.total_energy_fj();
        let t = st.total_latency_ns();
        assert!(e.is_finite() && t.is_finite());
        assert!(e >= last_e && t >= last_t, "stats must be monotone");
        last_e = e;
        last_t = t;
    }
}

// ====================================================================
// Multi-tile mapping (§4.2, Fig. 9): axis/plan geometry and
// tiled-vs-untiled bit-identity with the documented halo overhead.
// ====================================================================

#[test]
fn property_tile_plan_axis_geometry() {
    // Random (len, k, stride, cap) axis decompositions: every invariant
    // `plan_axis` documents, checked by enumeration.
    let mut rng = Rng::seed_from_u64(sweep_seed(0x7117));
    for case in 0..500 {
        let len = rng.gen_usize(1, 300);
        let k = rng.gen_usize(1, 14);
        let stride = rng.gen_usize(1, 7);
        let cap = rng.gen_usize(4, 160);
        let ol = if len >= k { (len - k) / stride + 1 } else { 0 };
        let Some(tiles) = plan_axis(len, k, stride, cap) else {
            assert!(ol > 0 && k > cap, "case {case}: None only for an oversized window");
            continue;
        };
        let mut next_out = 0usize;
        for (i, t) in tiles.iter().enumerate() {
            assert_eq!(t.out0, next_out, "case {case} tile {i}: outputs owned in order");
            next_out += t.out_n;
            assert_eq!(t.in0, t.out0 * stride, "case {case} tile {i}: slab origin");
            assert!(t.in_n <= cap, "case {case} tile {i}: slab exceeds capacity");
            assert!(t.in0 + t.in_n <= len, "case {case} tile {i}: slab exceeds input");
            assert!(t.halo <= t.in_n, "case {case} tile {i}: halo exceeds slab");
            if t.out_n > 0 {
                assert!(
                    (t.out0 + t.out_n - 1) * stride + k <= t.in0 + t.in_n,
                    "case {case} tile {i}: last owned window must fit inside the slab"
                );
            }
            if i == 0 {
                assert_eq!(t.halo, 0, "case {case}: first tile has no halo");
            } else {
                // The predecessor is always a full tile, so the overlap
                // is exactly the window carry-over.
                assert_eq!(
                    t.halo,
                    k.saturating_sub(stride),
                    "case {case} tile {i}: halo must be max(0, k − stride)"
                );
                let prev = &tiles[i - 1];
                assert_eq!(
                    (prev.in0 + prev.in_n).saturating_sub(t.in0),
                    t.halo,
                    "case {case} tile {i}: halo is the overlap with the previous slab"
                );
            }
        }
        assert_eq!(next_out, ol, "case {case}: every output owned exactly once");
        // Fresh loads count exactly the union of the slabs; when the
        // windows tile the axis (stride ≤ k, no tail remainder) that
        // union is the whole axis — the tiled run then loads exactly
        // the same fresh traffic as an untiled one.
        let fresh: usize = tiles.iter().map(AxisTile::fresh).sum();
        let mut union = 0usize;
        let mut covered_to = 0usize;
        for t in &tiles {
            let end = t.in0 + t.in_n;
            union += end.saturating_sub(t.in0.max(covered_to));
            covered_to = covered_to.max(end);
        }
        assert_eq!(fresh, union, "case {case}: fresh elements must partition the covered input");
        if ol > 0 && stride <= k && (len - k) % stride == 0 {
            assert_eq!(fresh, len, "case {case}: fresh loads must cover the axis exactly");
        }
    }
}

#[test]
fn property_tile_plan_counts_agree_with_analytic_mapping() {
    // The enumerated TilePlan (what the functional engine executes) and
    // the counting view (Tiling / ConvMapping, what the analytic model
    // charges) must agree for any geometry and subarray size.
    let mut rng = Rng::seed_from_u64(sweep_seed(0x2D71));
    for case in 0..300 {
        let mut cfg = ArchConfig::paper();
        cfg.rows = 8 * rng.gen_usize(4, 33); // 32..=256
        cfg.cols = rng.gen_usize(16, 129); // 16..=128
        let h = rng.gen_usize(1, 300);
        let w = rng.gen_usize(1, 300);
        let kh = rng.gen_usize(1, 8);
        let kw = rng.gen_usize(1, 8);
        let stride = rng.gen_usize(1, 5);
        let t = Tiling::of(h, w, kh, kw, stride, &cfg);
        let p = TilePlan::new(h, w, kh, kw, stride, cfg.rows, cfg.cols)
            .expect("window fits the subarray for these ranges");
        assert_eq!((t.tiles_h, t.tiles_w), (p.tiles_h, p.tiles_w), "case {case}: tile counts");
        assert_eq!(t.count(), p.count(), "case {case}");
        assert_eq!(p.count(), p.tiles_h * p.tiles_w, "case {case}: full grid enumerated");
        // Output rectangles partition the output exactly once.
        let oh = if h >= kh { (h - kh) / stride + 1 } else { 0 };
        let ow = if w >= kw { (w - kw) / stride + 1 } else { 0 };
        let owned: usize = p.tiles.iter().map(|e| e.out_w * e.out_h).sum();
        assert_eq!(owned, oh * ow, "case {case}: outputs owned exactly once");
        // halo_elems is consistent with the per-tile extents.
        let halo: usize = p
            .tiles
            .iter()
            .map(|e| e.in_w * e.in_h - (e.in_w - e.halo_w) * (e.in_h - e.halo_h))
            .sum();
        assert_eq!(halo, p.halo_elems(), "case {case}: plan-level halo roll-up");
        // The analytic conv mapping counts the same tiles.
        let in_c = rng.gen_usize(1, 5);
        let ibits = rng.gen_usize(1, 9) as u8;
        let out_c = rng.gen_usize(1, 65);
        let avail = rng.gen_usize(1, 4097);
        let m = ConvMapping::plan(&cfg, (in_c, h, w), out_c, kh, kw, stride, ibits, avail);
        assert_eq!(
            m.plane_units,
            (in_c * ibits as usize * t.count()).max(1),
            "case {case}: plane units follow the enumerated tiling"
        );
        assert_eq!(m.active_units(), m.plane_units * m.replication, "case {case}");
        assert!(m.replication >= 1 && m.replication <= out_c.max(1), "case {case}");
        assert!(m.serial_filters * m.replication >= out_c, "case {case}");
    }
}

/// Run `net` on a fresh paper-config engine, optionally forcing the
/// conv tile planner down to `tile_cap = (rows, cols)`.
fn engine_run(
    net: &Network,
    params: &ModelParams,
    input: &QTensor,
    tile_cap: Option<(usize, usize)>,
) -> (Vec<WideTensor>, Stats) {
    let mut eng = FunctionalEngine::new(ArchConfig::paper());
    if let Some((r, c)) = tile_cap {
        eng.force_tile_capacity(r, c);
    }
    let outs = eng.run(net, params, input);
    (outs, eng.stats)
}

#[test]
fn property_tiled_conv_bit_identical_with_documented_overhead() {
    // Random single-conv networks whose shapes straddle a forced tile
    // boundary. Shapes are constrained so the fresh regions of any
    // tiling partition the input exactly ((len − k) divisible by the
    // stride, stride ≤ k, on both axes): the tiled run then moves the
    // same fresh/weight/output traffic as the untiled one, and the only
    // bus-level difference is the documented halo re-send of
    // in_c · ibits · halo_elems() local-bus bits per conv layer.
    let mut rng = Rng::seed_from_u64(sweep_seed(0x7145));
    for case in 0..10u64 {
        let stride = rng.gen_usize(1, 3);
        let kh = stride + rng.gen_usize(0, 3);
        let kw = stride + rng.gen_usize(0, 3);
        let oh = rng.gen_usize(2, 7);
        let ow = rng.gen_usize(3, 10);
        let (h, w) = (kh + (oh - 1) * stride, kw + (ow - 1) * stride);
        let c = rng.gen_usize(1, 3);
        let out_c = rng.gen_usize(1, 4);
        let ibits = rng.gen_usize(1, 4) as u8;
        let wbits = rng.gen_usize(1, 4) as u8;
        // Capacities stay inside the force_tile_capacity clamp range and
        // always force ≥ 2 width tiles (cols_cap admits at most two
        // output columns per tile; ow ≥ 3).
        let rows_cap = (kh + stride * rng.gen_usize(0, 6)).max(8);
        let cols_cap = kw + stride * rng.gen_usize(0, 2);
        let net = Network {
            name: format!("TiledProp{case}"),
            input: (c, h, w),
            input_bits: ibits,
            nodes: vec![Node {
                layer: Layer::Conv { out_c, kh, kw, stride, pad: 0 },
                input: None,
            }],
        };
        let params = ModelParams::random(&net, wbits, 0xBEEF + case);
        let input = QTensor::random(c, h, w, ibits, 0xF00D + case);
        let golden = ref_exec::execute(&net, &params, &input);

        let (u_out, u_st) = engine_run(&net, &params, &input, None);
        let (t_out, t_st) = engine_run(&net, &params, &input, Some((rows_cap, cols_cap)));
        let plan = TilePlan::new(h, w, kh, kw, stride, rows_cap, cols_cap).expect("window fits");
        assert!(plan.count() >= 2, "case {case}: capacity override must force tiling");

        let ctx = format!(
            "case {case}: c={c} {h}x{w} k={kh}x{kw} s={stride} oc={out_c} \
             i{ibits} w{wbits} cap={rows_cap}x{cols_cap} tiles={}",
            plan.count()
        );
        assert_eq!(u_out, golden, "{ctx}: untiled vs golden");
        assert_eq!(t_out, golden, "{ctx}: tiled output must be bit-identical");

        // Documented overhead accounting (ARCHITECTURE.md): global
        // traffic (fresh loads + weight stream) is unchanged, local
        // traffic grows by exactly the halo re-send, the accumulator
        // read stream is tiling-independent, and the extra device work
        // is fused AND+count pairs plus slab (re)writes.
        let (uo, to) = (&u_st.ops, &t_st.ops);
        assert_eq!(to.global_bus_bits, uo.global_bus_bits, "{ctx}: global traffic");
        let halo_bits = (c * ibits as usize * plan.halo_elems()) as u64;
        assert_eq!(to.local_bus_bits, uo.local_bus_bits + halo_bits, "{ctx}: halo re-send");
        assert_eq!(to.reads, uo.reads, "{ctx}: accumulator stream tiling-independent");
        let d_ands = to.ands.checked_sub(uo.ands).expect("tiled AND stream is a superset");
        let d_counts =
            to.bitcounts.checked_sub(uo.bitcounts).expect("tiled count stream is a superset");
        assert_eq!(d_ands, d_counts, "{ctx}: extra conv steps are fused AND+count pairs");
        assert!(to.erases >= uo.erases, "{ctx}: slab erases");
        assert!(to.program_steps >= uo.program_steps, "{ctx}: slab programs");
        assert!(to.buffer_accesses >= uo.buffer_accesses, "{ctx}: weight broadcasts");
        assert!(t_st.total_energy_fj() >= u_st.total_energy_fj(), "{ctx}: energy");
        assert!(t_st.total_latency_ns() >= u_st.total_latency_ns(), "{ctx}: latency");
    }
}

#[test]
fn property_multilayer_tiled_network_matches_untiled() {
    // Whole-network version of the equivalence property: every layer of
    // small_cnn behind forcibly tiled convs still produces bit-identical
    // node outputs, and the bus overhead is exactly the per-conv halo
    // formula (conv1: 2ch × 3b over 14×22; conv2: 4ch × 3b over 6×10 —
    // both stride 1, pad 0, so fresh loads are tiling-invariant).
    let net = small_cnn(3);
    let params = ModelParams::random(&net, 3, 0x5EED);
    let input = QTensor::random(2, 14, 22, 3, 0x5EED + 1);
    let golden = ref_exec::execute(&net, &params, &input);

    let (u_out, u_st) = engine_run(&net, &params, &input, None);
    let (t_out, t_st) = engine_run(&net, &params, &input, Some((8, 7)));
    for (i, (a, b)) in u_out.iter().zip(&golden).enumerate() {
        assert_eq!(a, b, "untiled node {i} vs golden");
    }
    for (i, (a, b)) in t_out.iter().zip(&golden).enumerate() {
        assert_eq!(a, b, "tiled node {i} must be bit-identical");
    }

    let p1 = TilePlan::new(14, 22, 3, 3, 1, 8, 7).expect("conv1 plan");
    let p2 = TilePlan::new(6, 10, 3, 3, 1, 8, 7).expect("conv2 plan");
    assert!(p1.count() > 1 && p2.count() > 1, "both convs must actually tile");
    let halo_bits = (2 * 3 * p1.halo_elems() + 4 * 3 * p2.halo_elems()) as u64;
    assert_eq!(t_st.ops.global_bus_bits, u_st.ops.global_bus_bits);
    assert_eq!(t_st.ops.local_bus_bits, u_st.ops.local_bus_bits + halo_bits);
    assert_eq!(t_st.ops.reads, u_st.ops.reads);
}

// ====================================================================
// Intra-request parallelism: the per-filter fan-out must be
// bit-identical — outputs AND Stats — at any worker count, with and
// without the 1×1 fast path.
// ====================================================================

/// Run `net` on a fresh paper-config engine with an explicit
/// intra-request worker budget, optionally forcing the tile planner
/// down and/or the 1×1 conv layers onto the generic stepper.
fn engine_run_workers(
    net: &Network,
    params: &ModelParams,
    input: &QTensor,
    tile_cap: Option<(usize, usize)>,
    workers: usize,
    fast_paths: bool,
) -> (Vec<WideTensor>, Stats) {
    let mut eng = FunctionalEngine::new(ArchConfig::paper());
    if let Some((r, c)) = tile_cap {
        eng.force_tile_capacity(r, c);
    }
    eng.set_host_workers(workers);
    if !fast_paths {
        eng.disable_fast_paths();
    }
    let outs = eng.run(net, params, input);
    (outs, eng.stats)
}

#[test]
fn property_intra_request_fanout_bit_identical_across_worker_counts() {
    // Randomized single-conv networks (varied kernel/stride/padding)
    // behind a forced tile boundary: workers ∈ {1, 2, 7} must agree
    // bit-for-bit on the output AND on every Stats field — the ledger
    // merge replays the sequential charge order exactly.
    let mut rng = Rng::seed_from_u64(sweep_seed(0xFA17));
    for case in 0..8u64 {
        let stride = rng.gen_usize(1, 3);
        let kh = stride + rng.gen_usize(0, 3);
        let kw = stride + rng.gen_usize(0, 3);
        let pad = rng.gen_usize(0, 2);
        let h = rng.gen_usize(kh.max(4), 13);
        let w = rng.gen_usize(kw.max(6), 19);
        let c = rng.gen_usize(1, 3);
        let out_c = rng.gen_usize(2, 6);
        let ibits = rng.gen_usize(1, 4) as u8;
        let wbits = rng.gen_usize(1, 4) as u8;
        let rows_cap = (kh + stride * rng.gen_usize(0, 5)).max(8);
        let cols_cap = kw + stride * rng.gen_usize(0, 2);
        let net = Network {
            name: format!("FanoutProp{case}"),
            input: (c, h, w),
            input_bits: ibits,
            nodes: vec![Node {
                layer: Layer::Conv { out_c, kh, kw, stride, pad },
                input: None,
            }],
        };
        let params = ModelParams::random(&net, wbits, 0xFA20 + case);
        let input = QTensor::random(c, h, w, ibits, 0xFA30 + case);
        let golden = ref_exec::execute(&net, &params, &input);
        let cap = Some((rows_cap, cols_cap));
        let ctx = format!(
            "case {case}: c={c} {h}x{w} k={kh}x{kw} s={stride} p={pad} oc={out_c} \
             i{ibits} w{wbits} cap={rows_cap}x{cols_cap}"
        );
        let (base_out, base_st) = engine_run_workers(&net, &params, &input, cap, 1, true);
        assert_eq!(base_out, golden, "{ctx}: workers=1 vs golden");
        for workers in [2usize, 7] {
            let (out, st) = engine_run_workers(&net, &params, &input, cap, workers, true);
            assert_eq!(out, base_out, "{ctx}: workers={workers} outputs");
            assert_eq!(st, base_st, "{ctx}: workers={workers} Stats");
        }
    }
}

#[test]
fn property_intra_request_fanout_whole_network_invariant() {
    // Whole-network version: every small_cnn node output and the full
    // Stats account are worker-count invariant even with the convs
    // forcibly tiled (same capacities as the tiled-equivalence test).
    let net = small_cnn(3);
    let params = ModelParams::random(&net, 3, 0x90D);
    let input = QTensor::random(2, 14, 22, 3, 0x90E);
    let golden = ref_exec::execute(&net, &params, &input);
    let cap = Some((8, 7));
    let (base_out, base_st) = engine_run_workers(&net, &params, &input, cap, 1, true);
    for (i, (a, b)) in base_out.iter().zip(&golden).enumerate() {
        assert_eq!(a, b, "workers=1 node {i} vs golden");
    }
    for workers in [2usize, 7] {
        let (out, st) = engine_run_workers(&net, &params, &input, cap, workers, true);
        assert_eq!(out, base_out, "workers={workers}: outputs");
        assert_eq!(st, base_st, "workers={workers}: Stats");
    }
}

#[test]
fn property_1x1_fast_path_matches_generic_bit_and_stats() {
    // Randomized 1×1 stride-1 convs (the pointwise layers the fast path
    // targets), with and without padding and forced width tiling: the
    // flat-buffer fast path must agree with the generic tiled stepper
    // bit-for-bit on outputs AND Stats, at every worker count.
    let mut rng = Rng::seed_from_u64(sweep_seed(0x1B17));
    for case in 0..6u64 {
        let c = rng.gen_usize(1, 4);
        let out_c = rng.gen_usize(2, 7);
        let h = rng.gen_usize(3, 10);
        let w = rng.gen_usize(4, 14);
        let pad = rng.gen_usize(0, 2);
        let ibits = rng.gen_usize(1, 5) as u8;
        let wbits = rng.gen_usize(1, 5) as u8;
        let cols_cap = rng.gen_usize(2, 6);
        let net = Network {
            name: format!("PointwiseProp{case}"),
            input: (c, h, w),
            input_bits: ibits,
            nodes: vec![Node {
                layer: Layer::Conv { out_c, kh: 1, kw: 1, stride: 1, pad },
                input: None,
            }],
        };
        let params = ModelParams::random(&net, wbits, 0x1B20 + case);
        let input = QTensor::random(c, h, w, ibits, 0x1B30 + case);
        let golden = ref_exec::execute(&net, &params, &input);
        let cap = Some((8, cols_cap));
        let ctx = format!(
            "case {case}: c={c} {h}x{w} p={pad} oc={out_c} i{ibits} w{wbits} cap=8x{cols_cap}"
        );
        let (g_out, g_st) = engine_run_workers(&net, &params, &input, cap, 1, false);
        assert_eq!(g_out, golden, "{ctx}: generic vs golden");
        for workers in [1usize, 2, 7] {
            let (f_out, f_st) = engine_run_workers(&net, &params, &input, cap, workers, true);
            assert_eq!(f_out, golden, "{ctx}: fast path workers={workers} outputs");
            assert_eq!(f_st, g_st, "{ctx}: fast path workers={workers} Stats");
        }
    }
}

// ====================================================================
// Cost-aware shard router: invariants over randomized heterogeneous
// pools.
// ====================================================================

/// Uniform f64 in [0, 1) from the hand-rolled generator (the standard
/// 53-mantissa-bit u64 → f64 construction).
fn gen_f64(rng: &mut Rng) -> f64 {
    (rng.next_u64() >> 11) as f64 / (1u64 << 53) as f64
}

/// Random per-(chip, net) `(cold, warm)` cost rows with bounded skew:
/// warm in [100, 200) ns, cold = warm × [1, 2). Bounded skew means a
/// singleton batch costs under 400 ns anywhere while every routed
/// batch advances its chip's backlog by at least 100 ns — that ratio
/// is what makes the no-starvation property below provable rather
/// than probabilistic.
fn random_cost_rows(rng: &mut Rng, chips: usize, nets: usize) -> Vec<Vec<(f64, f64)>> {
    (0..chips)
        .map(|_| {
            (0..nets)
                .map(|_| {
                    let warm = 100.0 + 100.0 * gen_f64(rng);
                    (warm * (1.0 + gen_f64(rng)), warm)
                })
                .collect()
        })
        .collect()
}

#[test]
fn property_router_assignment_is_deterministic() {
    // Same cost table + same batch sequence → bit-identical chip
    // assignment, whatever the pool shape.
    let mut rng = Rng::seed_from_u64(sweep_seed(0x2077E));
    for case in 0..20 {
        let chips = rng.gen_usize(1, 7);
        let nets = rng.gen_usize(1, 5);
        let rows = random_cost_rows(&mut rng, chips, nets);
        let batches: Vec<(usize, usize)> =
            (0..48).map(|_| (rng.gen_usize(0, nets), rng.gen_usize(1, 9))).collect();
        let run = || {
            let mut router = ShardRouter::new(CostTable::new(rows.clone()));
            batches.iter().map(|&(net, n)| router.route(net, n)).collect::<Vec<usize>>()
        };
        assert_eq!(run(), run(), "case {case} chips={chips} nets={nets}");
    }
}

#[test]
fn property_router_routes_every_batch_exactly_once() {
    let mut rng = Rng::seed_from_u64(sweep_seed(0x207702));
    for case in 0..20 {
        let chips = rng.gen_usize(1, 7);
        let nets = rng.gen_usize(1, 5);
        let mut router =
            ShardRouter::new(CostTable::new(random_cost_rows(&mut rng, chips, nets)));
        let total = 64usize;
        for i in 0..total {
            let chip = router.route(rng.gen_usize(0, nets), rng.gen_usize(1, 9));
            assert!(chip < chips, "case {case}: chip {chip} out of range");
            let routed: u64 = (0..chips).map(|c| router.routed_batches(c)).sum();
            assert_eq!(routed, i as u64 + 1, "case {case}: every batch lands exactly once");
        }
        // Backlog accrues exactly on the chips that were routed to.
        for c in 0..chips {
            assert_eq!(
                router.routed_batches(c) == 0,
                router.est_busy_ns(c) == 0.0,
                "case {case} chip {c}: backlog iff routed"
            );
        }
    }
}

#[test]
fn property_router_starves_no_chip_under_bounded_skew() {
    // With warm in [100, 200) and cold < 2 · warm, a singleton batch
    // costs < 400 ns anywhere while every routed batch advances its
    // chip's backlog by ≥ 100 ns — so an idle chip becomes the
    // earliest-finish choice after at most 4 routes to any other chip.
    // Over 64 singleton batches, every chip of a ≤ 6-chip pool serves.
    let mut rng = Rng::seed_from_u64(sweep_seed(0x57A12E));
    for case in 0..20 {
        let chips = rng.gen_usize(2, 7);
        let nets = rng.gen_usize(1, 4);
        let mut router =
            ShardRouter::new(CostTable::new(random_cost_rows(&mut rng, chips, nets)));
        for _ in 0..64 {
            router.route(rng.gen_usize(0, nets), 1);
        }
        for c in 0..chips {
            assert!(
                router.routed_batches(c) > 0,
                "case {case}: chip {c} starved in a {chips}-chip pool"
            );
        }
    }
}

#[test]
fn property_router_with_identical_chips_is_least_loaded() {
    // cold == warm kills the residency asymmetry and identical rows
    // kill the heterogeneity: earliest-finish must then degenerate to
    // the classic least-loaded assignment with lowest-index tie-break,
    // replayed here as an inline reference model. Integer costs keep
    // every sum exact, so the comparison is bit-for-bit.
    let mut rng = Rng::seed_from_u64(sweep_seed(0x1EA57));
    for case in 0..20 {
        let chips = rng.gen_usize(1, 7);
        let cost = rng.gen_usize(1, 11) as f64;
        let mut router = ShardRouter::new(CostTable::new(vec![vec![(cost, cost)]; chips]));
        let mut busy = vec![0.0f64; chips];
        for i in 0..48 {
            let n = rng.gen_usize(1, 9);
            let expect = (0..chips)
                .map(|c| (c, busy[c]))
                .min_by(|a, b| a.1.total_cmp(&b.1).then(a.0.cmp(&b.0)))
                .map(|(c, _)| c)
                .expect("at least one chip");
            let got = router.route(0, n);
            assert_eq!(got, expect, "case {case} batch {i}: least-loaded chip");
            busy[expect] += n as f64 * cost;
            assert_eq!(router.est_busy_ns(expect), busy[expect], "case {case} batch {i}");
        }
    }
}
