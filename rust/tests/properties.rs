//! Property-style sweeps over the in-memory primitives and coordinator
//! invariants (hand-rolled generator: the build is offline, so proptest
//! is replaced by seeded random sweeps with shrink-friendly reporting).

use nandspin::arch::stats::{Phase, Stats};
use nandspin::device::energy::DeviceCosts;
use nandspin::subarray::primitives::{
    add_columns, compare_columns, multiply_columns, CompareScratch,
};
use nandspin::subarray::Subarray;
use nandspin::util::Rng;

fn sub() -> Subarray {
    Subarray::new(256, 128, 16, DeviceCosts::default())
}

fn store_vertical(s: &mut Subarray, base: usize, bits: usize, vals: &[u32]) {
    let mut st = Stats::default();
    for b in 0..bits {
        let mut row = 0u128;
        for (col, &v) in vals.iter().enumerate() {
            row |= (((v >> b) & 1) as u128) << col;
        }
        s.write_row(base + b, row, &mut st, Phase::LoadData);
    }
}

fn load_vertical(s: &Subarray, base: usize, bits: usize, cols: usize) -> Vec<u64> {
    (0..cols)
        .map(|col| {
            (0..bits).fold(0u64, |acc, b| {
                acc | ((((s.peek_row(base + b) >> col) & 1) as u64) << b)
            })
        })
        .collect()
}

#[test]
fn property_addition_random_operand_sets() {
    // 60 random cases: k operands of b bits each, all 128 columns.
    let mut rng = Rng::seed_from_u64(0xADD);
    for case in 0..60 {
        let k = rng.gen_usize(2, 9);
        let bits = rng.gen_usize(1, 9);
        let mut s = sub();
        let mut operands = Vec::new();
        for i in 0..k {
            let vals: Vec<u32> =
                (0..128).map(|_| rng.gen_range_inclusive((1u32 << bits) - 1)).collect();
            store_vertical(&mut s, i * bits, bits, &vals);
            operands.push(vals);
        }
        let mut st = Stats::default();
        let bases: Vec<usize> = (0..k).map(|i| i * bits).collect();
        let result_base = ((k * bits).div_ceil(8) + 1) * 8;
        let width = add_columns(&mut s, &bases, bits, result_base, &mut st, Phase::Pooling);
        let sums = load_vertical(&s, result_base, width, 128);
        for col in 0..128 {
            let expect: u64 = operands.iter().map(|o| o[col] as u64).sum();
            assert_eq!(sums[col], expect, "case {case} k={k} bits={bits} col={col}");
        }
    }
}

#[test]
fn property_multiplication_random_widths() {
    let mut rng = Rng::seed_from_u64(0x301);
    for case in 0..40 {
        let abits = rng.gen_usize(1, 9);
        let bbits = rng.gen_usize(1, 9);
        let mut s = sub();
        let a: Vec<u32> = (0..128).map(|_| rng.gen_range_inclusive((1u32 << abits) - 1)).collect();
        let b: Vec<u32> = (0..128).map(|_| rng.gen_range_inclusive((1u32 << bbits) - 1)).collect();
        store_vertical(&mut s, 0, abits, &a);
        let mut st = Stats::default();
        let mut buf_rows = Vec::new();
        for j in 0..bbits {
            let mut word = 0u128;
            for (col, &v) in b.iter().enumerate() {
                word |= (((v >> j) & 1) as u128) << col;
            }
            s.buffer_write(j, word, &mut st, Phase::LoadData);
            buf_rows.push(j);
        }
        let result_base = (abits.div_ceil(8) + 1) * 8;
        let width =
            multiply_columns(&mut s, 0, abits, &buf_rows, result_base, &mut st, Phase::BatchNorm);
        let prods = load_vertical(&s, result_base, width, 128);
        for col in 0..128 {
            assert_eq!(
                prods[col],
                a[col] as u64 * b[col] as u64,
                "case {case} a={abits}b b={bbits}b col={col}"
            );
        }
    }
}

#[test]
fn property_comparison_random_widths() {
    let mut rng = Rng::seed_from_u64(0xC0);
    for case in 0..40 {
        let bits = rng.gen_usize(1, 11);
        let mut s = sub();
        let a: Vec<u32> = (0..128).map(|_| rng.gen_range_inclusive((1u32 << bits) - 1)).collect();
        let b: Vec<u32> = (0..128).map(|_| rng.gen_range_inclusive((1u32 << bits) - 1)).collect();
        store_vertical(&mut s, 0, bits, &a);
        store_vertical(&mut s, bits, bits, &b);
        let scratch_strip = (2 * bits).div_ceil(8);
        let scratch = CompareScratch {
            tag_row: scratch_strip * 8,
            result_row: scratch_strip * 8 + 1,
            buf_tag: 0,
            buf_diff: 1,
        };
        let mut st = Stats::default();
        let result = compare_columns(&mut s, 0, bits, bits, scratch, &mut st, Phase::Pooling);
        for col in 0..128 {
            assert_eq!(
                (result >> col) & 1 == 1,
                a[col] > b[col],
                "case {case} bits={bits} col={col}: a={} b={}",
                a[col],
                b[col]
            );
        }
    }
}

#[test]
fn property_unipolar_program_only_sets_bits() {
    let mut rng = Rng::seed_from_u64(0x11);
    for _ in 0..50 {
        let mut s = sub();
        let mut st = Stats::default();
        let strip = rng.gen_usize(0, 32);
        let pos = rng.gen_usize(0, 8);
        let p1 = (rng.next_u64() as u128) << 64 | rng.next_u64() as u128;
        let p2 = (rng.next_u64() as u128) << 64 | rng.next_u64() as u128;
        s.program_row(strip, pos, p1, &mut st, Phase::LoadData);
        s.program_row(strip, pos, p2, &mut st, Phase::LoadData);
        assert_eq!(s.peek_row(strip * 8 + pos), p1 | p2, "program must OR");
        s.erase_strip(strip, &mut st, Phase::LoadData);
        assert_eq!(s.peek_row(strip * 8 + pos), 0);
    }
}

#[test]
fn property_stats_are_monotone_nonnegative() {
    // Any op sequence only grows stats; energies/latencies stay finite.
    let mut rng = Rng::seed_from_u64(0x57);
    let mut s = sub();
    let mut st = Stats::default();
    let mut last_e = 0.0;
    let mut last_t = 0.0;
    for _ in 0..500 {
        match rng.gen_usize(0, 4) {
            0 => s.erase_strip(rng.gen_usize(0, 32), &mut st, Phase::LoadData),
            1 => {
                let strip = rng.gen_usize(0, 32);
                let pos = rng.gen_usize(0, 8);
                s.program_row(strip, pos, rng.next_u64() as u128, &mut st, Phase::LoadData)
            }
            2 => {
                s.read_row(rng.gen_usize(0, 256), &mut st, Phase::Other);
            }
            _ => {
                let _ = s.and_row(
                    rng.gen_usize(0, 256),
                    rng.next_u64() as u128,
                    &mut st,
                    Phase::Convolution,
                );
            }
        }
        let e = st.total_energy_fj();
        let t = st.total_latency_ns();
        assert!(e.is_finite() && t.is_finite());
        assert!(e >= last_e && t >= last_t, "stats must be monotone");
        last_e = e;
        last_t = t;
    }
}
