//! Property-style sweeps over the in-memory primitives and coordinator
//! invariants (hand-rolled generator: the build is offline, so proptest
//! is replaced by seeded random sweeps with shrink-friendly reporting),
//! plus packed-vs-scalar equivalence properties: the word-parallel host
//! representation must be bit-identical — outputs *and* [`Stats`] — to
//! a faithful scalar per-column emulation of the pre-refactor path
//! issuing the same device-op sequence.

use nandspin::arch::stats::{Phase, Stats};
use nandspin::device::energy::DeviceCosts;
use nandspin::subarray::conv::{
    bitplane_conv_counts, window_sums, BitKernel, ConvGeometry,
};
use nandspin::subarray::primitives::{
    add_columns, add_result_width, compare_columns, multiply_columns, CompareScratch,
};
use nandspin::subarray::Subarray;
use nandspin::util::Rng;

fn sub() -> Subarray {
    Subarray::new(256, 128, 16, DeviceCosts::default())
}

fn store_vertical(s: &mut Subarray, base: usize, bits: usize, vals: &[u32]) {
    let mut st = Stats::default();
    for b in 0..bits {
        let mut row = 0u128;
        for (col, &v) in vals.iter().enumerate() {
            row |= (((v >> b) & 1) as u128) << col;
        }
        s.write_row(base + b, row, &mut st, Phase::LoadData);
    }
}

fn load_vertical(s: &Subarray, base: usize, bits: usize, cols: usize) -> Vec<u64> {
    (0..cols)
        .map(|col| {
            (0..bits).fold(0u64, |acc, b| {
                acc | ((((s.peek_row(base + b) >> col) & 1) as u64) << b)
            })
        })
        .collect()
}

#[test]
fn property_addition_random_operand_sets() {
    // 60 random cases: k operands of b bits each, all 128 columns.
    let mut rng = Rng::seed_from_u64(0xADD);
    for case in 0..60 {
        let k = rng.gen_usize(2, 9);
        let bits = rng.gen_usize(1, 9);
        let mut s = sub();
        let mut operands = Vec::new();
        for i in 0..k {
            let vals: Vec<u32> =
                (0..128).map(|_| rng.gen_range_inclusive((1u32 << bits) - 1)).collect();
            store_vertical(&mut s, i * bits, bits, &vals);
            operands.push(vals);
        }
        let mut st = Stats::default();
        let bases: Vec<usize> = (0..k).map(|i| i * bits).collect();
        let result_base = ((k * bits).div_ceil(8) + 1) * 8;
        let width = add_columns(&mut s, &bases, bits, result_base, &mut st, Phase::Pooling);
        let sums = load_vertical(&s, result_base, width, 128);
        for col in 0..128 {
            let expect: u64 = operands.iter().map(|o| o[col] as u64).sum();
            assert_eq!(sums[col], expect, "case {case} k={k} bits={bits} col={col}");
        }
    }
}

#[test]
fn property_multiplication_random_widths() {
    let mut rng = Rng::seed_from_u64(0x301);
    for case in 0..40 {
        let abits = rng.gen_usize(1, 9);
        let bbits = rng.gen_usize(1, 9);
        let mut s = sub();
        let a: Vec<u32> = (0..128).map(|_| rng.gen_range_inclusive((1u32 << abits) - 1)).collect();
        let b: Vec<u32> = (0..128).map(|_| rng.gen_range_inclusive((1u32 << bbits) - 1)).collect();
        store_vertical(&mut s, 0, abits, &a);
        let mut st = Stats::default();
        let mut buf_rows = Vec::new();
        for j in 0..bbits {
            let mut word = 0u128;
            for (col, &v) in b.iter().enumerate() {
                word |= (((v >> j) & 1) as u128) << col;
            }
            s.buffer_write(j, word, &mut st, Phase::LoadData);
            buf_rows.push(j);
        }
        let result_base = (abits.div_ceil(8) + 1) * 8;
        let width =
            multiply_columns(&mut s, 0, abits, &buf_rows, result_base, &mut st, Phase::BatchNorm);
        let prods = load_vertical(&s, result_base, width, 128);
        for col in 0..128 {
            assert_eq!(
                prods[col],
                a[col] as u64 * b[col] as u64,
                "case {case} a={abits}b b={bbits}b col={col}"
            );
        }
    }
}

#[test]
fn property_comparison_random_widths() {
    let mut rng = Rng::seed_from_u64(0xC0);
    for case in 0..40 {
        let bits = rng.gen_usize(1, 11);
        let mut s = sub();
        let a: Vec<u32> = (0..128).map(|_| rng.gen_range_inclusive((1u32 << bits) - 1)).collect();
        let b: Vec<u32> = (0..128).map(|_| rng.gen_range_inclusive((1u32 << bits) - 1)).collect();
        store_vertical(&mut s, 0, bits, &a);
        store_vertical(&mut s, bits, bits, &b);
        let scratch_strip = (2 * bits).div_ceil(8);
        let scratch = CompareScratch {
            tag_row: scratch_strip * 8,
            result_row: scratch_strip * 8 + 1,
            buf_tag: 0,
            buf_diff: 1,
        };
        let mut st = Stats::default();
        let result = compare_columns(&mut s, 0, bits, bits, scratch, &mut st, Phase::Pooling);
        for col in 0..128 {
            assert_eq!(
                (result >> col) & 1 == 1,
                a[col] > b[col],
                "case {case} bits={bits} col={col}: a={} b={}",
                a[col],
                b[col]
            );
        }
    }
}

// ---------------------------------------------------------------------
// Packed-vs-scalar equivalence: the pre-refactor scalar per-column host
// path, re-issued op for op, must agree with the packed implementation
// in outputs AND accumulated Stats.
// ---------------------------------------------------------------------

/// Faithful scalar emulation of the pre-refactor conv stepper: same
/// device ops in the same order (buffer loads per period, AND+count per
/// kernel row, bit-serial drain), but per-column `u32` bookkeeping on
/// the host. Returns (period, out_row, per-column counts).
fn scalar_conv_reference(
    sub: &mut Subarray,
    base: usize,
    geo: ConvGeometry,
    kernel: &BitKernel,
    stats: &mut Stats,
) -> Vec<(usize, usize, Vec<u32>)> {
    let out_h = geo.out_h(kernel.kh);
    let out_w = geo.out_w(kernel.kw);
    let mut used = vec![false; kernel.kw];
    for oc in 0..out_w {
        used[(oc * geo.stride) % kernel.kw] = true;
    }
    let count_bits = 32 - (kernel.kh as u32).leading_zeros();
    let mut results = Vec::new();
    for (p, _) in used.iter().enumerate().filter(|(_, &u)| u) {
        for kr in 0..kernel.kh {
            sub.buffer_write(kr, kernel.tile_row(kr, p, geo.in_w), stats, Phase::Convolution);
        }
        for or in 0..out_h {
            sub.counters.reset();
            let r0 = base + or * geo.stride;
            for kr in 0..kernel.kh {
                sub.and_count(r0 + kr, kr, stats, Phase::Convolution);
            }
            let mut counts = vec![0u32; geo.in_w];
            for bitpos in 0..count_bits {
                let lsbs = sub.counter_lsbs_shift(stats, Phase::Convolution);
                for (j, c) in counts.iter_mut().enumerate() {
                    *c |= (((lsbs >> j) & 1) as u32) << bitpos;
                }
            }
            results.push((p, or, counts));
        }
    }
    results
}

#[test]
fn property_conv_stepper_matches_scalar_reference_bit_and_stats() {
    let mut rng = Rng::seed_from_u64(0xC077);
    for case in 0..25 {
        // Randomized geometry, including the 128-column boundary.
        let w = [8, 17, 33, 64, 127, 128][rng.gen_usize(0, 6)];
        let h = rng.gen_usize(3, 24);
        let kh = rng.gen_usize(1, h.min(8) + 1);
        let kw = rng.gen_usize(1, w.min(7) + 1);
        let stride = rng.gen_usize(1, 4);
        let geo = ConvGeometry { in_h: h, in_w: w, stride };
        let kernel = BitKernel::new(
            kh,
            kw,
            (0..kh * kw).map(|_| rng.gen_bool()).collect(),
        );
        // Two identical subarrays, same stored bit-plane.
        let mut sa = sub();
        let mut sb = sub();
        let mut st_load = Stats::default();
        for r in 0..h {
            let word = ((rng.next_u64() as u128) << 64 | rng.next_u64() as u128)
                & if w == 128 { u128::MAX } else { (1u128 << w) - 1 };
            sa.write_row(r, word, &mut st_load, Phase::LoadData);
            sb.write_row(r, word, &mut st_load, Phase::LoadData);
        }
        let mut st_packed = Stats::default();
        let mut st_scalar = Stats::default();
        let packed =
            bitplane_conv_counts(&mut sa, 0, geo, &kernel, &mut st_packed, Phase::Convolution);
        let scalar = scalar_conv_reference(&mut sb, 0, geo, &kernel, &mut st_scalar);
        assert_eq!(
            st_packed, st_scalar,
            "case {case}: device-op stream diverged ({h}x{w} k{kh}x{kw} s{stride})"
        );
        assert_eq!(packed.len(), scalar.len(), "case {case}");
        for (pc, (p, or, counts)) in packed.iter().zip(&scalar) {
            assert_eq!((pc.period, pc.out_row), (*p, *or), "case {case}");
            assert_eq!(&pc.counts(), counts, "case {case} p={p} or={or}");
        }
        // The window fold agrees with the scalar fold of scalar counts.
        let out_w = geo.out_w(kw);
        let out_h = geo.out_h(kh);
        let mut expect = vec![vec![0u32; out_w]; out_h];
        for (p, or, counts) in &scalar {
            for oc in 0..out_w {
                let c0 = oc * stride;
                if c0 % kw != *p {
                    continue;
                }
                expect[*or][oc] = (0..kw).map(|kc| counts[c0 + kc]).sum();
            }
        }
        assert_eq!(window_sums(&packed, geo, &kernel), expect, "case {case}");
    }
}

/// Scalar emulation of the pre-refactor addition: identical op stream,
/// per-column `u32` counters on the host, each drained LSB word
/// cross-checked against the packed counter bank's.
fn scalar_add_reference(
    sub: &mut Subarray,
    operand_bases: &[usize],
    bits: usize,
    result_base: usize,
    cols: usize,
    stats: &mut Stats,
) -> usize {
    sub.counters.reset();
    assert_eq!(result_base % 8, 0);
    let width = add_result_width(operand_bases.len(), bits);
    let first = result_base / 8;
    for s in first..first + width.div_ceil(8) {
        sub.erase_strip(s, stats, Phase::Pooling);
    }
    let mut scalar = vec![0u32; cols];
    let mut written = 0;
    fn drain(sub: &mut Subarray, scalar: &mut [u32], stats: &mut Stats) -> u128 {
        let mut expect = 0u128;
        for (col, c) in scalar.iter_mut().enumerate() {
            expect |= ((*c & 1) as u128) << col;
            *c >>= 1;
        }
        let lsb = sub.counter_lsbs_shift(stats, Phase::Pooling);
        assert_eq!(lsb, expect, "packed counter bank diverged from scalar counters");
        lsb
    }
    for b in 0..bits {
        for &base in operand_bases {
            let row = sub.peek_row(base + b);
            for (col, c) in scalar.iter_mut().enumerate() {
                *c += ((row >> col) & 1) as u32;
            }
            sub.read_count(base + b, stats, Phase::Pooling);
        }
        let lsb = drain(sub, &mut scalar, stats);
        let row = result_base + written;
        sub.program_row(row / 8, row % 8, lsb, stats, Phase::Pooling);
        written += 1;
    }
    while scalar.iter().any(|&c| c != 0) {
        let lsb = drain(sub, &mut scalar, stats);
        let row = result_base + written;
        sub.program_row(row / 8, row % 8, lsb, stats, Phase::Pooling);
        written += 1;
    }
    assert!(sub.counters.is_zero(), "bank must drain exactly when scalar drains");
    written
}

#[test]
fn property_addition_matches_scalar_reference_bit_and_stats() {
    // Randomized widths (incl. the 128-column boundary and narrow
    // subarrays) and non-strip-aligned operand bases.
    let mut rng = Rng::seed_from_u64(0xADD2);
    for case in 0..20 {
        let cols = [8, 23, 64, 127, 128][rng.gen_usize(0, 5)];
        let k = rng.gen_usize(2, 7);
        let bits = rng.gen_usize(1, 8);
        // Operands packed back to back from a random, possibly
        // non-strip-aligned starting row.
        let start = rng.gen_usize(0, 5);
        let bases: Vec<usize> = (0..k).map(|i| start + i * bits).collect();
        let result_base = ((start + k * bits).div_ceil(8) + 1) * 8;

        let mut sa = Subarray::new(256, cols, 16, DeviceCosts::default());
        let mut sb = Subarray::new(256, cols, 16, DeviceCosts::default());
        let mut st_load = Stats::default();
        let mut operands: Vec<Vec<u32>> = Vec::new();
        for &base in &bases {
            let vals: Vec<u32> =
                (0..cols).map(|_| rng.gen_range_inclusive((1u32 << bits) - 1)).collect();
            for b in 0..bits {
                let mut row = 0u128;
                for (col, &v) in vals.iter().enumerate() {
                    row |= (((v >> b) & 1) as u128) << col;
                }
                sa.write_row(base + b, row, &mut st_load, Phase::LoadData);
                sb.write_row(base + b, row, &mut st_load, Phase::LoadData);
            }
            operands.push(vals);
        }

        let mut st_packed = Stats::default();
        let mut st_scalar = Stats::default();
        let w_packed =
            add_columns(&mut sa, &bases, bits, result_base, &mut st_packed, Phase::Pooling);
        let w_scalar =
            scalar_add_reference(&mut sb, &bases, bits, result_base, cols, &mut st_scalar);
        assert_eq!(w_packed, w_scalar, "case {case}");
        assert_eq!(st_packed, st_scalar, "case {case}: Stats diverged");
        // Same rows programmed, same sums read back.
        for b in 0..w_packed {
            assert_eq!(
                sa.peek_row(result_base + b),
                sb.peek_row(result_base + b),
                "case {case} row {b}"
            );
        }
        let sums = load_vertical(&sa, result_base, w_packed, cols);
        for col in 0..cols {
            let expect: u64 = operands.iter().map(|o| o[col] as u64).sum();
            assert_eq!(sums[col], expect, "case {case} col {col}");
        }
    }
}

/// Scalar emulation of the pre-refactor multiplication inner loop:
/// identical op stream, per-column scalar counters.
fn scalar_multiply_reference(
    sub: &mut Subarray,
    a_base: usize,
    a_bits: usize,
    b_buf_rows: &[usize],
    result_base: usize,
    cols: usize,
    stats: &mut Stats,
) -> usize {
    let b_bits = b_buf_rows.len();
    sub.counters.reset();
    assert_eq!(result_base % 8, 0);
    let width = a_bits + b_bits + 1;
    for s in result_base / 8..result_base / 8 + width.div_ceil(8) {
        sub.erase_strip(s, stats, Phase::BatchNorm);
    }
    let mut scalar = vec![0u32; cols];
    let mut written = 0;
    for p in 0..a_bits + b_bits {
        for i in 0..a_bits {
            let Some(j) = p.checked_sub(i) else { continue };
            if j >= b_bits {
                continue;
            }
            let partial = sub.peek_row(a_base + i) & sub.buffer.read(b_buf_rows[j]);
            for (col, c) in scalar.iter_mut().enumerate() {
                *c += ((partial >> col) & 1) as u32;
            }
            sub.and_count(a_base + i, b_buf_rows[j], stats, Phase::BatchNorm);
        }
        let mut expect = 0u128;
        for (col, c) in scalar.iter_mut().enumerate() {
            expect |= ((*c & 1) as u128) << col;
            *c >>= 1;
        }
        let lsb = sub.counter_lsbs_shift(stats, Phase::BatchNorm);
        assert_eq!(lsb, expect, "packed bank diverged in multiply");
        let row = result_base + written;
        sub.program_row(row / 8, row % 8, lsb, stats, Phase::BatchNorm);
        written += 1;
    }
    while scalar.iter().any(|&c| c != 0) {
        let mut expect = 0u128;
        for (col, c) in scalar.iter_mut().enumerate() {
            expect |= ((*c & 1) as u128) << col;
            *c >>= 1;
        }
        let lsb = sub.counter_lsbs_shift(stats, Phase::BatchNorm);
        assert_eq!(lsb, expect);
        let row = result_base + written;
        sub.program_row(row / 8, row % 8, lsb, stats, Phase::BatchNorm);
        written += 1;
    }
    assert!(sub.counters.is_zero());
    written
}

#[test]
fn property_multiplication_matches_scalar_reference_bit_and_stats() {
    let mut rng = Rng::seed_from_u64(0x3012);
    for case in 0..15 {
        let cols = [16, 64, 128][rng.gen_usize(0, 3)];
        let abits = rng.gen_usize(1, 7);
        let bbits = rng.gen_usize(1, 7);
        // Non-strip-aligned A operand.
        let a_base = rng.gen_usize(0, 6);
        let result_base = ((a_base + abits).div_ceil(8) + 1) * 8;
        let mut sa = Subarray::new(256, cols, 16, DeviceCosts::default());
        let mut sb = Subarray::new(256, cols, 16, DeviceCosts::default());
        let mut st_load = Stats::default();
        let a: Vec<u32> =
            (0..cols).map(|_| rng.gen_range_inclusive((1u32 << abits) - 1)).collect();
        for b in 0..abits {
            let mut row = 0u128;
            for (col, &v) in a.iter().enumerate() {
                row |= (((v >> b) & 1) as u128) << col;
            }
            sa.write_row(a_base + b, row, &mut st_load, Phase::LoadData);
            sb.write_row(a_base + b, row, &mut st_load, Phase::LoadData);
        }
        let bvals: Vec<u32> =
            (0..cols).map(|_| rng.gen_range_inclusive((1u32 << bbits) - 1)).collect();
        let mut buf_rows = Vec::new();
        for j in 0..bbits {
            let mut word = 0u128;
            for (col, &v) in bvals.iter().enumerate() {
                word |= (((v >> j) & 1) as u128) << col;
            }
            sa.buffer_write(j, word, &mut st_load, Phase::LoadData);
            sb.buffer_write(j, word, &mut st_load, Phase::LoadData);
            buf_rows.push(j);
        }
        let mut st_packed = Stats::default();
        let mut st_scalar = Stats::default();
        let w_packed = multiply_columns(
            &mut sa,
            a_base,
            abits,
            &buf_rows,
            result_base,
            &mut st_packed,
            Phase::BatchNorm,
        );
        let w_scalar = scalar_multiply_reference(
            &mut sb,
            a_base,
            abits,
            &buf_rows,
            result_base,
            cols,
            &mut st_scalar,
        );
        assert_eq!(w_packed, w_scalar, "case {case}");
        assert_eq!(st_packed, st_scalar, "case {case}: Stats diverged");
        let prods = load_vertical(&sa, result_base, w_packed, cols);
        for col in 0..cols {
            assert_eq!(
                prods[col],
                a[col] as u64 * bvals[col] as u64,
                "case {case} col {col}"
            );
        }
    }
}

#[test]
fn property_unipolar_program_only_sets_bits() {
    let mut rng = Rng::seed_from_u64(0x11);
    for _ in 0..50 {
        let mut s = sub();
        let mut st = Stats::default();
        let strip = rng.gen_usize(0, 32);
        let pos = rng.gen_usize(0, 8);
        let p1 = (rng.next_u64() as u128) << 64 | rng.next_u64() as u128;
        let p2 = (rng.next_u64() as u128) << 64 | rng.next_u64() as u128;
        s.program_row(strip, pos, p1, &mut st, Phase::LoadData);
        s.program_row(strip, pos, p2, &mut st, Phase::LoadData);
        assert_eq!(s.peek_row(strip * 8 + pos), p1 | p2, "program must OR");
        s.erase_strip(strip, &mut st, Phase::LoadData);
        assert_eq!(s.peek_row(strip * 8 + pos), 0);
    }
}

#[test]
fn property_stats_are_monotone_nonnegative() {
    // Any op sequence only grows stats; energies/latencies stay finite.
    let mut rng = Rng::seed_from_u64(0x57);
    let mut s = sub();
    let mut st = Stats::default();
    let mut last_e = 0.0;
    let mut last_t = 0.0;
    for _ in 0..500 {
        match rng.gen_usize(0, 4) {
            0 => s.erase_strip(rng.gen_usize(0, 32), &mut st, Phase::LoadData),
            1 => {
                let strip = rng.gen_usize(0, 32);
                let pos = rng.gen_usize(0, 8);
                s.program_row(strip, pos, rng.next_u64() as u128, &mut st, Phase::LoadData)
            }
            2 => {
                s.read_row(rng.gen_usize(0, 256), &mut st, Phase::Other);
            }
            _ => {
                let _ = s.and_row(
                    rng.gen_usize(0, 256),
                    rng.next_u64() as u128,
                    &mut st,
                    Phase::Convolution,
                );
            }
        }
        let e = st.total_energy_fj();
        let t = st.total_latency_ns();
        assert!(e.is_finite() && t.is_finite());
        assert!(e >= last_e && t >= last_t, "stats must be monotone");
        last_e = e;
        last_t = t;
    }
}
