//! Functional-vs-analytic engine agreement.
//!
//! Both engines draw every cost from the one L1 `DeviceCosts` table and
//! model the same mapping, so the analytic model's closed-form op
//! counts must track the op mix the functional engine actually
//! executes. They are not identical: the analytic model rounds at
//! mapping granularity (sliding periods, tiling, channel stacking) and
//! books the pooling comparison flow's ANDs as sense reads, while the
//! functional engine counts every physical array op it performs.
//!
//! **Documented tolerance** (asserted below, on the small presets the
//! functional engine can run):
//!
//! * AND stream — within 8× (micro_cnn, a single conv layer, within 4×);
//! * total sense-amp activity (reads + ANDs + bit-counts) — within 32×;
//! * total bus traffic (local + global bits) — within 32×.
//!
//! The agreement must hold under every `Calibration` ablation toggle:
//! the toggles reshape latency/energy composition and bus traffic, but
//! never the compute op mix.

use nandspin::arch::config::ArchConfig;
use nandspin::arch::stats::{Phase, Stats};
use nandspin::cnn::network::{alexnet, micro_cnn, small_cnn, small_resnet, Network};
use nandspin::cnn::ref_exec::ModelParams;
use nandspin::cnn::tensor::QTensor;
use nandspin::coordinator::{
    serve, AnalyticModel, Calibration, EngineMode, FunctionalEngine, Request, ServeConfig,
    SpotCheck,
};

const AND_TOL: f64 = 8.0;
const MICRO_AND_TOL: f64 = 4.0;
const SENSE_TOL: f64 = 32.0;
const BUS_TOL: f64 = 32.0;

fn functional_stats(net: &Network, wbits: u8, seed: u64) -> Stats {
    let params = ModelParams::random(net, wbits, seed);
    let input = QTensor::random(net.input.0, net.input.1, net.input.2, net.input_bits, seed + 1);
    let mut eng = FunctionalEngine::new(ArchConfig::paper());
    eng.run(net, &params, &input);
    eng.stats
}

fn analytic_stats(net: &Network, wbits: u8, cal: Calibration) -> Stats {
    let mut model = AnalyticModel::new(ArchConfig::paper());
    model.cal = cal;
    model.network_stats(net, wbits)
}

/// Ratio of two op counts, saturating at 1 to avoid 0/0.
fn ratio(a: u64, b: u64) -> f64 {
    a.max(1) as f64 / b.max(1) as f64
}

fn in_band(r: f64, tol: f64) -> bool {
    (1.0 / tol..=tol).contains(&r)
}

/// Every combination of the boolean calibration toggles (the ablations
/// of §4.1 / Fig. 12 plus the Table 3 residency condition).
fn all_toggle_combinations() -> Vec<Calibration> {
    let mut cals = Vec::new();
    for weights_resident in [false, true] {
        for weight_buffer_reuse in [true, false] {
            for cross_writing_pipeline in [true, false] {
                cals.push(Calibration {
                    weights_resident,
                    weight_buffer_reuse,
                    cross_writing_pipeline,
                    ..Calibration::default()
                });
            }
        }
    }
    cals
}

#[test]
fn analytic_op_mix_tracks_functional_on_small_presets() {
    let presets: [(Network, u8); 3] =
        [(micro_cnn(3), 3), (small_cnn(3), 3), (small_resnet(3), 3)];
    for (net, wbits) in presets {
        let f = functional_stats(&net, wbits, 11);
        assert!(f.ops.ands > 0 && f.ops.reads > 0, "{}: functional ran", net.name);
        let and_tol = if net.name == "MicroCNN" { MICRO_AND_TOL } else { AND_TOL };
        for cal in all_toggle_combinations() {
            let a = analytic_stats(&net, wbits, cal);
            let r_and = ratio(a.ops.ands, f.ops.ands);
            assert!(
                in_band(r_and, and_tol),
                "{}: AND ratio {r_and:.3} outside {and_tol}x band (cal {cal:?})",
                net.name
            );
            let sense = |s: &Stats| s.ops.ands + s.ops.reads + s.ops.bitcounts;
            let r_sense = ratio(sense(&a), sense(&f));
            assert!(
                in_band(r_sense, SENSE_TOL),
                "{}: sense-activity ratio {r_sense:.3} outside {SENSE_TOL}x band (cal {cal:?})",
                net.name
            );
            let bus = |s: &Stats| s.ops.local_bus_bits + s.ops.global_bus_bits;
            let r_bus = ratio(bus(&a), bus(&f));
            assert!(
                in_band(r_bus, BUS_TOL),
                "{}: bus-traffic ratio {r_bus:.3} outside {BUS_TOL}x band (cal {cal:?})",
                net.name
            );
        }
    }
}

#[test]
fn calibration_toggles_reshape_costs_not_the_compute_mix() {
    let net = small_cnn(3);
    let base = analytic_stats(&net, 3, Calibration::default());

    // Cross-writing pipelining off: identical op counts, strictly
    // slower (accumulation serialises after the AND/count stream).
    let no_pipe = analytic_stats(
        &net,
        3,
        Calibration { cross_writing_pipeline: false, ..Calibration::default() },
    );
    assert_eq!(no_pipe.ops, base.ops, "pipelining is latency-only");
    assert!(no_pipe.total_latency_ns() > base.total_latency_ns());

    // Resident weights: the weight stream leaves the global bus and the
    // load phase; the compute mix is untouched.
    let resident = analytic_stats(
        &net,
        3,
        Calibration { weights_resident: true, ..Calibration::default() },
    );
    assert!(resident.ops.global_bus_bits < base.ops.global_bus_bits);
    assert!(resident[Phase::LoadData].latency_ns < base[Phase::LoadData].latency_ns);
    assert_eq!(resident.ops.ands, base.ops.ands);

    // No weight-buffer reuse: the 1-bit weight matrix re-streams per
    // output row (the prior-design behaviour §4.1 argues against) —
    // more bus traffic, same compute mix.
    let no_reuse = analytic_stats(
        &net,
        3,
        Calibration { weight_buffer_reuse: false, ..Calibration::default() },
    );
    assert!(no_reuse.ops.global_bus_bits > base.ops.global_bus_bits);
    assert_eq!(no_reuse.ops.ands, base.ops.ands);
}

#[test]
fn per_layer_conv_counts_match_on_the_single_conv_micro_net() {
    // micro_cnn is effectively one conv layer plus a quantize, which
    // makes it a per-layer check: the conv AND count of the two engines
    // must agree tightly (the analytic formula
    // out_c · m · in_c · n · periods · oh · kh is exactly what the
    // functional stepper executes when the mapping divisions are exact).
    let net = micro_cnn(3);
    let f = functional_stats(&net, 3, 23);
    let a = analytic_stats(&net, 3, Calibration::default());
    let r = ratio(a.ops.ands, f.ops.ands);
    assert!(
        in_band(r, 2.0),
        "single-conv AND ratio {r:.3} outside the 2x per-layer band \
         (functional {}, analytic {})",
        f.ops.ands,
        a.ops.ands
    );
    // The bit-count stream rides the same ANDs in both engines (the
    // functional path adds the per-drain counter-shift steps, so the
    // band is wider than the AND band).
    assert!(in_band(ratio(a.ops.bitcounts, f.ops.bitcounts), 8.0));
}

#[test]
fn hybrid_alexnet_replays_through_the_tiled_functional_path() {
    // Full-size hybrid fidelity (the PR 4 acceptance condition): serve
    // AlexNet analytically and replay a sampled request bit-accurately
    // through the multi-tile functional path. The 1-bit operating point
    // keeps the replay inside the test time budget; the mapping and op
    // stream are the same as at ⟨8:8⟩, only narrower.
    let net = alexnet(1);
    let params = ModelParams::random(&net, 1, 7);
    let images: Vec<QTensor> = (0..2)
        .map(|i| QTensor::random(net.input.0, net.input.1, net.input.2, net.input_bits, 70 + i))
        .collect();
    let scfg = ServeConfig {
        chips: 1,
        max_batch: 2,
        engine: EngineMode::Hybrid { check_every: 2 },
        ..ServeConfig::default()
    };
    let report = serve(&ArchConfig::paper(), &scfg, &net, Some(&params), Request::stream(images));
    assert_eq!(report.served(), 2);
    report.verify().expect("hybrid identities incl. spot-check band");
    let sc = report
        .spot_check
        .expect("multi-tile mapping makes the full-size functional replay possible");
    assert_eq!(sc.checked, 1, "stream position 0 replayed");
    assert!(
        sc.passed(),
        "latency {:?} energy {:?} outside {:?}",
        sc.latency_ratio,
        sc.energy_ratio,
        SpotCheck::TOLERANCE
    );
    let (lo, hi) = SpotCheck::TOLERANCE;
    for (a, b) in [sc.latency_ratio, sc.energy_ratio] {
        assert!(a >= lo && b <= hi && a <= b, "ratio band {a}..{b} inside {lo}..{hi}");
    }
    // Hybrid serves analytically: completions carry no outputs.
    assert!(report.completions.iter().all(|c| c.output.is_none()));
}
