//! Integration tests of the deterministic tracing & metrics layer:
//! byte-identical telemetry exports across runs and host worker counts
//! (at a fixed fault seed), exact metrics re-derivation of the
//! `ServeReport` aggregates, complete per-request span chains, and the
//! zero-cost guarantee — tracing off leaves the report bit-identical.

use nandspin::arch::config::ArchConfig;
use nandspin::cnn::network::{micro_cnn, small_cnn, Network};
use nandspin::cnn::ref_exec::ModelParams;
use nandspin::cnn::tensor::QTensor;
use nandspin::coordinator::serve::{serve, EngineMode, Request, ServeConfig, ServeReport};
use nandspin::device::{FaultPlan, FaultRates};
use nandspin::trace::export;

fn requests(net: &Network, n: usize, seed: u64) -> Vec<Request> {
    Request::stream(
        (0..n)
            .map(|i| {
                QTensor::random(net.input.0, net.input.1, net.input.2, net.input_bits, seed + i as u64)
            })
            .collect(),
    )
}

/// A traced functional serve under fault injection at a fixed seed:
/// the scenario whose telemetry the determinism guarantee is judged on.
fn traced_fault_serve(workers: usize) -> ServeReport {
    let net = micro_cnn(3);
    let params = ModelParams::random(&net, 2, 1);
    let scfg = ServeConfig {
        chips: 2,
        max_batch: 2,
        host_workers: Some(workers),
        fault: Some(FaultPlan::new(7, FaultRates::uniform(1e-3))),
        trace: true,
        ..ServeConfig::default()
    };
    serve(&ArchConfig::paper(), &scfg, &net, Some(&params), requests(&net, 8, 301))
}

#[test]
fn traced_exports_are_byte_identical_across_runs_and_workers() {
    let exports = |r: &ServeReport| {
        let t = r.trace.as_ref().expect("traced serve carries a timeline");
        (export::to_chrome_json(t), export::to_jsonl(t), t.metrics.to_prometheus())
    };
    let base = traced_fault_serve(1);
    base.verify().expect("traced fault serve identities");
    let (chrome, jsonl, prom) = exports(&base);
    assert!(!chrome.is_empty() && !jsonl.is_empty() && !prom.is_empty());
    // Run-to-run at the same worker count, and across worker counts:
    // every export byte must match — the timeline rides the simulated
    // clock, never host scheduling.
    for workers in [1usize, 4] {
        let again = traced_fault_serve(workers);
        again.verify().expect("identities at every worker count");
        let (c, j, p) = exports(&again);
        assert_eq!(chrome, c, "Chrome trace drifted at workers={workers}");
        assert_eq!(jsonl, j, "JSONL log drifted at workers={workers}");
        assert_eq!(prom, p, "metrics snapshot drifted at workers={workers}");
    }
}

#[test]
fn tracing_off_leaves_the_report_bit_identical() {
    let net = small_cnn(3);
    let params = ModelParams::random(&net, 3, 9);
    let run = |trace: bool| {
        let scfg = ServeConfig {
            chips: 2,
            max_batch: 3,
            host_workers: Some(2),
            trace,
            ..ServeConfig::default()
        };
        serve(&ArchConfig::paper(), &scfg, &net, Some(&params), requests(&net, 6, 510))
    };
    let off = run(false);
    let on = run(true);
    assert!(off.trace.is_none(), "tracing off records nothing");
    assert!(on.trace.is_some());
    assert!(off.chips.iter().all(|c| c.layer_costs.is_none()), "no layer costs untraced");
    assert_eq!(off.served(), on.served());
    for (a, b) in off.completions.iter().zip(&on.completions) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.chip, b.chip);
        assert_eq!(a.batch, b.batch);
        assert_eq!(a.stats, b.stats, "request {}", a.id);
        assert_eq!(a.output, b.output, "request {}", a.id);
        assert_eq!(a.arrival_ns, b.arrival_ns);
        assert_eq!(a.flush_ns, b.flush_ns);
        assert_eq!(a.start_ns, b.start_ns);
        assert_eq!(a.finish_ns, b.finish_ns);
    }
}

#[test]
fn every_served_request_has_a_complete_span_chain() {
    // Fault-free traced serve: one arrival → lane_wait → queue_wait →
    // execute → complete chain per request, one flush → route → batch
    // triple per batcher flush.
    let net = micro_cnn(3);
    let params = ModelParams::random(&net, 2, 1);
    let scfg = ServeConfig { chips: 2, max_batch: 2, trace: true, ..ServeConfig::default() };
    let report =
        serve(&ArchConfig::paper(), &scfg, &net, Some(&params), requests(&net, 7, 42));
    let t = report.trace.as_ref().expect("trace");
    let served = report.served();
    for name in ["arrival", "lane_wait", "queue_wait", "execute", "complete"] {
        assert_eq!(t.count(name), served, "one '{name}' per served request");
    }
    let batches = report.counters.batches as usize;
    for name in ["flush", "route", "batch"] {
        assert_eq!(t.count(name), batches, "one '{name}' per batch");
    }
    // Tracks: the scheduler plane plus one per chip, matching pids.
    assert_eq!(t.tracks.len(), scfg.chips + 1);
    assert_eq!(t.tracks[0], "scheduler");
    assert_eq!(t.tracks[1], "chip 0");
    assert!(t.events.iter().all(|e| (e.pid as usize) < t.tracks.len()));
    // Sorted timeline: timestamps never decrease.
    assert!(t.events.windows(2).all(|w| w[0].ts_ns <= w[1].ts_ns));
}

#[test]
fn metrics_snapshot_rederives_report_aggregates_exactly() {
    let report = traced_fault_serve(2);
    let m = &report.trace.as_ref().expect("trace").metrics;
    assert_eq!(m.counter("nandspin_requests_served_total"), report.served() as u64);
    assert_eq!(m.counter("nandspin_batches_total"), report.counters.batches);
    assert_eq!(
        m.counter("nandspin_flushes_total{cause=\"size\"}"),
        report.counters.size_flushes
    );
    assert_eq!(
        m.counter("nandspin_flushes_total{cause=\"drain\"}"),
        report.counters.drain_flushes
    );
    for c in &report.chips {
        assert_eq!(
            m.counter(&format!("nandspin_chip_served_total{{chip=\"{}\"}}", c.chip)),
            c.served
        );
        assert_eq!(
            m.gauge(&format!("nandspin_chip_healthy{{chip=\"{}\"}}", c.chip)),
            Some(i64::from(c.healthy))
        );
    }
    for n in &report.networks {
        assert_eq!(
            m.counter(&format!("nandspin_net_served_total{{net=\"{}\"}}", n.name)),
            n.served
        );
        assert_eq!(
            m.counter(&format!("nandspin_net_deadline_violations_total{{net=\"{}\"}}", n.name)),
            n.deadline_violations
        );
    }
    // Fault counters re-derive the ledger exactly (integer identities).
    let fl = &report.faults.ledger;
    assert_eq!(m.counter("nandspin_faults_injected_total{kind=\"program\"}"), fl.program_faults);
    assert_eq!(m.counter("nandspin_faults_injected_total{kind=\"read\"}"), fl.read_flips);
    assert_eq!(m.counter("nandspin_faults_injected_total{kind=\"and\"}"), fl.and_flips);
    assert_eq!(m.counter("nandspin_fault_write_retries_total"), fl.write_retries);
    assert_eq!(m.counter("nandspin_fault_spared_rows_total"), fl.spared_rows);
    assert_eq!(m.gauge("nandspin_makespan_ns"), Some(report.makespan_ns() as i64));
    let lat = m.histogram("nandspin_request_latency_ns").expect("latency histogram");
    assert_eq!(lat.count, report.served() as u64);
    // The registry snapshot is exactly what report.metrics() derives.
    assert_eq!(*m, report.metrics());
}

#[test]
fn traced_chips_carry_layer_cost_profiles() {
    let net = small_cnn(3);
    let params = ModelParams::random(&net, 3, 21);
    let run = |engine: EngineMode| {
        let scfg = ServeConfig {
            chips: 1,
            max_batch: 4,
            engine,
            trace: true,
            ..ServeConfig::default()
        };
        let p = (engine != EngineMode::Analytic).then_some(&params);
        serve(&ArchConfig::paper(), &scfg, &net, p, requests(&net, 4, 77))
    };
    for engine in [EngineMode::Functional, EngineMode::Analytic] {
        let report = run(engine);
        let chip = &report.chips[0];
        let profiles = chip.layer_costs.as_ref().expect("traced chip records layer costs");
        assert_eq!(profiles.len(), 1, "one network served");
        let p = &profiles[0];
        assert_eq!(p.net, 0);
        assert_eq!(p.network, net.name);
        assert_eq!(p.requests, chip.served, "every request folded in");
        assert_eq!(p.layers.len(), net.nodes.len(), "one entry per node");
        assert!(p.total_latency_ns() > 0.0 && p.total_energy_fj() > 0.0);
        // The per-node fold can never exceed the chip's total charge
        // (the functional engine's pre-schedule input load is charged
        // outside any node), and must account for the bulk of it.
        let total = chip.stats.total_latency_ns();
        assert!(
            p.total_latency_ns() <= total * (1.0 + 1e-9),
            "{engine:?}: layer fold {} > chip total {total}",
            p.total_latency_ns()
        );
        assert!(
            p.total_latency_ns() > 0.5 * total,
            "{engine:?}: layer fold {} implausibly small vs {total}",
            p.total_latency_ns()
        );
    }
}

#[test]
fn hybrid_spot_checks_appear_in_the_timeline() {
    let net = small_cnn(3);
    let params = ModelParams::random(&net, 3, 17);
    let scfg = ServeConfig {
        chips: 2,
        max_batch: 2,
        engine: EngineMode::Hybrid { check_every: 2 },
        trace: true,
        ..ServeConfig::default()
    };
    let report =
        serve(&ArchConfig::paper(), &scfg, &net, Some(&params), requests(&net, 6, 60));
    let sc = report.spot_check.expect("small preset replays functionally");
    let t = report.trace.as_ref().expect("trace");
    assert_eq!(t.count("spot_check") as u64, sc.checked, "one event per replay");
}
