//! Integration tests of the batched multi-chip serving runtime:
//! end-to-end correctness across chips, the batching triggers, queue
//! backpressure, deterministic routing, the `ServeReport` aggregation
//! identities (sum of per-chip accounts == totals), and the
//! engine-generic paths: analytic serving of the full-size benchmark
//! networks and hybrid serving with functional spot-checks.

use nandspin::arch::config::ArchConfig;
use nandspin::cnn::network::{alexnet, micro_cnn, small_cnn, Network};
use nandspin::cnn::ref_exec::{self, ModelParams};
use nandspin::cnn::tensor::QTensor;
use nandspin::coordinator::engine::{EngineFactory, EngineKind, PoolSpec};
use nandspin::coordinator::serve::pool::{execute_with_workers, PlannedBatch};
use nandspin::coordinator::serve::{
    serve, serve_pool, EngineMode, FlushCause, Request, ServeConfig, ServedNetwork, SloPolicy,
};
use nandspin::device::{FaultPlan, FaultRates};

fn requests(net: &Network, n: usize, seed: u64) -> Vec<Request> {
    Request::stream(
        (0..n)
            .map(|i| {
                QTensor::random(net.input.0, net.input.1, net.input.2, net.input_bits, seed + i as u64)
            })
            .collect(),
    )
}

#[test]
fn end_to_end_bit_exact_and_identities_hold() {
    let net = small_cnn(3);
    let params = ModelParams::random(&net, 3, 3);
    let reqs = requests(&net, 10, 500);
    let images: Vec<QTensor> = reqs.iter().map(|r| r.image.clone()).collect();
    let scfg = ServeConfig { chips: 4, max_batch: 3, ..ServeConfig::default() };
    let report = serve(&ArchConfig::paper(), &scfg, &net, Some(&params), reqs);

    assert_eq!(report.served(), 10);
    report.verify().expect("aggregation identities");
    for c in &report.completions {
        let golden = ref_exec::execute(&net, &params, &images[c.id as usize]);
        let output = c.output.as_ref().expect("functional mode carries outputs");
        assert_eq!(output, golden.last().unwrap(), "request {} (chip {})", c.id, c.chip);
        assert!(c.latency_ns() > 0.0 && c.service_ns() > 0.0);
        assert!(c.queue_wait_ns() >= 0.0);
    }
    // Explicit roll-up identity: per-chip served/energy sums to totals.
    let served: u64 = report.chips.iter().map(|c| c.served).sum();
    assert_eq!(served, 10);
    let chip_energy: f64 = report.chips.iter().map(|c| c.stats.total_energy_mj()).sum();
    assert!((chip_energy - report.total_energy_mj()).abs() < 1e-9 * chip_energy.max(1.0));
}

#[test]
fn closed_burst_emits_size_flushes_plus_drain() {
    let net = micro_cnn(3);
    let params = ModelParams::random(&net, 2, 1);
    // 10 requests, batch target 4 → two size flushes + one 2-request drain.
    let scfg = ServeConfig { chips: 2, max_batch: 4, ..ServeConfig::default() };
    let report =
        serve(&ArchConfig::paper(), &scfg, &net, Some(&params), requests(&net, 10, 9));
    assert_eq!(report.counters.size_flushes, 2);
    assert_eq!(report.counters.drain_flushes, 1);
    assert_eq!(report.counters.deadline_flushes, 0, "burst arrives instantly");
    assert_eq!(report.counters.batches, 3);
    assert_eq!(report.counters.max_batch, 4);
    report.verify().expect("identities");
}

#[test]
fn slow_arrivals_trigger_deadline_flushes() {
    let net = micro_cnn(3);
    let params = ModelParams::random(&net, 2, 1);
    // Requests arrive every 100 µs but the deadline is 10 µs: no batch
    // ever fills to 8, every request ships alone on the deadline timer
    // (the last one ships on the end-of-stream drain).
    let scfg = ServeConfig {
        chips: 2,
        max_batch: 8,
        deadline_us: 10.0,
        arrival_interval_ns: 100_000.0,
        ..ServeConfig::default()
    };
    let report =
        serve(&ArchConfig::paper(), &scfg, &net, Some(&params), requests(&net, 5, 21));
    assert_eq!(report.counters.deadline_flushes, 4);
    assert_eq!(report.counters.drain_flushes, 1);
    assert_eq!(report.counters.size_flushes, 0);
    // Deadline-flushed singletons: batcher wait is exactly the deadline.
    let deadline_ns = 10.0 * 1e3;
    for c in report.completions.iter().filter(|c| c.id < 4) {
        assert!(
            c.queue_wait_ns() >= deadline_ns - 1e-6,
            "request {} waited {} ns < deadline",
            c.id,
            c.queue_wait_ns()
        );
    }
    report.verify().expect("identities");
}

#[test]
fn saturating_one_chip_applies_backpressure() {
    let net = micro_cnn(3);
    let params = ModelParams::random(&net, 2, 1);
    // Everything lands on one chip with a 1-deep queue: after the first
    // batch the queue is always full, so later batches must stall.
    let scfg = ServeConfig {
        chips: 1,
        max_batch: 1,
        queue_depth: 1,
        ..ServeConfig::default()
    };
    let report =
        serve(&ArchConfig::paper(), &scfg, &net, Some(&params), requests(&net, 4, 33));
    assert_eq!(report.counters.batches, 4);
    assert!(
        report.counters.stalled_batches >= 3,
        "expected backpressure stalls, got {}",
        report.counters.stalled_batches
    );
    assert_eq!(report.chips[0].stalled_batches, report.counters.stalled_batches);
    // Under backpressure the chip still serves FIFO with no idle gaps.
    let mut finishes: Vec<f64> = report.completions.iter().map(|c| c.finish_ns).collect();
    let sorted = {
        let mut s = finishes.clone();
        s.sort_by(f64::total_cmp);
        s
    };
    assert_eq!(finishes, sorted);
    finishes.dedup();
    assert_eq!(finishes.len(), 4, "distinct serial finish times");
    report.verify().expect("identities");
}

#[test]
fn backpressure_holds_while_retries_inflate_service_time() {
    let net = micro_cnn(3);
    let params = ModelParams::random(&net, 2, 1);
    // Same 1-chip / 1-deep-queue saturation as above, but now every
    // write runs a 30% transient-failure gauntlet: verify-retry loops
    // inflate each batch's service time. The queue must keep stalling
    // (no deadlock), and every request must still come back with the
    // report identities — retries included — intact. With one chip
    // there is nowhere to fail over to, so the chip stays in rotation.
    let scfg = ServeConfig {
        chips: 1,
        max_batch: 1,
        queue_depth: 1,
        fault: Some(FaultPlan::new(
            11,
            FaultRates { program_fail: 0.3, read_flip: 0.0, stuck_at: 0.0 },
        )),
        ..ServeConfig::default()
    };
    let report =
        serve(&ArchConfig::paper(), &scfg, &net, Some(&params), requests(&net, 6, 44));
    assert_eq!(report.served(), 6, "no request may be dropped under faulty backpressure");
    assert_eq!(report.counters.batches, 6);
    assert!(
        report.counters.stalled_batches >= 3,
        "expected backpressure stalls, got {}",
        report.counters.stalled_batches
    );
    assert!(
        report.faults.ledger.write_retries > 0,
        "retries are what inflate the service time"
    );
    assert_eq!(report.faults.failed_over_batches, 0, "one chip: nowhere to drain to");
    report.verify().expect("identities under faulty backpressure");
}

#[test]
fn routing_is_deterministic_and_balanced() {
    let net = micro_cnn(3);
    let params = ModelParams::random(&net, 2, 1);
    let scfg = ServeConfig { chips: 4, max_batch: 1, ..ServeConfig::default() };
    let run = || {
        let report =
            serve(&ArchConfig::paper(), &scfg, &net, Some(&params), requests(&net, 8, 77));
        let mut by_id: Vec<(u64, usize)> =
            report.completions.iter().map(|c| (c.id, c.chip)).collect();
        by_id.sort_unstable();
        by_id
    };
    let a = run();
    assert_eq!(a, run(), "identical streams must route identically");
    // Equal-work singleton batches round-robin: every chip serves 2.
    let mut per_chip = [0usize; 4];
    for &(_, chip) in &a {
        per_chip[chip] += 1;
    }
    assert_eq!(per_chip, [2, 2, 2, 2], "{a:?}");
}

#[test]
fn report_display_mentions_every_chip() {
    let net = micro_cnn(3);
    let params = ModelParams::random(&net, 2, 1);
    let scfg = ServeConfig { chips: 2, max_batch: 2, ..ServeConfig::default() };
    let report =
        serve(&ArchConfig::paper(), &scfg, &net, Some(&params), requests(&net, 4, 13));
    let text = format!("{report}");
    assert!(text.contains("aggregate"), "{text}");
    assert!(text.contains("FPS"), "{text}");
    assert!(text.contains("engine: functional"), "{text}");
    // Flush-cause consistency surfaced in the summary line.
    assert_eq!(
        report.counters.size_flushes + report.counters.deadline_flushes
            + report.counters.drain_flushes,
        report.counters.batches
    );
}

#[test]
fn serving_matches_flush_cause_enum() {
    // FlushCause is part of the public API surface used by downstream
    // tooling; pin its variants.
    let causes = [FlushCause::Size, FlushCause::Deadline, FlushCause::Drain];
    assert_eq!(causes.len(), 3);
}

// ================================================================
// Report edge cases: empty and single-request streams.
// ================================================================

#[test]
fn empty_stream_serves_cleanly() {
    let net = micro_cnn(3);
    let params = ModelParams::random(&net, 2, 1);
    let scfg = ServeConfig { chips: 3, ..ServeConfig::default() };
    let report = serve(&ArchConfig::paper(), &scfg, &net, Some(&params), Vec::new());
    assert_eq!(report.served(), 0);
    assert_eq!(report.counters.batches, 0);
    assert_eq!(report.sim_fps(), 0.0);
    assert_eq!(report.mean_latency_ms(), 0.0);
    assert_eq!(report.p95_latency_ms(), 0.0);
    assert_eq!(report.makespan_ns(), 0.0);
    report.verify().expect("empty stream verifies");
    // Display must not divide by zero either.
    let text = format!("{report}");
    assert!(text.contains("0 requests"), "{text}");
}

#[test]
fn single_request_stream_serves_cleanly() {
    let net = micro_cnn(3);
    let params = ModelParams::random(&net, 2, 1);
    let scfg = ServeConfig { chips: 4, ..ServeConfig::default() };
    let report =
        serve(&ArchConfig::paper(), &scfg, &net, Some(&params), requests(&net, 1, 55));
    assert_eq!(report.served(), 1);
    report.verify().expect("single-request stream verifies");
    // Percentiles collapse to the one observation.
    let lat_ms = report.completions[0].latency_ns() * 1e-6;
    assert!((report.mean_latency_ms() - lat_ms).abs() < 1e-12);
    assert!((report.p95_latency_ms() - lat_ms).abs() < 1e-12);
    assert!(report.sim_fps() > 0.0);
}

// ================================================================
// Engine-generic serving: analytic and hybrid modes.
// ================================================================

#[test]
fn analytic_engine_serves_and_amortises_weights() {
    let net = small_cnn(3);
    let scfg = ServeConfig {
        chips: 2,
        max_batch: 1,
        engine: EngineMode::Analytic,
        ..ServeConfig::default()
    };
    let report = serve(&ArchConfig::paper(), &scfg, &net, None, requests(&net, 6, 70));
    assert_eq!(report.served(), 6);
    report.verify().expect("analytic identities");
    for c in &report.completions {
        assert!(c.output.is_none(), "analytic completions carry no outputs");
        assert!(c.stats.total_latency_ns() > 0.0);
        assert!(c.stats.total_energy_fj() > 0.0);
    }
    // Round-robin routing: ids 0,2,4 on chip 0 and 1,3,5 on chip 1; the
    // first request per chip streams weights (cold), the rest reuse them.
    let by_id = |id: u64| {
        report
            .completions
            .iter()
            .find(|c| c.id == id)
            .unwrap_or_else(|| panic!("request {id} missing"))
    };
    assert!(
        by_id(0).stats.total_latency_ns() > by_id(2).stats.total_latency_ns(),
        "first request per chip must be charged the weight stream"
    );
    assert_eq!(by_id(2).stats, by_id(4).stats, "warm analytic requests are identical");
    for chip in &report.chips {
        assert!(chip.weight_misses > 0, "every chip streams weights once");
        assert!(chip.weight_hits > chip.weight_misses, "warm requests dominate");
    }
}

#[test]
fn analytic_engine_serves_full_size_alexnet() {
    // The acceptance condition of the engine-generic refactor: the
    // paper's full-size benchmark serves through the same batcher /
    // router / pool / report pipeline, with no model parameters needed.
    let net = alexnet(8);
    let scfg = ServeConfig {
        chips: 4,
        max_batch: 8,
        engine: EngineMode::Analytic,
        ..ServeConfig::default()
    };
    let report = serve(&ArchConfig::paper(), &scfg, &net, None, requests(&net, 8, 90));
    assert_eq!(report.served(), 8);
    report.verify().expect("full-size analytic identities");
    assert!(report.sim_fps() > 0.0);
    assert!(report.total_energy_mj() > 0.0);
    // AlexNet ⟨8:8⟩ per-request latency is macroscopic (microseconds at
    // the very least) — well beyond the tiny functional nets.
    assert!(report.completions.iter().all(|c| c.stats.total_latency_ms() > 1e-3));
}

/// Plan a single-chip stream of `reqs` split into `per_batch`-sized
/// batches, all flushed at t=0 (metadata only — execution is the thing
/// under test).
fn plan_single_chip(reqs: Vec<Request>, per_batch: usize) -> Vec<PlannedBatch> {
    let mut planned = Vec::new();
    let mut seq = 0usize;
    let mut reqs = reqs.into_iter().peekable();
    while reqs.peek().is_some() {
        let batch: Vec<Request> = reqs.by_ref().take(per_batch).collect();
        let arrivals = vec![0.0; batch.len()];
        planned.push(PlannedBatch {
            seq,
            chip: 0,
            net: 0,
            cause: FlushCause::Size,
            flush_ns: 0.0,
            requests: batch,
            arrivals_ns: arrivals,
            est_cost_ns: 0.0,
            est_finish_ns: 0.0,
        });
        seq += 1;
    }
    planned
}

#[test]
fn intra_chip_worker_split_is_bit_identical_to_sequential() {
    // The whole point of the worker split: same simulated results, only
    // host wall time changes. Compare the full ChipResult contents for
    // 1 worker (sequential) vs several.
    let net = small_cnn(3);
    let params = ModelParams::random(&net, 3, 77);
    let factory = EngineFactory::new(ArchConfig::paper(), EngineKind::Functional);
    let run = |workers: usize| {
        execute_with_workers(
            &factory,
            &net,
            Some(&params),
            1,
            plan_single_chip(requests(&net, 9, 900), 4),
            Some(workers),
        )
    };
    let sequential = run(1);
    for &w in &[2usize, 3, 8] {
        let parallel = run(w);
        assert_eq!(parallel.len(), sequential.len());
        for (p, s) in parallel.iter().zip(&sequential) {
            assert_eq!(p.weight_hits, s.weight_hits, "workers={w}");
            assert_eq!(p.weight_misses, s.weight_misses, "workers={w}");
            assert_eq!(p.batches.len(), s.batches.len());
            for (pb, sb) in p.batches.iter().zip(&s.batches) {
                assert_eq!(pb.seq, sb.seq);
                assert_eq!(pb.requests.len(), sb.requests.len());
                for (pr, sr) in pb.requests.iter().zip(&sb.requests) {
                    assert_eq!(pr.id, sr.id, "workers={w}");
                    assert_eq!(pr.stats, sr.stats, "workers={w} request {}", pr.id);
                    assert_eq!(pr.output, sr.output, "workers={w} request {}", pr.id);
                }
            }
        }
    }
}

#[test]
fn serve_uses_the_worker_split_transparently() {
    // End-to-end: the public serve() path (auto worker budget) must
    // produce the same verified report shape as always — outputs
    // bit-exact, identities holding — whatever the host parallelism.
    let net = small_cnn(3);
    let params = ModelParams::random(&net, 3, 55);
    let reqs = requests(&net, 12, 700);
    let images: Vec<QTensor> = reqs.iter().map(|r| r.image.clone()).collect();
    let scfg = ServeConfig { chips: 1, max_batch: 12, ..ServeConfig::default() };
    let report = serve(&ArchConfig::paper(), &scfg, &net, Some(&params), reqs);
    assert_eq!(report.served(), 12);
    report.verify().expect("identities under the worker split");
    for c in &report.completions {
        let golden = ref_exec::execute(&net, &params, &images[c.id as usize]);
        assert_eq!(
            c.output.as_ref().expect("functional outputs"),
            golden.last().unwrap(),
            "request {}",
            c.id
        );
    }
    // Sequential residency ledger: one stream, the rest hits.
    let convs = report.chips[0].weight_misses;
    assert!(convs > 0);
    assert_eq!(report.chips[0].weight_hits, convs * 11);
}

#[test]
fn hybrid_mode_spot_checks_small_presets() {
    let net = small_cnn(3);
    let params = ModelParams::random(&net, 3, 17);
    let scfg = ServeConfig {
        chips: 2,
        max_batch: 2,
        engine: EngineMode::Hybrid { check_every: 2 },
        ..ServeConfig::default()
    };
    let report =
        serve(&ArchConfig::paper(), &scfg, &net, Some(&params), requests(&net, 6, 60));
    report.verify().expect("hybrid identities incl. spot-check band");
    let sc = report.spot_check.expect("small preset => functional replay possible");
    assert_eq!(sc.checked, 3, "positions 0, 2, 4 replayed");
    assert!(sc.passed(), "latency {:?} energy {:?}", sc.latency_ratio, sc.energy_ratio);
    assert!(sc.latency_ratio.0 <= sc.latency_ratio.1);
    // Hybrid serves analytically: no outputs on the completions.
    assert!(report.completions.iter().all(|c| c.output.is_none()));
}

// ================================================================
// Per-network SLO lanes and the host-worker knob.
// ================================================================

#[test]
fn mixed_stream_requests_never_wait_past_their_lane_deadline() {
    // The SLO invariant, end to end: with per-network flush lanes, no
    // request's batcher wait exceeds its own lane's deadline — the
    // tight small_cnn lane cannot be held hostage by AlexNet's slowly
    // filling batches. Arrivals are slow enough (and max_batch large
    // enough) that every non-drain flush is deadline-driven, so the
    // invariant is exercised at its boundary.
    let big = alexnet(8);
    let small = small_cnn(3);
    let pool = PoolSpec::homogeneous(ArchConfig::paper(), EngineKind::Analytic, 2);
    let scfg = ServeConfig {
        chips: 2,
        max_batch: 16,
        deadline_us: 400.0,
        slo: SloPolicy::global().with_deadline_us(1, 30.0),
        arrival_interval_ns: 15_000.0,
        engine: EngineMode::Analytic,
        ..ServeConfig::default()
    };
    let n = 10usize;
    let streams = vec![
        (0..n)
            .map(|i| QTensor::random(big.input.0, big.input.1, big.input.2, 8, 800 + i as u64))
            .collect(),
        (0..n)
            .map(|i| {
                QTensor::random(
                    small.input.0,
                    small.input.1,
                    small.input.2,
                    small.input_bits,
                    900 + i as u64,
                )
            })
            .collect(),
    ];
    let nets = [
        ServedNetwork { net: &big, params: None },
        ServedNetwork { net: &small, params: None },
    ];
    let report = serve_pool(&pool, &scfg, &nets, Request::interleave(streams));
    assert_eq!(report.served(), 2 * n);
    report.verify().expect("per-network roll-up identities");
    assert!(report.counters.deadline_flushes > 0, "lanes must flush on their deadlines");

    // Per-request: batcher wait bounded by the request's OWN lane.
    let lane_deadline_ns = [400.0 * 1e3, 30.0 * 1e3];
    for c in &report.completions {
        assert!(
            c.batcher_wait_ns() <= lane_deadline_ns[c.net] + 1e-6,
            "request {} (net {}) waited {} ns past its lane deadline",
            c.id,
            c.net,
            c.batcher_wait_ns()
        );
    }
    // Per-network roll-ups agree: both lanes fully served, no
    // violations, and the tight lane's worst wait is bounded by ITS
    // deadline, not the relaxed global one.
    assert_eq!(report.networks.len(), 2);
    for nr in &report.networks {
        assert_eq!(nr.served, n as u64, "net {} ({})", nr.net, nr.name);
        assert_eq!(nr.deadline_violations, 0, "net {} ({})", nr.net, nr.name);
    }
    assert!(report.networks[1].max_batcher_wait_ns <= 30.0 * 1e3 + 1e-6);
    assert!((report.networks[1].deadline_ns - 30.0 * 1e3).abs() < 1e-9);
}

#[test]
fn host_worker_count_never_changes_simulated_results() {
    // Regression for the `host_workers` knob (née NANDSPIN_HOST_WORKERS):
    // host-side parallelism is a wall-clock optimisation only — the
    // simulated stream is defined by the plan, so every worker budget
    // must yield the identical report, bit for bit.
    let net = small_cnn(3);
    let params = ModelParams::random(&net, 3, 41);
    let run = |workers: usize| {
        let scfg = ServeConfig {
            chips: 1,
            max_batch: 12,
            host_workers: Some(workers),
            ..ServeConfig::default()
        };
        serve(&ArchConfig::paper(), &scfg, &net, Some(&params), requests(&net, 12, 410))
    };
    let one = run(1);
    let four = run(4);
    one.verify().expect("identities at 1 worker");
    four.verify().expect("identities at 4 workers");
    assert_eq!(one.served(), 12);
    assert_eq!(one.served(), four.served());
    for (a, b) in one.completions.iter().zip(&four.completions) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.chip, b.chip);
        assert_eq!(a.stats, b.stats, "request {}", a.id);
        assert_eq!(a.output, b.output, "request {}", a.id);
        assert!((a.finish_ns - b.finish_ns).abs() < 1e-9, "request {}", a.id);
    }
}

#[test]
fn hybrid_mode_degrades_to_analytic_on_full_size_networks() {
    // The multi-tile mapping makes AlexNet replayable on the functional
    // engine, but no params are supplied here — the serve must still
    // complete, with the spot-check skipped (hybrid fidelity with params
    // is covered in tests/engines.rs).
    let net = alexnet(8);
    let scfg = ServeConfig {
        chips: 2,
        max_batch: 4,
        engine: EngineMode::Hybrid { check_every: 2 },
        ..ServeConfig::default()
    };
    let report = serve(&ArchConfig::paper(), &scfg, &net, None, requests(&net, 4, 31));
    assert_eq!(report.served(), 4);
    report.verify().expect("degraded hybrid identities");
    assert!(report.spot_check.is_none(), "no functional replay possible");
}
