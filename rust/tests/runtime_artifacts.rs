//! Runtime integration: load the AOT JAX/Pallas artifacts and check
//! their numerics against the Rust golden implementations. Skipped (with
//! a note) when `artifacts/` has not been generated yet or when no PJRT
//! execution backend is linked (the default offline build — see
//! `nandspin::runtime`).

use nandspin::cnn::ref_exec::WideTensor;
use nandspin::cnn::tensor::{Kernel4, QTensor};
use nandspin::runtime::{ArgI32, Artifact, Runtime, RuntimeError};

/// Load `name`, or return `None` (with a note) when the artifact or the
/// execution backend is unavailable in this build.
fn load(name: &str) -> Option<Artifact> {
    let rt = Runtime::new("artifacts").expect("runtime");
    match rt.load(name) {
        Ok(a) => Some(a),
        Err(e @ RuntimeError::MissingArtifact(_)) => {
            eprintln!("skipping: {e}");
            None
        }
        Err(e @ RuntimeError::BackendUnavailable { .. }) => {
            eprintln!("skipping: {e}");
            None
        }
    }
}

#[test]
fn bitconv_artifact_matches_golden_conv() {
    let Some(artifact) = load("bitconv") else { return };
    // Shapes fixed at lowering time: x (2,8,12) 3-bit, w (3,2,3,3) 3-bit.
    let x = QTensor::random(2, 8, 12, 3, 11);
    let w = Kernel4::random(3, 2, 3, 3, 3, 12);
    let outs = artifact
        .run_i32(&[ArgI32::from_qtensor(&x), ArgI32::from_kernel(&w)])
        .expect("execute bitconv");
    // Golden: plain integer conv via the reference executor path.
    let wide = WideTensor::from_q(&x);
    let golden = {
        use nandspin::cnn::layer::Layer;
        use nandspin::cnn::network::{Network, Node};
        use nandspin::cnn::ref_exec::{execute, ModelParams};
        let net = Network {
            name: "conv-only".into(),
            input: (2, 8, 12),
            input_bits: 3,
            nodes: vec![Node {
                layer: Layer::Conv { out_c: 3, kh: 3, kw: 3, stride: 1, pad: 0 },
                input: None,
            }],
        };
        let params = ModelParams { conv_weights: vec![w.clone()], bn: vec![], quant: vec![] };
        execute(&net, &params, &x).pop().unwrap()
    };
    let _ = wide;
    let got: Vec<i64> = outs[0].iter().map(|&v| v as i64).collect();
    assert_eq!(got, golden.data, "PJRT bitconv vs golden integer conv");
}

#[test]
fn quantize_artifact_matches_quantparams() {
    let Some(artifact) = load("quantize") else { return };
    use nandspin::cnn::quantize::QuantParams;
    let p = QuantParams { mul: 3, add: 64, shift: 7, bits: 4 };
    let xs: Vec<i32> = (0..64).map(|i| i * 13 % 1024).collect();
    let outs = artifact
        .run_i32(&[
            ArgI32::vec(xs.clone()),
            ArgI32::vec(vec![p.mul as i32, p.add as i32, p.shift as i32, 15]),
        ])
        .expect("execute quantize");
    let want: Vec<i32> = xs.iter().map(|&x| p.apply(x as i64) as i32).collect();
    assert_eq!(outs[0], want);
}

#[test]
fn maxpool_artifact_matches_golden() {
    let Some(artifact) = load("maxpool") else { return };
    let x = QTensor::random(4, 12, 20, 8, 21);
    let outs = artifact.run_i32(&[ArgI32::from_qtensor(&x)]).expect("execute maxpool");
    // golden 2/2 maxpool
    let mut want = Vec::new();
    for c in 0..4 {
        for y in 0..6 {
            for xx in 0..10 {
                let m = [(0, 0), (0, 1), (1, 0), (1, 1)]
                    .iter()
                    .map(|&(dy, dx)| x.at(c, y * 2 + dy, xx * 2 + dx))
                    .max()
                    .unwrap();
                want.push(m as i32);
            }
        }
    }
    assert_eq!(outs[0], want);
}
