//! Bench: serving-runtime sweep over batch size × chip count.
//!
//! Serves a fixed closed burst of requests through the batched
//! multi-chip runtime for every (batch, chips) cell and reports
//! simulated throughput, mean/p95 latency, per-request energy and the
//! weight-residency hit rate — the serving-scale view of the paper's
//! Table 3 condition (weights streamed once per chip, reused across
//! the batch).

use std::time::Instant;

use nandspin::arch::config::ArchConfig;
use nandspin::cnn::network::small_cnn;
use nandspin::cnn::ref_exec::ModelParams;
use nandspin::cnn::tensor::QTensor;
use nandspin::coordinator::serve::{serve, Request, ServeConfig};

fn main() {
    let t0 = Instant::now();
    let net = small_cnn(3);
    let params = ModelParams::random(&net, 3, 5);
    let n = 16usize;
    let images: Vec<QTensor> = (0..n)
        .map(|i| {
            QTensor::random(net.input.0, net.input.1, net.input.2, net.input_bits, 40 + i as u64)
        })
        .collect();

    println!("== serving sweep: {} requests of {} (closed burst) ==", n, net.name);
    println!(
        "{:>6} {:>6} {:>10} {:>12} {:>12} {:>12} {:>10}",
        "batch", "chips", "FPS", "mean (µs)", "p95 (µs)", "mJ/req", "wt hit%"
    );
    for &batch in &[1usize, 4, 16] {
        for &chips in &[1usize, 2, 4] {
            let scfg = ServeConfig {
                chips,
                max_batch: batch,
                ..ServeConfig::default()
            };
            let requests: Vec<Request> = Request::stream(images.clone());
            let report = serve(&ArchConfig::paper(), &scfg, &net, &params, requests);
            report.verify().expect("aggregation identities");
            assert_eq!(report.served(), n);
            let (hits, misses) = report
                .chips
                .iter()
                .fold((0u64, 0u64), |(h, m), c| (h + c.weight_hits, m + c.weight_misses));
            println!(
                "{:>6} {:>6} {:>10.1} {:>12.2} {:>12.2} {:>12.4} {:>9.1}%",
                batch,
                chips,
                report.sim_fps(),
                report.mean_latency_ms() * 1e3,
                report.p95_latency_ms() * 1e3,
                report.total_energy_mj() / n as f64,
                100.0 * hits as f64 / (hits + misses).max(1) as f64
            );
        }
    }
    println!("\n[bench wall time: {:.2} s]", t0.elapsed().as_secs_f64());
}
