//! Bench: serving-runtime sweep over batch size × chip count × engine.
//!
//! Serves a fixed closed burst of requests through the batched
//! multi-chip runtime for every (engine, batch, chips) cell and reports
//! simulated throughput, mean/p95 latency, per-request energy and the
//! weight-residency hit rate — the serving-scale view of the paper's
//! Table 3 condition (weights streamed once per chip, reused across
//! the batch). The functional and analytic engines run the identical
//! stream, so the grid doubles as an engine-agreement check at serving
//! scale.
//!
//! Two networks are swept: `small_cnn`, which fits one subarray per
//! bit-plane (the untiled functional path), and `wide_cnn`, whose
//! 200-column feature map forces the multi-tile mapping (§4.2, Fig. 9)
//! — its functional rows measure the tiled path at serving scale. A
//! third, mixed sweep serves `alexnet` + `small_cnn` together over a
//! heterogeneous two-chip pool with per-network SLO lanes, tracking
//! per-network tail latency and deadline violations.
//!
//! Besides the human table, the bench writes `BENCH_serving.json`
//! (same grid, machine-readable, one `network` key per row) so the
//! perf trajectory can be tracked across PRs. Every serve runs traced
//! (`ServeConfig::trace`), so each grid cell also records the
//! per-layer **simulated** cost profile (`"layer_profile"`: mean
//! per-request latency/energy per network node, folded across chips) —
//! the observability layer's cost attribution, tracked across PRs
//! alongside the aggregate numbers.

use std::time::Instant;

use nandspin::arch::config::ArchConfig;
use nandspin::cnn::network::{alexnet, small_cnn, wide_cnn, Network};
use nandspin::cnn::ref_exec::ModelParams;
use nandspin::cnn::tensor::QTensor;
use nandspin::coordinator::engine::{EngineKind, PoolSpec};
use nandspin::coordinator::serve::{
    serve, serve_pool, EngineMode, Request, ServeConfig, ServeReport, ServedNetwork, SloPolicy,
};
use nandspin::trace::merge_layer_costs;

/// Per-layer simulated cost summary of a traced run, as a JSON array:
/// chips' `LayerCostProfile`s merged per network, one object per node
/// with its mean per-request latency (µs) and energy (mJ).
fn layer_profile_json(report: &ServeReport) -> String {
    let mut merged = None;
    for c in &report.chips {
        merge_layer_costs(&mut merged, c.layer_costs.clone());
    }
    let Some(profiles) = merged else { return "[]".to_string() };
    let mut entries = Vec::new();
    for p in &profiles {
        let requests = p.requests.max(1) as f64;
        for l in &p.layers {
            entries.push(format!(
                "{{\"network\": \"{}\", \"node\": {}, \"label\": \"{}\", \
                 \"latency_us_per_req\": {:.4}, \"mj_per_req\": {:.6}}}",
                p.network,
                l.node,
                l.label,
                l.stats.total_latency_ns() * 1e-3 / requests,
                l.stats.total_energy_mj() / requests,
            ));
        }
    }
    format!("[{}]", entries.join(", "))
}

/// Serve `n` requests of `net` for every (engine, batch, chips) cell,
/// printing the human table rows and appending JSON rows to `rows`.
fn sweep(
    net: &Network,
    n: usize,
    engines: &[EngineMode],
    batches: &[usize],
    chip_counts: &[usize],
    rows: &mut Vec<String>,
) {
    let params = ModelParams::random(net, 3, 5);
    let images: Vec<QTensor> = (0..n)
        .map(|i| {
            QTensor::random(net.input.0, net.input.1, net.input.2, net.input_bits, 40 + i as u64)
        })
        .collect();
    for &engine in engines {
        for &batch in batches {
            for &chips in chip_counts {
                let scfg = ServeConfig {
                    chips,
                    max_batch: batch,
                    engine,
                    trace: true,
                    ..ServeConfig::default()
                };
                let requests: Vec<Request> = Request::stream(images.clone());
                let report = serve(&ArchConfig::paper(), &scfg, net, Some(&params), requests);
                report.verify().expect("aggregation identities");
                assert_eq!(report.served(), n);
                let (hits, misses) = report
                    .chips
                    .iter()
                    .fold((0u64, 0u64), |(h, m), c| (h + c.weight_hits, m + c.weight_misses));
                let hit_rate = hits as f64 / (hits + misses).max(1) as f64;
                let mean_us = report.mean_latency_ms() * 1e3;
                let p95_us = report.p95_latency_ms() * 1e3;
                let mj_per_req = report.total_energy_mj() / n as f64;
                println!(
                    "{:>10} {:>10} {:>6} {:>6} {:>10.1} {:>12.2} {:>12.2} {:>12.4} {:>9.1}%",
                    net.name,
                    engine.label(),
                    batch,
                    chips,
                    report.sim_fps(),
                    mean_us,
                    p95_us,
                    mj_per_req,
                    100.0 * hit_rate
                );
                rows.push(format!(
                    "    {{\"network\": \"{}\", \"engine\": \"{}\", \"batch\": {}, \
                     \"chips\": {}, \"sim_fps\": {:.3}, \"mean_latency_us\": {:.3}, \
                     \"p95_latency_us\": {:.3}, \"mj_per_request\": {:.6}, \
                     \"weight_hit_rate\": {:.4}, \"wall_s\": {:.4}, \
                     \"layer_profile\": {}}}",
                    net.name,
                    engine.label(),
                    batch,
                    chips,
                    report.sim_fps(),
                    mean_us,
                    p95_us,
                    mj_per_req,
                    hit_rate,
                    report.wall_seconds,
                    layer_profile_json(&report)
                ));
            }
        }
    }
}

/// Mixed-network SLO rows: an `alexnet` + `small_cnn` stream over a
/// heterogeneous two-chip pool (paper point vs a narrow 32-bit bus),
/// analytic engine, open arrivals. Each network batches in its own SLO
/// lane (alexnet relaxed, small_cnn tight) and the cost-aware router
/// schedules on each chip's own closed-form batching law — these rows
/// track the per-network tail latency and violation count across PRs.
fn sweep_mixed(batches: &[usize], n: usize, rows: &mut Vec<String>) {
    let big = alexnet(8);
    let small = small_cnn(3);
    let mut narrow = ArchConfig::paper();
    narrow.bus_width_bits = 32;
    let pool = PoolSpec::heterogeneous(vec![ArchConfig::paper(), narrow], EngineKind::Analytic);
    let nets = [
        ServedNetwork { net: &big, params: None },
        ServedNetwork { net: &small, params: None },
    ];
    let streams = |seed: u64| -> Vec<Request> {
        Request::interleave(vec![
            (0..n)
                .map(|i| {
                    QTensor::random(big.input.0, big.input.1, big.input.2, 8, seed + i as u64)
                })
                .collect(),
            (0..n)
                .map(|i| {
                    QTensor::random(
                        small.input.0,
                        small.input.1,
                        small.input.2,
                        small.input_bits,
                        seed + 1000 + i as u64,
                    )
                })
                .collect(),
        ])
    };
    for &batch in batches {
        let scfg = ServeConfig {
            chips: pool.chips(),
            max_batch: batch,
            engine: EngineMode::Analytic,
            arrival_interval_ns: 20_000.0,
            slo: SloPolicy::global().with_deadline_us(0, 500.0).with_deadline_us(1, 50.0),
            trace: true,
            ..ServeConfig::default()
        };
        let report = serve_pool(&pool, &scfg, &nets, streams(70));
        report.verify().expect("aggregation identities");
        assert_eq!(report.served(), 2 * n);
        let violations: u64 = report.networks.iter().map(|nr| nr.deadline_violations).sum();
        for nr in &report.networks {
            let label = format!("mix:{}", nr.name);
            println!(
                "{:>14} {:>10} {:>6} {:>6} {:>10.1} {:>12.2} {:>12.2} {:>12.4} {:>9}",
                label,
                "analytic",
                batch,
                pool.chips(),
                report.sim_fps(),
                nr.mean_latency_ms() * 1e3,
                nr.p95_latency_ns * 1e-3,
                nr.stats.total_energy_mj() / nr.served.max(1) as f64,
                nr.deadline_violations,
            );
        }
        rows.push(format!(
            "    {{\"network\": \"mixed(alexnet+small_cnn)\", \"engine\": \"analytic\", \
             \"batch\": {}, \"chips\": {}, \"sim_fps\": {:.3}, \
             \"mean_latency_us\": {:.3}, \"p95_latency_us\": {:.3}, \
             \"mj_per_request\": {:.6}, \"slo_violations\": {}, \"wall_s\": {:.4}, \
             \"layer_profile\": {}}}",
            batch,
            pool.chips(),
            report.sim_fps(),
            report.mean_latency_ms() * 1e3,
            report.p95_latency_ms() * 1e3,
            report.total_energy_mj() / (2 * n) as f64,
            violations,
            report.wall_seconds,
            layer_profile_json(&report)
        ));
    }
}

fn main() {
    let t0 = Instant::now();
    let net = small_cnn(3);
    let wide = wide_cnn(3);
    let n = 16usize;

    println!("== serving sweep: {n} requests per cell (closed burst) ==");
    println!(
        "{:>10} {:>10} {:>6} {:>6} {:>10} {:>12} {:>12} {:>12} {:>10}",
        "network", "engine", "batch", "chips", "FPS", "mean (µs)", "p95 (µs)", "mJ/req", "wt hit%"
    );
    let mut rows: Vec<String> = Vec::new();
    sweep(
        &net,
        n,
        &[EngineMode::Functional, EngineMode::Analytic],
        &[1, 4, 16],
        &[1, 2, 4],
        &mut rows,
    );
    // The tiled-functional cells: wide_cnn splits into two width tiles
    // with a 2-column halo on the paper's 256x128 subarray, so these
    // rows track the multi-tile path's serving cost across PRs.
    sweep(&wide, n, &[EngineMode::Functional], &[1, 4], &[1, 2], &mut rows);

    println!("\n== mixed-network SLO sweep: alexnet+small_cnn, heterogeneous 2-chip pool ==");
    println!(
        "{:>14} {:>10} {:>6} {:>6} {:>10} {:>12} {:>12} {:>12} {:>9}",
        "network", "engine", "batch", "chips", "FPS", "mean (µs)", "p95 (µs)", "mJ/req", "SLO viol"
    );
    sweep_mixed(&[1, 4, 16], n, &mut rows);

    let json = format!(
        "{{\n  \"bench\": \"serving\",\n  \"network\": \"{}\",\n  \"requests\": {},\n  \
         \"grid\": [\n{}\n  ]\n}}\n",
        net.name,
        n,
        rows.join(",\n")
    );
    std::fs::write("BENCH_serving.json", &json).expect("write BENCH_serving.json");
    println!("\n[wrote BENCH_serving.json: {} grid cells]", rows.len());
    println!("[bench wall time: {:.2} s]", t0.elapsed().as_secs_f64());
}
