//! Bench: serving-runtime sweep over batch size × chip count × engine.
//!
//! Serves a fixed closed burst of requests through the batched
//! multi-chip runtime for every (engine, batch, chips) cell and reports
//! simulated throughput, mean/p95 latency, per-request energy and the
//! weight-residency hit rate — the serving-scale view of the paper's
//! Table 3 condition (weights streamed once per chip, reused across
//! the batch). The functional and analytic engines run the identical
//! stream, so the grid doubles as an engine-agreement check at serving
//! scale.
//!
//! Besides the human table, the bench writes `BENCH_serving.json`
//! (same grid, machine-readable) so the perf trajectory can be tracked
//! across PRs.

use std::time::Instant;

use nandspin::arch::config::ArchConfig;
use nandspin::cnn::network::small_cnn;
use nandspin::cnn::ref_exec::ModelParams;
use nandspin::cnn::tensor::QTensor;
use nandspin::coordinator::serve::{serve, EngineMode, Request, ServeConfig};

fn main() {
    let t0 = Instant::now();
    let net = small_cnn(3);
    let params = ModelParams::random(&net, 3, 5);
    let n = 16usize;
    let images: Vec<QTensor> = (0..n)
        .map(|i| {
            QTensor::random(net.input.0, net.input.1, net.input.2, net.input_bits, 40 + i as u64)
        })
        .collect();

    println!("== serving sweep: {} requests of {} (closed burst) ==", n, net.name);
    println!(
        "{:>10} {:>6} {:>6} {:>10} {:>12} {:>12} {:>12} {:>10}",
        "engine", "batch", "chips", "FPS", "mean (µs)", "p95 (µs)", "mJ/req", "wt hit%"
    );
    let mut rows: Vec<String> = Vec::new();
    for &engine in &[EngineMode::Functional, EngineMode::Analytic] {
        for &batch in &[1usize, 4, 16] {
            for &chips in &[1usize, 2, 4] {
                let scfg = ServeConfig {
                    chips,
                    max_batch: batch,
                    engine,
                    ..ServeConfig::default()
                };
                let requests: Vec<Request> = Request::stream(images.clone());
                let report = serve(&ArchConfig::paper(), &scfg, &net, Some(&params), requests);
                report.verify().expect("aggregation identities");
                assert_eq!(report.served(), n);
                let (hits, misses) = report
                    .chips
                    .iter()
                    .fold((0u64, 0u64), |(h, m), c| (h + c.weight_hits, m + c.weight_misses));
                let hit_rate = hits as f64 / (hits + misses).max(1) as f64;
                let mean_us = report.mean_latency_ms() * 1e3;
                let p95_us = report.p95_latency_ms() * 1e3;
                let mj_per_req = report.total_energy_mj() / n as f64;
                println!(
                    "{:>10} {:>6} {:>6} {:>10.1} {:>12.2} {:>12.2} {:>12.4} {:>9.1}%",
                    engine.label(),
                    batch,
                    chips,
                    report.sim_fps(),
                    mean_us,
                    p95_us,
                    mj_per_req,
                    100.0 * hit_rate
                );
                rows.push(format!(
                    "    {{\"engine\": \"{}\", \"batch\": {}, \"chips\": {}, \
                     \"sim_fps\": {:.3}, \"mean_latency_us\": {:.3}, \
                     \"p95_latency_us\": {:.3}, \"mj_per_request\": {:.6}, \
                     \"weight_hit_rate\": {:.4}, \"wall_s\": {:.4}}}",
                    engine.label(),
                    batch,
                    chips,
                    report.sim_fps(),
                    mean_us,
                    p95_us,
                    mj_per_req,
                    hit_rate,
                    report.wall_seconds
                ));
            }
        }
    }

    let json = format!(
        "{{\n  \"bench\": \"serving\",\n  \"network\": \"{}\",\n  \"requests\": {},\n  \
         \"grid\": [\n{}\n  ]\n}}\n",
        net.name,
        n,
        rows.join(",\n")
    );
    std::fs::write("BENCH_serving.json", &json).expect("write BENCH_serving.json");
    println!("\n[wrote BENCH_serving.json: {} grid cells]", rows.len());
    println!("[bench wall time: {:.2} s]", t0.elapsed().as_secs_f64());
}
