//! Bench: regenerate Fig. 16 — latency and energy breakdown of the
//! proposed accelerator on ResNet50 ⟨8:8⟩.

use std::time::Instant;

use nandspin::arch::stats::Phase;
use nandspin::cnn::network::resnet50;
use nandspin::coordinator::Coordinator;

/// Paper shares for reference (latency %, energy %).
const PAPER: [(&str, f64, f64); 6] = [
    ("load data", 38.4, 32.6),
    ("convolution", 33.9, 35.5),
    ("data transfer", 4.8, 4.9),
    ("pooling", 13.2, 15.4),
    ("batch norm", 4.4, 5.1),
    ("quantization", 5.3, 6.5),
];

fn main() {
    let t0 = Instant::now();
    let coord = Coordinator::paper();
    let net = resnet50(8);
    let st = coord.analytic_stats(&net, 8);
    println!("== Fig. 16: ResNet50 ⟨8:8⟩ breakdown (measured vs paper) ==");
    println!("total: {:.3} ms, {:.3} mJ ({:.1} FPS)", st.total_latency_ms(), st.total_energy_mj(),
        1000.0 / st.total_latency_ms());
    println!(
        "{:<16} {:>10} {:>10} {:>10} {:>10}",
        "phase", "lat %", "paper %", "energy %", "paper %"
    );
    for &p in &Phase::ALL {
        let lat = 100.0 * st[p].latency_ns / st.total_latency_ns();
        let en = 100.0 * st[p].energy_fj / st.total_energy_fj();
        let (pl, pe) = PAPER
            .iter()
            .find(|(n, _, _)| *n == p.label())
            .map(|&(_, l, e)| (l, e))
            .unwrap_or((0.0, 0.0));
        println!("{:<16} {:>10.1} {:>10.1} {:>10.1} {:>10.1}", p.label(), lat, pl, en, pe);
    }
    println!("\n[bench wall time: {:.2} s]", t0.elapsed().as_secs_f64());
}
