//! Bench: functional-engine hot paths, with packed-vs-scalar ratios.
//!
//! The bit-accurate engine's throughput comes from the word-parallel
//! host representation (packed `u128` rows, bit-sliced counters, plane
//! folds). This bench times each leg of that hot path — the bitwise
//! conv pass, the composed add/multiply primitives, and a full
//! small-network inference — and, for the legs where it is meaningful,
//! times a faithful emulation of the pre-refactor scalar per-column
//! host loops over the *same* device-op sequence, so the speedup of
//! the packed representation is measured (not asserted) on every run.
//!
//! Results are written to `BENCH_functional.json` (machine-readable,
//! one snapshot per run — same contract as `BENCH_serving.json`) next
//! to the human table, so the functional-leg trajectory is tracked
//! across PRs.

use std::hint::black_box;
use std::time::Instant;

use nandspin::arch::config::ArchConfig;
use nandspin::arch::stats::{Phase, Stats};
use nandspin::cnn::network::{preset, small_cnn};
use nandspin::cnn::ref_exec::ModelParams;
use nandspin::cnn::tensor::QTensor;
use nandspin::coordinator::engine::{EngineFactory, EngineKind};
use nandspin::coordinator::serve::pool::{execute_with_workers, PlannedBatch};
use nandspin::coordinator::serve::{FlushCause, Request};
use nandspin::coordinator::FunctionalEngine;
use nandspin::device::energy::DeviceCosts;
use nandspin::subarray::conv::{
    bitplane_conv_counts_tiled, window_sum_planes, BitKernel, ConvGeometry,
};
use nandspin::subarray::primitives::{add_columns, multiply_columns};
use nandspin::subarray::{BitCounterBank, Subarray};
use nandspin::util::Rng;

fn time<F: FnMut()>(iters: u32, mut f: F) -> f64 {
    f(); // warmup
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_secs_f64() / iters as f64
}

/// Scalar emulation of the pre-refactor per-column counter bank: one
/// `u32` per column, updated with a 128-iteration walk per accumulate.
fn scalar_accumulate(counters: &mut [u32; 128], row: u128) {
    for (col, c) in counters.iter_mut().enumerate() {
        *c += ((row >> col) & 1) as u32;
    }
}

/// Scalar emulation of the pre-refactor conv pass host work: tiling
/// words rebuilt bit-by-bit per call, drained counts scattered into a
/// per-column `Vec<u32>`, window sums folded column by column. The
/// device-op sequence (buffer loads, ANDs, drains) is the same as the
/// packed pass — only the host bookkeeping differs.
fn scalar_conv_pass(
    sub: &mut Subarray,
    geo: ConvGeometry,
    kernel: &BitKernel,
    stats: &mut Stats,
) -> Vec<Vec<u32>> {
    let out_h = geo.out_h(kernel.kh);
    let out_w = geo.out_w(kernel.kw);
    let count_bits = 32 - (kernel.kh as u32).leading_zeros();
    let mut all = Vec::new();
    for p in 0..kernel.kw {
        for kr in 0..kernel.kh {
            let word = kernel.tile_row(kr, p, geo.in_w); // rebuilt per call
            sub.buffer_write(kr, word, stats, Phase::Convolution);
        }
        for or in 0..out_h {
            sub.counters.reset();
            let r0 = or * geo.stride;
            for kr in 0..kernel.kh {
                sub.and_count(r0 + kr, kr, stats, Phase::Convolution);
            }
            let mut counts = vec![0u32; geo.in_w];
            for bitpos in 0..count_bits {
                let lsbs = sub.counter_lsbs_shift(stats, Phase::Convolution);
                for (j, c) in counts.iter_mut().enumerate() {
                    *c |= (((lsbs >> j) & 1) as u32) << bitpos;
                }
            }
            all.push((p, or, counts));
        }
    }
    // Per-column window fold.
    let mut out = vec![vec![0u32; out_w]; out_h];
    for (p, or, counts) in &all {
        for oc in 0..out_w {
            let c0 = oc * geo.stride;
            if c0 % kernel.kw != *p {
                continue;
            }
            out[*or][oc] = (0..kernel.kw).map(|kc| counts[c0 + kc]).sum();
        }
    }
    out
}

fn main() {
    let t0 = Instant::now();
    let mut rng = Rng::seed_from_u64(0xF0);
    println!("== functional-engine microbenchmarks (packed host representation) ==");

    // ---- Leg 1: counter accumulate, packed vs scalar. ----------------
    let rows: Vec<u128> =
        (0..64).map(|_| (rng.next_u64() as u128) << 64 | rng.next_u64() as u128).collect();
    let mut bank = BitCounterBank::new(128);
    let packed_acc = time(20_000, || {
        bank.reset();
        for &r in &rows {
            bank.accumulate(black_box(r));
        }
    }) / rows.len() as f64;
    let mut scalar_bank = [0u32; 128];
    let scalar_acc = time(2_000, || {
        scalar_bank = [0u32; 128];
        for &r in &rows {
            scalar_accumulate(&mut scalar_bank, black_box(r));
        }
    }) / rows.len() as f64;
    let acc_speedup = scalar_acc / packed_acc.max(f64::MIN_POSITIVE);
    println!(
        "counter accumulate     packed {:>8.1} ns  scalar {:>8.1} ns  ({:.1}x)",
        packed_acc * 1e9,
        scalar_acc * 1e9,
        acc_speedup
    );

    // ---- Leg 2: one full bit-plane conv pass, packed vs scalar. ------
    let geo = ConvGeometry { in_h: 64, in_w: 128, stride: 1 };
    let kbits: Vec<bool> = (0..9).map(|_| rng.gen_bool()).collect();
    let kernel = BitKernel::new(3, 3, kbits);
    let tiling = kernel.tilings(geo.in_w);
    let mut sub = Subarray::new(256, 128, 16, DeviceCosts::default());
    let mut stats = Stats::default();
    for r in 0..geo.in_h {
        let word = (rng.next_u64() as u128) << 64 | rng.next_u64() as u128;
        sub.write_row(r, word, &mut stats, Phase::LoadData);
    }
    let packed_conv = time(400, || {
        let counts =
            bitplane_conv_counts_tiled(&mut sub, 0, geo, &tiling, &mut stats, Phase::Convolution);
        black_box(window_sum_planes(&counts, geo, 3, 3));
    });
    let scalar_conv = time(100, || {
        black_box(scalar_conv_pass(&mut sub, geo, &kernel, &mut stats));
    });
    let conv_speedup = scalar_conv / packed_conv.max(f64::MIN_POSITIVE);
    println!(
        "conv pass 3x3 @64x128  packed {:>8.1} µs  scalar {:>8.1} µs  ({:.1}x)",
        packed_conv * 1e6,
        scalar_conv * 1e6,
        conv_speedup
    );

    // ---- Leg 3: composed primitives. ---------------------------------
    let mut sub2 = Subarray::new(256, 128, 16, DeviceCosts::default());
    for b in 0..64 {
        let word = (rng.next_u64() as u128) << 64 | rng.next_u64() as u128;
        sub2.write_row(b, word, &mut stats, Phase::LoadData);
    }
    let bases: Vec<usize> = (0..8).map(|i| i * 8).collect();
    let add_us = time(2_000, || {
        black_box(add_columns(&mut sub2, &bases, 8, 128, &mut stats, Phase::Pooling));
    }) * 1e6;
    for j in 0..8 {
        let word = (rng.next_u64() as u128) << 64 | rng.next_u64() as u128;
        sub2.buffer_write(j, word, &mut stats, Phase::LoadData);
    }
    let buf_rows: Vec<usize> = (0..8).collect();
    let mul_us = time(1_000, || {
        black_box(multiply_columns(&mut sub2, 0, 8, &buf_rows, 128, &mut stats, Phase::BatchNorm));
    }) * 1e6;
    println!("add_columns 8x8b       {add_us:>8.2} µs/op");
    println!("multiply_columns 8x8b  {mul_us:>8.2} µs/op");

    // ---- Leg 4: full small-network inference. ------------------------
    let net = small_cnn(3);
    let params = ModelParams::random(&net, 3, 5);
    let img = QTensor::random(net.input.0, net.input.1, net.input.2, net.input_bits, 6);
    let mut engine = FunctionalEngine::new(ArchConfig::paper());
    let run_ms = time(20, || {
        black_box(engine.run(&net, &params, &img));
    }) * 1e3;
    println!("small_cnn inference    {run_ms:>8.2} ms/run");

    // ---- Leg 5: functional serve, sequential vs worker-split. --------
    let n = 16usize;
    let (c, h, w) = net.input;
    let make_planned = |seed: u64| -> Vec<PlannedBatch> {
        let images: Vec<QTensor> = (0..n)
            .map(|i| QTensor::random(c, h, w, net.input_bits, seed + i as u64))
            .collect();
        let requests = Request::stream(images);
        let arrivals = vec![0.0; n];
        vec![PlannedBatch {
            seq: 0,
            chip: 0,
            net: 0,
            cause: FlushCause::Size,
            flush_ns: 0.0,
            requests,
            arrivals_ns: arrivals,
            est_cost_ns: 0.0,
            est_finish_ns: 0.0,
        }]
    };
    let factory = EngineFactory::new(ArchConfig::paper(), EngineKind::Functional);
    let t = Instant::now();
    let seq = execute_with_workers(&factory, &net, Some(&params), 1, make_planned(40), Some(1));
    let serve_seq_s = t.elapsed().as_secs_f64();
    let workers = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    let t = Instant::now();
    let par =
        execute_with_workers(&factory, &net, Some(&params), 1, make_planned(40), Some(workers));
    let serve_par_s = t.elapsed().as_secs_f64();
    assert_eq!(seq[0].weight_hits, par[0].weight_hits, "split must be bit-identical");
    let serve_speedup = serve_seq_s / serve_par_s.max(f64::MIN_POSITIVE);
    println!(
        "serve {n} reqs (1 chip)  1 worker {serve_seq_s:>6.2} s  {workers} workers {serve_par_s:>6.2} s  ({serve_speedup:.1}x)"
    );

    // ---- Leg 6: intra-request fan-out, full-size AlexNet ⟨2:2⟩. ------
    // One request, so the serve-level request split cannot help: the
    // speedup here is purely the per-filter fan-out inside each conv
    // layer. Outputs and Stats are asserted bit-identical — the fan-out
    // is a wall-clock optimisation only.
    let anet = preset("alexnet", 2).expect("alexnet preset");
    let aparams = ModelParams::random(&anet, 2, 7);
    let (ac, ah, aw) = anet.input;
    let aimg = QTensor::random(ac, ah, aw, anet.input_bits, 8);
    let mut eng_seq = FunctionalEngine::new(ArchConfig::paper());
    eng_seq.set_host_workers(1);
    let t = Instant::now();
    let out_seq = eng_seq.run(&anet, &aparams, &aimg);
    let intra_seq_s = t.elapsed().as_secs_f64();
    let mut eng_par = FunctionalEngine::new(ArchConfig::paper());
    eng_par.set_host_workers(workers);
    let t = Instant::now();
    let out_par = eng_par.run(&anet, &aparams, &aimg);
    let intra_par_s = t.elapsed().as_secs_f64();
    assert_eq!(out_seq, out_par, "intra-request fan-out must be bit-identical");
    assert_eq!(eng_seq.stats, eng_par.stats, "fan-out must leave Stats bit-identical");
    let intra_speedup = intra_seq_s / intra_par_s.max(f64::MIN_POSITIVE);
    println!(
        "alexnet <2:2> request   1 worker {intra_seq_s:>6.2} s  {workers} workers {intra_par_s:>6.2} s  ({intra_speedup:.1}x)"
    );

    let json = format!(
        "{{\n  \"bench\": \"functional\",\n  \"network\": \"{}\",\n  \
         \"counter_accumulate\": {{\"packed_ns\": {:.2}, \"scalar_ns\": {:.2}, \"speedup\": {:.2}}},\n  \
         \"conv_pass\": {{\"packed_us\": {:.3}, \"scalar_us\": {:.3}, \"speedup\": {:.2}}},\n  \
         \"add_columns_us\": {:.3},\n  \"multiply_columns_us\": {:.3},\n  \
         \"small_cnn_run_ms\": {:.3},\n  \
         \"serve_functional\": {{\"requests\": {}, \"sequential_s\": {:.4}, \"parallel_s\": {:.4}, \
         \"workers\": {}, \"speedup\": {:.2}}},\n  \
         \"alexnet_intra\": {{\"bits\": 2, \"sequential_s\": {:.4}, \"parallel_s\": {:.4}, \
         \"workers\": {}, \"speedup\": {:.2}}}\n}}\n",
        net.name,
        packed_acc * 1e9,
        scalar_acc * 1e9,
        acc_speedup,
        packed_conv * 1e6,
        scalar_conv * 1e6,
        conv_speedup,
        add_us,
        mul_us,
        run_ms,
        n,
        serve_seq_s,
        serve_par_s,
        workers,
        serve_speedup,
        intra_seq_s,
        intra_par_s,
        workers,
        intra_speedup
    );
    std::fs::write("BENCH_functional.json", &json).expect("write BENCH_functional.json");
    println!("\n[wrote BENCH_functional.json]");
    println!("[bench wall time: {:.2} s]", t0.elapsed().as_secs_f64());
}
