//! Bench: regenerate Fig. 17 — area overhead breakdown of the add-on
//! PIM circuits (plus the §5.3 8.9 % overhead claim and Table 3 area).

use std::time::Instant;

use nandspin::arch::area::AreaModel;
use nandspin::arch::config::ArchConfig;

fn main() {
    let t0 = Instant::now();
    let cfg = ArchConfig::paper();
    let area = AreaModel::default();
    let b = area.breakdown(&cfg);
    println!("== Fig. 17: area overhead breakdown (measured vs paper) ==");
    println!("base memory array : {:>8.2} mm²", b.base_mm2());
    println!(
        "PIM add-on        : {:>8.2} mm²  ({:.1} % overhead; paper: 8.9 %)",
        b.addon_mm2(),
        100.0 * b.overhead_ratio()
    );
    let paper = [("computation units", 47.0), ("buffer", 4.0), ("controller + mux", 21.0), ("other circuits", 28.0)];
    for (s, (pname, pfrac)) in area.fig17_slices(&cfg).iter().zip(paper) {
        assert_eq!(s.name, pname);
        println!(
            "  {:<18}: {:>6.2} mm²  ({:>4.1} %; paper {:>4.1} %)",
            s.name,
            s.mm2,
            100.0 * s.fraction,
            pfrac
        );
    }
    println!("total             : {:>8.2} mm²  (Table 3: 64.5 mm²)", b.total_mm2());
    println!("\n[bench wall time: {:.2} s]", t0.elapsed().as_secs_f64());
}
