//! Bench: regenerate Table 3 — throughput (FPS), capacity and area for
//! all six in-memory CNN accelerators (ResNet50-class workload, 64 MB).

use std::time::Instant;

use nandspin::baselines::designs::BaselineKind;
use nandspin::cnn::network::resnet50;
use nandspin::coordinator::Coordinator;

fn main() {
    let t0 = Instant::now();
    let net = resnet50(8);
    println!("== Table 3: comparison with related in-memory CNN accelerators ==");
    println!(
        "{:<12} {:<10} {:>10} {:>12} {:>10} {:>10}",
        "Accelerator", "Technology", "FPS", "paper FPS", "Cap (MB)", "Area (mm²)"
    );
    for kind in BaselineKind::ALL {
        let b = kind.model();
        let m = b.metrics(&net, 8);
        println!(
            "{:<12} {:<10} {:>10.1} {:>12.1} {:>10} {:>10.1}",
            b.name, b.technology, m.fps(), kind.table3_fps(), 64, b.area_mm2
        );
    }
    let coord = Coordinator::paper();
    let m = coord.analytic_metrics(&net, 8);
    println!(
        "{:<12} {:<10} {:>10.1} {:>12.1} {:>10} {:>10.1}",
        "Proposed", "NAND-SPIN", m.fps(), 80.6, 64, m.area_mm2
    );
    // Steady-state serving condition: weights loaded once per batch.
    let t = coord.throughput_metrics(&net, 8);
    println!(
        "{:<12} {:<10} {:>10.1} {:>12.1} {:>10} {:>10.1}",
        " (resident)", "NAND-SPIN", t.fps(), 80.6, 64, t.area_mm2
    );
    println!("\n[bench wall time: {:.2} s]", t0.elapsed().as_secs_f64());
}
