//! Bench: regenerate Fig. 15 — performance normalised to area for all
//! six designs across ⟨W:I⟩ grids on the three models, plus the paper's
//! headline speedups.

use std::time::Instant;

use nandspin::baselines::designs::BaselineKind;
use nandspin::cnn::network::{alexnet, resnet50, vgg19};
use nandspin::coordinator::Coordinator;
use nandspin::workload::PRECISION_GRID;

fn main() {
    let t0 = Instant::now();
    let coord = Coordinator::paper();
    println!("== Fig. 15: performance normalised to area (GOPS/mm²) ==");
    let mut ratios: Vec<(&str, f64)> = Vec::new();
    for (name, mk) in [
        ("AlexNet", alexnet as fn(u8) -> nandspin::cnn::network::Network),
        ("VGG19", vgg19),
        ("ResNet50", resnet50),
    ] {
        println!("--- {name} ---");
        print!("{:<12}", "design");
        for (w, i) in PRECISION_GRID {
            print!("{:>12}", format!("<{w}:{i}>"));
        }
        println!();
        let mut ours = Vec::new();
        for (w, i) in PRECISION_GRID {
            ours.push(coord.analytic_metrics(&mk(i), w).gops_per_mm2());
        }
        for kind in BaselineKind::ALL {
            let b = kind.model();
            print!("{:<12}", b.name);
            for (gi, (w, i)) in PRECISION_GRID.into_iter().enumerate() {
                let v = b.metrics(&mk(i), w).gops_per_mm2();
                print!("{v:>12.3}");
                ratios.push((b.name, ours[gi] / v));
            }
            println!();
        }
        print!("{:<12}", "Proposed");
        for v in &ours {
            print!("{v:>12.3}");
        }
        println!();
    }
    println!("\n== average speedup of Proposed (paper: DRAM 6.3x, ReRAM 13.5x, STT-CiM 2.6x, SOT 5.1x) ==");
    for name in ["DRISA", "PRIME", "STT-CiM", "MRIMA", "IMCE"] {
        let rs: Vec<f64> = ratios.iter().filter(|(n, _)| *n == name).map(|(_, r)| *r).collect();
        let avg = rs.iter().sum::<f64>() / rs.len() as f64;
        println!("  vs {name:<8}: {avg:>6.2}x");
    }
    println!("\n[bench wall time: {:.2} s]", t0.elapsed().as_secs_f64());
}
