//! Microbenchmarks of the simulator hot paths (the §Perf targets):
//! row AND+count, bitwise conv stepper, in-memory addition, the full
//! functional SmallCNN, and the analytic ResNet50 schedule.

use std::hint::black_box;
use std::time::Instant;

use nandspin::arch::config::ArchConfig;
use nandspin::arch::stats::{Phase, Stats};
use nandspin::cnn::network::{resnet50, small_cnn};
use nandspin::cnn::ref_exec::ModelParams;
use nandspin::cnn::tensor::QTensor;
use nandspin::coordinator::{AnalyticModel, Coordinator};
use nandspin::device::energy::DeviceCosts;
use nandspin::subarray::conv::{bitplane_conv_counts, BitKernel, ConvGeometry};
use nandspin::subarray::primitives::add_columns;
use nandspin::subarray::Subarray;

fn bench<F: FnMut()>(name: &str, iters: u32, mut f: F) -> f64 {
    // Warmup.
    f();
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    println!("{name:<38} {:>12.3} µs/iter  ({iters} iters)", per * 1e6);
    per
}

fn main() {
    println!("== hotpath microbenchmarks ==");
    let mut stats = Stats::default();

    // Row AND + bit-count (the innermost conv op).
    let mut sub = Subarray::new(256, 128, 16, DeviceCosts::default());
    for r in 0..32 {
        sub.write_row(r, (r as u128).wrapping_mul(0x9e3779b9) | 1, &mut stats, Phase::LoadData);
    }
    sub.buffer_write(0, u128::MAX, &mut stats, Phase::LoadData);
    let per = bench("and_count (row AND + counter)", 200_000, || {
        sub.and_count(black_box(7), 0, &mut stats, Phase::Convolution);
    });
    println!("  -> {:.1} M row-ops/s ({:.2} G bit-ops/s)", 1e-6 / per, 128e-9 / per);

    // Bit-plane conv stepper (3x3 over 32x64 plane).
    let geo = ConvGeometry { in_h: 32, in_w: 64, stride: 1 };
    let kernel = BitKernel::new(3, 3, vec![true, false, true, true, true, false, false, true, true]);
    bench("bitplane_conv_counts 3x3 @32x64", 2_000, || {
        sub.counters.reset();
        black_box(bitplane_conv_counts(&mut sub, 0, geo, &kernel, &mut stats, Phase::Convolution));
    });

    // In-memory 8-operand addition.
    let mut sub2 = Subarray::new(256, 128, 16, DeviceCosts::default());
    for b in 0..64 {
        sub2.write_row(b, (b as u128).wrapping_mul(0xdeadbeef) | 3, &mut stats, Phase::LoadData);
    }
    let bases: Vec<usize> = (0..8).map(|i| i * 8).collect();
    bench("add_columns 8 operands x 8 bits", 5_000, || {
        black_box(add_columns(&mut sub2, &bases, 8, 128, &mut stats, Phase::Pooling));
    });

    // Full functional SmallCNN inference.
    let net = small_cnn(4);
    let params = ModelParams::random(&net, 4, 1);
    let input = QTensor::random(2, 14, 22, 4, 2);
    let coord = Coordinator::paper();
    bench("functional SmallCNN inference", 3, || {
        black_box(coord.functional_run(&net, &params, &input));
    });

    // Analytic ResNet50 schedule (the sweep inner loop).
    let model = AnalyticModel::new(ArchConfig::paper());
    let net50 = resnet50(8);
    bench("analytic ResNet50 schedule", 50, || {
        black_box(model.network_stats(&net50, 8));
    });
}
