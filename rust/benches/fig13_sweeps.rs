//! Bench: regenerate Fig. 13a (capacity sweep) and Fig. 13b (bus-width
//! sweep) — peak performance / energy efficiency / utilisation vs the
//! design parameters, ResNet50 ⟨8:8⟩ workload.

use std::time::Instant;

use nandspin::arch::config::ArchConfig;
use nandspin::arch::stats::Phase;
use nandspin::cnn::network::resnet50;
use nandspin::coordinator::Coordinator;

fn main() {
    let net = resnet50(8);
    let t0 = Instant::now();

    println!("== Fig. 13a: effect of capacity on peak performance and energy efficiency ==");
    println!(
        "{:>9} {:>12} {:>14} {:>16} {:>12}",
        "cap (MB)", "FPS", "GOPS/mm²", "GOPS/W/mm²", "area (mm²)"
    );
    for cap in [8usize, 16, 32, 64, 128, 256] {
        let mut cfg = ArchConfig::paper();
        cfg.capacity_mb = cap;
        let m = Coordinator::new(cfg).analytic_metrics(&net, 8);
        println!(
            "{:>9} {:>12.1} {:>14.3} {:>16.3} {:>12.1}",
            cap, m.fps(), m.gops_per_mm2(), m.efficiency_per_mm2(), m.area_mm2
        );
    }

    println!();
    println!("== Fig. 13b: effect of bus width on peak performance and utilisation ==");
    println!("{:>10} {:>12} {:>14} {:>14}", "bus (bit)", "FPS", "GOPS/mm²", "util (%)");
    for bus in [32usize, 64, 128, 256, 512] {
        let mut cfg = ArchConfig::paper();
        cfg.bus_width_bits = bus;
        let coord = Coordinator::new(cfg);
        let m = coord.analytic_metrics(&net, 8);
        let st = coord.analytic_stats(&net, 8);
        // Utilisation: fraction of time the compute units are busy, i.e.
        // not stalled on data delivery (loads + inter-layer transfer).
        let stalled = st[Phase::LoadData].latency_ns + st[Phase::DataTransfer].latency_ns;
        let util = 1.0 - stalled / st.total_latency_ns();
        println!(
            "{:>10} {:>12.1} {:>14.3} {:>14.1}",
            bus, m.fps(), m.gops_per_mm2(), util * 100.0
        );
    }
    println!("\n[bench wall time: {:.2} s]", t0.elapsed().as_secs_f64());
}
