//! Ablation bench: quantify the paper's two key design choices on
//! ResNet50 ⟨8:8⟩ — the weight-reuse buffer (§4.1) and the cross-writing
//! partial-sum pipeline (Fig. 12) — plus the precision ladder.

use nandspin::arch::config::ArchConfig;
use nandspin::cnn::network::resnet50;
use nandspin::coordinator::{AnalyticModel, Calibration};

fn run(label: &str, cal: Calibration) -> f64 {
    let mut m = AnalyticModel::new(ArchConfig::paper());
    m.cal = cal;
    let st = m.network_stats(&resnet50(8), 8);
    println!(
        "{label:<40} {:>9.3} ms ({:>6.1} FPS)  {:>9.3} mJ",
        st.total_latency_ms(),
        1000.0 / st.total_latency_ms(),
        st.total_energy_mj()
    );
    st.total_latency_ms()
}

fn main() {
    println!("== ablations: ResNet50 ⟨8:8⟩ @ 64 MB ==");
    let base = run("full design (paper)", Calibration::default());
    let no_buf = run(
        "no weight-reuse buffer",
        Calibration { weight_buffer_reuse: false, ..Calibration::default() },
    );
    let no_pipe = run(
        "no cross-writing pipeline",
        Calibration { cross_writing_pipeline: false, ..Calibration::default() },
    );
    let neither = run(
        "neither",
        Calibration {
            weight_buffer_reuse: false,
            cross_writing_pipeline: false,
            ..Calibration::default()
        },
    );
    println!();
    println!("weight-buffer reuse saves     : {:.2}x", no_buf / base);
    println!("cross-writing pipeline saves  : {:.2}x", no_pipe / base);
    println!("both together                 : {:.2}x", neither / base);
    println!("(the paper attributes its energy and speed advantage over prior");
    println!(" PIM designs chiefly to these two mechanisms — §5.3 items 1–2)");
}
