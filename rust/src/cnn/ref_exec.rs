//! Pure-Rust golden executor: the integer-exact reference every other
//! implementation (PIM simulator, JAX/Pallas artifact) must match
//! bit-for-bit.

use crate::util::Rng;

use super::layer::{Layer, Shape};
use super::network::Network;
use super::quantize::{relu, BnParams, QuantParams};
use super::tensor::{Kernel4, QTensor};

/// Wide-accumulator tensor used between quantization points.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WideTensor {
    /// Channels.
    pub c: usize,
    /// Height.
    pub h: usize,
    /// Width.
    pub w: usize,
    /// CHW data.
    pub data: Vec<i64>,
}

impl WideTensor {
    /// Zero tensor.
    pub fn zeros(c: usize, h: usize, w: usize) -> Self {
        Self { c, h, w, data: vec![0; c * h * w] }
    }

    /// Value at (c, y, x).
    #[inline]
    pub fn at(&self, c: usize, y: usize, x: usize) -> i64 {
        self.data[(c * self.h + y) * self.w + x]
    }

    /// Mutable value at (c, y, x).
    #[inline]
    pub fn at_mut(&mut self, c: usize, y: usize, x: usize) -> &mut i64 {
        &mut self.data[(c * self.h + y) * self.w + x]
    }

    /// Lift a quantized tensor.
    pub fn from_q(t: &QTensor) -> Self {
        Self { c: t.c, h: t.h, w: t.w, data: t.data().iter().map(|&v| v as i64).collect() }
    }

    /// Lower to a quantized tensor.
    ///
    /// # Panics
    /// If any value is outside the `bits` range.
    pub fn to_q(&self, bits: u8) -> QTensor {
        let data = self
            .data
            .iter()
            .map(|&v| {
                assert!(v >= 0 && v <= QTensor::max_value(bits) as i64, "value {v} out of range");
                v as u32
            })
            .collect();
        QTensor::from_vec(self.c, self.h, self.w, bits, data)
    }
}

/// Fixed-point average-pool scale: `avg = (sum · mul + 2^(shift−1)) >> shift`
/// with `mul = round(2^shift / k²)`. Shared by all implementations.
pub fn avg_pool_scale(k: usize) -> (u32, u8) {
    const SHIFT: u8 = 16;
    let mul = ((1u64 << SHIFT) as f64 / (k * k) as f64).round() as u32;
    (mul, SHIFT)
}

/// Concrete parameters for every parameterised node of a network,
/// index-aligned by node kind occurrence order.
#[derive(Debug, Clone)]
pub struct ModelParams {
    /// One kernel per `Conv` node, in node order.
    pub conv_weights: Vec<Kernel4>,
    /// One set per `BatchNorm` node, in node order.
    pub bn: Vec<BnParams>,
    /// One set per `Quantize` node, in node order.
    pub quant: Vec<QuantParams>,
}

impl ModelParams {
    /// Deterministic pseudo-random parameters: random `w_bits` weights,
    /// near-identity BN, and rescaling quantizers sized to keep values in
    /// range — a stand-in for trained weights (throughput/energy depend
    /// on shapes, not values; see DESIGN.md §2).
    pub fn random(net: &Network, w_bits: u8, seed: u64) -> Self {
        let mut rng = Rng::seed_from_u64(seed);
        let shapes = net.shapes();
        let mut conv_weights = Vec::new();
        let mut bn = Vec::new();
        let mut quant = Vec::new();
        for (i, node) in net.nodes.iter().enumerate() {
            let in_shape: Shape = match node.input {
                Some(j) => shapes[j],
                None if i == 0 => net.input,
                None => shapes[i - 1],
            };
            match node.layer {
                Layer::Conv { out_c, kh, kw, .. } => {
                    conv_weights.push(Kernel4::random(
                        out_c,
                        in_shape.0,
                        kh,
                        kw,
                        w_bits,
                        rng.gen_seed(),
                    ));
                }
                Layer::BatchNorm => {
                    let (c, _, _) = shapes[i];
                    bn.push(BnParams::identity(c, 8));
                }
                Layer::Quantize { bits } => {
                    // Rescale so a typical accumulator fits `bits`:
                    // divide by 2^s where s ≈ log2(max_acc / max_out).
                    let macs_bits = {
                        let prev = &net.nodes[..i];
                        let last_conv = prev.iter().rev().find_map(|n| match n.layer {
                            Layer::Conv { kh, kw, .. } => Some((kh * kw) as u32),
                            _ => None,
                        });
                        let fan_in = last_conv.unwrap_or(1) * in_shape.0.max(1) as u32;
                        32 - fan_in.leading_zeros()
                    };
                    let in_bits = net.input_bits as u32 + w_bits as u32;
                    // Random uniform values average half the max, so the
                    // accumulator typically needs ~2 fewer bits than the
                    // worst case; keep a margin of 2.
                    let s = (in_bits + macs_bits)
                        .saturating_sub(bits as u32 + 2)
                        .min(40) as u8;
                    quant.push(QuantParams::rescale(s, bits));
                }
                _ => {}
            }
        }
        Self { conv_weights, bn, quant }
    }
}

/// Execute `net` on `input`, returning every node's output (wide form).
///
/// # Panics
/// On IR inconsistencies (shape mismatches, missing params).
pub fn execute(net: &Network, params: &ModelParams, input: &QTensor) -> Vec<WideTensor> {
    assert_eq!((input.c, input.h, input.w), net.input, "input shape mismatch");
    let mut outs: Vec<WideTensor> = Vec::with_capacity(net.nodes.len());
    let input_wide = WideTensor::from_q(input);
    let (mut ci, mut bi, mut qi) = (0usize, 0usize, 0usize);

    for (i, node) in net.nodes.iter().enumerate() {
        let src: &WideTensor = match node.input {
            Some(j) => &outs[j],
            None if i == 0 => &input_wide,
            None => &outs[i - 1],
        };
        let out = match node.layer {
            Layer::Conv { out_c, kh, kw, stride, pad } => {
                let k = &params.conv_weights[ci];
                ci += 1;
                assert_eq!((k.oc, k.ic, k.kh, k.kw), (out_c, src.c, kh, kw));
                conv2d(src, k, stride, pad)
            }
            Layer::MaxPool { k, stride } => max_pool(src, k, stride),
            Layer::AvgPool { k, stride } => avg_pool(src, k, stride),
            Layer::BatchNorm => {
                let p = &params.bn[bi];
                bi += 1;
                batch_norm(src, p)
            }
            Layer::Relu => map(src, relu),
            Layer::Quantize { .. } => {
                let p = params.quant[qi];
                qi += 1;
                map(src, move |v| p.apply(v) as i64)
            }
            Layer::Residual { from } => residual(src, &outs[from]),
        };
        outs.push(out);
    }
    outs
}

/// Final output of [`execute`] as a quantized tensor.
pub fn output_q(net: &Network, outs: &[WideTensor], bits: u8) -> QTensor {
    let _ = net;
    outs.last().expect("empty network").to_q(bits)
}

fn conv2d(x: &WideTensor, k: &Kernel4, stride: usize, pad: usize) -> WideTensor {
    let oh = (x.h + 2 * pad - k.kh) / stride + 1;
    let ow = (x.w + 2 * pad - k.kw) / stride + 1;
    let mut y = WideTensor::zeros(k.oc, oh, ow);
    for oc in 0..k.oc {
        for oy in 0..oh {
            for ox in 0..ow {
                let mut acc = 0i64;
                for ic in 0..k.ic {
                    for ky in 0..k.kh {
                        for kx in 0..k.kw {
                            let iy = (oy * stride + ky) as isize - pad as isize;
                            let ix = (ox * stride + kx) as isize - pad as isize;
                            if iy < 0 || ix < 0 || iy >= x.h as isize || ix >= x.w as isize {
                                continue;
                            }
                            acc += x.at(ic, iy as usize, ix as usize)
                                * k.at(oc, ic, ky, kx) as i64;
                        }
                    }
                }
                *y.at_mut(oc, oy, ox) = acc;
            }
        }
    }
    y
}

fn max_pool(x: &WideTensor, k: usize, stride: usize) -> WideTensor {
    let oh = (x.h - k) / stride + 1;
    let ow = (x.w - k) / stride + 1;
    let mut y = WideTensor::zeros(x.c, oh, ow);
    for c in 0..x.c {
        for oy in 0..oh {
            for ox in 0..ow {
                let mut m = i64::MIN;
                for dy in 0..k {
                    for dx in 0..k {
                        m = m.max(x.at(c, oy * stride + dy, ox * stride + dx));
                    }
                }
                *y.at_mut(c, oy, ox) = m;
            }
        }
    }
    y
}

fn avg_pool(x: &WideTensor, k: usize, stride: usize) -> WideTensor {
    let (mul, shift) = avg_pool_scale(k);
    let oh = (x.h - k) / stride + 1;
    let ow = (x.w - k) / stride + 1;
    let mut y = WideTensor::zeros(x.c, oh, ow);
    for c in 0..x.c {
        for oy in 0..oh {
            for ox in 0..ow {
                let mut s = 0i64;
                for dy in 0..k {
                    for dx in 0..k {
                        s += x.at(c, oy * stride + dy, ox * stride + dx);
                    }
                }
                *y.at_mut(c, oy, ox) = (s * mul as i64 + (1i64 << (shift - 1))) >> shift;
            }
        }
    }
    y
}

fn batch_norm(x: &WideTensor, p: &BnParams) -> WideTensor {
    assert_eq!(p.channels(), x.c);
    let mut y = WideTensor::zeros(x.c, x.h, x.w);
    for c in 0..x.c {
        for i in 0..x.h * x.w {
            y.data[c * x.h * x.w + i] = p.apply(c, x.data[c * x.h * x.w + i]);
        }
    }
    y
}

fn map(x: &WideTensor, f: impl Fn(i64) -> i64) -> WideTensor {
    WideTensor { c: x.c, h: x.h, w: x.w, data: x.data.iter().map(|&v| f(v)).collect() }
}

fn residual(a: &WideTensor, b: &WideTensor) -> WideTensor {
    assert_eq!((a.c, a.h, a.w), (b.c, b.h, b.w), "residual shape mismatch");
    WideTensor {
        c: a.c,
        h: a.h,
        w: a.w,
        data: a.data.iter().zip(&b.data).map(|(&x, &y)| x + y).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::network::{micro_cnn, small_cnn};

    #[test]
    fn conv2d_hand_checked() {
        // 1×2×2 input [[1,2],[3,4]], single 2×2 kernel [[1,0],[0,1]] → 1+4.
        let x = WideTensor { c: 1, h: 2, w: 2, data: vec![1, 2, 3, 4] };
        let k = Kernel4::from_vec(1, 1, 2, 2, 2, vec![1, 0, 0, 1]);
        let y = conv2d(&x, &k, 1, 0);
        assert_eq!(y.data, vec![5]);
    }

    #[test]
    fn conv2d_padding() {
        let x = WideTensor { c: 1, h: 2, w: 2, data: vec![1, 2, 3, 4] };
        let k = Kernel4::from_vec(1, 1, 3, 3, 1, vec![0, 0, 0, 0, 1, 0, 0, 0, 0]);
        let y = conv2d(&x, &k, 1, 1);
        assert_eq!((y.h, y.w), (2, 2));
        assert_eq!(y.data, vec![1, 2, 3, 4], "identity kernel with pad 1");
    }

    #[test]
    fn pooling_hand_checked() {
        let x = WideTensor { c: 1, h: 2, w: 4, data: vec![1, 5, 2, 0, 3, 1, 8, 2] };
        let y = max_pool(&x, 2, 2);
        assert_eq!(y.data, vec![5, 8]);
        let a = avg_pool(&x, 2, 2);
        // (1+5+3+1)/4 = 2.5 → 3 (round half up); (2+0+8+2)/4 = 3.
        assert_eq!(a.data, vec![3, 3]);
    }

    #[test]
    fn avg_pool_scale_is_exact_for_powers_of_two() {
        let (mul, shift) = avg_pool_scale(2);
        assert_eq!(mul as u64, 1u64 << (shift - 2));
    }

    #[test]
    fn micro_network_runs() {
        let net = micro_cnn(4);
        let params = ModelParams::random(&net, 4, 1);
        let input = QTensor::random(1, 4, 6, 4, 2);
        let outs = execute(&net, &params, &input);
        assert_eq!(outs.len(), net.nodes.len());
        let last = outs.last().unwrap();
        assert_eq!((last.c, last.h, last.w), (2, 3, 5));
        // Quantized output within 4 bits.
        assert!(last.data.iter().all(|&v| v >= 0 && v < 16));
    }

    #[test]
    fn small_cnn_runs_and_is_deterministic() {
        let net = small_cnn(4);
        let params = ModelParams::random(&net, 4, 7);
        let input = QTensor::random(2, 14, 22, 4, 3);
        let a = execute(&net, &params, &input);
        let b = execute(&net, &params, &input);
        assert_eq!(a.last(), b.last());
    }

    #[test]
    fn residual_adds() {
        let a = WideTensor { c: 1, h: 1, w: 3, data: vec![1, 2, 3] };
        let b = WideTensor { c: 1, h: 1, w: 3, data: vec![10, 20, 30] };
        assert_eq!(residual(&a, &b).data, vec![11, 22, 33]);
    }
}
