//! Quantization (Eq. 2) and batch normalisation (Eq. 3) in the
//! fixed-point form the accelerator executes.
//!
//! The paper evaluates both transformations in-memory as an addition plus
//! a multiplication by a *precomputed* factor. We mirror that: the float
//! parameters are folded offline into integer `(mul, add, shift)`
//! triples, and the online op is exactly
//!
//! ```text
//! y = clamp((x · mul + add) >> shift, 0, 2^bits − 1)
//! ```
//!
//! which all three implementations (Rust golden, PIM simulator, JAX
//! model) perform identically, guaranteeing bit-exact agreement.


/// Fixed-point quantization parameters (Eq. 2 folded).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QuantParams {
    /// Multiplier.
    pub mul: u32,
    /// Pre-shift additive term (also absorbs −Q_min·scale and rounding).
    pub add: i64,
    /// Right-shift amount.
    pub shift: u8,
    /// Output bit-width `k`.
    pub bits: u8,
}

impl QuantParams {
    /// Fold the float Eq. 2 transform
    /// `Q_o = round((Q_i − Q_min) · (2^k − 1)/(Q_max − Q_min))`
    /// into fixed point with `shift`-bit precision.
    pub fn fold(q_min: f64, q_max: f64, bits: u8, shift: u8) -> Self {
        assert!(q_max > q_min);
        let scale = ((1u64 << bits) - 1) as f64 / (q_max - q_min);
        let mul = (scale * (1u64 << shift) as f64).round() as u32;
        // add = −Q_min·scale·2^shift + rounding-half.
        let add = (-q_min * scale * (1u64 << shift) as f64).round() as i64
            + (1i64 << shift) / 2;
        Self { mul, add, shift, bits }
    }

    /// Identity-ish requantization: divide by `2^shift` with rounding
    /// (used to bring wide conv accumulators back to `bits` width).
    pub fn rescale(shift: u8, bits: u8) -> Self {
        Self { mul: 1, add: (1i64 << shift) / 2, shift, bits }
    }

    /// Apply to one value (saturating).
    #[inline]
    pub fn apply(&self, x: i64) -> u32 {
        let max = ((1u64 << self.bits) - 1) as i64;
        let y = (x * self.mul as i64 + self.add) >> self.shift;
        y.clamp(0, max) as u32
    }
}

/// Per-channel fixed-point batch-norm parameters (Eq. 3 folded):
/// `y = (x · mul + add) >> shift`, where `mul` encodes `γ/√(σ²+ε)` and
/// `add` encodes `β − μγ/√(σ²+ε)` in the same fixed point.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BnParams {
    /// Per-channel multiplier.
    pub mul: Vec<u32>,
    /// Per-channel additive term.
    pub add: Vec<i64>,
    /// Shared right-shift.
    pub shift: u8,
}

impl BnParams {
    /// Fold float BN statistics into fixed point.
    ///
    /// # Panics
    /// If the per-channel slices disagree in length.
    pub fn fold(gamma: &[f64], beta: &[f64], mu: &[f64], sigma2: &[f64], shift: u8) -> Self {
        assert!(gamma.len() == beta.len() && beta.len() == mu.len() && mu.len() == sigma2.len());
        const EPS: f64 = 1e-5;
        let one = (1u64 << shift) as f64;
        let mut mul = Vec::with_capacity(gamma.len());
        let mut add = Vec::with_capacity(gamma.len());
        for i in 0..gamma.len() {
            let inv_std = gamma[i] / (sigma2[i] + EPS).sqrt();
            assert!(inv_std >= 0.0, "negative BN scale needs signed datapath");
            mul.push((inv_std * one).round() as u32);
            add.push(((beta[i] - mu[i] * inv_std) * one).round() as i64 + (1i64 << shift) / 2);
        }
        Self { mul, add, shift }
    }

    /// Identity BN for `c` channels (testing / pass-through).
    pub fn identity(c: usize, shift: u8) -> Self {
        Self {
            mul: vec![1u32 << shift; c],
            add: vec![(1i64 << shift) / 2; c],
            shift,
        }
    }

    /// Apply to one value of channel `c`, clamping at 0 (the datapath is
    /// unsigned; a following ReLU would clamp anyway).
    #[inline]
    pub fn apply(&self, c: usize, x: i64) -> i64 {
        ((x * self.mul[c] as i64 + self.add[c]) >> self.shift).max(0)
    }

    /// Number of channels.
    pub fn channels(&self) -> usize {
        self.mul.len()
    }
}

/// ReLU on the signless datapath: negatives cannot be represented, so the
/// hardware realises ReLU by checking the *sign bit of the pre-BN
/// accumulator* (paper §4.2: "the MSB of the input is read out first and
/// used to determine whether to write zero"). On the integer path it is
/// simply a max with zero.
#[inline]
pub fn relu(x: i64) -> i64 {
    x.max(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quant_matches_float_reference() {
        let p = QuantParams::fold(0.0, 255.0, 8, 16);
        for x in [0i64, 1, 17, 128, 200, 255] {
            let float_ref = ((x as f64 - 0.0) * 255.0 / 255.0).round() as u32;
            assert_eq!(p.apply(x), float_ref, "x={x}");
        }
    }

    #[test]
    fn quant_range_mapping() {
        // Map [10, 522] → 4 bits.
        let p = QuantParams::fold(10.0, 522.0, 4, 16);
        assert_eq!(p.apply(10), 0);
        assert_eq!(p.apply(522), 15);
        let mid = p.apply(266);
        assert!(mid >= 7 && mid <= 8, "midpoint → ~7.5, got {mid}");
    }

    #[test]
    fn quant_saturates() {
        let p = QuantParams::fold(0.0, 100.0, 4, 16);
        assert_eq!(p.apply(-50), 0);
        assert_eq!(p.apply(1000), 15);
    }

    #[test]
    fn rescale_rounds() {
        let p = QuantParams::rescale(4, 8);
        assert_eq!(p.apply(16), 1);
        assert_eq!(p.apply(23), 1); // 23/16 = 1.4375 → 1
        assert_eq!(p.apply(24), 2); // 1.5 → 2
    }

    #[test]
    fn bn_identity_is_identity() {
        let bn = BnParams::identity(3, 8);
        for x in [0i64, 5, 100, 4096] {
            for c in 0..3 {
                assert_eq!(bn.apply(c, x), x);
            }
        }
    }

    #[test]
    fn bn_fold_matches_float() {
        let bn = BnParams::fold(&[2.0], &[3.0], &[10.0], &[4.0 - 1e-5], 16);
        // y = (x − 10)/2 · 2 + 3 = x − 10 + 3 = x − 7.
        for x in [7i64, 10, 100] {
            let expect = (x - 7).max(0);
            assert_eq!(bn.apply(0, x), expect, "x={x}");
        }
    }

    #[test]
    fn relu_clamps_negatives() {
        assert_eq!(relu(-5), 0);
        assert_eq!(relu(5), 5);
    }
}
