//! Layer IR: the operations the accelerator schedules (paper §4.2).


/// Shape of an activation tensor (C, H, W).
pub type Shape = (usize, usize, usize);

/// One network layer.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Layer {
    /// Convolution (`out_c` filters of `kh×kw`, stride `s`, zero-pad `p`).
    /// Fully-connected layers are expressed as convolutions whose kernel
    /// covers the whole input (paper §4.2).
    Conv {
        /// Output channels.
        out_c: usize,
        /// Kernel height.
        kh: usize,
        /// Kernel width.
        kw: usize,
        /// Stride.
        stride: usize,
        /// Symmetric zero padding.
        pad: usize,
    },
    /// Max pooling over `k×k` windows with stride `s`.
    MaxPool {
        /// Window size.
        k: usize,
        /// Stride.
        stride: usize,
    },
    /// Average pooling over `k×k` windows with stride `s`.
    AvgPool {
        /// Window size.
        k: usize,
        /// Stride.
        stride: usize,
    },
    /// Batch normalisation (Eq. 3), per channel.
    BatchNorm,
    /// ReLU activation.
    Relu,
    /// Quantization to `bits` (Eq. 2) — brings wide accumulators back to
    /// the working precision.
    Quantize {
        /// Target bit-width.
        bits: u8,
    },
    /// Residual element-wise addition with the output of an earlier layer
    /// (index into the network's layer list, post-execution shape must
    /// match). Used by the ResNet50 preset.
    Residual {
        /// Source layer index.
        from: usize,
    },
}

impl Layer {
    /// Output shape for an input of shape `s`.
    ///
    /// # Panics
    /// If the layer is not applicable to `s` (e.g. kernel larger than
    /// input without padding).
    pub fn out_shape(&self, s: Shape) -> Shape {
        let (c, h, w) = s;
        match *self {
            Layer::Conv { out_c, kh, kw, stride, pad } => {
                let h2 = (h + 2 * pad).checked_sub(kh).expect("kernel taller than input") / stride + 1;
                let w2 = (w + 2 * pad).checked_sub(kw).expect("kernel wider than input") / stride + 1;
                (out_c, h2, w2)
            }
            Layer::MaxPool { k, stride } | Layer::AvgPool { k, stride } => {
                ((c), (h - k) / stride + 1, (w - k) / stride + 1)
            }
            Layer::BatchNorm | Layer::Relu | Layer::Quantize { .. } | Layer::Residual { .. } => s,
        }
    }

    /// Multiply-accumulate count for an input of shape `s` (0 for
    /// non-conv layers; pooling/BN/quant op costs are modelled
    /// separately).
    pub fn macs(&self, s: Shape) -> u64 {
        match *self {
            Layer::Conv { out_c, kh, kw, .. } => {
                let (in_c, _, _) = s;
                let (oc, oh, ow) = self.out_shape(s);
                debug_assert_eq!(oc, out_c);
                (oc * oh * ow) as u64 * (in_c * kh * kw) as u64
            }
            _ => 0,
        }
    }

    /// Number of scalar elements this layer produces.
    pub fn out_elems(&self, s: Shape) -> u64 {
        let (c, h, w) = self.out_shape(s);
        (c * h * w) as u64
    }

    /// Short mnemonic for logs.
    pub fn mnemonic(&self) -> &'static str {
        match self {
            Layer::Conv { .. } => "conv",
            Layer::MaxPool { .. } => "maxpool",
            Layer::AvgPool { .. } => "avgpool",
            Layer::BatchNorm => "bn",
            Layer::Relu => "relu",
            Layer::Quantize { .. } => "quant",
            Layer::Residual { .. } => "residual",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_shape_and_macs() {
        let l = Layer::Conv { out_c: 64, kh: 3, kw: 3, stride: 1, pad: 1 };
        assert_eq!(l.out_shape((3, 224, 224)), (64, 224, 224));
        assert_eq!(l.macs((3, 224, 224)), 64 * 224 * 224 * 3 * 3 * 3);
    }

    #[test]
    fn strided_conv_shape() {
        // AlexNet conv1: 96 filters 11×11 stride 4 on 3×227×227.
        let l = Layer::Conv { out_c: 96, kh: 11, kw: 11, stride: 4, pad: 0 };
        assert_eq!(l.out_shape((3, 227, 227)), (96, 55, 55));
    }

    #[test]
    fn pool_shape() {
        let l = Layer::MaxPool { k: 2, stride: 2 };
        assert_eq!(l.out_shape((64, 112, 112)), (64, 56, 56));
        assert_eq!(l.macs((64, 112, 112)), 0);
    }

    #[test]
    fn pointwise_layers_preserve_shape() {
        for l in [Layer::BatchNorm, Layer::Relu, Layer::Quantize { bits: 8 }] {
            assert_eq!(l.out_shape((7, 9, 11)), (7, 9, 11));
        }
    }

    #[test]
    fn fc_as_full_kernel_conv() {
        // FC 4096 on a 256×6×6 input = conv with 6×6 kernel.
        let l = Layer::Conv { out_c: 4096, kh: 6, kw: 6, stride: 1, pad: 0 };
        assert_eq!(l.out_shape((256, 6, 6)), (4096, 1, 1));
    }
}
