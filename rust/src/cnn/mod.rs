//! Quantized CNN intermediate representation, tensors, bit-plane
//! decomposition, quantization / batch-norm semantics and the pure-Rust
//! golden executor.
//!
//! Everything in this module is *integer-exact*: the same semantics are
//! implemented three times (here, in the PIM functional simulator, and in
//! the JAX/Pallas model) and must agree bit-for-bit.

pub mod layer;
pub mod network;
pub mod quantize;
pub mod ref_exec;
pub mod tensor;

pub use layer::Layer;
pub use network::Network;
pub use quantize::{BnParams, QuantParams};
pub use tensor::{Kernel4, QTensor};
