//! Network IR and the paper's benchmark models (AlexNet, VGG19,
//! ResNet50) plus small functional-mode networks.


use super::layer::{Layer, Shape};

/// One node of the network graph: a layer plus an optional explicit input
/// (defaults to the previous node; the network input for node 0).
/// Explicit inputs express ResNet-style branches.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Node {
    /// The operation.
    pub layer: Layer,
    /// Input node index; `None` = previous node's output.
    pub input: Option<usize>,
}

/// A whole network.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Network {
    /// Model name (used in reports).
    pub name: String,
    /// Input shape (C, H, W).
    pub input: Shape,
    /// Input activation bit-width.
    pub input_bits: u8,
    /// Topologically-ordered nodes.
    pub nodes: Vec<Node>,
}

impl Network {
    /// Structural fingerprint of the network: a deterministic 64-bit
    /// FNV-1a hash over the name, input shape/precision and every
    /// node's layer kind, parameters and wiring.
    ///
    /// Engines key their weight-residency and synthesis caches on this
    /// instead of the old `(name, nodes.len())` pair, which collided
    /// for two different networks that happened to share a name and
    /// node count. Two [`crate::cnn::ref_exec::ModelParams`] sets for
    /// one architecture still hash alike — a serving pool pairs each
    /// engine with exactly one parameter set, so that ambiguity never
    /// reaches an engine.
    pub fn fingerprint(&self) -> u64 {
        const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
        const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
        let mut h = FNV_OFFSET;
        let mut mix = |v: u64| {
            for byte in v.to_le_bytes() {
                h ^= byte as u64;
                h = h.wrapping_mul(FNV_PRIME);
            }
        };
        // Length-prefix the name so its bytes cannot shift into the
        // numeric fields that follow (domain separation).
        mix(self.name.len() as u64);
        for &b in self.name.as_bytes() {
            mix(b as u64);
        }
        let (c, hh, w) = self.input;
        mix(c as u64);
        mix(hh as u64);
        mix(w as u64);
        mix(self.input_bits as u64);
        for node in &self.nodes {
            // Wiring: explicit inputs are offset so `None` (= previous
            // node) never aliases `Some(0)`.
            mix(match node.input {
                None => 0,
                Some(j) => j as u64 + 1,
            });
            match node.layer {
                Layer::Conv { out_c, kh, kw, stride, pad } => {
                    mix(1);
                    mix(out_c as u64);
                    mix(kh as u64);
                    mix(kw as u64);
                    mix(stride as u64);
                    mix(pad as u64);
                }
                Layer::MaxPool { k, stride } => {
                    mix(2);
                    mix(k as u64);
                    mix(stride as u64);
                }
                Layer::AvgPool { k, stride } => {
                    mix(3);
                    mix(k as u64);
                    mix(stride as u64);
                }
                Layer::BatchNorm => mix(4),
                Layer::Relu => mix(5),
                Layer::Quantize { bits } => {
                    mix(6);
                    mix(bits as u64);
                }
                Layer::Residual { from } => {
                    mix(7);
                    mix(from as u64);
                }
            }
        }
        h
    }

    /// Output shape of every node (index-aligned with `nodes`).
    pub fn shapes(&self) -> Vec<Shape> {
        let mut out = Vec::with_capacity(self.nodes.len());
        for (i, node) in self.nodes.iter().enumerate() {
            let in_shape = match node.input {
                Some(j) => {
                    assert!(j < i, "node {i} reads from later node {j}");
                    out[j]
                }
                None if i == 0 => self.input,
                None => out[i - 1],
            };
            if let Layer::Residual { from } = node.layer {
                assert!(from < i, "residual from later node");
                assert_eq!(out[from], in_shape, "residual shape mismatch at node {i}");
            }
            out.push(node.layer.out_shape(in_shape));
        }
        out
    }

    /// Input shape of node `i`.
    pub fn in_shape(&self, i: usize) -> Shape {
        match self.nodes[i].input {
            Some(j) => self.shapes()[j],
            None if i == 0 => self.input,
            None => self.shapes()[i - 1],
        }
    }

    /// Total multiply-accumulates of one inference.
    pub fn total_macs(&self) -> u64 {
        let shapes = self.shapes();
        self.nodes
            .iter()
            .enumerate()
            .map(|(i, n)| {
                let s = match n.input {
                    Some(j) => shapes[j],
                    None if i == 0 => self.input,
                    None => shapes[i - 1],
                };
                n.layer.macs(s)
            })
            .sum()
    }

    /// Total ops (paper convention: 1 MAC = 2 ops).
    pub fn total_ops(&self) -> u64 {
        2 * self.total_macs()
    }

    /// Total weight parameter count (conv kernels only).
    pub fn total_weights(&self) -> u64 {
        let shapes = self.shapes();
        self.nodes
            .iter()
            .enumerate()
            .map(|(i, n)| {
                let (in_c, _, _) = match n.input {
                    Some(j) => shapes[j],
                    None if i == 0 => self.input,
                    None => shapes[i - 1],
                };
                match n.layer {
                    Layer::Conv { out_c, kh, kw, .. } => (out_c * in_c * kh * kw) as u64,
                    _ => 0,
                }
            })
            .sum()
    }
}

/// Builder for sequential-with-branches networks.
struct Builder {
    nodes: Vec<Node>,
}

impl Builder {
    fn new() -> Self {
        Self { nodes: Vec::new() }
    }

    /// Push a node consuming the previous output; returns its index.
    fn push(&mut self, layer: Layer) -> usize {
        self.nodes.push(Node { layer, input: None });
        self.nodes.len() - 1
    }

    /// Push a node with an explicit input; returns its index.
    fn push_from(&mut self, layer: Layer, input: usize) -> usize {
        self.nodes.push(Node { layer, input: Some(input) });
        self.nodes.len() - 1
    }

    /// conv → BN → ReLU → quantize, returns the quantize node index.
    fn conv_bn_relu(&mut self, out_c: usize, k: usize, stride: usize, pad: usize, bits: u8) -> usize {
        self.push(Layer::Conv { out_c, kh: k, kw: k, stride, pad });
        self.push(Layer::BatchNorm);
        self.push(Layer::Relu);
        self.push(Layer::Quantize { bits })
    }
}

/// AlexNet with the paper's quantized inference pipeline
/// (conv → BN → ReLU → quantize; FCs as full-kernel convs).
pub fn alexnet(bits: u8) -> Network {
    let mut b = Builder::new();
    b.conv_bn_relu(96, 11, 4, 0, bits);
    b.push(Layer::MaxPool { k: 3, stride: 2 });
    b.conv_bn_relu(256, 5, 1, 2, bits);
    b.push(Layer::MaxPool { k: 3, stride: 2 });
    b.conv_bn_relu(384, 3, 1, 1, bits);
    b.conv_bn_relu(384, 3, 1, 1, bits);
    b.conv_bn_relu(256, 3, 1, 1, bits);
    b.push(Layer::MaxPool { k: 3, stride: 2 });
    // FC layers as convs over the remaining 6×6 spatial extent.
    b.push(Layer::Conv { out_c: 4096, kh: 6, kw: 6, stride: 1, pad: 0 });
    b.push(Layer::Relu);
    b.push(Layer::Quantize { bits });
    b.push(Layer::Conv { out_c: 4096, kh: 1, kw: 1, stride: 1, pad: 0 });
    b.push(Layer::Relu);
    b.push(Layer::Quantize { bits });
    b.push(Layer::Conv { out_c: 1000, kh: 1, kw: 1, stride: 1, pad: 0 });
    Network { name: "AlexNet".into(), input: (3, 227, 227), input_bits: bits, nodes: b.nodes }
}

/// VGG19 (16 convs + 3 FCs) with the quantized pipeline.
pub fn vgg19(bits: u8) -> Network {
    let mut b = Builder::new();
    let blocks: [(usize, usize); 5] = [(64, 2), (128, 2), (256, 4), (512, 4), (512, 4)];
    for (c, reps) in blocks {
        for _ in 0..reps {
            b.conv_bn_relu(c, 3, 1, 1, bits);
        }
        b.push(Layer::MaxPool { k: 2, stride: 2 });
    }
    b.push(Layer::Conv { out_c: 4096, kh: 7, kw: 7, stride: 1, pad: 0 });
    b.push(Layer::Relu);
    b.push(Layer::Quantize { bits });
    b.push(Layer::Conv { out_c: 4096, kh: 1, kw: 1, stride: 1, pad: 0 });
    b.push(Layer::Relu);
    b.push(Layer::Quantize { bits });
    b.push(Layer::Conv { out_c: 1000, kh: 1, kw: 1, stride: 1, pad: 0 });
    Network { name: "VGG19".into(), input: (3, 224, 224), input_bits: bits, nodes: b.nodes }
}

/// ResNet50 with bottleneck blocks and projection shortcuts.
pub fn resnet50(bits: u8) -> Network {
    let mut b = Builder::new();
    b.conv_bn_relu(64, 7, 2, 3, bits);
    b.push(Layer::MaxPool { k: 3, stride: 2 });

    let stages: [(usize, usize, usize); 4] =
        [(64, 256, 3), (128, 512, 4), (256, 1024, 6), (512, 2048, 3)];
    let mut block_in = b.nodes.len() - 1; // index producing the stage input
    for (si, (mid, out, reps)) in stages.into_iter().enumerate() {
        for r in 0..reps {
            let stride = if si > 0 && r == 0 { 2 } else { 1 };
            // Main path: 1×1 (stride) → 3×3 → 1×1.
            b.push_from(Layer::Conv { out_c: mid, kh: 1, kw: 1, stride, pad: 0 }, block_in);
            b.push(Layer::BatchNorm);
            b.push(Layer::Relu);
            b.push(Layer::Quantize { bits });
            b.conv_bn_relu(mid, 3, 1, 1, bits);
            b.push(Layer::Conv { out_c: out, kh: 1, kw: 1, stride: 1, pad: 0 });
            b.push(Layer::BatchNorm);
            let main_end = b.push(Layer::Quantize { bits });
            // Shortcut: projection on the first block of a stage,
            // identity otherwise.
            let skip = if r == 0 {
                let _proj = b.push_from(
                    Layer::Conv { out_c: out, kh: 1, kw: 1, stride, pad: 0 },
                    block_in,
                );
                b.push(Layer::BatchNorm);
                b.push(Layer::Quantize { bits })
            } else {
                block_in
            };
            // Merge: residual add + ReLU + requantize.
            let merged = b.push_from(Layer::Residual { from: skip }, main_end);
            b.push(Layer::Relu);
            block_in = b.push(Layer::Quantize { bits });
            let _ = merged;
        }
    }
    b.push(Layer::AvgPool { k: 7, stride: 7 });
    b.push(Layer::Conv { out_c: 1000, kh: 1, kw: 1, stride: 1, pad: 0 });
    Network { name: "ResNet50".into(), input: (3, 224, 224), input_bits: bits, nodes: b.nodes }
}

/// Small CNN for the bit-exact functional path (fits one mat: every
/// feature map ≤ 128 columns wide).
pub fn small_cnn(bits: u8) -> Network {
    let mut b = Builder::new();
    b.push(Layer::Conv { out_c: 4, kh: 3, kw: 3, stride: 1, pad: 0 });
    b.push(Layer::BatchNorm);
    b.push(Layer::Relu);
    b.push(Layer::Quantize { bits });
    b.push(Layer::MaxPool { k: 2, stride: 2 });
    b.push(Layer::Conv { out_c: 6, kh: 3, kw: 3, stride: 1, pad: 0 });
    b.push(Layer::Relu);
    b.push(Layer::Quantize { bits });
    b.push(Layer::AvgPool { k: 3, stride: 3 });
    Network { name: "SmallCNN".into(), input: (2, 14, 22), input_bits: bits, nodes: b.nodes }
}

/// Small residual network for the bit-exact functional path: one
/// padded conv stage plus a ResNet-style block (main path + identity
/// skip + residual add), exercising `Residual` and padding in the
/// functional engine.
pub fn small_resnet(bits: u8) -> Network {
    let mut b = Builder::new();
    // Stem: padded 3×3 conv keeps 12×18.
    b.push(Layer::Conv { out_c: 4, kh: 3, kw: 3, stride: 1, pad: 1 });
    b.push(Layer::Relu);
    let stem = b.push(Layer::Quantize { bits });
    // Main path: two padded convs preserving shape.
    b.push_from(Layer::Conv { out_c: 4, kh: 3, kw: 3, stride: 1, pad: 1 }, stem);
    b.push(Layer::Relu);
    b.push(Layer::Quantize { bits });
    b.push(Layer::Conv { out_c: 4, kh: 1, kw: 1, stride: 1, pad: 0 });
    let main_end = b.push(Layer::Quantize { bits });
    // Merge with the identity skip.
    let merged = b.push_from(Layer::Residual { from: stem }, main_end);
    b.push(Layer::Relu);
    b.push(Layer::Quantize { bits });
    let _ = merged;
    b.push(Layer::AvgPool { k: 2, stride: 2 });
    Network { name: "SmallResNet".into(), input: (2, 12, 18), input_bits: bits, nodes: b.nodes }
}

/// Single-conv micro network (kernel tests / quickstart).
pub fn micro_cnn(bits: u8) -> Network {
    let mut b = Builder::new();
    b.push(Layer::Conv { out_c: 2, kh: 2, kw: 2, stride: 1, pad: 0 });
    b.push(Layer::Quantize { bits });
    Network { name: "MicroCNN".into(), input: (1, 4, 6), input_bits: bits, nodes: b.nodes }
}

/// Wide single-conv network whose 200-column feature map exceeds one
/// 128-column subarray: the cheapest preset that genuinely exercises
/// the multi-tile mapping (§4.2, Fig. 9) at the real subarray capacity
/// — two width tiles with a `kw − stride = 2`-column halo. Used by the
/// serving bench for tiled-functional rows and handy for quick
/// multi-tile smoke runs.
pub fn wide_cnn(bits: u8) -> Network {
    let mut b = Builder::new();
    b.push(Layer::Conv { out_c: 4, kh: 3, kw: 3, stride: 1, pad: 0 });
    b.push(Layer::Relu);
    b.push(Layer::Quantize { bits });
    Network { name: "WideCNN".into(), input: (1, 16, 200), input_bits: bits, nodes: b.nodes }
}

/// Names accepted by [`preset`]: the paper's three full-size benchmarks
/// first, then the small functional-mode networks.
pub const PRESET_NAMES: [&str; 7] =
    ["alexnet", "vgg19", "resnet50", "small", "small_resnet", "micro", "wide"];

/// Look up a benchmark / functional-mode network preset by CLI name.
/// `bits` sets the activation precision (and the default weight
/// precision callers derive from it). Returns `None` for unknown names
/// (see [`PRESET_NAMES`]).
pub fn preset(name: &str, bits: u8) -> Option<Network> {
    match name {
        "alexnet" => Some(alexnet(bits)),
        "vgg19" => Some(vgg19(bits)),
        "resnet50" => Some(resnet50(bits)),
        "small" | "small_cnn" => Some(small_cnn(bits)),
        "small_resnet" => Some(small_resnet(bits)),
        "micro" | "micro_cnn" => Some(micro_cnn(bits)),
        "wide" | "wide_cnn" => Some(wide_cnn(bits)),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fingerprint_distinguishes_same_name_same_length_networks() {
        // The old `(name, nodes.len())` residency key collided here:
        // same name, same node count, different structure.
        let mut a = small_cnn(4);
        let mut b = small_cnn(4);
        if let Layer::Conv { stride, .. } = &mut b.nodes[0].layer {
            *stride += 1;
        } else {
            panic!("expected a conv at node 0");
        }
        assert_eq!(a.name, b.name);
        assert_eq!(a.nodes.len(), b.nodes.len());
        assert_ne!(a.fingerprint(), b.fingerprint(), "structure must be keyed");
        // Identical networks agree; the hash is deterministic.
        assert_eq!(small_cnn(4).fingerprint(), small_cnn(4).fingerprint());
        // Name, precision and wiring all contribute.
        a.name = "renamed".into();
        assert_ne!(a.fingerprint(), small_cnn(4).fingerprint());
        assert_ne!(small_cnn(3).fingerprint(), small_cnn(4).fingerprint());
        let mut c = small_resnet(4);
        let base = c.fingerprint();
        if let Some(node) = c.nodes.iter_mut().find(|n| n.input.is_some()) {
            node.input = None;
            assert_ne!(c.fingerprint(), base, "wiring must be keyed");
        }
    }

    #[test]
    fn alexnet_macs_in_known_range() {
        let n = alexnet(8);
        let macs = n.total_macs();
        // AlexNet ≈ 0.7–1.2 GMACs depending on FC handling.
        assert!(macs > 600e6 as u64 && macs < 1500e6 as u64, "{macs}");
        assert_eq!(n.shapes().last().unwrap(), &(1000, 1, 1));
    }

    #[test]
    fn vgg19_macs_in_known_range() {
        let n = vgg19(8);
        let macs = n.total_macs();
        // VGG19 ≈ 19.6 GMACs.
        assert!(macs > 18e9 as u64 && macs < 21e9 as u64, "{macs}");
        assert_eq!(n.shapes().last().unwrap(), &(1000, 1, 1));
    }

    #[test]
    fn resnet50_macs_in_known_range() {
        let n = resnet50(8);
        let macs = n.total_macs();
        // ResNet50 ≈ 3.8–4.1 GMACs.
        assert!(macs > 3.4e9 as u64 && macs < 4.6e9 as u64, "{macs}");
        assert_eq!(n.shapes().last().unwrap(), &(1000, 1, 1));
    }

    #[test]
    fn resnet50_shapes_are_consistent() {
        // shapes() asserts residual shape agreement internally.
        let n = resnet50(8);
        let shapes = n.shapes();
        // Unpadded 3/2 max-pool gives 55×55 (vs. 56×56 with pad=1 in the
        // torchvision variant) — stage extents follow from there.
        assert!(shapes.contains(&(256, 55, 55)));
        assert!(shapes.contains(&(512, 28, 28)));
        assert!(shapes.contains(&(1024, 14, 14)));
        assert!(shapes.contains(&(2048, 7, 7)));
    }

    #[test]
    fn small_cnn_fits_subarray_width() {
        let n = small_cnn(4);
        for (c, _h, w) in n.shapes() {
            assert!(w <= 128, "width {w} exceeds subarray columns");
            assert!(c <= 16);
        }
    }

    #[test]
    fn wide_cnn_exceeds_subarray_width() {
        // The whole point of the preset: its input row is wider than the
        // paper subarray's 128 columns, forcing the multi-tile mapping.
        let n = wide_cnn(3);
        assert!(n.input.2 > 128, "WideCNN must not fit one subarray");
        let (_, oh, ow) = n.shapes()[1];
        assert_eq!((oh, ow), (14, 198));
    }

    #[test]
    fn weights_counted() {
        let n = micro_cnn(4);
        assert_eq!(n.total_weights(), 2 * 1 * 2 * 2);
    }

    #[test]
    fn preset_lookup_covers_every_name() {
        for name in PRESET_NAMES {
            let net = preset(name, 4).unwrap_or_else(|| panic!("preset {name} missing"));
            assert!(!net.nodes.is_empty(), "{name}");
        }
        assert!(preset("lenet", 4).is_none());
        assert_eq!(preset("alexnet", 8).unwrap().name, "AlexNet");
        assert_eq!(preset("small", 4).unwrap().name, "SmallCNN");
    }
}
