//! Quantized integer tensors and bit-plane decomposition.
//!
//! The PIM dataflow operates on *bit-planes*: an M-bit feature map is M
//! 1-bit matrices stored in M subarrays; an N-bit weight tensor is N
//! 1-bit matrices broadcast to the subarray buffers (paper §4.1).

use crate::util::Rng;

/// A quantized activation tensor in CHW layout, unsigned `bits`-bit
/// values.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct QTensor {
    /// Channels.
    pub c: usize,
    /// Height.
    pub h: usize,
    /// Width.
    pub w: usize,
    /// Value bit-width.
    pub bits: u8,
    data: Vec<u32>,
}

impl QTensor {
    /// Zero tensor.
    pub fn zeros(c: usize, h: usize, w: usize, bits: u8) -> Self {
        assert!(bits >= 1 && bits <= 16);
        Self { c, h, w, bits, data: vec![0; c * h * w] }
    }

    /// Build from raw CHW data.
    ///
    /// # Panics
    /// If the length mismatches or any value overflows `bits`.
    pub fn from_vec(c: usize, h: usize, w: usize, bits: u8, data: Vec<u32>) -> Self {
        assert_eq!(data.len(), c * h * w);
        let max = Self::max_value(bits);
        assert!(data.iter().all(|&v| v <= max), "value exceeds {bits}-bit range");
        Self { c, h, w, bits, data }
    }

    /// Pseudo-random tensor (deterministic per seed) — synthetic workload.
    pub fn random(c: usize, h: usize, w: usize, bits: u8, seed: u64) -> Self {
        let mut rng = Rng::seed_from_u64(seed);
        let max = Self::max_value(bits);
        let data = (0..c * h * w).map(|_| rng.gen_range_inclusive(max)).collect();
        Self { c, h, w, bits, data }
    }

    /// Largest representable value for a bit-width.
    #[inline]
    pub fn max_value(bits: u8) -> u32 {
        if bits >= 32 {
            u32::MAX
        } else {
            (1u32 << bits) - 1
        }
    }

    /// Element count.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True if the tensor has no elements.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Value at (c, y, x).
    #[inline]
    pub fn at(&self, c: usize, y: usize, x: usize) -> u32 {
        self.data[(c * self.h + y) * self.w + x]
    }

    /// Mutable value at (c, y, x).
    #[inline]
    pub fn at_mut(&mut self, c: usize, y: usize, x: usize) -> &mut u32 {
        &mut self.data[(c * self.h + y) * self.w + x]
    }

    /// Raw CHW slice.
    pub fn data(&self) -> &[u32] {
        &self.data
    }

    /// Bit-plane `n` of channel `c` as H rows of W bools:
    /// `plane[y][x] = bit n of self[c][y][x]`.
    pub fn bitplane(&self, c: usize, n: u8) -> Vec<Vec<bool>> {
        (0..self.h)
            .map(|y| (0..self.w).map(|x| (self.at(c, y, x) >> n) & 1 == 1).collect())
            .collect()
    }

    /// Bit-plane rows packed as u128 words (bit x = column x), ready for
    /// subarray storage. `w` must be ≤ 128.
    pub fn bitplane_rows(&self, c: usize, n: u8) -> Vec<u128> {
        assert!(self.w <= 128);
        (0..self.h)
            .map(|y| {
                let mut word = 0u128;
                for x in 0..self.w {
                    if (self.at(c, y, x) >> n) & 1 == 1 {
                        word |= 1 << x;
                    }
                }
                word
            })
            .collect()
    }
}

/// A quantized convolution kernel in OIHW layout, unsigned `bits`-bit
/// values.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Kernel4 {
    /// Output channels.
    pub oc: usize,
    /// Input channels.
    pub ic: usize,
    /// Kernel height.
    pub kh: usize,
    /// Kernel width.
    pub kw: usize,
    /// Value bit-width.
    pub bits: u8,
    data: Vec<u32>,
}

impl Kernel4 {
    /// Zero kernel.
    pub fn zeros(oc: usize, ic: usize, kh: usize, kw: usize, bits: u8) -> Self {
        Self { oc, ic, kh, kw, bits, data: vec![0; oc * ic * kh * kw] }
    }

    /// Build from raw OIHW data.
    pub fn from_vec(oc: usize, ic: usize, kh: usize, kw: usize, bits: u8, data: Vec<u32>) -> Self {
        assert_eq!(data.len(), oc * ic * kh * kw);
        let max = QTensor::max_value(bits);
        assert!(data.iter().all(|&v| v <= max));
        Self { oc, ic, kh, kw, bits, data }
    }

    /// Pseudo-random kernel (deterministic per seed).
    pub fn random(oc: usize, ic: usize, kh: usize, kw: usize, bits: u8, seed: u64) -> Self {
        let mut rng = Rng::seed_from_u64(seed);
        let max = QTensor::max_value(bits);
        let data = (0..oc * ic * kh * kw).map(|_| rng.gen_range_inclusive(max)).collect();
        Self { oc, ic, kh, kw, bits, data }
    }

    /// Value at (oc, ic, ky, kx).
    #[inline]
    pub fn at(&self, oc: usize, ic: usize, ky: usize, kx: usize) -> u32 {
        self.data[((oc * self.ic + ic) * self.kh + ky) * self.kw + kx]
    }

    /// Raw OIHW slice.
    pub fn data(&self) -> &[u32] {
        &self.data
    }

    /// Bit-plane `m` of filter (oc, ic) as a row-major bool vec
    /// (kh × kw) — the 1-bit weight matrix broadcast to a subarray buffer.
    pub fn bitplane(&self, oc: usize, ic: usize, m: u8) -> Vec<bool> {
        let mut bits = Vec::with_capacity(self.kh * self.kw);
        for ky in 0..self.kh {
            for kx in 0..self.kw {
                bits.push((self.at(oc, ic, ky, kx) >> m) & 1 == 1);
            }
        }
        bits
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bitplanes_reconstruct_values() {
        let t = QTensor::random(2, 4, 6, 8, 42);
        for c in 0..2 {
            for y in 0..4 {
                for x in 0..6 {
                    let mut v = 0u32;
                    for n in 0..8 {
                        if t.bitplane(c, n)[y][x] {
                            v |= 1 << n;
                        }
                    }
                    assert_eq!(v, t.at(c, y, x));
                }
            }
        }
    }

    #[test]
    fn bitplane_rows_match_bitplane() {
        let t = QTensor::random(1, 5, 120, 4, 7);
        for n in 0..4 {
            let rows = t.bitplane_rows(0, n);
            let plane = t.bitplane(0, n);
            for (y, row) in rows.iter().enumerate() {
                for x in 0..120 {
                    assert_eq!((row >> x) & 1 == 1, plane[y][x]);
                }
            }
        }
    }

    #[test]
    fn random_respects_bit_range() {
        let t = QTensor::random(3, 8, 8, 3, 1);
        assert!(t.data().iter().all(|&v| v < 8));
        let k = Kernel4::random(4, 3, 3, 3, 2, 2);
        assert!(k.data().iter().all(|&v| v < 4));
    }

    #[test]
    fn random_is_deterministic() {
        assert_eq!(QTensor::random(2, 3, 4, 8, 9), QTensor::random(2, 3, 4, 8, 9));
        assert_ne!(QTensor::random(2, 3, 4, 8, 9), QTensor::random(2, 3, 4, 8, 10));
    }

    #[test]
    fn kernel_bitplane_layout_is_row_major() {
        let mut k = Kernel4::zeros(1, 1, 2, 3, 4);
        // Set value 1 at (ky=1, kx=2).
        k.data[1 * 3 + 2] = 1;
        let plane = k.bitplane(0, 0, 0);
        assert_eq!(plane, vec![false, false, false, false, false, true]);
    }
}
