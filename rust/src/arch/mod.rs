//! Architecture-level configuration, statistics accounting and area model.

pub mod area;
pub mod config;
pub mod stats;

pub use area::AreaModel;
pub use config::ArchConfig;
pub use stats::{Phase, Stats};
