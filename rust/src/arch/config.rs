//! Architecture configuration: the Fig. 2 hierarchy parameters and the
//! §5.2 experimental operating point (4×4 subarrays of 256×128 per mat,
//! 4×4 mats per group, 64 MB total, 128-bit bus).


use crate::device::energy::DeviceCosts;
use crate::device::nand_spin::MTJS_PER_DEVICE;

/// Full architecture configuration.
#[derive(Debug, Clone)]
pub struct ArchConfig {
    /// MTJ rows per subarray (paper: 256).
    pub rows: usize,
    /// Columns (SAs / bit-counters) per subarray (paper: 128).
    pub cols: usize,
    /// Subarrays per mat along each dimension (paper: 4×4).
    pub subarrays_per_mat: (usize, usize),
    /// Mats per bank group along each dimension (paper: 4×4).
    pub mats_per_bank: (usize, usize),
    /// Total memory capacity in MB (paper design point: 64).
    pub capacity_mb: usize,
    /// Shared data-bus width in bits (paper design point: 128).
    pub bus_width_bits: usize,
    /// Weight-buffer rows per subarray (holds 1-bit weight rows + the
    /// comparison scratch rows).
    pub buffer_rows: usize,
    /// Device/periphery cost scalars.
    pub costs: DeviceCosts,
}

impl Default for ArchConfig {
    fn default() -> Self {
        Self {
            rows: 256,
            cols: 128,
            subarrays_per_mat: (4, 4),
            mats_per_bank: (4, 4),
            capacity_mb: 64,
            bus_width_bits: 128,
            // Enough rows for one 1-bit weight matrix of the largest
            // mainstream kernel (11×11 in AlexNet) plus comparison scratch.
            buffer_rows: 16,
            costs: DeviceCosts::default(),
        }
    }
}

impl ArchConfig {
    /// Paper §5.2 operating point (the default).
    pub fn paper() -> Self {
        Self::default()
    }

    /// Subarray capacity in bits.
    pub fn subarray_bits(&self) -> usize {
        self.rows * self.cols
    }

    /// Subarrays per mat.
    pub fn subarrays_in_mat(&self) -> usize {
        self.subarrays_per_mat.0 * self.subarrays_per_mat.1
    }

    /// Mats per bank group.
    pub fn mats_in_bank(&self) -> usize {
        self.mats_per_bank.0 * self.mats_per_bank.1
    }

    /// Bits per mat.
    pub fn mat_bits(&self) -> usize {
        self.subarray_bits() * self.subarrays_in_mat()
    }

    /// Bits per bank group.
    pub fn bank_bits(&self) -> usize {
        self.mat_bits() * self.mats_in_bank()
    }

    /// Number of bank groups needed to reach `capacity_mb`.
    pub fn num_banks(&self) -> usize {
        let total_bits = self.capacity_mb * 1024 * 1024 * 8;
        total_bits.div_ceil(self.bank_bits())
    }

    /// Total number of subarrays in the configured capacity — the
    /// compute-parallelism budget of the accelerator.
    pub fn total_subarrays(&self) -> usize {
        self.num_banks() * self.mats_in_bank() * self.subarrays_in_mat()
    }

    /// NAND-SPIN strip rows per subarray (each strip stacks
    /// [`MTJS_PER_DEVICE`] MTJ rows).
    pub fn strip_rows(&self) -> usize {
        self.rows / MTJS_PER_DEVICE
    }

    /// Validate structural invariants.
    pub fn validate(&self) -> Result<(), String> {
        if self.rows % MTJS_PER_DEVICE != 0 {
            return Err(format!(
                "rows ({}) must be a multiple of MTJs per device ({MTJS_PER_DEVICE})",
                self.rows
            ));
        }
        if self.cols == 0 || self.cols > 128 {
            return Err(format!(
                "cols ({}) must be in 1..=128 (one u128 word per row)",
                self.cols
            ));
        }
        if self.bus_width_bits == 0 {
            return Err("bus width must be non-zero".into());
        }
        if self.buffer_rows < 2 {
            return Err("buffer needs >= 2 rows (comparison uses two scratch rows)".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_point_geometry() {
        let c = ArchConfig::paper();
        c.validate().unwrap();
        assert_eq!(c.subarray_bits(), 256 * 128); // 4 KiB
        assert_eq!(c.mat_bits(), 16 * 4096 * 8); // 64 KiB
        assert_eq!(c.bank_bits(), 1024 * 1024 * 8); // 1 MiB
        assert_eq!(c.num_banks(), 64); // 64 MB total
        assert_eq!(c.total_subarrays(), 64 * 16 * 16);
        assert_eq!(c.strip_rows(), 32);
    }

    #[test]
    fn capacity_scales_banks() {
        let mut c = ArchConfig::paper();
        for cap in [8, 16, 32, 64, 128, 256] {
            c.capacity_mb = cap;
            assert_eq!(c.num_banks(), cap, "1 MiB per bank group");
        }
    }

    #[test]
    fn invalid_configs_rejected() {
        let mut c = ArchConfig::paper();
        c.rows = 255;
        assert!(c.validate().is_err());
        let mut c = ArchConfig::paper();
        c.cols = 129;
        assert!(c.validate().is_err());
        let mut c = ArchConfig::paper();
        c.buffer_rows = 1;
        assert!(c.validate().is_err());
    }
}
