//! Area model wrapper: Fig. 17 breakdown and Table 3 figures.


use crate::arch::config::ArchConfig;
use crate::nvsim::{AreaBreakdown, NvSimModel};

/// High-level area model for the proposed accelerator.
#[derive(Debug, Clone, Default)]
pub struct AreaModel {
    nvsim: NvSimModel,
}

/// One Fig. 17 pie slice.
#[derive(Debug, Clone)]
pub struct AreaSlice {
    /// Component name.
    pub name: &'static str,
    /// Area in mm².
    pub mm2: f64,
    /// Fraction of the total add-on.
    pub fraction: f64,
}

impl AreaModel {
    /// Full structural breakdown for `cfg`.
    pub fn breakdown(&self, cfg: &ArchConfig) -> AreaBreakdown {
        self.nvsim.area(cfg)
    }

    /// Total chip area in mm² (Table 3 row).
    pub fn total_mm2(&self, cfg: &ArchConfig) -> f64 {
        self.breakdown(cfg).total_mm2()
    }

    /// Fig. 17: the add-on area pie (computation units / buffer /
    /// controller+mux / other).
    pub fn fig17_slices(&self, cfg: &ArchConfig) -> Vec<AreaSlice> {
        let b = self.breakdown(cfg);
        let addon = b.addon_mm2();
        let mk = |name, mm2: f64| AreaSlice { name, mm2, fraction: mm2 / addon };
        vec![
            mk("computation units", b.addon_compute_mm2),
            mk("buffer", b.addon_buffer_mm2),
            mk("controller + mux", b.addon_ctrl_mux_mm2),
            mk("other circuits", b.addon_other_mm2),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig17_slices_sum_to_one() {
        let m = AreaModel::default();
        let slices = m.fig17_slices(&ArchConfig::paper());
        let total: f64 = slices.iter().map(|s| s.fraction).sum();
        assert!((total - 1.0).abs() < 1e-9);
        assert_eq!(slices.len(), 4);
        // Computation units dominate (Fig. 17: ~47 %).
        assert!(slices[0].fraction > slices[1].fraction);
    }
}
