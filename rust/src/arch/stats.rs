//! Latency / energy / op-count accounting with the Fig. 16 breakdown
//! categories.
//!
//! Two composition rules mirror the hardware: subarrays within a step run
//! in *parallel* (`merge_parallel`: energy sums, time is the max) while
//! successive steps are *serial* (`merge_serial`: both sum). The
//! coordinator chooses which rule applies at each schedule point.

use std::fmt;
use std::ops::{Index, IndexMut};

/// Breakdown categories of Fig. 16 (latency & energy breakdown for
/// ResNet50) plus a readout/other bucket.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Phase {
    /// Loading inputs/weights from outside and distributing them into
    /// arrays (Fig. 16: "load", 38.4 % latency / 32.6 % energy).
    LoadData,
    /// Bitwise convolution: AND + bit-count + partial-sum accumulation.
    Convolution,
    /// In-mat / inter-mat data movement of intermediate results.
    DataTransfer,
    /// Pooling-layer comparisons / averaging.
    Pooling,
    /// Batch normalisation (Eq. 3).
    BatchNorm,
    /// Quantization (Eq. 2).
    Quantization,
    /// Everything else (result readout, control).
    Other,
}

impl Phase {
    /// All phases in Fig. 16 presentation order.
    pub const ALL: [Phase; 7] = [
        Phase::LoadData,
        Phase::Convolution,
        Phase::DataTransfer,
        Phase::Pooling,
        Phase::BatchNorm,
        Phase::Quantization,
        Phase::Other,
    ];

    /// Stable index for dense storage.
    #[inline]
    pub fn idx(self) -> usize {
        match self {
            Phase::LoadData => 0,
            Phase::Convolution => 1,
            Phase::DataTransfer => 2,
            Phase::Pooling => 3,
            Phase::BatchNorm => 4,
            Phase::Quantization => 5,
            Phase::Other => 6,
        }
    }

    /// Human label matching the paper's figure.
    pub fn label(self) -> &'static str {
        match self {
            Phase::LoadData => "load data",
            Phase::Convolution => "convolution",
            Phase::DataTransfer => "data transfer",
            Phase::Pooling => "pooling",
            Phase::BatchNorm => "batch norm",
            Phase::Quantization => "quantization",
            Phase::Other => "other",
        }
    }
}

/// Energy/latency accumulated for one phase.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PhaseStats {
    /// Energy in femtojoules.
    pub energy_fj: f64,
    /// Latency in nanoseconds.
    pub latency_ns: f64,
}

/// Raw operation counts — useful for cross-checking analytic vs functional
/// paths and for the op-level regression tests.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct OpCounts {
    /// Strip (SOT) erase operations.
    pub erases: u64,
    /// Program steps (one MTJ position across a row).
    pub program_steps: u64,
    /// Individual bits switched AP→P.
    pub programmed_bits: u64,
    /// Row read operations.
    pub reads: u64,
    /// Row AND operations.
    pub ands: u64,
    /// Bit-counter accumulate steps.
    pub bitcounts: u64,
    /// Weight-buffer row accesses.
    pub buffer_accesses: u64,
    /// Bits moved on local (in-mat) buses.
    pub local_bus_bits: u64,
    /// Bits moved on the global (inter-mat / I/O) bus.
    pub global_bus_bits: u64,
}

impl OpCounts {
    /// Every counter with its stable name, in declaration order — the
    /// canonical iteration for op-mix telemetry (layer-cost exports,
    /// metrics registries), so every emitter agrees on names and order.
    pub fn named(&self) -> [(&'static str, u64); 9] {
        [
            ("erases", self.erases),
            ("program_steps", self.program_steps),
            ("programmed_bits", self.programmed_bits),
            ("reads", self.reads),
            ("ands", self.ands),
            ("bitcounts", self.bitcounts),
            ("buffer_accesses", self.buffer_accesses),
            ("local_bus_bits", self.local_bus_bits),
            ("global_bus_bits", self.global_bus_bits),
        ]
    }

    fn add(&mut self, o: &OpCounts) {
        self.erases += o.erases;
        self.program_steps += o.program_steps;
        self.programmed_bits += o.programmed_bits;
        self.reads += o.reads;
        self.ands += o.ands;
        self.bitcounts += o.bitcounts;
        self.buffer_accesses += o.buffer_accesses;
        self.local_bus_bits += o.local_bus_bits;
        self.global_bus_bits += o.global_bus_bits;
    }

    fn sub(&mut self, o: &OpCounts) {
        self.erases -= o.erases;
        self.program_steps -= o.program_steps;
        self.programmed_bits -= o.programmed_bits;
        self.reads -= o.reads;
        self.ands -= o.ands;
        self.bitcounts -= o.bitcounts;
        self.buffer_accesses -= o.buffer_accesses;
        self.local_bus_bits -= o.local_bus_bits;
        self.global_bus_bits -= o.global_bus_bits;
    }
}

/// Fault-injection and recovery counters, recorded by the subarray
/// fault hooks ([`crate::device::fault::FaultPlan`]) alongside the op
/// counts. They ride inside [`Stats`], so they flow through the same
/// `merge_serial` / `merge_parallel` / `delta_since` / [`OpLedger`]
/// machinery — the fan-out merge stays bit-identical at any worker
/// count, fault counters included.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultLedger {
    /// Transient STT program failures injected (one intended bit
    /// failed to switch in a program step).
    pub program_faults: u64,
    /// SPCSA decision flips injected on read senses.
    pub read_flips: u64,
    /// SPCSA decision flips injected on AND senses.
    pub and_flips: u64,
    /// Write-verify retries performed (each charged as a real
    /// erase + program rewrite).
    pub write_retries: u64,
    /// Rows spared after the retry budget was exhausted (each charged
    /// as a remap rewrite onto a spare row).
    pub spared_rows: u64,
}

impl FaultLedger {
    /// Injected fault events (excludes the recovery actions).
    pub fn injected(&self) -> u64 {
        self.program_faults + self.read_flips + self.and_flips
    }

    /// True when nothing was injected and nothing was recovered.
    pub fn is_zero(&self) -> bool {
        *self == FaultLedger::default()
    }

    fn add(&mut self, o: &FaultLedger) {
        self.program_faults += o.program_faults;
        self.read_flips += o.read_flips;
        self.and_flips += o.and_flips;
        self.write_retries += o.write_retries;
        self.spared_rows += o.spared_rows;
    }

    fn sub(&mut self, o: &FaultLedger) {
        self.program_faults -= o.program_faults;
        self.read_flips -= o.read_flips;
        self.and_flips -= o.and_flips;
        self.write_retries -= o.write_retries;
        self.spared_rows -= o.spared_rows;
    }
}

/// Queue / batching counters of the serving runtime
/// ([`crate::coordinator::serve`](mod@crate::coordinator::serve)):
/// how requests moved through the
/// dynamic batcher and the per-chip queues. Kept here next to [`Stats`]
/// so the serving report can aggregate device-level and queue-level
/// accounting through one module.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct QueueCounters {
    /// Requests accepted into the batcher.
    pub enqueued: u64,
    /// Batches emitted (all flush causes).
    pub batches: u64,
    /// Batches flushed because they reached the size target.
    pub size_flushes: u64,
    /// Batches flushed because the oldest request hit the deadline.
    pub deadline_flushes: u64,
    /// Batches flushed by the end-of-stream drain.
    pub drain_flushes: u64,
    /// Largest number of requests ever waiting in the batcher.
    pub max_queue_depth: usize,
    /// Largest batch emitted.
    pub max_batch: usize,
    /// Batches whose dispatch stalled on a full per-chip queue
    /// (backpressure events).
    pub stalled_batches: u64,
}

/// Full statistics record.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Stats {
    phases: [PhaseStats; 7],
    /// Op counts (not phase-resolved).
    pub ops: OpCounts,
    /// Fault-injection / recovery counters (all-zero when no fault
    /// plan is active).
    pub faults: FaultLedger,
}

impl Index<Phase> for Stats {
    type Output = PhaseStats;
    fn index(&self, p: Phase) -> &PhaseStats {
        &self.phases[p.idx()]
    }
}

impl IndexMut<Phase> for Stats {
    fn index_mut(&mut self, p: Phase) -> &mut PhaseStats {
        &mut self.phases[p.idx()]
    }
}

impl Stats {
    /// Record `energy_fj` and `latency_ns` against `phase`.
    #[inline]
    pub fn record(&mut self, phase: Phase, energy_fj: f64, latency_ns: f64) {
        let p = &mut self.phases[phase.idx()];
        p.energy_fj += energy_fj;
        p.latency_ns += latency_ns;
    }

    /// Total energy across phases (fJ).
    pub fn total_energy_fj(&self) -> f64 {
        self.phases.iter().map(|p| p.energy_fj).sum()
    }

    /// Total latency across phases (ns).
    pub fn total_latency_ns(&self) -> f64 {
        self.phases.iter().map(|p| p.latency_ns).sum()
    }

    /// Total energy in millijoules.
    pub fn total_energy_mj(&self) -> f64 {
        self.total_energy_fj() * 1e-12
    }

    /// Total latency in milliseconds.
    pub fn total_latency_ms(&self) -> f64 {
        self.total_latency_ns() * 1e-6
    }

    /// Serial composition: this step happens after `other` — both energy
    /// and latency accumulate.
    pub fn merge_serial(&mut self, other: &Stats) {
        for i in 0..self.phases.len() {
            self.phases[i].energy_fj += other.phases[i].energy_fj;
            self.phases[i].latency_ns += other.phases[i].latency_ns;
        }
        self.ops.add(&other.ops);
        self.faults.add(&other.faults);
    }

    /// Parallel composition: `others` ran concurrently — energies sum,
    /// per-phase latency is the maximum over the group.
    pub fn merge_parallel(&mut self, others: &[Stats]) {
        for i in 0..self.phases.len() {
            let mut max_lat = 0.0f64;
            for o in others {
                self.phases[i].energy_fj += o.phases[i].energy_fj;
                max_lat = max_lat.max(o.phases[i].latency_ns);
            }
            self.phases[i].latency_ns += max_lat;
        }
        for o in others {
            self.ops.add(&o.ops);
            self.faults.add(&o.faults);
        }
    }

    /// The increment recorded since `earlier` was snapshotted from the
    /// same accumulating record: per-phase energies/latencies and op
    /// counts subtract. Used by the serving runtime to attribute one
    /// engine's monotonically growing stats to individual requests.
    ///
    /// # Panics
    /// In debug builds, if `earlier` is not an earlier snapshot of
    /// `self` (any op count would go negative).
    pub fn delta_since(&self, earlier: &Stats) -> Stats {
        debug_assert!(
            self.ops.program_steps >= earlier.ops.program_steps
                && self.ops.reads >= earlier.ops.reads
                && self.ops.ands >= earlier.ops.ands,
            "delta_since: `earlier` is not a prefix snapshot"
        );
        let mut d = self.clone();
        for i in 0..d.phases.len() {
            d.phases[i].energy_fj -= earlier.phases[i].energy_fj;
            d.phases[i].latency_ns -= earlier.phases[i].latency_ns;
        }
        d.ops.sub(&earlier.ops);
        d.faults.sub(&earlier.faults);
        d
    }

    /// Per-phase latency fractions (sums to 1 unless empty).
    pub fn latency_breakdown(&self) -> Vec<(Phase, f64)> {
        let t = self.total_latency_ns();
        Phase::ALL
            .iter()
            .map(|&p| (p, if t > 0.0 { self[p].latency_ns / t } else { 0.0 }))
            .collect()
    }

    /// Per-phase energy fractions.
    pub fn energy_breakdown(&self) -> Vec<(Phase, f64)> {
        let e = self.total_energy_fj();
        Phase::ALL
            .iter()
            .map(|&p| (p, if e > 0.0 { self[p].energy_fj / e } else { 0.0 }))
            .collect()
    }
}

/// Deterministic merge of independently-recorded [`Stats`] deltas.
///
/// The functional engine's intra-request fan-out records each filter
/// pass into its own zero-based `Stats` (a "ledger entry") keyed by the
/// pass index, then folds every entry into the request total **in
/// ascending key order** via [`Stats::merge_serial`] — regardless of
/// the order workers finished. Because floating-point addition is not
/// associative, this canonical ordering is what makes parallel
/// execution bit-identical to sequential execution: both run the exact
/// same sequence of `f64` additions.
#[derive(Debug, Default)]
pub struct OpLedger {
    entries: Vec<(usize, Stats)>,
}

impl OpLedger {
    /// Empty ledger.
    pub fn new() -> Self {
        Self { entries: Vec::new() }
    }

    /// Record one pass's zero-based stats delta under `index`.
    /// Indices must be unique; push order is irrelevant.
    pub fn push(&mut self, index: usize, stats: Stats) {
        self.entries.push((index, stats));
    }

    /// Fold every entry into `total` in ascending index order.
    pub fn merge_into(mut self, total: &mut Stats) {
        self.entries.sort_unstable_by_key(|(i, _)| *i);
        for (_, s) in &self.entries {
            total.merge_serial(s);
        }
    }
}

impl fmt::Display for Stats {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(
            f,
            "total: {:.3} ms, {:.3} mJ",
            self.total_latency_ms(),
            self.total_energy_mj()
        )?;
        for &p in &Phase::ALL {
            let s = self[p];
            if s.latency_ns == 0.0 && s.energy_fj == 0.0 {
                continue;
            }
            writeln!(
                f,
                "  {:>14}: {:>10.3} ms ({:>5.1} %)  {:>10.3} mJ ({:>5.1} %)",
                p.label(),
                s.latency_ns * 1e-6,
                100.0 * s.latency_ns / self.total_latency_ns().max(f64::MIN_POSITIVE),
                s.energy_fj * 1e-12,
                100.0 * s.energy_fj / self.total_energy_fj().max(f64::MIN_POSITIVE),
            )?;
        }
        if !self.faults.is_zero() {
            let f_ = &self.faults;
            writeln!(
                f,
                "  faults: {} program, {} read flips, {} AND flips; {} retries, {} spared",
                f_.program_faults, f_.read_flips, f_.and_flips, f_.write_retries, f_.spared_rows,
            )?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_totals() {
        let mut s = Stats::default();
        s.record(Phase::Convolution, 100.0, 2.0);
        s.record(Phase::LoadData, 50.0, 8.0);
        assert_eq!(s.total_energy_fj(), 150.0);
        assert_eq!(s.total_latency_ns(), 10.0);
        assert_eq!(s[Phase::Convolution].energy_fj, 100.0);
    }

    #[test]
    fn parallel_merge_takes_max_latency() {
        let mut a = Stats::default();
        let mut x = Stats::default();
        let mut y = Stats::default();
        x.record(Phase::Convolution, 10.0, 5.0);
        y.record(Phase::Convolution, 20.0, 3.0);
        a.merge_parallel(&[x, y]);
        assert_eq!(a[Phase::Convolution].energy_fj, 30.0);
        assert_eq!(a[Phase::Convolution].latency_ns, 5.0);
    }

    #[test]
    fn serial_merge_sums_both() {
        let mut a = Stats::default();
        let mut b = Stats::default();
        a.record(Phase::Pooling, 10.0, 5.0);
        b.record(Phase::Pooling, 1.0, 1.0);
        a.merge_serial(&b);
        assert_eq!(a[Phase::Pooling].energy_fj, 11.0);
        assert_eq!(a[Phase::Pooling].latency_ns, 6.0);
    }

    #[test]
    fn delta_since_recovers_the_increment() {
        let mut s = Stats::default();
        s.record(Phase::Convolution, 10.0, 1.0);
        s.ops.ands += 3;
        let snap = s.clone();
        s.record(Phase::Convolution, 5.0, 2.0);
        s.record(Phase::Pooling, 7.0, 3.0);
        s.ops.ands += 2;
        s.ops.reads += 4;
        let d = s.delta_since(&snap);
        assert_eq!(d[Phase::Convolution].energy_fj, 5.0);
        assert_eq!(d[Phase::Convolution].latency_ns, 2.0);
        assert_eq!(d[Phase::Pooling].energy_fj, 7.0);
        assert_eq!(d.ops.ands, 2);
        assert_eq!(d.ops.reads, 4);
        // Identity: snapshot + delta == final totals.
        let mut back = snap.clone();
        back.merge_serial(&d);
        assert_eq!(back.total_energy_fj(), s.total_energy_fj());
        assert_eq!(back.ops, s.ops);
    }

    #[test]
    fn ledger_merge_is_order_deterministic() {
        // Build entries whose f64 magnitudes differ wildly, so any
        // change in summation order would change the rounded total.
        let entry = |i: usize| {
            let mut s = Stats::default();
            s.record(Phase::Convolution, 1e16_f64.powf(0.1 * i as f64), 1.0 + 1e-9 * i as f64);
            s.ops.ands += i as u64;
            s
        };
        let n = 9;
        let mut forward = OpLedger::new();
        for i in 0..n {
            forward.push(i, entry(i));
        }
        let mut shuffled = OpLedger::new();
        // A fixed permutation that is far from sorted.
        for &i in &[4usize, 8, 0, 6, 2, 7, 1, 5, 3] {
            shuffled.push(i, entry(i));
        }
        let mut a = Stats::default();
        let mut b = Stats::default();
        forward.merge_into(&mut a);
        shuffled.merge_into(&mut b);
        // Bitwise equality, not approximate: the ledger must erase any
        // trace of completion order.
        let (pa, pb) = (a[Phase::Convolution], b[Phase::Convolution]);
        assert_eq!(pa.energy_fj.to_bits(), pb.energy_fj.to_bits());
        assert_eq!(pa.latency_ns.to_bits(), pb.latency_ns.to_bits());
        assert_eq!(a.ops, b.ops);
    }

    #[test]
    fn fault_ledger_flows_through_merges_and_deltas() {
        let mut a = Stats::default();
        a.faults.program_faults = 2;
        a.faults.write_retries = 1;
        let mut b = Stats::default();
        b.faults.read_flips = 3;
        b.faults.spared_rows = 1;
        let snap = a.clone();
        a.merge_serial(&b);
        assert_eq!(a.faults.program_faults, 2);
        assert_eq!(a.faults.read_flips, 3);
        assert_eq!(a.faults.injected(), 5);
        let d = a.delta_since(&snap);
        assert_eq!(d.faults, b.faults);
        let mut p = Stats::default();
        p.merge_parallel(&[a.clone(), b.clone()]);
        assert_eq!(p.faults.read_flips, 6);
        assert_eq!(p.faults.write_retries, 1);
        assert!(!p.faults.is_zero());
        assert!(Stats::default().faults.is_zero());
        // The Display fault line appears only when something happened.
        assert!(!format!("{}", Stats::default()).contains("faults:"));
        assert!(format!("{p}").contains("faults:"));
    }

    #[test]
    fn breakdown_fractions_sum_to_one() {
        let mut s = Stats::default();
        s.record(Phase::Convolution, 30.0, 3.0);
        s.record(Phase::LoadData, 70.0, 7.0);
        let lat: f64 = s.latency_breakdown().iter().map(|(_, f)| f).sum();
        let en: f64 = s.energy_breakdown().iter().map(|(_, f)| f).sum();
        assert!((lat - 1.0).abs() < 1e-12);
        assert!((en - 1.0).abs() < 1e-12);
    }
}
