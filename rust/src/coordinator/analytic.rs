//! Analytic performance/energy model of the proposed accelerator.
//!
//! Mirrors the paper's architecture simulator: every layer is decomposed
//! into the primitive-op counts the functional simulator would execute
//! (erase/program/read/AND/bit-count/bus), costed with the calibrated
//! device scalars, and composed with the layer-level parallelism the
//! mapping provides. The functional simulator ([`super::functional`])
//! executes the same op sequences bit-accurately on small networks; an
//! integration test checks the two agree on op counts for a layer that
//! both can run.
//!
//! ## Latency composition
//! * Within a layer, compute subarrays run in parallel; the per-subarray
//!   serial op stream sets the latency.
//! * Convolution AND/count and partial-sum accumulation are pipelined by
//!   the cross-writing scheme (Fig. 12): layer latency takes the max of
//!   the two streams.
//! * Data loading is bottlenecked by the chip I/O / global bus; writes
//!   into NAND-SPIN overlap per-subarray but follow bus delivery.

use crate::arch::config::ArchConfig;
use crate::arch::stats::{Phase, Stats};
use crate::cnn::layer::{Layer, Shape};
use crate::cnn::network::Network;
use crate::mapping::{ConvMapping, PoolSplit};

/// Ceiling log2 (bits to represent values `0..=v`).
fn clog2(v: usize) -> u32 {
    usize::BITS - v.leading_zeros()
}

/// Calibration knobs of the analytic model (documented in DESIGN.md §7 /
/// EXPERIMENTS.md). Defaults are pinned so the ResNet50 ⟨8:8⟩ breakdown
/// reproduces Fig. 16's ordering.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Calibration {
    /// Effective cycles per bit for off-chip data delivery (DRAM fetch +
    /// handshake on top of the raw bus cycle). Pinned against Fig. 16's
    /// 38 % load share.
    pub load_cycles_per_bit: f64,
    /// Fraction of peak subarray parallelism the scheduler sustains
    /// (imbalance between layers, drain bubbles).
    pub scheduler_efficiency: f64,
    /// Subarray-level parallelism of the pooling pass. The paper's
    /// Fig. 11 comparison flow is in-place and column-parallel only
    /// (which is what makes pooling 13 % of ResNet50 latency in
    /// Fig. 16); 1.0 reproduces that behaviour.
    pub pooling_parallel: f64,
    /// Subarray-level parallelism of the affine (BN/quantize) and
    /// element-wise passes: one mat's worth of subarrays streams the
    /// tensor (Fig. 16's 4–5 % shares).
    pub affine_parallel: f64,
    /// Throughput mode: weights stay resident across a batch (loaded
    /// once, amortised), as in steady-state serving; per-image stats
    /// then exclude the weight-load stream. Latency mode (default)
    /// charges it per inference.
    pub weights_resident: bool,
    /// Ablation: weight-buffer reuse (§4.1). When disabled, the 1-bit
    /// weight matrix is re-broadcast for every output row instead of
    /// being held in the subarray buffer — the data-movement behaviour
    /// of the prior designs the paper compares against.
    pub weight_buffer_reuse: bool,
    /// Ablation: cross-writing pipelining (Fig. 12). When disabled,
    /// partial-sum accumulation serialises after the AND/count stream
    /// instead of overlapping it.
    pub cross_writing_pipeline: bool,
}

impl Default for Calibration {
    fn default() -> Self {
        Self {
            load_cycles_per_bit: 3.3,
            scheduler_efficiency: 0.85,
            pooling_parallel: 1.0,
            affine_parallel: 16.0,
            weights_resident: false,
            weight_buffer_reuse: true,
            cross_writing_pipeline: true,
        }
    }
}

/// The analytic model.
#[derive(Debug, Clone)]
pub struct AnalyticModel {
    /// Architecture configuration.
    pub cfg: ArchConfig,
    /// Calibration knobs.
    pub cal: Calibration,
}

impl AnalyticModel {
    /// Model with default calibration.
    pub fn new(cfg: ArchConfig) -> Self {
        Self { cfg, cal: Calibration::default() }
    }

    /// Stats for a full inference of `net` at weight precision `wbits`
    /// (activation precision comes from the network's quantize nodes /
    /// `input_bits`): the serial fold of
    /// [`network_layer_stats`](Self::network_layer_stats), in node
    /// order — the same additions the per-node path performs, so the
    /// two views agree bit-for-bit.
    pub fn network_stats(&self, net: &Network, wbits: u8) -> Stats {
        let mut total = Stats::default();
        for s in self.network_layer_stats(net, wbits) {
            total.merge_serial(&s);
        }
        total
    }

    /// Per-node stats for a full inference of `net` at weight precision
    /// `wbits`: one [`Stats`] per network node, in schedule order. The
    /// per-layer cost attribution behind the observability layer's
    /// [`LayerCostProfile`](crate::trace::LayerCostProfile)s.
    pub fn network_layer_stats(&self, net: &Network, wbits: u8) -> Vec<Stats> {
        let shapes = net.shapes();
        let mut layers = Vec::with_capacity(net.nodes.len());
        let mut act_bits = net.input_bits;

        for (i, node) in net.nodes.iter().enumerate() {
            let in_shape = match node.input {
                Some(j) => shapes[j],
                None if i == 0 => net.input,
                None => shapes[i - 1],
            };
            let out_shape = shapes[i];
            let layer = &node.layer;
            let s = match *layer {
                Layer::Conv { out_c, kh, kw, stride, .. } => {
                    self.conv_stats(in_shape, out_shape, out_c, kh, kw, stride, wbits, act_bits, i == 0)
                }
                Layer::MaxPool { k, .. } => self.maxpool_stats(out_shape, k, act_bits),
                Layer::AvgPool { k, .. } => self.avgpool_stats(out_shape, k, act_bits),
                Layer::BatchNorm => self.affine_stats(out_shape, act_bits, 16, Phase::BatchNorm),
                Layer::Relu => self.relu_stats(out_shape, act_bits),
                Layer::Quantize { bits } => {
                    let s = self.affine_stats(out_shape, act_bits.max(bits), 8, Phase::Quantization);
                    act_bits = bits;
                    s
                }
                Layer::Residual { .. } => self.residual_stats(out_shape, act_bits),
            };
            layers.push(s);
        }
        layers
    }

    /// Convolution layer: load (weights + activations), AND/bit-count,
    /// partial transfer, cross-writing accumulation.
    #[allow(clippy::too_many_arguments)]
    fn conv_stats(
        &self,
        in_shape: Shape,
        out_shape: Shape,
        out_c: usize,
        kh: usize,
        kw: usize,
        stride: usize,
        wbits: u8,
        ibits: u8,
        first_layer: bool,
    ) -> Stats {
        let cfg = &self.cfg;
        let c = &cfg.costs;
        let (in_c, h, w) = in_shape;
        let (_, oh, ow) = out_shape;
        let (n, m) = (ibits as usize, wbits as usize);
        let split = PoolSplit::of(cfg);
        let map = ConvMapping::plan(cfg, in_shape, out_c, kh, kw, stride, ibits, split.compute);
        let mut st = Stats::default();

        // ---- channel stacking: multiple input-channel planes share one
        // subarray when the plane is short, letting the bit-counter
        // accumulate across channels before a drain (the paper's "fully
        // exploit data locality").
        let rows_per_plane = h.div_ceil(map.tiling.tiles_h).max(1);
        let ch_per_sub = (cfg.rows / rows_per_plane).clamp(1, in_c);
        let ch_groups = in_c.div_ceil(ch_per_sub);
        // Subarrays holding one full copy of the input bit-planes.
        let plane_units = (ch_groups * n * map.tiling.count()).max(1);
        let replication = (split.compute / plane_units).clamp(1, out_c);
        let serial_filters = out_c.div_ceil(replication);
        let active = plane_units * replication;
        let eff = self.cal.scheduler_efficiency;

        // ---- load: weights via chip I/O once, buffered per subarray.
        // Without the weight-reuse buffer (ablation), every output row
        // re-streams its weight row over the bus (§4.1's "additional data
        // duplication and reorganization while the weight matrix slides").
        let reuse_factor = if self.cal.weight_buffer_reuse { 1 } else { oh.max(1) as u64 };
        let weight_bits = (out_c * in_c * kh * kw * m) as u64 * reuse_factor;
        if !self.cal.weights_resident {
            let io_latency = weight_bits as f64 * self.cal.load_cycles_per_bit * c.bus_cycle_ns
                / cfg.bus_width_bits as f64;
            st.ops.global_bus_bits += weight_bits;
            st.record(
                Phase::LoadData,
                (c.offchip_energy_per_bit_fj + c.global_bus_energy_per_bit_fj)
                    * weight_bits as f64,
                io_latency,
            );
        }

        // ---- load: activations. First layer arrives off-chip; later
        // layers are written here by the producing layer (charged there as
        // DataTransfer), but every replica beyond the first needs its own
        // copy distributed on-chip.
        let act_bits_total = (in_c * h * w * n) as u64;
        if first_layer {
            let lat = act_bits_total as f64 * self.cal.load_cycles_per_bit * c.bus_cycle_ns
                / cfg.bus_width_bits as f64;
            st.ops.global_bus_bits += act_bits_total;
            st.record(
                Phase::LoadData,
                (c.offchip_energy_per_bit_fj + c.global_bus_energy_per_bit_fj)
                    * act_bits_total as f64,
                lat,
            );
        } else {
            // Inter-layer movement: the previous layer's outputs stream
            // over the shared global bus into this layer's conv layout.
            let lat = act_bits_total as f64 * c.bus_cycle_ns / cfg.bus_width_bits as f64;
            st.ops.global_bus_bits += act_bits_total;
            st.record(
                Phase::DataTransfer,
                c.global_bus_energy_per_bit_fj * act_bits_total as f64,
                lat,
            );
        }
        if replication > 1 {
            let copy_bits = act_bits_total * (replication as u64 - 1);
            // Distributed over per-bank global buses.
            let buses = cfg.num_banks().max(1) as f64;
            let lat = copy_bits as f64 * c.bus_cycle_ns / (cfg.bus_width_bits as f64 * buses);
            st.ops.global_bus_bits += copy_bits;
            st.record(Phase::LoadData, c.global_bus_energy_per_bit_fj * copy_bits as f64, lat);
        }
        // Strip writes of all activation copies into the conv layout.
        {
            let planes = (in_c * n * map.tiling.count() * replication) as u64;
            let strips_per_plane = (rows_per_plane.div_ceil(8)) as u64;
            let strips = planes * strips_per_plane;
            let write_lat_per_sub =
                (ch_per_sub as u64 * strips_per_plane) as f64 * c.row_write_latency_ns();
            // Half the programmed bits switch on average.
            let energy = strips as f64
                * (c.row_erase_energy_fj(cfg.cols)
                    + 8.0 * 0.5 * c.program_energy_per_bit_fj() * cfg.cols as f64);
            st.ops.erases += strips;
            st.ops.program_steps += strips * 8;
            st.ops.programmed_bits += strips * 8 * cfg.cols as u64 / 2;
            st.record(Phase::LoadData, energy, write_lat_per_sub / eff);
        }

        // ---- convolution: AND + count, weight buffer reused per period.
        // Channel stacking packs several channel planes per subarray for
        // capacity, but counts are drained per channel (Fig. 8/12 keeps
        // per-channel partial sums separate).
        let oh_per_tile = oh.div_ceil(map.tiling.tiles_h);
        let row_acts_per_drain = kh as u64; // kernel rows ANDed before one drain
        let drains_per_sub =
            (serial_filters * m * map.periods * oh_per_tile * ch_per_sub) as u64;
        let ands_per_sub = drains_per_sub * row_acts_per_drain;
        let cb = clog2(kh); // drained count width
        let buffer_loads_per_sub = (serial_filters * m * map.periods * kh) as u64;

        let conv_lat_per_sub = ands_per_sub as f64 * c.and_latency_ns
            + drains_per_sub as f64 * cb as f64 * c.bitcount_latency_ns
            + buffer_loads_per_sub as f64 * c.buffer_latency_ns;
        let conv_energy = active as f64
            * (ands_per_sub as f64
                * cfg.cols as f64
                * (c.and_energy_per_bit_fj + c.bitcount_energy_per_bit_fj)
                + drains_per_sub as f64 * cb as f64 * cfg.cols as f64 * c.bitcount_energy_per_bit_fj
                + buffer_loads_per_sub as f64 * cfg.cols as f64 * c.buffer_energy_per_bit_fj);
        st.ops.ands += ands_per_sub * active as u64;
        st.ops.bitcounts += ands_per_sub * active as u64;
        st.ops.buffer_accesses += buffer_loads_per_sub * active as u64;

        // ---- cross-writing accumulation (pipelined with conv).
        // Partial counts per output element: one per (channel, input-bit,
        // weight-bit) — Eq. 1 expanded over channels.
        let partials = (oh * ow * out_c) as u64 * (in_c * n * m) as u64;
        let acc_bits = (n + m) as u32 + clog2(in_c * kh * kw);
        // Writes of partials (cb bits, column-parallel over 128 outputs),
        // reads during the multi-operand add, result write-back.
        let col_par = cfg.cols as u64;
        let acc_programs = partials * cb as u64 / col_par;
        let acc_reads = partials * (cb as u64 + 2) / col_par;
        let result_writes = (oh * ow * out_c) as u64 * acc_bits as u64 / col_par;
        let acc_units = (plane_units * replication).max(1) as f64;
        let acc_lat = (acc_programs as f64 * c.program_latency_per_bit_ns
            + acc_reads as f64 * (c.read_latency_ns + c.bitcount_latency_ns)
            + result_writes as f64 * c.program_latency_per_bit_ns)
            / (acc_units * eff);
        let used_w = w.min(cfg.cols) as f64;
        let acc_energy = (acc_programs + result_writes) as f64
            * used_w
            * 0.5
            * c.program_energy_per_bit_fj()
            + acc_reads as f64 * used_w * (c.read_energy_per_bit_fj + c.bitcount_energy_per_bit_fj);
        st.ops.program_steps += acc_programs + result_writes;
        st.ops.reads += acc_reads;

        // Conv and accumulation overlap (cross-writing pipeline); the
        // ablation serialises them instead.
        let pipe_lat = if self.cal.cross_writing_pipeline {
            (conv_lat_per_sub / eff).max(acc_lat)
        } else {
            conv_lat_per_sub / eff + acc_lat
        };
        st.record(Phase::Convolution, conv_energy + acc_energy, pipe_lat);

        // ---- partial-sum movement to accumulation subarrays. The
        // cross-writing scheme makes this part of the convolution pipeline
        // (Fig. 12), so it is charged to the Convolution phase; the
        // DataTransfer category covers inter-layer movement only, matching
        // Fig. 16's 4.8 % share.
        let xfer_bits = drains_per_sub * active as u64 * cb as u64 * used_w as u64;
        // One local bus per active mat.
        let mats = (active as f64 / cfg.subarrays_in_mat() as f64).max(1.0);
        let xfer_lat =
            xfer_bits as f64 * c.bus_cycle_ns / (cfg.bus_width_bits as f64 * mats * eff);
        st.ops.local_bus_bits += xfer_bits;
        st.record(Phase::Convolution, c.bus_energy_per_bit_fj * xfer_bits as f64, xfer_lat);

        st
    }

    /// Max pooling: iterative in-memory comparison (Fig. 11) — per output
    /// element, `k²−1` comparisons of `bits`-bit values plus the masked
    /// select copy.
    fn maxpool_stats(&self, out_shape: Shape, k: usize, bits: u8) -> Stats {
        let cfg = &self.cfg;
        let c = &cfg.costs;
        let (oc, oh, ow) = out_shape;
        let out_elems = (oc * oh * ow) as u64;
        let comparisons = out_elems * (k * k - 1) as u64;
        let col_par = cfg.cols as u64;

        // Per comparison per bit (from the Fig. 11 op sequence):
        // 1 tag read + 3 ANDs + 1 result read + 2 tag/result programs +
        // 3 buffer writes; plus the select copy: bits reads + writes.
        let per_bit_sense = 5u64;
        let per_bit_prog = 2u64;
        let per_bit_buf = 3u64;
        let groups = comparisons.div_ceil(col_par); // column-parallel batches
        let sense = groups * per_bit_sense * bits as u64;
        let progs = groups * per_bit_prog * bits as u64;
        let bufw = groups * per_bit_buf * bits as u64;
        let select = groups * 2 * bits as u64; // masked copy of the winner

        let units = self.cal.pooling_parallel.min(groups as f64).max(1.0);
        // Total serial cost across all column-parallel groups, spread
        // over the available subarray units.
        let lat = (sense as f64 * c.read_latency_ns
            + progs as f64 * c.program_latency_per_bit_ns
            + bufw as f64 * c.buffer_latency_ns
            + select as f64 * (c.read_latency_ns + c.program_latency_per_bit_ns))
            / units;
        // Energy over all columns.
        let e = (sense + select) as f64 * cfg.cols as f64 * c.read_energy_per_bit_fj
            + (progs + select) as f64 * cfg.cols as f64 * 0.5 * c.program_energy_per_bit_fj()
            + bufw as f64 * cfg.cols as f64 * c.buffer_energy_per_bit_fj;
        let mut st = Stats::default();
        st.ops.reads += sense + select;
        st.ops.program_steps += progs + select;
        st.ops.buffer_accesses += bufw;
        st.record(Phase::Pooling, e, lat);
        st
    }

    /// Average pooling: window addition + multiply by the precomputed
    /// 1/k² scale.
    fn avgpool_stats(&self, out_shape: Shape, k: usize, bits: u8) -> Stats {
        let cfg = &self.cfg;
        let c = &cfg.costs;
        let (oc, oh, ow) = out_shape;
        let out_elems = (oc * oh * ow) as u64;
        let col_par = cfg.cols as u64;
        let groups = out_elems.div_ceil(col_par);
        let sum_bits = bits as u64 + clog2(k * k) as u64;
        // Addition: k² operands of `bits` bits read + counted, sum written;
        // scale multiply: sum_bits × 16-bit shared multiplier ANDs.
        let reads = groups * (k * k) as u64 * bits as u64;
        let mul_ands = groups * sum_bits * 16;
        let writes = groups * sum_bits;
        let units = self.cal.pooling_parallel.min(groups as f64).max(1.0);
        let lat = (reads as f64 * (c.read_latency_ns + c.bitcount_latency_ns)
            + mul_ands as f64 * (c.and_latency_ns + c.bitcount_latency_ns)
            + writes as f64 * c.program_latency_per_bit_ns)
            / units;
        let e = (reads + mul_ands) as f64 * cfg.cols as f64
            * (c.read_energy_per_bit_fj + c.bitcount_energy_per_bit_fj)
            + writes as f64 * cfg.cols as f64 * 0.5 * c.program_energy_per_bit_fj();
        let mut st = Stats::default();
        st.ops.reads += reads;
        st.ops.ands += mul_ands;
        st.ops.program_steps += writes;
        st.record(Phase::Pooling, e, lat);
        st
    }

    /// Affine transform (BN or quantization): in-memory multiply by a
    /// `coef_bits` shared/per-channel coefficient + bias add + shift.
    fn affine_stats(&self, out_shape: Shape, bits: u8, coef_bits: u8, phase: Phase) -> Stats {
        let cfg = &self.cfg;
        let c = &cfg.costs;
        let (oc, oh, ow) = out_shape;
        let elems = (oc * oh * ow) as u64;
        let groups = elems.div_ceil(cfg.cols as u64);
        // Schoolbook bit-serial multiply: bits × coef_bits AND+count steps,
        // then (bits + coef_bits) result writes.
        let ands = groups * bits as u64 * coef_bits as u64;
        let writes = groups * (bits + coef_bits) as u64;
        let units = self.cal.affine_parallel.min(groups as f64).max(1.0);
        let lat = (ands as f64 * (c.and_latency_ns + c.bitcount_latency_ns)
            + writes as f64 * c.program_latency_per_bit_ns)
            / units;
        let e = ands as f64 * cfg.cols as f64 * (c.and_energy_per_bit_fj + c.bitcount_energy_per_bit_fj)
            + writes as f64 * cfg.cols as f64 * 0.5 * c.program_energy_per_bit_fj();
        let mut st = Stats::default();
        st.ops.ands += ands;
        st.ops.program_steps += writes;
        st.record(phase, e, lat);
        st
    }

    /// ReLU: MSB-controlled zero write (paper §4.2).
    fn relu_stats(&self, out_shape: Shape, _bits: u8) -> Stats {
        let cfg = &self.cfg;
        let c = &cfg.costs;
        let (oc, oh, ow) = out_shape;
        let groups = ((oc * oh * ow) as u64).div_ceil(cfg.cols as u64);
        let units = self.cal.affine_parallel.min(groups as f64).max(1.0);
        let lat = (c.read_latency_ns + c.program_latency_per_bit_ns) * (groups as f64 / units);
        let e = groups as f64 * cfg.cols as f64
            * (c.read_energy_per_bit_fj + 0.1 * c.program_energy_per_bit_fj());
        let mut st = Stats::default();
        st.ops.reads += groups;
        st.ops.program_steps += groups;
        st.record(Phase::Other, e, lat);
        st
    }

    /// Residual addition: two-operand in-memory add.
    fn residual_stats(&self, out_shape: Shape, bits: u8) -> Stats {
        let cfg = &self.cfg;
        let c = &cfg.costs;
        let (oc, oh, ow) = out_shape;
        let groups = ((oc * oh * ow) as u64).div_ceil(cfg.cols as u64);
        let reads = groups * 2 * bits as u64;
        let writes = groups * (bits as u64 + 1);
        let units = self.cal.affine_parallel.min(groups as f64).max(1.0);
        let lat = (reads as f64 * (c.read_latency_ns + c.bitcount_latency_ns)
            + writes as f64 * c.program_latency_per_bit_ns)
            / units;
        let e = reads as f64 * cfg.cols as f64 * (c.read_energy_per_bit_fj + c.bitcount_energy_per_bit_fj)
            + writes as f64 * cfg.cols as f64 * 0.5 * c.program_energy_per_bit_fj();
        let mut st = Stats::default();
        st.ops.reads += reads;
        st.ops.program_steps += writes;
        st.record(Phase::Convolution, e, lat);
        st
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::network::{alexnet, resnet50, small_cnn, vgg19};

    #[test]
    fn resnet50_runs_and_produces_positive_stats() {
        let m = AnalyticModel::new(ArchConfig::paper());
        let st = m.network_stats(&resnet50(8), 8);
        assert!(st.total_latency_ms() > 0.1 && st.total_latency_ms() < 1000.0,
            "latency {} ms", st.total_latency_ms());
        assert!(st.total_energy_mj() > 0.01 && st.total_energy_mj() < 10_000.0,
            "energy {} mJ", st.total_energy_mj());
    }

    #[test]
    fn load_and_conv_dominate_resnet50() {
        // Fig. 16 ordering: load and convolution are the two biggest
        // latency shares.
        let m = AnalyticModel::new(ArchConfig::paper());
        let st = m.network_stats(&resnet50(8), 8);
        let lat = |p: Phase| st[p].latency_ns;
        let mut shares: Vec<(Phase, f64)> = st.latency_breakdown();
        shares.sort_by(|a, b| b.1.total_cmp(&a.1));
        let top2: Vec<Phase> = shares[..2].iter().map(|(p, _)| *p).collect();
        assert!(top2.contains(&Phase::LoadData) && top2.contains(&Phase::Convolution),
            "top-2 should be load+conv, got {shares:?}");
        assert!(lat(Phase::DataTransfer) < lat(Phase::Convolution));
    }

    #[test]
    fn precision_scales_cost() {
        // Bit-serial: higher ⟨W:I⟩ must cost more (Figs. 14–15 trend).
        let m = AnalyticModel::new(ArchConfig::paper());
        let net = alexnet(8);
        let lo = m.network_stats(&alexnet(2), 2);
        let hi = m.network_stats(&net, 8);
        assert!(hi.total_latency_ns() > 2.0 * lo.total_latency_ns());
        assert!(hi.total_energy_fj() > 2.0 * lo.total_energy_fj());
    }

    #[test]
    fn bigger_capacity_is_faster() {
        let mut cfg_small = ArchConfig::paper();
        cfg_small.capacity_mb = 16;
        let small = AnalyticModel::new(cfg_small);
        let big = AnalyticModel::new(ArchConfig::paper());
        let net = vgg19(8);
        assert!(
            big.network_stats(&net, 8).total_latency_ns()
                < small.network_stats(&net, 8).total_latency_ns()
        );
    }

    #[test]
    fn vgg_costs_more_than_small_cnn() {
        let m = AnalyticModel::new(ArchConfig::paper());
        let big = m.network_stats(&vgg19(8), 8);
        let tiny = m.network_stats(&small_cnn(4), 4);
        assert!(big.total_latency_ns() > 100.0 * tiny.total_latency_ns());
    }
}
