//! Weight-resident engine pool and the per-chip queue timeline.
//!
//! Execution model: one [`InferenceEngine`] per simulated PIM chip,
//! built by that chip's own [`EngineFactory`] from the run's
//! [`PoolSpec`] (chips may be heterogeneous — different capacities or
//! bus widths — and the pool is generic over the engine trait) and
//! switched into the Table 3 serving condition
//! ([`InferenceEngine::make_weights_resident`]) so the network's
//! weights cross chip I/O once per chip and are then reused by every
//! request the chip serves. Chips are independent (full weight
//! replicas), so the pool runs one host thread per chip; results are
//! collected in chip order and the simulated-time accounting is done
//! afterwards by the pure [`timeline`] scheduler, which keeps the whole
//! run deterministic regardless of host-thread interleaving.
//!
//! ## Intra-chip worker split (host-time only)
//!
//! A *bit-accurate* chip serving a large stream used to be one long
//! serial host loop — the wall-clock bottleneck of functional serving.
//! [`execute_with_workers`] additionally splits one chip's request
//! stream across worker threads, each with its own engine replica.
//! Simulated semantics are preserved exactly: in sequential serving
//! only the chip's *first* request pays the weight stream (cold) and
//! every later request runs warm, so each extra worker first replays
//! one request on its private engine to reach the warm state
//! (discarded), then serves its contiguous chunk. Per-request stats and
//! outputs are deterministic functions of (config, params, input,
//! cold/warm), so the merged result — chunks re-concatenated in stream
//! order, residency folded back to the sequential ledger (one miss set,
//! `n−1` warm hits per conv layer) — is bit-identical to the
//! single-thread run whatever the worker count. Only host wall time
//! changes.
//!
//! The same budget also feeds the *intra-request* filter fan-out inside
//! [`crate::coordinator::functional::FunctionalEngine`]: a chip's
//! `workers` threads are divided between `R` request-split replicas and
//! a `⌊workers / R⌋` per-replica fan-out budget
//! ([`InferenceEngine::set_host_workers`]), so the two levels of
//! parallelism share one budget instead of oversubscribing the host.
//! Short streams (down to a single request) put the whole budget into
//! the fan-out.
//!
//! [`timeline`] models each chip as a FIFO single server behind a
//! bounded batch queue: a batch flushed while the queue is full is held
//! back (backpressure) until a slot frees, which is how a saturated
//! chip pushes delay upstream instead of queueing unboundedly.

use std::env;
use std::thread;

use crate::arch::stats::Stats;
use crate::cnn::network::Network;
use crate::cnn::ref_exec::{ModelParams, WideTensor};

use crate::coordinator::engine::{EngineFactory, EngineKind, InferenceEngine, PoolSpec};
use crate::coordinator::functional::HostLayerProfile;
use crate::trace::{LayerCost, LayerCostProfile};

use super::batcher::FlushCause;
use super::{Request, ServedNetwork};

/// A batch after planning: flushed, routed, awaiting execution.
#[derive(Debug)]
pub struct PlannedBatch {
    /// Global flush sequence number (batcher emission order).
    pub seq: usize,
    /// Chip the router assigned.
    pub chip: usize,
    /// Network the batch's requests target (index into the serve's
    /// network slice; batches are single-network by construction — one
    /// SLO lane per network).
    pub net: usize,
    /// Why the batcher flushed it.
    pub cause: FlushCause,
    /// Simulated flush time (ns).
    pub flush_ns: f64,
    /// The batched requests, in arrival order.
    pub requests: Vec<Request>,
    /// Arrival time of each request (ns), parallel to `requests`.
    pub arrivals_ns: Vec<f64>,
    /// Router's estimated service cost of the batch (ns) at routing
    /// time, before the chip horizon was charged.
    pub est_cost_ns: f64,
    /// Router's estimated finish horizon of the chosen chip (ns) after
    /// charging this batch.
    pub est_finish_ns: f64,
}

/// One executed request: its own simulated cost, plus the output when
/// the engine runs bit-accurately.
#[derive(Debug)]
pub struct ExecutedRequest {
    /// Request id.
    pub id: u64,
    /// Final network output (bit-accurate engines); `None` when the
    /// engine synthesizes stats only.
    pub output: Option<WideTensor>,
    /// Simulated PIM cost of this request alone (engine-stats delta).
    pub stats: Stats,
    /// Per-node stats deltas of this request (recorded only when layer
    /// cost tracing is on; `None` otherwise).
    pub layer_stats: Option<Vec<Stats>>,
}

/// One executed batch, still carrying its planning metadata.
#[derive(Debug)]
pub struct ExecutedBatch {
    /// Global flush sequence number.
    pub seq: usize,
    /// Network the batch's requests target.
    pub net: usize,
    /// Why the batcher flushed it.
    pub cause: FlushCause,
    /// Simulated flush time (ns).
    pub flush_ns: f64,
    /// Per-request arrival times (ns).
    pub arrivals_ns: Vec<f64>,
    /// Router's estimated service cost at routing time (ns).
    pub est_cost_ns: f64,
    /// Router's estimated chip finish horizon after this batch (ns).
    pub est_finish_ns: f64,
    /// Executed requests, in batch order.
    pub requests: Vec<ExecutedRequest>,
}

impl ExecutedBatch {
    /// Serial service time of the whole batch on its chip (ns).
    pub fn service_ns(&self) -> f64 {
        self.requests.iter().map(|r| r.stats.total_latency_ns()).sum()
    }
}

/// Everything one chip produced.
#[derive(Debug)]
pub struct ChipResult {
    /// Chip index.
    pub chip: usize,
    /// Executed batches, in dispatch order.
    pub batches: Vec<ExecutedBatch>,
    /// Weight-residency hits on this chip's engine.
    pub weight_hits: u64,
    /// Weight-residency misses (streams) on this chip's engine.
    pub weight_misses: u64,
    /// Per-conv-layer host wall-time profile accumulated across the
    /// chip's *whole* request stream (bit-accurate engines; `None` for
    /// synthesized ones). Wall times sum over runs, worker/tile counts
    /// keep their maxima. Wall-clock figures — diagnostic only, never
    /// simulated cost.
    pub host_profile: Option<Vec<HostLayerProfile>>,
    /// Per-network simulated layer-cost profiles, folded across this
    /// chip's stream in arrival order (only when layer cost tracing is
    /// on).
    pub layer_costs: Option<Vec<LayerCostProfile>>,
}

/// Execute `planned` batches on `chips` identical weight-resident
/// engines built by `factory`, one host thread per chip (bit-accurate
/// chips additionally split their stream across an automatic worker
/// budget — see [`execute_with_workers`]). Returns per-chip results
/// ordered by chip index; within a chip, batches keep their flush
/// order. `params` is required by bit-accurate engines and optional
/// for synthesized ones.
pub fn execute(
    factory: &EngineFactory,
    net: &Network,
    params: Option<&ModelParams>,
    chips: usize,
    planned: Vec<PlannedBatch>,
) -> Vec<ChipResult> {
    execute_with_workers(factory, net, params, chips, planned, None)
}

/// [`execute`] with an explicit intra-chip worker count.
///
/// `workers_per_chip = None` picks the automatic budget: host
/// parallelism divided by the chip count (override with
/// [`ServeConfig::host_workers`](super::ServeConfig::host_workers) or
/// the `NANDSPIN_HOST_WORKERS` environment variable — useful for
/// pinning benchmarks and CI). The worker split changes host wall time
/// only; the returned results are bit-identical for every worker count.
pub fn execute_with_workers(
    factory: &EngineFactory,
    net: &Network,
    params: Option<&ModelParams>,
    chips: usize,
    planned: Vec<PlannedBatch>,
    workers_per_chip: Option<usize>,
) -> Vec<ChipResult> {
    let pool = PoolSpec::replicate(factory.clone(), chips.max(1));
    execute_pool(&pool, &[ServedNetwork { net, params }], planned, workers_per_chip, false)
}

/// Execute `planned` batches across a (possibly heterogeneous)
/// [`PoolSpec`]: each chip builds its engine from its own factory and
/// serves its batches in flush order, looking each batch's network up
/// in `nets` by the batch's `net` tag. One host thread per chip;
/// single-network bit-accurate chips additionally split their stream
/// across the worker budget (mixed-network chips serve sequentially —
/// the residency ledger across network switches is inherently serial).
///
/// `record_layer_costs` switches on per-node stats recording in each
/// chip's engine ([`InferenceEngine::set_layer_recording`]); the
/// per-request deltas are folded into each [`ChipResult::layer_costs`]
/// in stream order, so the profiles are bit-identical at any worker
/// count.
///
/// # Panics
/// If a batch names an out-of-range chip or network.
pub fn execute_pool(
    pool: &PoolSpec,
    nets: &[ServedNetwork<'_>],
    planned: Vec<PlannedBatch>,
    workers_per_chip: Option<usize>,
    record_layer_costs: bool,
) -> Vec<ChipResult> {
    let chips = pool.chips();
    let workers = workers_per_chip.unwrap_or_else(|| auto_workers(chips)).max(1);
    let mut per_chip: Vec<Vec<PlannedBatch>> = (0..chips).map(|_| Vec::new()).collect();
    for b in planned {
        assert!(b.chip < chips, "router produced an out-of-range chip");
        assert!(b.net < nets.len(), "batch names an out-of-range network");
        per_chip[b.chip].push(b);
    }

    thread::scope(|scope| {
        let handles: Vec<_> = per_chip
            .into_iter()
            .enumerate()
            .map(|(chip, batches)| {
                let factory = pool.factory(chip);
                scope.spawn(move || {
                    let mut result =
                        run_chip(factory, nets, chip, batches, workers, record_layer_costs);
                    result.layer_costs =
                        collect_layer_costs(record_layer_costs, &mut result.batches, nets);
                    result
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("chip worker panicked")).collect()
    })
}

/// Fold each executed request's per-node stats deltas (present only
/// when layer recording was on) into per-network
/// [`LayerCostProfile`]s, iterating batches and requests in stream
/// order so the f64 fold order is canonical — the same order a
/// single-threaded chip would have charged them in.
fn collect_layer_costs(
    enabled: bool,
    batches: &mut [ExecutedBatch],
    nets: &[ServedNetwork<'_>],
) -> Option<Vec<LayerCostProfile>> {
    if !enabled {
        return None;
    }
    let mut profiles: Vec<LayerCostProfile> = Vec::new();
    for b in batches.iter_mut() {
        for r in &mut b.requests {
            let Some(layers) = r.layer_stats.take() else { continue };
            let profile = match profiles.iter_mut().find(|p| p.net == b.net) {
                Some(p) => p,
                None => {
                    let network = nets[b.net].net;
                    profiles.push(LayerCostProfile {
                        net: b.net,
                        network: network.name.clone(),
                        requests: 0,
                        layers: network
                            .nodes
                            .iter()
                            .enumerate()
                            .map(|(node, n)| LayerCost {
                                node,
                                label: n.layer.mnemonic().to_string(),
                                stats: Stats::default(),
                            })
                            .collect(),
                    });
                    profiles.last_mut().expect("just pushed")
                }
            };
            profile.fold_request(&layers);
        }
    }
    (!profiles.is_empty()).then_some(profiles)
}

/// Fold one engine run's per-conv-layer host profile into a chip-level
/// accumulator keyed by `(node, label)`: wall times add across the
/// stream, worker/tile counts keep their maxima. The engine clears its
/// profile every run, so without this fold a chip would only report its
/// *last* request.
pub(crate) fn fold_host_profile(
    acc: &mut Option<Vec<HostLayerProfile>>,
    run: Option<&[HostLayerProfile]>,
) {
    let Some(run) = run else { return };
    let acc = acc.get_or_insert_with(Vec::new);
    for layer in run {
        if let Some(slot) = acc.iter_mut().find(|s| s.node == layer.node && s.label == layer.label)
        {
            slot.workers = slot.workers.max(layer.workers);
            slot.tiles = slot.tiles.max(layer.tiles);
            slot.load_ns += layer.load_ns;
            slot.pass_ns += layer.pass_ns;
            slot.conv_ns += layer.conv_ns;
            slot.acc_ns += layer.acc_ns;
        } else {
            acc.push(layer.clone());
        }
    }
}

/// Automatic intra-chip worker budget: host cores spread over the
/// chip threads, overridable via `NANDSPIN_HOST_WORKERS`.
fn auto_workers(chips: usize) -> usize {
    if let Ok(v) = env::var("NANDSPIN_HOST_WORKERS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            return n.max(1);
        }
    }
    let host = thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    (host / chips.max(1)).max(1)
}

/// Serve one chip's batches, splitting across up to `workers` threads
/// when the engine is bit-accurate, the chip serves a single network,
/// and there is enough work to pay for the per-worker warm-up replay
/// (each worker needs a chunk of ≥ 2 requests to amortise its one
/// discarded warm-up run). A chip serving several networks runs
/// sequentially: its residency ledger depends on the exact network
/// switch order, which a chunk split would not preserve.
///
/// `workers` is the chip's whole host budget, shared between the two
/// levels of parallelism: `R` request-split replicas each get a
/// `⌊workers / R⌋` intra-request (per-filter fan-out) budget, so a chip
/// never runs more than ~`workers` busy threads regardless of how the
/// split falls. When the stream is too short to split (`R == 1`), the
/// whole budget goes to intra-request parallelism — that is what makes
/// a functional `--requests 1` serve of a full-size network fast.
fn run_chip(
    factory: &EngineFactory,
    nets: &[ServedNetwork<'_>],
    chip: usize,
    batches: Vec<PlannedBatch>,
    workers: usize,
    record_layer_costs: bool,
) -> ChipResult {
    let n: usize = batches.iter().map(|b| b.requests.len()).sum();
    let single_net = batches.windows(2).all(|w| w[0].net == w[1].net);
    let replicas = if factory.kind() == EngineKind::Functional && single_net {
        workers.min(n / 2).max(1)
    } else {
        // Synthesized engines are closed-form — a split cannot pay —
        // and mixed-network streams are inherently serial.
        1
    };
    let intra = (workers / replicas).max(1);
    if replicas <= 1 {
        run_chip_sequential(factory, nets, chip, batches, intra, record_layer_costs)
    } else {
        run_chip_parallel(factory, nets, chip, batches, replicas, intra, record_layer_costs)
    }
}

/// Serve one chip's batches on a fresh weight-resident engine with an
/// `intra`-thread per-request fan-out budget.
fn run_chip_sequential(
    factory: &EngineFactory,
    nets: &[ServedNetwork<'_>],
    chip: usize,
    batches: Vec<PlannedBatch>,
    intra: usize,
    record_layer_costs: bool,
) -> ChipResult {
    let mut engine = factory.build();
    engine.make_weights_resident();
    engine.set_host_workers(intra);
    engine.set_layer_recording(record_layer_costs);
    let mut host_profile = None;
    let mut out = Vec::with_capacity(batches.len());
    for b in batches {
        let sn = &nets[b.net];
        let mut executed = Vec::with_capacity(b.requests.len());
        for req in b.requests {
            let exec = engine.execute(sn.net, sn.params, &req.image);
            fold_host_profile(&mut host_profile, engine.host_profile());
            let output = exec.outputs.map(|mut outs| outs.pop().expect("non-empty network"));
            executed.push(ExecutedRequest {
                id: req.id,
                output,
                stats: exec.stats,
                layer_stats: exec.layer_stats,
            });
        }
        out.push(ExecutedBatch {
            seq: b.seq,
            net: b.net,
            cause: b.cause,
            flush_ns: b.flush_ns,
            arrivals_ns: b.arrivals_ns,
            est_cost_ns: b.est_cost_ns,
            est_finish_ns: b.est_finish_ns,
            requests: executed,
        });
    }
    let (hits, misses) = engine
        .residency()
        .map(|r| (r.hits, r.misses))
        .unwrap_or((0, 0));
    ChipResult {
        chip,
        batches: out,
        weight_hits: hits,
        weight_misses: misses,
        host_profile,
        layer_costs: None,
    }
}

/// Serve one chip's single-network stream across `workers ≥ 2` engine
/// replicas with a deterministic merge (see the module docs for why
/// the result is bit-identical to [`run_chip_sequential`]). Each
/// replica runs its per-request filter fan-out on `intra` threads —
/// its share of the chip's one host budget.
fn run_chip_parallel(
    factory: &EngineFactory,
    nets: &[ServedNetwork<'_>],
    chip: usize,
    batches: Vec<PlannedBatch>,
    workers: usize,
    intra: usize,
    record_layer_costs: bool,
) -> ChipResult {
    // Guarded by `run_chip`: every batch targets the same network.
    let sn = &nets[batches[0].net];
    let (net, params) = (sn.net, sn.params);
    // Flatten the stream, keeping each batch's metadata for reassembly.
    let mut metas = Vec::with_capacity(batches.len());
    let mut flat: Vec<Request> = Vec::new();
    for b in batches {
        metas.push((
            b.seq,
            b.net,
            b.cause,
            b.flush_ns,
            b.arrivals_ns,
            b.est_cost_ns,
            b.est_finish_ns,
            b.requests.len(),
        ));
        flat.extend(b.requests);
    }
    let n = flat.len();
    debug_assert!(workers >= 2 && n >= 2 * workers - 1);

    // Contiguous per-worker chunks (stream order).
    let bounds: Vec<usize> = (0..=workers).map(|k| k * n / workers).collect();
    let mut chunks: Vec<Vec<Request>> = Vec::with_capacity(workers);
    let mut rest = flat;
    for k in (1..=workers).rev() {
        chunks.push(rest.split_off(bounds[k - 1]));
    }
    chunks.reverse();

    type WorkerOut = (Vec<ExecutedRequest>, u64, Option<Vec<HostLayerProfile>>);
    let results: Vec<WorkerOut> = thread::scope(|scope| {
        let handles: Vec<_> = chunks
            .into_iter()
            .enumerate()
            .map(|(k, chunk)| {
                scope.spawn(move || {
                    let mut engine = factory.build();
                    engine.make_weights_resident();
                    engine.set_host_workers(intra);
                    engine.set_layer_recording(record_layer_costs);
                    let mut profile = None;
                    let mut out = Vec::with_capacity(chunk.len());
                    for (i, req) in chunk.iter().enumerate() {
                        if k > 0 && i == 0 {
                            // Warm-up replay: stream the weights into
                            // this worker's private engine and discard
                            // the run, so every request it *reports*
                            // carries the sequential (warm) cost.
                            let _ = engine.execute(net, params, &req.image);
                            fold_host_profile(&mut profile, engine.host_profile());
                        }
                        let exec = engine.execute(net, params, &req.image);
                        fold_host_profile(&mut profile, engine.host_profile());
                        let output =
                            exec.outputs.map(|mut o| o.pop().expect("non-empty network"));
                        out.push(ExecutedRequest {
                            id: req.id,
                            output,
                            stats: exec.stats,
                            layer_stats: exec.layer_stats,
                        });
                    }
                    let misses = engine.residency().map(|r| r.misses).unwrap_or(0);
                    (out, misses, profile)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("chip worker panicked")).collect()
    });

    // Deterministic merge: re-concatenate the chunks in stream order and
    // fold residency back to the sequential ledger — worker 0's misses
    // are the chip's one cold weight stream (= conv-layer count), and
    // every other request of the stream is a warm hit on each of those
    // layers, exactly as one engine serving the stream would record.
    let streams = results.first().map(|(_, m, _)| *m).unwrap_or(0);
    let mut host_profile = None;
    let mut all: Vec<ExecutedRequest> = Vec::with_capacity(n);
    for (out, _, profile) in results {
        fold_host_profile(&mut host_profile, profile.as_deref());
        all.extend(out);
    }
    let mut all = all.into_iter();
    let out_batches: Vec<ExecutedBatch> = metas
        .into_iter()
        .map(|(seq, net, cause, flush_ns, arrivals_ns, est_cost_ns, est_finish_ns, len)| {
            ExecutedBatch {
                seq,
                net,
                cause,
                flush_ns,
                arrivals_ns,
                est_cost_ns,
                est_finish_ns,
                requests: all.by_ref().take(len).collect(),
            }
        })
        .collect();
    ChipResult {
        chip,
        batches: out_batches,
        weight_hits: streams * (n as u64 - 1),
        weight_misses: streams,
        host_profile,
        layer_costs: None,
    }
}

/// Dispatch timing of one batch on its chip.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BatchTiming {
    /// When the batch entered the chip queue (ns). Later than the flush
    /// time iff the queue was full (backpressure).
    pub enqueue_ns: f64,
    /// When the chip started executing the batch (ns).
    pub start_ns: f64,
    /// When the chip finished the batch (ns).
    pub finish_ns: f64,
    /// True when the batch stalled on a full queue before enqueueing.
    pub stalled: bool,
}

/// Simulated-time schedule of one chip's batches: FIFO single server
/// behind a bounded queue of `queue_depth` batches (waiting + in
/// service; `queue_depth == 1` means no buffering — a new batch waits
/// for the previous one to finish before it is even accepted).
///
/// `flush_ns[i]` is when batch `i` became ready, `service_ns[i]` how
/// long it occupies the chip; both slices run in flush order.
///
/// # Panics
/// If the slices differ in length or `queue_depth` is 0.
pub fn timeline(flush_ns: &[f64], service_ns: &[f64], queue_depth: usize) -> Vec<BatchTiming> {
    assert_eq!(flush_ns.len(), service_ns.len());
    assert!(queue_depth >= 1, "queue depth must be >= 1");
    let mut timings: Vec<BatchTiming> = Vec::with_capacity(flush_ns.len());
    for i in 0..flush_ns.len() {
        // Backpressure: wait for the batch `queue_depth` places ahead to
        // clear the queue before this one can enter it.
        let free_slot_ns = if i >= queue_depth { timings[i - queue_depth].finish_ns } else { 0.0 };
        let enqueue_ns = flush_ns[i].max(free_slot_ns);
        let prev_finish = if i > 0 { timings[i - 1].finish_ns } else { 0.0 };
        let start_ns = enqueue_ns.max(prev_finish);
        timings.push(BatchTiming {
            enqueue_ns,
            start_ns,
            finish_ns: start_ns + service_ns[i],
            stalled: enqueue_ns > flush_ns[i],
        });
    }
    timings
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn idle_chip_starts_batches_at_flush_time() {
        let t = timeline(&[0.0, 100.0], &[10.0, 10.0], 2);
        assert_eq!(t[0].start_ns, 0.0);
        assert_eq!(t[0].finish_ns, 10.0);
        assert_eq!(t[1].start_ns, 100.0, "chip idle, no queueing");
        assert!(!t[0].stalled && !t[1].stalled);
    }

    #[test]
    fn busy_chip_queues_fifo() {
        let t = timeline(&[0.0, 1.0, 2.0], &[10.0, 10.0, 10.0], 3);
        assert_eq!(t[1].start_ns, 10.0);
        assert_eq!(t[2].start_ns, 20.0);
        assert_eq!(t[2].finish_ns, 30.0);
        assert!(!t.iter().any(|b| b.stalled), "queue depth 3 absorbs all three");
    }

    #[test]
    fn full_queue_applies_backpressure() {
        // Depth 2: batch 2 cannot enqueue until batch 0 finishes, batch 3
        // until batch 1 finishes — even though all flush at t=0.
        let t = timeline(&[0.0, 0.0, 0.0, 0.0], &[10.0, 10.0, 10.0, 10.0], 2);
        assert_eq!(t[2].enqueue_ns, 10.0);
        assert!(t[2].stalled);
        assert_eq!(t[3].enqueue_ns, 20.0);
        assert!(t[3].stalled);
        // FIFO service order is preserved under backpressure.
        assert_eq!(
            t.iter().map(|b| b.start_ns).collect::<Vec<_>>(),
            vec![0.0, 10.0, 20.0, 30.0]
        );
    }

    #[test]
    fn depth_one_serialises_completely() {
        let t = timeline(&[0.0, 0.0], &[5.0, 5.0], 1);
        assert_eq!(t[1].enqueue_ns, 5.0, "no buffering at depth 1");
        assert!(t[1].stalled);
        assert_eq!(t[1].finish_ns, 10.0);
    }
}
