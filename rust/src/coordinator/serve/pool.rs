//! Weight-resident engine pool and the per-chip queue timeline.
//!
//! Execution model: one [`InferenceEngine`] per simulated PIM chip,
//! built by the run's [`EngineFactory`] (functional or analytic — the
//! pool is generic over the trait) and switched into the Table 3
//! serving condition
//! ([`InferenceEngine::make_weights_resident`]) so the network's
//! weights cross chip I/O once per chip and are then reused by every
//! request the chip serves. Chips are independent (full weight
//! replicas), so the pool runs one host thread per chip; results are
//! collected in chip order and the simulated-time accounting is done
//! afterwards by the pure [`timeline`] scheduler, which keeps the whole
//! run deterministic regardless of host-thread interleaving.
//!
//! [`timeline`] models each chip as a FIFO single server behind a
//! bounded batch queue: a batch flushed while the queue is full is held
//! back (backpressure) until a slot frees, which is how a saturated
//! chip pushes delay upstream instead of queueing unboundedly.

use std::thread;

use crate::arch::stats::Stats;
use crate::cnn::network::Network;
use crate::cnn::ref_exec::{ModelParams, WideTensor};

use crate::coordinator::engine::{EngineFactory, InferenceEngine};

use super::batcher::FlushCause;
use super::Request;

/// A batch after planning: flushed, routed, awaiting execution.
#[derive(Debug)]
pub struct PlannedBatch {
    /// Global flush sequence number (batcher emission order).
    pub seq: usize,
    /// Chip the router assigned.
    pub chip: usize,
    /// Why the batcher flushed it.
    pub cause: FlushCause,
    /// Simulated flush time (ns).
    pub flush_ns: f64,
    /// The batched requests, in arrival order.
    pub requests: Vec<Request>,
    /// Arrival time of each request (ns), parallel to `requests`.
    pub arrivals_ns: Vec<f64>,
}

/// One executed request: its own simulated cost, plus the output when
/// the engine runs bit-accurately.
#[derive(Debug)]
pub struct ExecutedRequest {
    /// Request id.
    pub id: u64,
    /// Final network output (bit-accurate engines); `None` when the
    /// engine synthesizes stats only.
    pub output: Option<WideTensor>,
    /// Simulated PIM cost of this request alone (engine-stats delta).
    pub stats: Stats,
}

/// One executed batch, still carrying its planning metadata.
#[derive(Debug)]
pub struct ExecutedBatch {
    /// Global flush sequence number.
    pub seq: usize,
    /// Why the batcher flushed it.
    pub cause: FlushCause,
    /// Simulated flush time (ns).
    pub flush_ns: f64,
    /// Per-request arrival times (ns).
    pub arrivals_ns: Vec<f64>,
    /// Executed requests, in batch order.
    pub requests: Vec<ExecutedRequest>,
}

impl ExecutedBatch {
    /// Serial service time of the whole batch on its chip (ns).
    pub fn service_ns(&self) -> f64 {
        self.requests.iter().map(|r| r.stats.total_latency_ns()).sum()
    }
}

/// Everything one chip produced.
#[derive(Debug)]
pub struct ChipResult {
    /// Chip index.
    pub chip: usize,
    /// Executed batches, in dispatch order.
    pub batches: Vec<ExecutedBatch>,
    /// Weight-residency hits on this chip's engine.
    pub weight_hits: u64,
    /// Weight-residency misses (streams) on this chip's engine.
    pub weight_misses: u64,
}

/// Execute `planned` batches on `chips` weight-resident engines built
/// by `factory`, one host thread per chip. Returns per-chip results
/// ordered by chip index; within a chip, batches keep their flush
/// order. `params` is required by bit-accurate engines and optional
/// for synthesized ones.
pub fn execute(
    factory: &EngineFactory,
    net: &Network,
    params: Option<&ModelParams>,
    chips: usize,
    planned: Vec<PlannedBatch>,
) -> Vec<ChipResult> {
    let mut per_chip: Vec<Vec<PlannedBatch>> = (0..chips).map(|_| Vec::new()).collect();
    for b in planned {
        assert!(b.chip < chips, "router produced an out-of-range chip");
        per_chip[b.chip].push(b);
    }

    thread::scope(|scope| {
        let handles: Vec<_> = per_chip
            .into_iter()
            .enumerate()
            .map(|(chip, batches)| {
                scope.spawn(move || run_chip(factory, net, params, chip, batches))
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("chip worker panicked")).collect()
    })
}

/// Serve one chip's batches on a fresh weight-resident engine.
fn run_chip(
    factory: &EngineFactory,
    net: &Network,
    params: Option<&ModelParams>,
    chip: usize,
    batches: Vec<PlannedBatch>,
) -> ChipResult {
    let mut engine = factory.build();
    engine.make_weights_resident();
    let mut out = Vec::with_capacity(batches.len());
    for b in batches {
        let mut executed = Vec::with_capacity(b.requests.len());
        for req in b.requests {
            let exec = engine.execute(net, params, &req.image);
            let output = exec.outputs.map(|mut outs| outs.pop().expect("non-empty network"));
            executed.push(ExecutedRequest { id: req.id, output, stats: exec.stats });
        }
        out.push(ExecutedBatch {
            seq: b.seq,
            cause: b.cause,
            flush_ns: b.flush_ns,
            arrivals_ns: b.arrivals_ns,
            requests: executed,
        });
    }
    let (hits, misses) = engine
        .residency()
        .map(|r| (r.hits, r.misses))
        .unwrap_or((0, 0));
    ChipResult { chip, batches: out, weight_hits: hits, weight_misses: misses }
}

/// Dispatch timing of one batch on its chip.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BatchTiming {
    /// When the batch entered the chip queue (ns). Later than the flush
    /// time iff the queue was full (backpressure).
    pub enqueue_ns: f64,
    /// When the chip started executing the batch (ns).
    pub start_ns: f64,
    /// When the chip finished the batch (ns).
    pub finish_ns: f64,
    /// True when the batch stalled on a full queue before enqueueing.
    pub stalled: bool,
}

/// Simulated-time schedule of one chip's batches: FIFO single server
/// behind a bounded queue of `queue_depth` batches (waiting + in
/// service; `queue_depth == 1` means no buffering — a new batch waits
/// for the previous one to finish before it is even accepted).
///
/// `flush_ns[i]` is when batch `i` became ready, `service_ns[i]` how
/// long it occupies the chip; both slices run in flush order.
///
/// # Panics
/// If the slices differ in length or `queue_depth` is 0.
pub fn timeline(flush_ns: &[f64], service_ns: &[f64], queue_depth: usize) -> Vec<BatchTiming> {
    assert_eq!(flush_ns.len(), service_ns.len());
    assert!(queue_depth >= 1, "queue depth must be >= 1");
    let mut timings: Vec<BatchTiming> = Vec::with_capacity(flush_ns.len());
    for i in 0..flush_ns.len() {
        // Backpressure: wait for the batch `queue_depth` places ahead to
        // clear the queue before this one can enter it.
        let free_slot_ns = if i >= queue_depth { timings[i - queue_depth].finish_ns } else { 0.0 };
        let enqueue_ns = flush_ns[i].max(free_slot_ns);
        let prev_finish = if i > 0 { timings[i - 1].finish_ns } else { 0.0 };
        let start_ns = enqueue_ns.max(prev_finish);
        timings.push(BatchTiming {
            enqueue_ns,
            start_ns,
            finish_ns: start_ns + service_ns[i],
            stalled: enqueue_ns > flush_ns[i],
        });
    }
    timings
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn idle_chip_starts_batches_at_flush_time() {
        let t = timeline(&[0.0, 100.0], &[10.0, 10.0], 2);
        assert_eq!(t[0].start_ns, 0.0);
        assert_eq!(t[0].finish_ns, 10.0);
        assert_eq!(t[1].start_ns, 100.0, "chip idle, no queueing");
        assert!(!t[0].stalled && !t[1].stalled);
    }

    #[test]
    fn busy_chip_queues_fifo() {
        let t = timeline(&[0.0, 1.0, 2.0], &[10.0, 10.0, 10.0], 3);
        assert_eq!(t[1].start_ns, 10.0);
        assert_eq!(t[2].start_ns, 20.0);
        assert_eq!(t[2].finish_ns, 30.0);
        assert!(!t.iter().any(|b| b.stalled), "queue depth 3 absorbs all three");
    }

    #[test]
    fn full_queue_applies_backpressure() {
        // Depth 2: batch 2 cannot enqueue until batch 0 finishes, batch 3
        // until batch 1 finishes — even though all flush at t=0.
        let t = timeline(&[0.0, 0.0, 0.0, 0.0], &[10.0, 10.0, 10.0, 10.0], 2);
        assert_eq!(t[2].enqueue_ns, 10.0);
        assert!(t[2].stalled);
        assert_eq!(t[3].enqueue_ns, 20.0);
        assert!(t[3].stalled);
        // FIFO service order is preserved under backpressure.
        assert_eq!(
            t.iter().map(|b| b.start_ns).collect::<Vec<_>>(),
            vec![0.0, 10.0, 20.0, 30.0]
        );
    }

    #[test]
    fn depth_one_serialises_completely() {
        let t = timeline(&[0.0, 0.0], &[5.0, 5.0], 1);
        assert_eq!(t[1].enqueue_ns, 5.0, "no buffering at depth 1");
        assert!(t[1].stalled);
        assert_eq!(t[1].finish_ns, 10.0);
    }
}
