//! The batched multi-chip serving runtime: the L3 deployment topology.
//!
//! The paper's headline gains (the ~2.6× speedup / ~1.4× energy
//! efficiency of the parallelism-friendly mapping) pay off at *serving*
//! scale, where weights are loaded once and reused across a stream of
//! requests — Table 3's operating condition. This subsystem models that
//! deployment end to end, generically over the
//! [`InferenceEngine`](crate::coordinator::engine::InferenceEngine)
//! trait:
//!
//! ```text
//!  requests ──▶ SloBatcher ─────▶ ShardRouter ──▶ per-chip queues
//!  (tagged      (one flush lane     (cost-aware      (bounded; FIFO;
//!   with a       per network:        earliest-        backpressure)
//!   network)     size / per-lane     finish, from        │
//!                SLO deadline)       BatchLaw costs)     ▼
//!                                      weight-resident engine pool
//!                         ServeReport ◀── (PoolSpec: one EngineFactory
//!                          (per-net         per chip — chips may be
//!                           SLO accounts)   heterogeneous; weights
//!                                           streamed once per switch)
//! ```
//!
//! * [`batcher::SloBatcher`] keeps one [`batcher::DynamicBatcher`]
//!   flush lane per served network: a lane flushes when it fills (size
//!   flush) or when its oldest request hits *that network's* SLO
//!   deadline ([`SloPolicy`]) — a latency-critical network no longer
//!   waits behind a throughput-oriented one.
//! * [`router::ShardRouter`] maps each batch onto one of N simulated
//!   chips deterministically, picking the earliest estimated finish
//!   from a [`router::CostTable`] of per-(chip, network) cold/warm
//!   service times synthesized by [`laws::BatchLaw`] — so a fast chip
//!   absorbs more work and networks stick to chips already holding
//!   their weights. Identical chips degrade to the legacy least-loaded
//!   round-robin.
//! * [`pool`] executes each chip's batches on its own weight-resident
//!   engine built from that chip's own factory in the
//!   [`PoolSpec`](crate::coordinator::engine::PoolSpec) — chips may
//!   model different operating points (capacity, bus width, …). One
//!   host thread per chip; a single-network bit-accurate chip's stream
//!   is further split across worker threads
//!   ([`ServeConfig::host_workers`]) with a deterministic,
//!   bit-identical merge, and each replica spends its share of the same
//!   budget on the functional engine's per-filter fan-out inside each
//!   request — host wall time is the only thing that
//!   changes. Batches are scheduled on the simulated clock behind a
//!   bounded queue ([`pool::timeline`]), so a saturated chip exerts
//!   backpressure instead of queueing unboundedly.
//! * [`report::ServeReport`] rolls per-request completions up into
//!   per-chip, per-network (SLO deadline violations, lane waits) and
//!   aggregate latency/energy accounts and can
//!   [`verify`](report::ServeReport::verify) that every roll-up equals
//!   the fold of its parts.
//!
//! [`EngineMode`] selects what the pool builds: `Functional` serves
//! bit-accurately (small networks, outputs checked), `Analytic` serves
//! the paper's full-size benchmarks at closed-form speed (stats only),
//! and `Hybrid` serves analytically while replaying every K-th request
//! on a functional engine to cross-check stats plausibility
//! ([`SpotCheck`]).
//!
//! Everything is deterministic: batching and routing run on the
//! simulated clock before execution starts, chips are independent, and
//! host threads only parallelise the simulation work itself. That
//! includes fault injection: with a [`FaultPlan`] active
//! ([`ServeConfig::fault`] or per-chip factory plans), chips draw
//! independent seeded fault streams, a chip whose injected-fault rate
//! trips [`ServeConfig::fault_health_threshold`] is marked unhealthy
//! and its batches are drained and re-routed to the survivors under
//! [`ServeConfig::retry_budget`], and the [`ServeReport`] carries the
//! exact fault/failover account.

// Serving must degrade, not panic: a `.unwrap()` on this path would
// turn one bad batch into a dropped stream. Use `expect` with a
// reason, or handle the case.
#![deny(clippy::unwrap_used)]

pub mod batcher;
pub mod laws;
pub mod pool;
pub mod report;
pub mod router;

pub use batcher::{DynamicBatcher, Flush, FlushCause, SloBatcher};
pub use laws::{serving_wbits, BatchLaw};
pub use pool::{BatchTiming, PlannedBatch};
pub use report::{ChipReport, Completion, FaultSummary, NetworkReport, ServeReport, SpotCheck};
pub use router::{CostTable, RouteDecision, ShardRouter};

use std::collections::{HashMap, VecDeque};
use std::time::Instant;

use crate::arch::config::ArchConfig;
use crate::arch::stats::Stats;
use crate::cnn::network::Network;
use crate::cnn::ref_exec::ModelParams;
use crate::cnn::tensor::QTensor;
use crate::coordinator::engine::{EngineFactory, EngineKind, InferenceEngine, PoolSpec};
use crate::device::fault::FaultPlan;
use crate::trace::{Trace, TraceEvent};

use pool::ChipResult;
use report::NetworkMeta;

/// One inference request.
#[derive(Debug)]
pub struct Request {
    /// Caller-assigned id — unique across the stream (the hybrid
    /// spot-check looks completions up by id).
    pub id: u64,
    /// Network this request targets: an index into the serve's network
    /// slice, and the SLO lane it queues in.
    pub net: usize,
    /// Input image.
    pub image: QTensor,
}

impl Request {
    /// Work weight of the request: its input volume in bits.
    pub fn work_bits(&self) -> u64 {
        (self.image.c * self.image.h * self.image.w * self.image.bits as usize) as u64
    }

    /// Number `images` into a single-network request stream: ids
    /// `0..n` in order, all targeting network 0.
    pub fn stream(images: Vec<QTensor>) -> Vec<Request> {
        images
            .into_iter()
            .enumerate()
            .map(|(i, image)| Request { id: i as u64, net: 0, image })
            .collect()
    }

    /// Interleave one image stream per network into a single arrival
    /// stream with globally unique ids: network 0's first image, then
    /// network 1's first, …, then every second image, and so on until
    /// all streams drain (streams may differ in length).
    pub fn interleave(streams: Vec<Vec<QTensor>>) -> Vec<Request> {
        let mut queues: Vec<VecDeque<QTensor>> = streams.into_iter().map(Into::into).collect();
        let mut out = Vec::new();
        let mut id = 0u64;
        while queues.iter().any(|q| !q.is_empty()) {
            for (net, q) in queues.iter_mut().enumerate() {
                if let Some(image) = q.pop_front() {
                    out.push(Request { id, net, image });
                    id += 1;
                }
            }
        }
        out
    }
}

/// One network a pool serve targets: the network plus its optional
/// model parameters (required by bit-accurate engines; synthesized
/// engines use them only for the weight precision).
#[derive(Debug, Clone, Copy)]
pub struct ServedNetwork<'a> {
    /// The network.
    pub net: &'a Network,
    /// Its model parameters, when available.
    pub params: Option<&'a ModelParams>,
}

/// Which engine the serving pool executes requests on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineMode {
    /// Bit-accurate functional engines: outputs are produced and
    /// bit-exact (small networks only).
    Functional,
    /// Closed-form analytic engines: any network, synthesized stats,
    /// no output tensors.
    Analytic,
    /// Serve on analytic engines, but replay sampled requests on a
    /// functional engine and cross-check stats plausibility. The
    /// replay only happens when the network fits the functional path
    /// (the small presets) and model parameters were supplied;
    /// otherwise the serve degrades to pure analytic.
    Hybrid {
        /// Replay stride: requests at stream positions `0, k, 2k, …`
        /// are spot-checked.
        check_every: usize,
    },
}

impl EngineMode {
    /// Engine kind the pool builds for this mode.
    pub fn serving_kind(self) -> EngineKind {
        match self {
            EngineMode::Functional => EngineKind::Functional,
            EngineMode::Analytic | EngineMode::Hybrid { .. } => EngineKind::Analytic,
        }
    }

    /// Whether completions carry bit-accurate outputs.
    pub fn bit_accurate(self) -> bool {
        matches!(self, EngineMode::Functional)
    }

    /// Human/CLI label.
    pub fn label(self) -> &'static str {
        match self {
            EngineMode::Functional => "functional",
            EngineMode::Analytic => "analytic",
            EngineMode::Hybrid { .. } => "hybrid",
        }
    }
}

/// Per-network service-level objectives: an optional batching deadline
/// per network, falling back to the serve's global
/// [`deadline_us`](ServeConfig::deadline_us). Each network's deadline
/// bounds how long any of its requests may sit in its own
/// [`SloBatcher`] flush lane.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SloPolicy {
    /// `deadlines_us[net]` overrides the global batching deadline for
    /// that network (simulated µs); `None` — or a missing trailing
    /// entry — inherits the global one.
    pub deadlines_us: Vec<Option<f64>>,
}

impl SloPolicy {
    /// Every network inherits the global deadline.
    pub fn global() -> Self {
        Self::default()
    }

    /// Builder: pin network `net`'s lane deadline to `us` simulated µs.
    pub fn with_deadline_us(mut self, net: usize, us: f64) -> Self {
        if self.deadlines_us.len() <= net {
            self.deadlines_us.resize(net + 1, None);
        }
        self.deadlines_us[net] = Some(us);
        self
    }

    /// Effective lane deadline of network `net` (µs), given the
    /// serve's global deadline.
    pub fn deadline_us(&self, net: usize, global_us: f64) -> f64 {
        self.deadlines_us.get(net).copied().flatten().unwrap_or(global_us)
    }

    /// Validate structural invariants.
    pub fn validate(&self) -> Result<(), String> {
        for d in self.deadlines_us.iter().flatten() {
            if d.is_nan() || *d < 0.0 {
                return Err("per-network deadline must be a non-negative time".into());
            }
        }
        Ok(())
    }
}

/// Configuration of the serving runtime.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Simulated PIM chips (each a full weight replica with its own
    /// engine). [`serve`] builds a homogeneous pool of this size;
    /// [`serve_pool`] takes its chip count from the supplied
    /// [`PoolSpec`] instead and ignores this field.
    pub chips: usize,
    /// Batch size target: a batch flushes as soon as it holds this many
    /// requests.
    pub max_batch: usize,
    /// Global batching deadline in simulated microseconds: no request
    /// waits longer than this in its flush lane, unless its network
    /// overrides it via [`slo`](Self::slo).
    pub deadline_us: f64,
    /// Per-network deadline overrides (SLO lanes).
    pub slo: SloPolicy,
    /// Per-chip queue capacity in batches (waiting + in service). A
    /// flush into a full queue stalls — backpressure.
    pub queue_depth: usize,
    /// Simulated inter-arrival gap of the request stream (ns); `0.0`
    /// models a closed burst where everything arrives at once.
    pub arrival_interval_ns: f64,
    /// Which engine the pool serves on.
    pub engine: EngineMode,
    /// Host worker threads per chip for bit-accurate serving (`None`
    /// picks the automatic budget: host cores / chips, overridable via
    /// the `NANDSPIN_HOST_WORKERS` environment variable). This is one
    /// budget shared by both levels of host parallelism on a chip:
    /// request-stream splitting across engine replicas and the
    /// per-filter fan-out *inside* each request — a chip divides its
    /// budget between them instead of oversubscribing (single-request
    /// serves put all of it into the fan-out). Changes host wall time
    /// only — results are bit-identical for every count.
    pub host_workers: Option<usize>,
    /// Serve-wide fault plan: specialised per chip via
    /// [`FaultPlan::for_chip`] so chips draw independent fault
    /// streams. A chip whose factory carries its own plan keeps it.
    /// `None` (or an inactive plan) serves on the exact fault-free
    /// path. Only bit-accurate engines inject faults; synthesized
    /// engines ignore the plan (a hybrid serve still injects on its
    /// spot-check replays).
    pub fault: Option<FaultPlan>,
    /// Extra failover rounds the serve may spend re-routing batches
    /// off chips that trip the health threshold (0 = never fail over).
    pub retry_budget: usize,
    /// Injected-fault events per charged device op above which a chip
    /// is marked unhealthy and drained.
    pub fault_health_threshold: f64,
    /// Record a deterministic observability trace of the serve: a
    /// simulated-clock event timeline (one `arrival → … → complete`
    /// span chain per request, plus batch / fault / failover /
    /// spot-check events), an integer metrics snapshot, and per-layer
    /// simulated cost profiles on every chip — all attached to
    /// [`ServeReport::trace`] / [`ChipReport::layer_costs`]. Off by
    /// default; when off the serve runs the exact pre-trace path and
    /// the report is bit-identical to an untraced run.
    pub trace: bool,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            chips: 4,
            max_batch: 8,
            deadline_us: 50.0,
            slo: SloPolicy::global(),
            queue_depth: 2,
            arrival_interval_ns: 0.0,
            engine: EngineMode::Functional,
            host_workers: None,
            fault: None,
            retry_budget: 1,
            fault_health_threshold: 0.01,
            trace: false,
        }
    }
}

impl ServeConfig {
    /// Validate structural invariants.
    pub fn validate(&self) -> Result<(), String> {
        if self.chips == 0 {
            return Err("need at least one chip".into());
        }
        if self.max_batch == 0 {
            return Err("batch size target must be >= 1".into());
        }
        if self.deadline_us.is_nan() || self.deadline_us < 0.0 {
            return Err("deadline must be a non-negative time".into());
        }
        self.slo.validate()?;
        if self.queue_depth == 0 {
            return Err("queue depth must be >= 1".into());
        }
        if self.arrival_interval_ns.is_nan() || self.arrival_interval_ns < 0.0 {
            return Err("arrival interval must be a non-negative time".into());
        }
        if self.host_workers == Some(0) {
            return Err("host worker budget must be >= 1 (or None for automatic)".into());
        }
        if let EngineMode::Hybrid { check_every } = self.engine {
            if check_every == 0 {
                return Err("hybrid check stride must be >= 1".into());
            }
        }
        if let Some(plan) = &self.fault {
            plan.rates.validate()?;
        }
        if !self.fault_health_threshold.is_finite() || self.fault_health_threshold < 0.0 {
            return Err("fault health threshold must be a non-negative rate".into());
        }
        Ok(())
    }
}

/// Serve a single-network request stream through the batched
/// multi-chip runtime on a homogeneous pool of `scfg.chips` chips at
/// operating point `cfg` — the classic entry point, now a thin wrapper
/// over [`serve_pool`].
///
/// Requests arrive on the simulated clock at `scfg.arrival_interval_ns`
/// spacing (in the given order); the stream drains at the last arrival.
/// With [`EngineMode::Functional`], outputs are bit-exact with
/// [`ref_exec::execute`](crate::cnn::ref_exec::execute) per request,
/// whichever chip serves it, and `params` is required. With
/// [`EngineMode::Analytic`] (or `Hybrid`), any network serves —
/// including the paper's full-size benchmarks — with synthesized
/// per-request stats; `params` is optional and only sets the weight
/// precision (and enables the hybrid functional replay).
///
/// # Panics
/// If `scfg` is invalid, the engine cannot run `net` (functional mode
/// on a network wider than the subarray), a bit-accurate mode is
/// missing `params`, or a network output is empty.
pub fn serve(
    cfg: &ArchConfig,
    scfg: &ServeConfig,
    net: &Network,
    params: Option<&ModelParams>,
    requests: Vec<Request>,
) -> ServeReport {
    scfg.validate().expect("invalid serve config");
    let pool = PoolSpec::homogeneous(cfg.clone(), scfg.engine.serving_kind(), scfg.chips);
    serve_pool(&pool, scfg, &[ServedNetwork { net, params }], requests)
}

/// Serve a multi-network request stream across a (possibly
/// heterogeneous) chip pool, with one SLO flush lane per network.
///
/// `nets[i]` is the network requests tagged `net == i` target; each
/// network batches in its own [`SloBatcher`] lane under its own
/// deadline ([`ServeConfig::slo`], falling back to the global one).
/// Batches route to chips by earliest estimated finish, where the
/// estimates are the closed-form [`BatchLaw`] cold/warm service times
/// of each network on each chip's own operating point — so routing is
/// engine-agnostic and a serve's schedule is pinned to the analytic
/// model it is verified against. The pool's chip count overrides
/// `scfg.chips`.
///
/// # Panics
/// If `scfg` is invalid, `nets` is empty, a request targets an unknown
/// network, any chip's engine cannot run any of the networks, or a
/// bit-accurate mode is missing a network's parameters.
pub fn serve_pool(
    pool: &PoolSpec,
    scfg: &ServeConfig,
    nets: &[ServedNetwork<'_>],
    requests: Vec<Request>,
) -> ServeReport {
    scfg.validate().expect("invalid serve config");
    assert!(!nets.is_empty(), "need at least one network to serve");
    for sn in nets {
        for chip in 0..pool.chips() {
            let eplan = pool.factory(chip).plan(sn.net);
            assert!(
                eplan.supported,
                "chip {chip}'s {} engine cannot serve {}: {}",
                pool.factory(chip).kind().label(),
                sn.net.name,
                eplan.unsupported_reason.as_deref().unwrap_or("unsupported network"),
            );
        }
        if scfg.engine.bit_accurate() {
            assert!(
                sn.params.is_some(),
                "functional serving needs model parameters for {}",
                sn.net.name
            );
        }
    }
    for r in &requests {
        assert!(
            r.net < nets.len(),
            "request {} targets network {} but only {} are being served",
            r.id,
            r.net,
            nets.len()
        );
    }
    let started = Instant::now();

    // Routing costs: the closed-form batching law of every network on
    // every chip's own operating point. Derived for every engine mode,
    // so functional, analytic and hybrid serves of one stream share
    // the same schedule.
    let costs = CostTable::new(
        (0..pool.chips())
            .map(|chip| {
                nets.iter()
                    .map(|sn| {
                        let wbits = serving_wbits(sn.net, sn.params);
                        let law = BatchLaw::derive(pool.factory(chip).cfg(), sn.net, wbits);
                        (law.cold_latency_ns, law.warm_latency_ns)
                    })
                    .collect()
            })
            .collect(),
    );

    // Fault plans: a chip whose factory carries its own plan keeps it;
    // otherwise the serve-wide plan is specialised per chip so chips
    // draw independent fault streams. With none active, execution is
    // the exact fault-free path.
    let fault_plans: Vec<Option<FaultPlan>> = (0..pool.chips())
        .map(|chip| {
            pool.factory(chip)
                .fault_plan()
                .copied()
                .or_else(|| scfg.fault.map(|p| p.for_chip(chip)))
                .filter(FaultPlan::is_active)
        })
        .collect();
    let fault_active = fault_plans.iter().any(Option::is_some);

    // Hybrid: sample every K-th request (by stream position) for the
    // functional replay, before the planner consumes the stream — but
    // only for networks where the replay is actually possible (params
    // supplied and the network fits some chip's bit-accurate path);
    // otherwise skip the clones and degrade to pure analytic.
    let replayable: Vec<bool> = nets
        .iter()
        .map(|sn| {
            sn.params.is_some()
                && pool.factories().iter().any(|f| {
                    EngineFactory::new(f.cfg().clone(), EngineKind::Functional)
                        .plan(sn.net)
                        .supported
                })
        })
        .collect();
    let samples: Vec<(u64, usize, QTensor)> = match scfg.engine {
        EngineMode::Hybrid { check_every } => requests
            .iter()
            .enumerate()
            .filter(|(i, r)| i % check_every == 0 && replayable[r.net])
            .map(|(_, r)| (r.id, r.net, r.image.clone()))
            .collect(),
        _ => Vec::new(),
    };
    // Escalation reserve: under an active fault plan a hybrid serve
    // may halve its spot-check stride if the run degrades, so hold
    // clones of the extra sample positions too (fault-free serves skip
    // the clones and keep today's exact behaviour).
    let extra_samples: Vec<(u64, usize, QTensor)> = match scfg.engine {
        EngineMode::Hybrid { check_every } if fault_active && check_every > 1 => {
            let stride = (check_every / 2).max(1);
            requests
                .iter()
                .enumerate()
                .filter(|(i, r)| i % check_every != 0 && i % stride == 0 && replayable[r.net])
                .map(|(_, r)| (r.id, r.net, r.image.clone()))
                .collect()
        }
        _ => Vec::new(),
    };

    // Plan: walk the arrival stream through the SLO lanes + router on
    // the simulated clock. Deterministic — no execution yet.
    let lane_deadlines_ns: Vec<f64> = (0..nets.len())
        .map(|i| scfg.slo.deadline_us(i, scfg.deadline_us) * 1e3)
        .collect();
    let mut batcher = SloBatcher::new(&lane_deadlines_ns, scfg.max_batch);
    let mut router = ShardRouter::new(costs);
    let mut planned: Vec<PlannedBatch> = Vec::new();
    let mut seq = 0usize;
    let mut last_arrival_ns = 0.0f64;
    for (i, req) in requests.into_iter().enumerate() {
        let t = i as f64 * scfg.arrival_interval_ns;
        last_arrival_ns = t;
        for (lane, f) in batcher.poll(t) {
            planned.push(plan(lane, f, &mut router, &mut seq));
        }
        if let Some((lane, f)) = batcher.push(req, t) {
            planned.push(plan(lane, f, &mut router, &mut seq));
        }
    }
    for (lane, f) in batcher.drain(last_arrival_ns) {
        planned.push(plan(lane, f, &mut router, &mut seq));
    }
    let counters = batcher.counters();

    // Execute: one host thread per chip, weight-resident engines. With
    // no active fault plan this is exactly the fault-free path; under
    // one, the failover loop below drains and re-routes batches off
    // chips whose injected-fault rate trips the health threshold,
    // spending at most `retry_budget` extra rounds.
    let chips = pool.chips();
    let mut unhealthy = vec![false; chips];
    // (rounds, failed-over batches, failed-over requests).
    let mut failover = (0u64, 0u64, 0u64);
    // Failover / health events for the trace, collected as the loop
    // reacts (everything is on the simulated clock, so the list is
    // deterministic).
    let mut sched_events: Vec<TraceEvent> = Vec::new();
    let results = if !fault_active {
        pool::execute_pool(pool, nets, planned, scfg.host_workers, scfg.trace)
    } else {
        let mut fpool = pool.clone();
        for (chip, plan) in fault_plans.iter().enumerate() {
            if let Some(p) = plan {
                fpool.factory_mut(chip).set_fault_plan(*p);
            }
        }
        let mut retired: Vec<ChipResult> = (0..chips)
            .map(|chip| ChipResult {
                chip,
                batches: Vec::new(),
                weight_hits: 0,
                weight_misses: 0,
                host_profile: None,
                layer_costs: None,
            })
            .collect();
        let mut pending = planned;
        while !pending.is_empty() {
            // Re-routable clones: a tripped chip's round is discarded
            // and re-executed from these on a surviving chip.
            let spares: Vec<PlannedBatch> = pending
                .iter()
                .map(|b| PlannedBatch {
                    seq: b.seq,
                    chip: b.chip,
                    net: b.net,
                    cause: b.cause,
                    flush_ns: b.flush_ns,
                    requests: b
                        .requests
                        .iter()
                        .map(|r| Request { id: r.id, net: r.net, image: r.image.clone() })
                        .collect(),
                    arrivals_ns: b.arrivals_ns.clone(),
                    est_cost_ns: b.est_cost_ns,
                    est_finish_ns: b.est_finish_ns,
                })
                .collect();
            let results = pool::execute_pool(&fpool, nets, pending, scfg.host_workers, scfg.trace);
            // Health: injected fault events per charged device op,
            // over the chip's batches of this round.
            let newly: Vec<usize> = results
                .iter()
                .filter(|r| !unhealthy[r.chip] && !r.batches.is_empty())
                .filter(|r| {
                    let mut s = Stats::default();
                    for b in &r.batches {
                        for q in &b.requests {
                            s.merge_serial(&q.stats);
                        }
                    }
                    let ops = s.ops.reads + s.ops.ands + s.ops.program_steps;
                    s.faults.injected() as f64
                        > scfg.fault_health_threshold * ops.max(1) as f64
                })
                .map(|r| r.chip)
                .collect();
            let healthy = unhealthy.iter().filter(|&&u| !u).count();
            if newly.is_empty()
                || failover.0 >= scfg.retry_budget as u64
                || newly.len() >= healthy
            {
                // Nothing tripped, the budget is spent, or draining
                // would leave no chip: retire this round as-is so
                // every request is still served.
                for r in results {
                    retire(&mut retired[r.chip], r);
                }
                break;
            }
            failover.0 += 1;
            for &chip in &newly {
                unhealthy[chip] = true;
                router.mark_unhealthy(chip);
                if scfg.trace {
                    // Deterministic stamp: the earliest flush of the
                    // work this chip is about to be drained of.
                    let ts = spares
                        .iter()
                        .filter(|b| b.chip == chip)
                        .map(|b| b.flush_ns)
                        .fold(f64::INFINITY, f64::min);
                    sched_events.push(
                        TraceEvent::instant("chip_unhealthy", "fault", ts.min(f64::MAX))
                            .on(chip as u64 + 1, 0)
                            .arg("round", failover.0),
                    );
                }
            }
            for r in results {
                if !unhealthy[r.chip] {
                    retire(&mut retired[r.chip], r);
                }
            }
            pending = Vec::new();
            for mut b in spares {
                if unhealthy[b.chip] {
                    failover.1 += 1;
                    failover.2 += b.requests.len() as u64;
                    let decision = router.route_decision(b.net, b.requests.len());
                    if scfg.trace {
                        sched_events.push(
                            TraceEvent::instant("failover", "fault", b.flush_ns)
                                .on(0, b.seq as u64)
                                .arg("round", failover.0)
                                .arg("from", b.chip as u64)
                                .arg("to", decision.chip as u64)
                                .arg("requests", b.requests.len() as u64),
                        );
                    }
                    b.chip = decision.chip;
                    b.est_cost_ns = decision.cost_ns;
                    b.est_finish_ns = decision.finish_ns;
                    pending.push(b);
                }
            }
        }
        for r in &mut retired {
            r.batches.sort_by_key(|b| b.seq);
        }
        retired
    };

    // Account: schedule each chip's batches behind its bounded queue.
    let timings: Vec<Vec<BatchTiming>> = results
        .iter()
        .map(|r| {
            let flushes: Vec<f64> = r.batches.iter().map(|b| b.flush_ns).collect();
            let services: Vec<f64> = r.batches.iter().map(|b| b.service_ns()).collect();
            pool::timeline(&flushes, &services, scfg.queue_depth)
        })
        .collect();
    if scfg.trace {
        // Batch-plane events: flush + route decision on the scheduler
        // track, the execution span on the chip track. Built in chip
        // order from the retired results — deterministic.
        for (r, chip_timings) in results.iter().zip(&timings) {
            for (b, t) in r.batches.iter().zip(chip_timings) {
                let seq = b.seq as u64;
                sched_events.push(
                    TraceEvent::instant("flush", "batch", b.flush_ns)
                        .on(0, seq)
                        .arg("net", nets[b.net].net.name.as_str())
                        .arg("cause", b.cause.label())
                        .arg("requests", b.requests.len() as u64),
                );
                sched_events.push(
                    TraceEvent::instant("route", "batch", b.flush_ns)
                        .on(0, seq)
                        .arg("chip", r.chip as u64)
                        .arg("est_cost_ns", b.est_cost_ns)
                        .arg("est_finish_ns", b.est_finish_ns),
                );
                sched_events.push(
                    TraceEvent::span("batch", "batch", t.start_ns, t.finish_ns - t.start_ns)
                        .on(r.chip as u64 + 1, seq)
                        .arg("requests", b.requests.len() as u64)
                        .arg("stalled", u64::from(t.stalled)),
                );
            }
        }
    }
    let nets_meta: Vec<NetworkMeta> = nets
        .iter()
        .zip(&lane_deadlines_ns)
        .map(|(sn, &deadline_ns)| NetworkMeta { name: sn.net.name.clone(), deadline_ns })
        .collect();
    let mut report = ServeReport::assemble(
        scfg.engine,
        nets_meta,
        results,
        timings,
        counters,
        started.elapsed().as_secs_f64(),
    );
    if fault_active {
        report.faults.active = true;
        report.faults.failover_rounds = failover.0;
        report.faults.failed_over_batches = failover.1;
        report.faults.failed_over_requests = failover.2;
        report.faults.unhealthy_chips = unhealthy.iter().filter(|&&u| u).count() as u64;
        for c in &mut report.chips {
            c.healthy = !unhealthy[c.chip];
        }
    }
    let mut spot_obs: Vec<SpotObservation> = Vec::new();
    if !samples.is_empty() {
        let (mut check, replay_stats, obs) =
            spot_check(pool, nets, &fault_plans, &samples, &report);
        spot_obs.extend(obs);
        // Hybrid degradation: when the serve failed chips over, or the
        // fault-injected replays themselves trip the health threshold,
        // halve the spot-check stride by folding the reserve samples in.
        let replay_ops =
            replay_stats.ops.reads + replay_stats.ops.ands + replay_stats.ops.program_steps;
        let replay_tripped = replay_stats.faults.injected() as f64
            > scfg.fault_health_threshold * replay_ops.max(1) as f64;
        let degraded = unhealthy.iter().any(|&u| u) || replay_tripped;
        if degraded && !extra_samples.is_empty() {
            report.faults.spot_check_escalated = true;
            let (extra, _, obs) = spot_check(pool, nets, &fault_plans, &extra_samples, &report);
            spot_obs.extend(obs);
            check = match (check, extra) {
                (Some(mut a), Some(b)) => {
                    a.absorb(&b);
                    Some(a)
                }
                (a, b) => a.or(b),
            };
        }
        report.spot_check = check;
        report.wall_seconds = started.elapsed().as_secs_f64();
    }
    if scfg.trace {
        report.trace = Some(build_trace(chips, nets, &report, sched_events, &spot_obs));
    }
    report
}

/// One hybrid spot-check replay, for the trace: `(request id, chip,
/// simulated finish time ns, functional/analytic latency ratio,
/// energy ratio)`.
type SpotObservation = (u64, usize, f64, f64, f64);

/// Assemble the serve's deterministic [`Trace`]: per-request span
/// chains and fault markers from the completions, the pre-collected
/// batch / failover / health events, spot-check markers, and the
/// report's metrics snapshot. Everything is derived from
/// planning metadata and the assembled report — both already
/// bit-identical across host worker counts — so the trace (and every
/// byte of its exports) is too.
fn build_trace(
    chips: usize,
    nets: &[ServedNetwork<'_>],
    report: &ServeReport,
    sched_events: Vec<TraceEvent>,
    spot_obs: &[SpotObservation],
) -> Trace {
    let mut trace = Trace::default();
    trace.tracks.push("scheduler".to_string());
    for chip in 0..chips {
        trace.tracks.push(format!("chip {chip}"));
    }
    trace.events = sched_events;
    for c in &report.completions {
        let pid = c.chip as u64 + 1;
        trace.events.push(
            TraceEvent::instant("arrival", "request", c.arrival_ns)
                .on(0, c.id)
                .arg("net", nets[c.net].net.name.as_str()),
        );
        trace.events.push(
            TraceEvent::span("lane_wait", "request", c.arrival_ns, c.batcher_wait_ns())
                .on(0, c.id)
                .arg("batch", c.batch as u64),
        );
        trace.events.push(
            TraceEvent::span("queue_wait", "request", c.flush_ns, c.start_ns - c.flush_ns)
                .on(0, c.id)
                .arg("chip", c.chip as u64),
        );
        trace.events.push(
            TraceEvent::span("execute", "request", c.start_ns, c.service_ns())
                .on(pid, c.id)
                .arg("net", nets[c.net].net.name.as_str())
                .arg("energy_fj", c.stats.total_energy_fj()),
        );
        trace.events.push(
            TraceEvent::instant("complete", "request", c.finish_ns)
                .on(pid, c.id)
                .arg("latency_ns", c.latency_ns()),
        );
        let faults = &c.stats.faults;
        if !faults.is_zero() {
            trace.events.push(
                TraceEvent::instant("faults", "fault", c.finish_ns)
                    .on(pid, c.id)
                    .arg("program", faults.program_faults)
                    .arg("read", faults.read_flips)
                    .arg("and", faults.and_flips)
                    .arg("write_retries", faults.write_retries)
                    .arg("spared_rows", faults.spared_rows),
            );
        }
    }
    for &(id, chip, finish_ns, latency_ratio, energy_ratio) in spot_obs {
        trace.events.push(
            TraceEvent::instant("spot_check", "check", finish_ns)
                .on(chip as u64 + 1, id)
                .arg("latency_ratio", latency_ratio)
                .arg("energy_ratio", energy_ratio),
        );
    }
    trace.metrics = report.metrics();
    trace.sort_events();
    trace
}

/// Fold one execution round's result for a chip into its retired
/// account (the failover loop may execute a chip more than once).
fn retire(into: &mut ChipResult, from: ChipResult) {
    debug_assert_eq!(into.chip, from.chip);
    into.batches.extend(from.batches);
    into.weight_hits += from.weight_hits;
    into.weight_misses += from.weight_misses;
    pool::fold_host_profile(&mut into.host_profile, from.host_profile.as_deref());
    crate::trace::merge_layer_costs(&mut into.layer_costs, from.layer_costs);
}

/// Route one flushed batch of network `net` and stamp it with its
/// sequence number and the router's cost estimates (the trace's
/// route-decision events report them).
fn plan(net: usize, flush: Flush, router: &mut ShardRouter, seq: &mut usize) -> PlannedBatch {
    let decision = router.route_decision(net, flush.requests.len());
    let b = PlannedBatch {
        seq: *seq,
        chip: decision.chip,
        net,
        cause: flush.cause,
        flush_ns: flush.at_ns,
        requests: flush.requests,
        arrivals_ns: flush.arrivals_ns,
        est_cost_ns: decision.cost_ns,
        est_finish_ns: decision.finish_ns,
    };
    *seq += 1;
    b
}

/// Lazily-built bit-accurate replay engines of the hybrid spot-check,
/// one per (serving chip, network); `None` marks a pair whose chip
/// operating point cannot run the network functionally.
type ReplayEngines = HashMap<(usize, usize), Option<Box<dyn InferenceEngine>>>;

/// Replay the sampled requests on bit-accurate engines at the
/// operating point of the chip that served each sample, and fold each
/// replay's functional/analytic stat ratios into a [`SpotCheck`]. A
/// serving chip's fault plan is installed on its replay engine, so the
/// replays see the degradation the synthesized serve cannot model.
/// Samples whose serving chip cannot run their network functionally
/// are skipped; the check is `None` when nothing could be replayed.
/// Also returns the serial fold of every replay's stats (the caller
/// judges replay fault rates from it) and the per-replay observations
/// (the trace's spot-check markers).
fn spot_check(
    pool: &PoolSpec,
    nets: &[ServedNetwork<'_>],
    fault_plans: &[Option<FaultPlan>],
    samples: &[(u64, usize, QTensor)],
    report: &ServeReport,
) -> (Option<SpotCheck>, Stats, Vec<SpotObservation>) {
    let mut engines: ReplayEngines = HashMap::new();
    let mut check = SpotCheck::new();
    let mut replay_stats = Stats::default();
    let mut observations = Vec::new();
    for (id, net_idx, image) in samples {
        let sn = &nets[*net_idx];
        let Some(params) = sn.params else { continue };
        let completion = report
            .completions
            .iter()
            .find(|c| c.id == *id)
            .expect("sampled request completed");
        let entry = engines.entry((completion.chip, *net_idx)).or_insert_with(|| {
            let factory = EngineFactory::new(
                pool.factory(completion.chip).cfg().clone(),
                EngineKind::Functional,
            );
            if factory.plan(sn.net).supported {
                let mut engine = factory.build();
                if let Some(plan) = fault_plans[completion.chip] {
                    engine.set_fault_plan(plan);
                }
                engine.make_weights_resident();
                Some(engine)
            } else {
                None
            }
        });
        let Some(engine) = entry.as_mut() else { continue };
        let replay = engine.execute(sn.net, Some(params), image);
        let analytic = &completion.stats;
        replay_stats.merge_serial(&replay.stats);
        let latency_ratio =
            replay.stats.total_latency_ns() / analytic.total_latency_ns().max(f64::MIN_POSITIVE);
        let energy_ratio =
            replay.stats.total_energy_fj() / analytic.total_energy_fj().max(f64::MIN_POSITIVE);
        check.observe(latency_ratio, energy_ratio);
        observations.push((
            *id,
            completion.chip,
            completion.finish_ns,
            latency_ratio,
            energy_ratio,
        ));
    }
    if check.checked == 0 {
        (None, replay_stats, observations)
    } else {
        (Some(check), replay_stats, observations)
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests may panic on impossible states
mod tests {
    use super::*;
    use crate::cnn::network::small_cnn;
    use crate::cnn::ref_exec;
    use crate::device::fault::FaultRates;

    fn requests(net: &Network, n: usize, seed: u64) -> Vec<Request> {
        Request::stream(
            (0..n)
                .map(|i| {
                    QTensor::random(
                        net.input.0,
                        net.input.1,
                        net.input.2,
                        net.input_bits,
                        seed + i as u64,
                    )
                })
                .collect(),
        )
    }

    #[test]
    fn serves_bit_exactly_across_chips() {
        let net = small_cnn(3);
        let params = ModelParams::random(&net, 3, 2);
        let reqs = requests(&net, 6, 100);
        let images: Vec<QTensor> = reqs.iter().map(|r| r.image.clone()).collect();
        let scfg = ServeConfig { chips: 3, max_batch: 2, ..ServeConfig::default() };
        let report = serve(&ArchConfig::paper(), &scfg, &net, Some(&params), reqs);
        assert_eq!(report.served(), 6);
        report.verify().expect("aggregation identities");
        for c in &report.completions {
            let golden = ref_exec::execute(&net, &params, &images[c.id as usize]);
            let output = c.output.as_ref().expect("functional mode carries outputs");
            assert_eq!(output, golden.last().unwrap(), "request {}", c.id);
            assert!(c.stats.total_latency_ns() > 0.0);
        }
        // All three chips participated in the closed burst.
        let distinct: std::collections::HashSet<usize> =
            report.completions.iter().map(|c| c.chip).collect();
        assert_eq!(distinct.len(), 3, "expected all chips busy, got {distinct:?}");
        assert!(report.sim_fps() > 0.0);
    }

    #[test]
    fn chip_assignment_is_deterministic_across_runs() {
        let net = small_cnn(2);
        let params = ModelParams::random(&net, 2, 5);
        let scfg = ServeConfig { chips: 2, max_batch: 2, ..ServeConfig::default() };
        let assignment = |seed: u64| {
            let report = serve(
                &ArchConfig::paper(),
                &scfg,
                &net,
                Some(&params),
                requests(&net, 6, seed),
            );
            let mut by_id: Vec<(u64, usize)> =
                report.completions.iter().map(|c| (c.id, c.chip)).collect();
            by_id.sort_unstable();
            by_id
        };
        assert_eq!(assignment(9), assignment(9));
    }

    #[test]
    fn resident_weights_make_big_batches_cheaper_per_request() {
        // One chip, one batch: the weight stream amortises across the
        // batch, so per-request mean energy falls as the batch grows.
        let net = small_cnn(3);
        let params = ModelParams::random(&net, 3, 7);
        let scfg = ServeConfig { chips: 1, max_batch: 16, ..ServeConfig::default() };
        let run = |n: usize| {
            let report = serve(
                &ArchConfig::paper(),
                &scfg,
                &net,
                Some(&params),
                requests(&net, n, 30),
            );
            report.total_energy_mj() / n as f64
        };
        assert!(run(4) < run(1), "batching must amortise the weight stream");
    }

    #[test]
    fn analytic_mode_shares_the_batching_and_routing_laws() {
        // Same stream, same plan: only the engine (and thus the stats
        // fidelity) changes between functional and analytic serves.
        let net = small_cnn(3);
        let params = ModelParams::random(&net, 3, 7);
        let scfg = ServeConfig { chips: 2, max_batch: 2, ..ServeConfig::default() };
        let functional =
            serve(&ArchConfig::paper(), &scfg, &net, Some(&params), requests(&net, 6, 40));
        let acfg = ServeConfig { engine: EngineMode::Analytic, ..scfg };
        let analytic =
            serve(&ArchConfig::paper(), &acfg, &net, Some(&params), requests(&net, 6, 40));
        analytic.verify().expect("analytic identities");
        let routes = |r: &ServeReport| {
            let mut v: Vec<(u64, usize, usize)> =
                r.completions.iter().map(|c| (c.id, c.chip, c.batch)).collect();
            v.sort_unstable();
            v
        };
        assert_eq!(routes(&functional), routes(&analytic), "planning is engine-agnostic");
        assert!(analytic.completions.iter().all(|c| c.output.is_none()));
    }

    #[test]
    fn mixed_networks_serve_bit_exactly_in_their_own_lanes() {
        // Two networks interleaved through one functional pool: every
        // completion must be bit-exact against its *own* network's
        // golden executor, and each network gets its own SLO account.
        let net_a = small_cnn(3);
        let net_b = crate::cnn::network::micro_cnn(3);
        let params_a = ModelParams::random(&net_a, 3, 11);
        let params_b = ModelParams::random(&net_b, 3, 12);
        let images = |net: &Network, n: usize, seed: u64| -> Vec<QTensor> {
            (0..n)
                .map(|i| {
                    QTensor::random(
                        net.input.0,
                        net.input.1,
                        net.input.2,
                        net.input_bits,
                        seed + i as u64,
                    )
                })
                .collect()
        };
        let reqs =
            Request::interleave(vec![images(&net_a, 4, 200), images(&net_b, 4, 300)]);
        let keyed: Vec<(usize, QTensor)> =
            reqs.iter().map(|r| (r.net, r.image.clone())).collect();
        let scfg = ServeConfig {
            chips: 2,
            max_batch: 2,
            slo: SloPolicy::global().with_deadline_us(1, 5.0),
            ..ServeConfig::default()
        };
        let pool =
            PoolSpec::homogeneous(ArchConfig::paper(), EngineKind::Functional, scfg.chips);
        let nets =
            [ServedNetwork { net: &net_a, params: Some(&params_a) }, ServedNetwork {
                net: &net_b,
                params: Some(&params_b),
            }];
        let report = serve_pool(&pool, &scfg, &nets, reqs);
        report.verify().expect("mixed-network identities");
        assert_eq!(report.served(), 8);
        assert_eq!(report.networks.len(), 2);
        assert_eq!(report.networks[0].served, 4);
        assert_eq!(report.networks[1].served, 4);
        assert!((report.networks[1].deadline_ns - 5_000.0).abs() < 1e-9, "lane 1 SLO");
        assert!(report.networks.iter().all(|n| n.deadline_violations == 0));
        for c in &report.completions {
            let (net_idx, image) = &keyed[c.id as usize];
            assert_eq!(c.net, *net_idx, "completion keeps its network tag");
            let (net, params) =
                if c.net == 0 { (&net_a, &params_a) } else { (&net_b, &params_b) };
            let golden = ref_exec::execute(net, params, image);
            let output = c.output.as_ref().expect("functional mode carries outputs");
            assert_eq!(output, golden.last().unwrap(), "request {}", c.id);
        }
    }

    #[test]
    fn invalid_configs_are_rejected() {
        assert!(ServeConfig { chips: 0, ..ServeConfig::default() }.validate().is_err());
        assert!(ServeConfig { max_batch: 0, ..ServeConfig::default() }.validate().is_err());
        assert!(ServeConfig { queue_depth: 0, ..ServeConfig::default() }.validate().is_err());
        assert!(
            ServeConfig { deadline_us: f64::NAN, ..ServeConfig::default() }.validate().is_err()
        );
        assert!(ServeConfig { host_workers: Some(0), ..ServeConfig::default() }
            .validate()
            .is_err());
        assert!(ServeConfig {
            slo: SloPolicy::global().with_deadline_us(0, f64::NAN),
            ..ServeConfig::default()
        }
        .validate()
        .is_err());
        assert!(ServeConfig {
            engine: EngineMode::Hybrid { check_every: 0 },
            ..ServeConfig::default()
        }
        .validate()
        .is_err());
        assert!(ServeConfig::default().validate().is_ok());
        assert!(ServeConfig {
            engine: EngineMode::Hybrid { check_every: 4 },
            ..ServeConfig::default()
        }
        .validate()
        .is_ok());
        assert!(ServeConfig {
            fault: Some(FaultPlan::new(1, FaultRates::uniform(1.5))),
            ..ServeConfig::default()
        }
        .validate()
        .is_err());
        assert!(ServeConfig { fault_health_threshold: f64::NAN, ..ServeConfig::default() }
            .validate()
            .is_err());
        assert!(ServeConfig { fault_health_threshold: -0.1, ..ServeConfig::default() }
            .validate()
            .is_err());
        assert!(ServeConfig {
            fault: Some(FaultPlan::new(1, FaultRates::uniform(1e-3))),
            retry_budget: 2,
            ..ServeConfig::default()
        }
        .validate()
        .is_ok());
    }

    #[test]
    fn slo_policy_overrides_fall_back_to_the_global_deadline() {
        let slo = SloPolicy::global().with_deadline_us(2, 7.5);
        assert_eq!(slo.deadline_us(0, 50.0), 50.0, "unset lane inherits");
        assert_eq!(slo.deadline_us(2, 50.0), 7.5, "pinned lane overrides");
        assert_eq!(slo.deadline_us(9, 50.0), 50.0, "past the vec inherits");
        assert!(slo.validate().is_ok());
    }
}
