//! The batched multi-chip serving runtime: the L3 deployment topology.
//!
//! The paper's headline gains (the ~2.6× speedup / ~1.4× energy
//! efficiency of the parallelism-friendly mapping) pay off at *serving*
//! scale, where weights are loaded once and reused across a stream of
//! requests — Table 3's operating condition. This subsystem models that
//! deployment end to end, generically over the
//! [`InferenceEngine`](crate::coordinator::engine::InferenceEngine)
//! trait:
//!
//! ```text
//!  requests ──▶ DynamicBatcher ──▶ ShardRouter ──▶ per-chip queues
//!               (size/deadline       (deterministic   (bounded; FIFO;
//!                flush)               least-loaded)    backpressure)
//!                                                        │
//!                                      weight-resident   ▼
//!                         ServeReport ◀── engine pool (1 chip = 1
//!                                          engine from EngineFactory:
//!                                          functional or analytic,
//!                                          weights streamed once)
//! ```
//!
//! * [`batcher::DynamicBatcher`] groups requests until a batch fills
//!   (size flush) or the oldest request hits the deadline (deadline
//!   flush) — the throughput/tail-latency dial.
//! * [`router::ShardRouter`] maps each batch onto one of N simulated
//!   chips, deterministically (least routed work, lowest index ties).
//! * [`pool`] executes each chip's batches on its own weight-resident
//!   engine built by an
//!   [`EngineFactory`](crate::coordinator::engine::EngineFactory)
//!   (one host thread per chip; a bit-accurate chip's stream is
//!   further split across worker threads with a deterministic,
//!   bit-identical merge — host wall time is the only thing that
//!   changes) and schedules them on the simulated clock behind a
//!   bounded queue ([`pool::timeline`]), so a saturated chip exerts
//!   backpressure instead of queueing unboundedly.
//! * [`report::ServeReport`] rolls per-request completions up into
//!   per-chip and aggregate latency/energy accounts and can
//!   [`verify`](report::ServeReport::verify) that every roll-up equals
//!   the fold of its parts.
//!
//! [`EngineMode`] selects what the pool builds: `Functional` serves
//! bit-accurately (small networks, outputs checked), `Analytic` serves
//! the paper's full-size benchmarks at closed-form speed (stats only),
//! and `Hybrid` serves analytically while replaying every K-th request
//! on a functional engine to cross-check stats plausibility
//! ([`SpotCheck`]).
//!
//! Everything is deterministic: batching and routing run on the
//! simulated clock before execution starts, chips are independent, and
//! host threads only parallelise the simulation work itself.

pub mod batcher;
pub mod pool;
pub mod report;
pub mod router;

pub use batcher::{DynamicBatcher, Flush, FlushCause};
pub use pool::{BatchTiming, PlannedBatch};
pub use report::{ChipReport, Completion, ServeReport, SpotCheck};
pub use router::ShardRouter;

use std::time::Instant;

use crate::arch::config::ArchConfig;
use crate::cnn::network::Network;
use crate::cnn::ref_exec::ModelParams;
use crate::cnn::tensor::QTensor;
use crate::coordinator::engine::{EngineFactory, EngineKind, InferenceEngine};

/// One inference request.
#[derive(Debug)]
pub struct Request {
    /// Caller-assigned id.
    pub id: u64,
    /// Input image.
    pub image: QTensor,
}

impl Request {
    /// Work weight of the request for routing: its input volume in bits.
    pub fn work_bits(&self) -> u64 {
        (self.image.c * self.image.h * self.image.w * self.image.bits as usize) as u64
    }

    /// Number `images` into a request stream: ids `0..n` in order.
    pub fn stream(images: Vec<QTensor>) -> Vec<Request> {
        images
            .into_iter()
            .enumerate()
            .map(|(i, image)| Request { id: i as u64, image })
            .collect()
    }
}

/// Which engine the serving pool executes requests on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineMode {
    /// Bit-accurate functional engines: outputs are produced and
    /// bit-exact (small networks only).
    Functional,
    /// Closed-form analytic engines: any network, synthesized stats,
    /// no output tensors.
    Analytic,
    /// Serve on analytic engines, but replay sampled requests on a
    /// functional engine and cross-check stats plausibility. The
    /// replay only happens when the network fits the functional path
    /// (the small presets) and model parameters were supplied;
    /// otherwise the serve degrades to pure analytic.
    Hybrid {
        /// Replay stride: requests at stream positions `0, k, 2k, …`
        /// are spot-checked.
        check_every: usize,
    },
}

impl EngineMode {
    /// Engine kind the pool builds for this mode.
    pub fn serving_kind(self) -> EngineKind {
        match self {
            EngineMode::Functional => EngineKind::Functional,
            EngineMode::Analytic | EngineMode::Hybrid { .. } => EngineKind::Analytic,
        }
    }

    /// Whether completions carry bit-accurate outputs.
    pub fn bit_accurate(self) -> bool {
        matches!(self, EngineMode::Functional)
    }

    /// Human/CLI label.
    pub fn label(self) -> &'static str {
        match self {
            EngineMode::Functional => "functional",
            EngineMode::Analytic => "analytic",
            EngineMode::Hybrid { .. } => "hybrid",
        }
    }
}

/// Configuration of the serving runtime.
#[derive(Debug, Clone, Copy)]
pub struct ServeConfig {
    /// Simulated PIM chips (each a full weight replica with its own
    /// engine).
    pub chips: usize,
    /// Batch size target: a batch flushes as soon as it holds this many
    /// requests.
    pub max_batch: usize,
    /// Batching deadline in simulated microseconds: no request waits
    /// longer than this in the batcher.
    pub deadline_us: f64,
    /// Per-chip queue capacity in batches (waiting + in service). A
    /// flush into a full queue stalls — backpressure.
    pub queue_depth: usize,
    /// Simulated inter-arrival gap of the request stream (ns); `0.0`
    /// models a closed burst where everything arrives at once.
    pub arrival_interval_ns: f64,
    /// Which engine the pool serves on.
    pub engine: EngineMode,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            chips: 4,
            max_batch: 8,
            deadline_us: 50.0,
            queue_depth: 2,
            arrival_interval_ns: 0.0,
            engine: EngineMode::Functional,
        }
    }
}

impl ServeConfig {
    /// Validate structural invariants.
    pub fn validate(&self) -> Result<(), String> {
        if self.chips == 0 {
            return Err("need at least one chip".into());
        }
        if self.max_batch == 0 {
            return Err("batch size target must be >= 1".into());
        }
        if self.deadline_us.is_nan() || self.deadline_us < 0.0 {
            return Err("deadline must be a non-negative time".into());
        }
        if self.queue_depth == 0 {
            return Err("queue depth must be >= 1".into());
        }
        if self.arrival_interval_ns.is_nan() || self.arrival_interval_ns < 0.0 {
            return Err("arrival interval must be a non-negative time".into());
        }
        if let EngineMode::Hybrid { check_every } = self.engine {
            if check_every == 0 {
                return Err("hybrid check stride must be >= 1".into());
            }
        }
        Ok(())
    }
}

/// Serve `requests` through the batched multi-chip runtime.
///
/// Requests arrive on the simulated clock at `scfg.arrival_interval_ns`
/// spacing (in the given order); the stream drains at the last arrival.
/// With [`EngineMode::Functional`], outputs are bit-exact with
/// [`ref_exec::execute`](crate::cnn::ref_exec::execute) per request,
/// whichever chip serves it, and `params` is required. With
/// [`EngineMode::Analytic`] (or `Hybrid`), any network serves —
/// including the paper's full-size benchmarks — with synthesized
/// per-request stats; `params` is optional and only sets the weight
/// precision (and enables the hybrid functional replay).
///
/// # Panics
/// If `scfg` is invalid, the engine cannot run `net` (functional mode
/// on a network wider than the subarray), a bit-accurate mode is
/// missing `params`, or a network output is empty.
pub fn serve(
    cfg: &ArchConfig,
    scfg: &ServeConfig,
    net: &Network,
    params: Option<&ModelParams>,
    requests: Vec<Request>,
) -> ServeReport {
    scfg.validate().expect("invalid serve config");
    let factory = EngineFactory::new(cfg.clone(), scfg.engine.serving_kind());
    let eplan = factory.plan(net);
    assert!(
        eplan.supported,
        "{} engine cannot serve {}: {}",
        factory.kind().label(),
        net.name,
        eplan.unsupported_reason.as_deref().unwrap_or("unsupported network"),
    );
    if scfg.engine.bit_accurate() {
        assert!(params.is_some(), "functional serving needs model parameters");
    }
    let started = Instant::now();

    // Hybrid: sample every K-th request (by stream position) for the
    // functional replay, before the planner consumes the stream — but
    // only when the replay is actually possible (params supplied and
    // the network fits the bit-accurate path); otherwise skip the
    // clones and degrade to pure analytic.
    let replay_possible = matches!(scfg.engine, EngineMode::Hybrid { .. })
        && params.is_some()
        && EngineFactory::new(cfg.clone(), EngineKind::Functional).plan(net).supported;
    let samples: Vec<(u64, QTensor)> = match scfg.engine {
        EngineMode::Hybrid { check_every } if replay_possible => requests
            .iter()
            .enumerate()
            .filter(|(i, _)| i % check_every == 0)
            .map(|(_, r)| (r.id, r.image.clone()))
            .collect(),
        _ => Vec::new(),
    };

    // Plan: walk the arrival stream through batcher + router on the
    // simulated clock. Deterministic — no execution yet.
    let mut batcher = DynamicBatcher::new(scfg.max_batch, scfg.deadline_us * 1e3);
    let mut router = ShardRouter::new(scfg.chips);
    let mut planned: Vec<PlannedBatch> = Vec::new();
    let mut seq = 0usize;
    let mut last_arrival_ns = 0.0f64;
    for (i, req) in requests.into_iter().enumerate() {
        let t = i as f64 * scfg.arrival_interval_ns;
        last_arrival_ns = t;
        if let Some(f) = batcher.poll(t) {
            planned.push(plan(f, &mut router, &mut seq));
        }
        if let Some(f) = batcher.push(req, t) {
            planned.push(plan(f, &mut router, &mut seq));
        }
    }
    if let Some(f) = batcher.drain(last_arrival_ns) {
        planned.push(plan(f, &mut router, &mut seq));
    }
    let counters = batcher.counters;

    // Execute: one host thread per chip, weight-resident engines.
    let results = pool::execute(&factory, net, params, scfg.chips, planned);

    // Account: schedule each chip's batches behind its bounded queue.
    let timings: Vec<Vec<BatchTiming>> = results
        .iter()
        .map(|r| {
            let flushes: Vec<f64> = r.batches.iter().map(|b| b.flush_ns).collect();
            let services: Vec<f64> = r.batches.iter().map(|b| b.service_ns()).collect();
            pool::timeline(&flushes, &services, scfg.queue_depth)
        })
        .collect();
    let mut report = ServeReport::assemble(
        scfg.engine,
        results,
        timings,
        counters,
        started.elapsed().as_secs_f64(),
    );
    if let (true, Some(params)) = (replay_possible, params) {
        let sc = spot_check(cfg, net, params, &samples, &report);
        report.spot_check = sc;
        report.wall_seconds = started.elapsed().as_secs_f64();
    }
    report
}

/// Route one flushed batch and stamp it with its sequence number.
fn plan(flush: Flush, router: &mut ShardRouter, seq: &mut usize) -> PlannedBatch {
    let work: u64 = flush.requests.iter().map(Request::work_bits).sum();
    let chip = router.route(work);
    let b = PlannedBatch {
        seq: *seq,
        chip,
        cause: flush.cause,
        flush_ns: flush.at_ns,
        requests: flush.requests,
        arrivals_ns: flush.arrivals_ns,
    };
    *seq += 1;
    b
}

/// Replay the sampled requests on a bit-accurate functional engine and
/// fold each replay's functional/analytic stat ratios into a
/// [`SpotCheck`]. The caller has already established that the replay
/// is possible (params supplied, network fits the functional path);
/// returns `None` only for an empty sample.
fn spot_check(
    cfg: &ArchConfig,
    net: &Network,
    params: &ModelParams,
    samples: &[(u64, QTensor)],
    report: &ServeReport,
) -> Option<SpotCheck> {
    if samples.is_empty() {
        return None;
    }
    let mut engine = EngineFactory::new(cfg.clone(), EngineKind::Functional).build();
    engine.make_weights_resident();
    let mut check = SpotCheck::new();
    for (id, image) in samples {
        let replay = engine.execute(net, Some(params), image);
        let analytic = &report
            .completions
            .iter()
            .find(|c| c.id == *id)
            .expect("sampled request completed")
            .stats;
        check.observe(
            replay.stats.total_latency_ns() / analytic.total_latency_ns().max(f64::MIN_POSITIVE),
            replay.stats.total_energy_fj() / analytic.total_energy_fj().max(f64::MIN_POSITIVE),
        );
    }
    Some(check)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::network::small_cnn;
    use crate::cnn::ref_exec;

    fn requests(net: &Network, n: usize, seed: u64) -> Vec<Request> {
        Request::stream(
            (0..n)
                .map(|i| {
                    QTensor::random(
                        net.input.0,
                        net.input.1,
                        net.input.2,
                        net.input_bits,
                        seed + i as u64,
                    )
                })
                .collect(),
        )
    }

    #[test]
    fn serves_bit_exactly_across_chips() {
        let net = small_cnn(3);
        let params = ModelParams::random(&net, 3, 2);
        let reqs = requests(&net, 6, 100);
        let images: Vec<QTensor> = reqs.iter().map(|r| r.image.clone()).collect();
        let scfg = ServeConfig { chips: 3, max_batch: 2, ..ServeConfig::default() };
        let report = serve(&ArchConfig::paper(), &scfg, &net, Some(&params), reqs);
        assert_eq!(report.served(), 6);
        report.verify().expect("aggregation identities");
        for c in &report.completions {
            let golden = ref_exec::execute(&net, &params, &images[c.id as usize]);
            let output = c.output.as_ref().expect("functional mode carries outputs");
            assert_eq!(output, golden.last().unwrap(), "request {}", c.id);
            assert!(c.stats.total_latency_ns() > 0.0);
        }
        // All three chips participated in the closed burst.
        let distinct: std::collections::HashSet<usize> =
            report.completions.iter().map(|c| c.chip).collect();
        assert_eq!(distinct.len(), 3, "expected all chips busy, got {distinct:?}");
        assert!(report.sim_fps() > 0.0);
    }

    #[test]
    fn chip_assignment_is_deterministic_across_runs() {
        let net = small_cnn(2);
        let params = ModelParams::random(&net, 2, 5);
        let scfg = ServeConfig { chips: 2, max_batch: 2, ..ServeConfig::default() };
        let assignment = |seed: u64| {
            let report = serve(
                &ArchConfig::paper(),
                &scfg,
                &net,
                Some(&params),
                requests(&net, 6, seed),
            );
            let mut by_id: Vec<(u64, usize)> =
                report.completions.iter().map(|c| (c.id, c.chip)).collect();
            by_id.sort_unstable();
            by_id
        };
        assert_eq!(assignment(9), assignment(9));
    }

    #[test]
    fn resident_weights_make_big_batches_cheaper_per_request() {
        // One chip, one batch: the weight stream amortises across the
        // batch, so per-request mean energy falls as the batch grows.
        let net = small_cnn(3);
        let params = ModelParams::random(&net, 3, 7);
        let scfg = ServeConfig { chips: 1, max_batch: 16, ..ServeConfig::default() };
        let run = |n: usize| {
            let report = serve(
                &ArchConfig::paper(),
                &scfg,
                &net,
                Some(&params),
                requests(&net, n, 30),
            );
            report.total_energy_mj() / n as f64
        };
        assert!(run(4) < run(1), "batching must amortise the weight stream");
    }

    #[test]
    fn analytic_mode_shares_the_batching_and_routing_laws() {
        // Same stream, same plan: only the engine (and thus the stats
        // fidelity) changes between functional and analytic serves.
        let net = small_cnn(3);
        let params = ModelParams::random(&net, 3, 7);
        let scfg = ServeConfig { chips: 2, max_batch: 2, ..ServeConfig::default() };
        let functional =
            serve(&ArchConfig::paper(), &scfg, &net, Some(&params), requests(&net, 6, 40));
        let acfg = ServeConfig { engine: EngineMode::Analytic, ..scfg };
        let analytic =
            serve(&ArchConfig::paper(), &acfg, &net, Some(&params), requests(&net, 6, 40));
        analytic.verify().expect("analytic identities");
        let routes = |r: &ServeReport| {
            let mut v: Vec<(u64, usize, usize)> =
                r.completions.iter().map(|c| (c.id, c.chip, c.batch)).collect();
            v.sort_unstable();
            v
        };
        assert_eq!(routes(&functional), routes(&analytic), "planning is engine-agnostic");
        assert!(analytic.completions.iter().all(|c| c.output.is_none()));
    }

    #[test]
    fn invalid_configs_are_rejected() {
        assert!(ServeConfig { chips: 0, ..ServeConfig::default() }.validate().is_err());
        assert!(ServeConfig { max_batch: 0, ..ServeConfig::default() }.validate().is_err());
        assert!(ServeConfig { queue_depth: 0, ..ServeConfig::default() }.validate().is_err());
        assert!(
            ServeConfig { deadline_us: f64::NAN, ..ServeConfig::default() }.validate().is_err()
        );
        assert!(ServeConfig {
            engine: EngineMode::Hybrid { check_every: 0 },
            ..ServeConfig::default()
        }
        .validate()
        .is_err());
        assert!(ServeConfig::default().validate().is_ok());
        assert!(ServeConfig {
            engine: EngineMode::Hybrid { check_every: 4 },
            ..ServeConfig::default()
        }
        .validate()
        .is_ok());
    }
}
