//! Shard router: deterministic, cost-aware batch → chip assignment.
//!
//! Each simulated PIM chip holds a full weight replica (data
//! parallelism — the mapping *within* a chip is the paper's Fig. 5
//! scheme and is unchanged here), so any chip can serve any batch and
//! routing is purely a scheduling decision. Chips are no longer assumed
//! identical: a [`CostTable`] carries a per-chip, per-network service
//! estimate in simulated nanoseconds — in practice the analytic
//! engine's cold (weights streamed) and warm (weights resident)
//! per-request latencies, synthesized by
//! [`BatchLaw`](super::laws::BatchLaw) for each chip's own
//! `ArchConfig`. The router tracks each chip's estimated busy horizon
//! and which network its weights currently hold, and assigns every
//! batch to the chip that would *finish it earliest*: a chip already
//! holding the batch's network serves the first request warm, any other
//! chip pays the cold re-stream. Ties break on the lowest chip index.
//!
//! Given the same batch sequence the assignment is identical on every
//! run — no hashing, no randomness — which keeps the whole serving
//! schedule reproducible. Like the batcher, the router is
//! engine-agnostic: estimates come from the closed-form model whatever
//! engine ultimately executes, so functional, analytic and hybrid
//! serves of the same stream produce the same chip assignment. When
//! every chip has the same uniform cost the earliest-finish rule
//! degenerates to the classic deterministic least-loaded policy.

/// Per-chip, per-network service-time estimates (simulated ns per
/// request): `(cold, warm)` — the first request after a network switch
/// pays `cold` (weights streamed over chip I/O), every further request
/// of the same network pays `warm` (weights resident).
#[derive(Debug, Clone)]
pub struct CostTable {
    /// `[chip][net] -> (cold_ns, warm_ns)`.
    cold_warm_ns: Vec<Vec<(f64, f64)>>,
}

impl CostTable {
    /// Table from explicit `[chip][net] -> (cold_ns, warm_ns)` rows.
    ///
    /// # Panics
    /// If there are no chips, no networks, the rows are ragged, or any
    /// estimate is negative/non-finite.
    pub fn new(cold_warm_ns: Vec<Vec<(f64, f64)>>) -> Self {
        assert!(!cold_warm_ns.is_empty(), "need at least one chip");
        let nets = cold_warm_ns[0].len();
        assert!(nets >= 1, "need at least one network");
        for row in &cold_warm_ns {
            assert_eq!(row.len(), nets, "every chip must cost every network");
            for &(cold, warm) in row {
                assert!(
                    cold.is_finite() && warm.is_finite() && cold >= 0.0 && warm >= 0.0,
                    "service estimates must be finite and non-negative"
                );
            }
        }
        Self { cold_warm_ns }
    }

    /// Identical-chip table: every (chip, net) costs `(1, 1)` ns, which
    /// reduces the router to deterministic least-loaded round-robin.
    pub fn uniform(chips: usize, nets: usize) -> Self {
        Self::new(vec![vec![(1.0, 1.0); nets]; chips])
    }

    /// Number of chips costed.
    pub fn chips(&self) -> usize {
        self.cold_warm_ns.len()
    }

    /// Number of networks costed.
    pub fn nets(&self) -> usize {
        self.cold_warm_ns[0].len()
    }

    /// `(cold_ns, warm_ns)` estimate for one request of `net` on `chip`.
    pub fn cost_ns(&self, chip: usize, net: usize) -> (f64, f64) {
        self.cold_warm_ns[chip][net]
    }
}

/// One routing decision: the chosen chip plus the cost estimates the
/// earliest-finish rule minimised, surfaced so the serving runtime can
/// stamp them onto the trace timeline (`route` events carry the chip
/// and its estimated-finish cost).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RouteDecision {
    /// The chip the batch was assigned to.
    pub chip: usize,
    /// Estimated service cost of the batch on that chip (ns),
    /// residency-aware at decision time (before the batch was charged).
    pub cost_ns: f64,
    /// The chip's estimated busy horizon after charging the batch (ns)
    /// — the earliest-finish figure the router minimised.
    pub finish_ns: f64,
}

/// Deterministic earliest-finish router over a (possibly
/// heterogeneous) chip pool.
#[derive(Debug, Clone)]
pub struct ShardRouter {
    costs: CostTable,
    /// Estimated busy horizon per chip (ns of routed service).
    est_busy_ns: Vec<f64>,
    /// Network whose weights each chip is estimated to hold.
    resident_net: Vec<Option<usize>>,
    /// Batches routed to each chip so far.
    routed_batches: Vec<u64>,
    /// Chips taken out of rotation (fault-health failover).
    unhealthy: Vec<bool>,
}

impl ShardRouter {
    /// Router scheduling by `costs`.
    pub fn new(costs: CostTable) -> Self {
        let chips = costs.chips();
        Self {
            costs,
            est_busy_ns: vec![0.0; chips],
            resident_net: vec![None; chips],
            routed_batches: vec![0; chips],
            unhealthy: vec![false; chips],
        }
    }

    /// Router over `chips` identical single-network chips — the legacy
    /// least-loaded behaviour.
    ///
    /// # Panics
    /// If `chips` is 0.
    pub fn identical(chips: usize) -> Self {
        Self::new(CostTable::uniform(chips, 1))
    }

    /// Number of chips.
    pub fn chips(&self) -> usize {
        self.est_busy_ns.len()
    }

    /// Estimated service time of a batch of `requests` requests of
    /// `net` on `chip`, given the chip's current estimated residency:
    /// the first request pays warm iff the chip already holds `net`,
    /// every further request is warm.
    pub fn batch_cost_ns(&self, chip: usize, net: usize, requests: usize) -> f64 {
        if requests == 0 {
            return 0.0;
        }
        let (cold, warm) = self.costs.cost_ns(chip, net);
        let first = if self.resident_net[chip] == Some(net) { warm } else { cold };
        first + (requests as f64 - 1.0) * warm
    }

    /// Route one batch of `requests` requests of network `net`: returns
    /// the chip that would finish it earliest (estimated busy horizon +
    /// batch cost, residency-aware), lowest index winning ties, then
    /// charges the batch to that chip and marks `net` resident there.
    /// Zero-cost batches still advance the horizon by 1 ns so they
    /// cannot pile onto one chip. Chips marked unhealthy are skipped.
    ///
    /// # Panics
    /// If `net` is outside the cost table or no healthy chip remains.
    pub fn route(&mut self, net: usize, requests: usize) -> usize {
        self.route_decision(net, requests).chip
    }

    /// [`Self::route`], also returning the estimates behind the
    /// decision: the batch's residency-aware service cost on the chosen
    /// chip (captured *before* routing mutates the chip's residency)
    /// and the chip's post-charge busy horizon.
    ///
    /// # Panics
    /// If `net` is outside the cost table or no healthy chip remains.
    pub fn route_decision(&mut self, net: usize, requests: usize) -> RouteDecision {
        assert!(net < self.costs.nets(), "network {net} is not in the cost table");
        let chip = (0..self.chips())
            .filter(|&c| !self.unhealthy[c])
            .map(|c| (c, self.est_busy_ns[c] + self.batch_cost_ns(c, net, requests)))
            .min_by(|a, b| a.1.total_cmp(&b.1).then(a.0.cmp(&b.0)))
            .map(|(c, _)| c)
            .expect("at least one healthy chip");
        let cost = self.batch_cost_ns(chip, net, requests);
        self.est_busy_ns[chip] += cost.max(1.0);
        self.resident_net[chip] = Some(net);
        self.routed_batches[chip] += 1;
        RouteDecision { chip, cost_ns: cost, finish_ns: self.est_busy_ns[chip] }
    }

    /// Estimated busy horizon of `chip` (ns of routed service).
    pub fn est_busy_ns(&self, chip: usize) -> f64 {
        self.est_busy_ns[chip]
    }

    /// Batches routed to `chip` so far.
    pub fn routed_batches(&self, chip: usize) -> u64 {
        self.routed_batches[chip]
    }

    /// Take `chip` out of rotation: [`Self::route`] will never pick it
    /// again. Its in-flight batches are the caller's to re-route.
    pub fn mark_unhealthy(&mut self, chip: usize) {
        self.unhealthy[chip] = true;
    }

    /// True when `chip` is still in rotation.
    pub fn is_healthy(&self, chip: usize) -> bool {
        !self.unhealthy[chip]
    }

    /// Chips still in rotation.
    pub fn healthy_chips(&self) -> usize {
        self.unhealthy.iter().filter(|&&u| !u).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_costs_round_robin_by_index() {
        let mut r = ShardRouter::identical(3);
        let chips: Vec<usize> = (0..6).map(|_| r.route(0, 1)).collect();
        assert_eq!(chips, vec![0, 1, 2, 0, 1, 2]);
        for c in 0..3 {
            assert_eq!(r.routed_batches(c), 2);
        }
    }

    #[test]
    fn cheaper_chip_absorbs_more_batches() {
        // Chip 0 serves a request in 1 ns, chip 1 in 10 ns: earliest
        // finish keeps feeding chip 0 until its backlog exceeds one
        // batch on chip 1.
        let mut r = ShardRouter::new(CostTable::new(vec![vec![(1.0, 1.0)], vec![(10.0, 10.0)]]));
        for _ in 0..22 {
            r.route(0, 1);
        }
        assert!(
            r.routed_batches(0) > r.routed_batches(1),
            "fast chip must absorb more: {} vs {}",
            r.routed_batches(0),
            r.routed_batches(1)
        );
        assert!(r.routed_batches(1) >= 1, "slow chip still participates");
        assert_eq!(r.routed_batches(0) + r.routed_batches(1), 22);
    }

    #[test]
    fn network_switch_pays_the_cold_restream() {
        // One chip, two networks: the first batch of a network is cold,
        // a repeat is warm, and switching away evicts.
        let mut r = ShardRouter::new(CostTable::new(vec![vec![(100.0, 10.0), (80.0, 8.0)]]));
        assert_eq!(r.batch_cost_ns(0, 0, 1), 100.0, "cold before first route");
        r.route(0, 1);
        assert_eq!(r.batch_cost_ns(0, 0, 1), 10.0, "warm repeat");
        assert_eq!(r.batch_cost_ns(0, 0, 4), 40.0, "whole batch warm");
        r.route(1, 1);
        assert_eq!(r.batch_cost_ns(0, 0, 1), 100.0, "switch evicted net 0");
        assert_eq!(r.batch_cost_ns(0, 1, 2), 16.0, "net 1 now resident");
    }

    #[test]
    fn route_decision_reports_pre_charge_cost_and_post_charge_horizon() {
        let mut r = ShardRouter::new(CostTable::new(vec![vec![(100.0, 10.0)]]));
        let d = r.route_decision(0, 2);
        assert_eq!(d.chip, 0);
        assert_eq!(d.cost_ns, 110.0, "first request cold, second warm");
        assert_eq!(d.finish_ns, 110.0, "horizon starts at the batch cost");
        let d = r.route_decision(0, 2);
        assert_eq!(d.cost_ns, 20.0, "now resident: whole batch warm");
        assert_eq!(d.finish_ns, 130.0);
    }

    #[test]
    fn residency_awareness_keeps_networks_sticky() {
        // Two identical chips, two networks with a heavy cold
        // re-stream: alternating nets should settle one net per chip
        // instead of thrashing both residencies.
        let table = CostTable::new(vec![vec![(1000.0, 10.0); 2]; 2]);
        let mut r = ShardRouter::new(table);
        let routes: Vec<usize> = (0..8).map(|i| r.route(i % 2, 1)).collect();
        assert_eq!(routes[0], 0, "net 0 lands on chip 0");
        assert_eq!(routes[1], 1, "net 1 avoids chip 0's re-stream");
        for (i, &chip) in routes.iter().enumerate() {
            assert_eq!(chip, i % 2, "route {i} thrashes residency: {routes:?}");
        }
    }

    #[test]
    fn assignment_is_deterministic() {
        let table = || {
            CostTable::new(vec![
                vec![(700.0, 70.0), (300.0, 30.0)],
                vec![(900.0, 90.0), (100.0, 10.0)],
                vec![(400.0, 40.0), (400.0, 40.0)],
            ])
        };
        let stream = [(0usize, 3usize), (1, 1), (0, 2), (1, 8), (0, 1), (1, 2), (0, 4)];
        let run = || {
            let mut r = ShardRouter::new(table());
            stream.iter().map(|&(net, n)| r.route(net, n)).collect::<Vec<_>>()
        };
        assert_eq!(run(), run(), "same inputs, same assignment");
    }

    #[test]
    fn zero_cost_batches_still_advance_the_router() {
        let mut r = ShardRouter::new(CostTable::new(vec![vec![(0.0, 0.0)]; 2]));
        assert_eq!(r.route(0, 1), 0);
        assert_eq!(r.route(0, 1), 1, "zero-cost batches must not pile on one chip");
    }

    #[test]
    fn unhealthy_chips_are_skipped() {
        let mut r = ShardRouter::identical(3);
        assert_eq!(r.healthy_chips(), 3);
        r.mark_unhealthy(0);
        assert!(!r.is_healthy(0));
        assert_eq!(r.healthy_chips(), 2);
        let chips: Vec<usize> = (0..4).map(|_| r.route(0, 1)).collect();
        assert_eq!(chips, vec![1, 2, 1, 2], "chip 0 must never be picked again");
    }

    #[test]
    fn failover_prefers_the_cheapest_survivor() {
        // Chip 0 is the clear earliest finisher until it is marked
        // unhealthy; routing then falls over to the next-cheapest chip.
        let mut r = ShardRouter::new(CostTable::new(vec![
            vec![(1.0, 1.0)],
            vec![(5.0, 5.0)],
            vec![(50.0, 50.0)],
        ]));
        assert_eq!(r.route(0, 1), 0);
        r.mark_unhealthy(0);
        assert_eq!(r.route(0, 1), 1, "survivors compete on cost as before");
        assert_eq!(r.route(0, 1), 1);
    }
}
