//! Shard router: deterministic batch → chip assignment.
//!
//! Each simulated PIM chip holds a full weight replica (data
//! parallelism — the mapping *within* a chip is the paper's Fig. 5
//! scheme and is unchanged here), so any chip can serve any batch and
//! routing is purely a load-balancing decision. The router assigns each
//! batch to the chip with the least total routed work so far, breaking
//! ties on the lowest chip index. Given the same batch sequence the
//! assignment is identical on every run — no hashing, no randomness —
//! which keeps the whole serving schedule reproducible. Like the
//! batcher, the router is engine-agnostic: it routes on request work
//! bits alone, so functional, analytic and hybrid serves of the same
//! stream produce the same chip assignment.

/// Deterministic least-loaded router over `chips` identical chips.
#[derive(Debug, Clone)]
pub struct ShardRouter {
    /// Total work (weight units) routed to each chip so far.
    routed_work: Vec<u64>,
    /// Batches routed to each chip so far.
    routed_batches: Vec<u64>,
}

impl ShardRouter {
    /// Router over `chips` chips.
    ///
    /// # Panics
    /// If `chips` is 0.
    pub fn new(chips: usize) -> Self {
        assert!(chips >= 1, "need at least one chip");
        Self { routed_work: vec![0; chips], routed_batches: vec![0; chips] }
    }

    /// Number of chips.
    pub fn chips(&self) -> usize {
        self.routed_work.len()
    }

    /// Route one batch of `work` units (e.g. total input bits): returns
    /// the chip index with the least routed work, lowest index winning
    /// ties, and charges the work to it.
    pub fn route(&mut self, work: u64) -> usize {
        let chip = self
            .routed_work
            .iter()
            .enumerate()
            .min_by_key(|&(i, &w)| (w, i))
            .map(|(i, _)| i)
            .expect("at least one chip");
        self.routed_work[chip] += work.max(1);
        self.routed_batches[chip] += 1;
        chip
    }

    /// Total work routed to `chip` so far.
    pub fn routed_work(&self, chip: usize) -> u64 {
        self.routed_work[chip]
    }

    /// Batches routed to `chip` so far.
    pub fn routed_batches(&self, chip: usize) -> u64 {
        self.routed_batches[chip]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn equal_work_round_robins_by_index() {
        let mut r = ShardRouter::new(3);
        let chips: Vec<usize> = (0..6).map(|_| r.route(10)).collect();
        assert_eq!(chips, vec![0, 1, 2, 0, 1, 2]);
        for c in 0..3 {
            assert_eq!(r.routed_work(c), 20);
            assert_eq!(r.routed_batches(c), 2);
        }
    }

    #[test]
    fn unequal_work_balances_toward_lightest_chip() {
        let mut r = ShardRouter::new(2);
        assert_eq!(r.route(100), 0);
        // Chip 1 is lightest until it has absorbed 100 units.
        assert_eq!(r.route(30), 1);
        assert_eq!(r.route(30), 1);
        assert_eq!(r.route(30), 1);
        // Now 100 vs 90 → chip 1 again, then chip 0.
        assert_eq!(r.route(30), 1);
        assert_eq!(r.route(1), 0);
    }

    #[test]
    fn assignment_is_deterministic() {
        let works = [7u64, 3, 3, 9, 1, 1, 4, 8, 2, 6];
        let run = || {
            let mut r = ShardRouter::new(4);
            works.iter().map(|&w| r.route(w)).collect::<Vec<_>>()
        };
        assert_eq!(run(), run(), "same inputs, same assignment");
    }

    #[test]
    fn zero_work_batches_still_advance_the_router() {
        let mut r = ShardRouter::new(2);
        assert_eq!(r.route(0), 0);
        assert_eq!(r.route(0), 1, "zero-work batches must not pile on one chip");
    }
}
