//! Dynamic batcher: size- and deadline-triggered request batching on the
//! simulated clock.
//!
//! Requests accumulate until either the batch reaches the configured
//! size target (*size flush*) or the **oldest** pending request has
//! waited out the batching deadline (*deadline flush*) — the standard
//! serving trade-off between throughput (big batches amortise the
//! per-batch weight-residency warm-up and chip hand-off) and tail
//! latency (no request waits longer than the deadline just to fill a
//! batch). A final *drain flush* empties the batcher at end-of-stream.
//!
//! The batcher is a pure state machine over simulated nanoseconds — no
//! threads, no host clock — so every trigger path is unit-testable and
//! the whole serving schedule stays deterministic. It is also fully
//! engine-agnostic: batching sees only requests and the simulated
//! clock, never the
//! [`InferenceEngine`](crate::coordinator::engine::InferenceEngine)
//! that will execute them, so the same schedule drives functional,
//! analytic and hybrid serves.

use crate::arch::stats::QueueCounters;

use super::Request;

/// Why a batch left the batcher.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FlushCause {
    /// The batch reached the size target.
    Size,
    /// The oldest pending request hit the batching deadline.
    Deadline,
    /// End-of-stream drain.
    Drain,
}

impl FlushCause {
    /// Stable lower-case label for traces and exports.
    pub fn label(self) -> &'static str {
        match self {
            FlushCause::Size => "size",
            FlushCause::Deadline => "deadline",
            FlushCause::Drain => "drain",
        }
    }
}

/// One emitted batch: the requests plus their arrival times.
#[derive(Debug)]
pub struct Flush {
    /// What triggered the flush.
    pub cause: FlushCause,
    /// Simulated time the batch left the batcher (ns).
    pub at_ns: f64,
    /// The batched requests, in arrival order.
    pub requests: Vec<Request>,
    /// Arrival time of each request (ns), parallel to `requests`.
    pub arrivals_ns: Vec<f64>,
}

impl Flush {
    /// Number of requests in the batch.
    pub fn len(&self) -> usize {
        self.requests.len()
    }

    /// True when the batch is empty (never emitted by the batcher).
    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }
}

/// The dynamic batcher.
#[derive(Debug)]
pub struct DynamicBatcher {
    max_batch: usize,
    deadline_ns: f64,
    pending: Vec<(Request, f64)>,
    /// Queue / flush counters.
    pub counters: QueueCounters,
}

impl DynamicBatcher {
    /// Batcher with a size target of `max_batch` requests and a batching
    /// deadline of `deadline_ns` simulated nanoseconds.
    ///
    /// # Panics
    /// If `max_batch` is 0 or `deadline_ns` is negative/NaN.
    pub fn new(max_batch: usize, deadline_ns: f64) -> Self {
        assert!(max_batch >= 1, "batch size target must be >= 1");
        assert!(deadline_ns >= 0.0, "deadline must be a non-negative time");
        Self { max_batch, deadline_ns, pending: Vec::new(), counters: QueueCounters::default() }
    }

    /// Requests currently waiting.
    pub fn pending(&self) -> usize {
        self.pending.len()
    }

    /// The batching deadline this batcher flushes on (ns).
    pub fn deadline_ns(&self) -> f64 {
        self.deadline_ns
    }

    /// Arrival time of the oldest pending request, if any (ns).
    pub fn oldest_arrival_ns(&self) -> Option<f64> {
        self.pending.first().map(|&(_, t)| t)
    }

    /// Accept a request arriving at `now_ns`. Returns the flushed batch
    /// when this arrival fills it to the size target.
    ///
    /// Callers should [`poll`](Self::poll) at (or before) `now_ns` first
    /// so an overdue deadline flush is emitted ahead of the new arrival.
    pub fn push(&mut self, req: Request, now_ns: f64) -> Option<Flush> {
        self.pending.push((req, now_ns));
        self.counters.enqueued += 1;
        self.counters.max_queue_depth = self.counters.max_queue_depth.max(self.pending.len());
        if self.pending.len() >= self.max_batch {
            return Some(self.flush(FlushCause::Size, now_ns));
        }
        None
    }

    /// Fire the deadline timer: if the oldest pending request has waited
    /// `deadline_ns` by `now_ns`, flush. The emitted batch is stamped
    /// with the exact deadline expiry, not `now_ns`, so accounting is
    /// independent of how sparsely the clock is polled.
    pub fn poll(&mut self, now_ns: f64) -> Option<Flush> {
        let due = self.oldest_arrival_ns()? + self.deadline_ns;
        if due <= now_ns {
            return Some(self.flush(FlushCause::Deadline, due));
        }
        None
    }

    /// End-of-stream: flush whatever is pending at `now_ns`.
    pub fn drain(&mut self, now_ns: f64) -> Option<Flush> {
        if self.pending.is_empty() {
            return None;
        }
        Some(self.flush(FlushCause::Drain, now_ns))
    }

    fn flush(&mut self, cause: FlushCause, at_ns: f64) -> Flush {
        let (requests, arrivals_ns) = std::mem::take(&mut self.pending).into_iter().unzip();
        let f = Flush { cause, at_ns, requests, arrivals_ns };
        self.counters.batches += 1;
        self.counters.max_batch = self.counters.max_batch.max(f.len());
        match cause {
            FlushCause::Size => self.counters.size_flushes += 1,
            FlushCause::Deadline => self.counters.deadline_flushes += 1,
            FlushCause::Drain => self.counters.drain_flushes += 1,
        }
        f
    }
}

/// Per-network SLO flush lanes: one [`DynamicBatcher`] per served
/// network, each with its own batching deadline, sharing one size
/// target. Requests land on the lane their [`Request::net`] tag names,
/// so an AlexNet stream batching under a relaxed deadline never delays
/// a latency-critical small-preset stream sharing the pool — the
/// per-network SLO is enforced *by construction*: callers poll every
/// lane before each push (and before the drain), so no request can sit
/// in the batcher past its own lane's deadline on the simulated clock.
///
/// Like the single batcher it wraps, the lane set is a pure state
/// machine over simulated nanoseconds, fully deterministic: due lanes
/// flush in expiry order (ties by lane index) so downstream routing
/// sees one reproducible batch sequence.
#[derive(Debug)]
pub struct SloBatcher {
    lanes: Vec<DynamicBatcher>,
}

impl SloBatcher {
    /// One lane per entry of `lane_deadlines_ns`, all sharing the
    /// `max_batch` size target.
    ///
    /// # Panics
    /// If there are no lanes, `max_batch` is 0, or any deadline is
    /// negative/NaN.
    pub fn new(lane_deadlines_ns: &[f64], max_batch: usize) -> Self {
        assert!(!lane_deadlines_ns.is_empty(), "need at least one network lane");
        Self {
            lanes: lane_deadlines_ns.iter().map(|&d| DynamicBatcher::new(max_batch, d)).collect(),
        }
    }

    /// Number of network lanes.
    pub fn lanes(&self) -> usize {
        self.lanes.len()
    }

    /// Requests currently waiting across all lanes.
    pub fn pending(&self) -> usize {
        self.lanes.iter().map(DynamicBatcher::pending).sum()
    }

    /// Batching deadline of lane `net` (ns).
    pub fn lane_deadline_ns(&self, net: usize) -> f64 {
        self.lanes[net].deadline_ns()
    }

    /// Accept a request arriving at `now_ns` on its network's lane.
    /// Returns `(net, flush)` when the arrival fills that lane to the
    /// size target. Call [`poll`](Self::poll) first, as with the single
    /// batcher.
    ///
    /// # Panics
    /// If the request's `net` tag names no lane.
    pub fn push(&mut self, req: Request, now_ns: f64) -> Option<(usize, Flush)> {
        let net = req.net;
        assert!(net < self.lanes.len(), "request {} tagged with unknown network {net}", req.id);
        self.lanes[net].push(req, now_ns).map(|f| (net, f))
    }

    /// Fire every lane's deadline timer at `now_ns`: all lanes whose
    /// oldest request is due flush, each stamped at its own exact
    /// expiry, emitted in expiry order (ties by lane index).
    pub fn poll(&mut self, now_ns: f64) -> Vec<(usize, Flush)> {
        let mut due: Vec<(f64, usize)> = self
            .lanes
            .iter()
            .enumerate()
            .filter_map(|(i, lane)| {
                lane.oldest_arrival_ns().map(|t| (t + lane.deadline_ns(), i))
            })
            .filter(|&(expiry, _)| expiry <= now_ns)
            .collect();
        due.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        due.into_iter()
            .map(|(_, i)| (i, self.lanes[i].poll(now_ns).expect("due lane flushes")))
            .collect()
    }

    /// End-of-stream: flush every lane's remainder at `now_ns`, in lane
    /// order.
    pub fn drain(&mut self, now_ns: f64) -> Vec<(usize, Flush)> {
        self.lanes
            .iter_mut()
            .enumerate()
            .filter_map(|(i, lane)| lane.drain(now_ns).map(|f| (i, f)))
            .collect()
    }

    /// Fold of the per-lane queue counters: counts sum; the high-water
    /// marks (`max_queue_depth`, `max_batch`) are per-lane maxima.
    pub fn counters(&self) -> QueueCounters {
        let mut total = QueueCounters::default();
        for lane in &self.lanes {
            let c = &lane.counters;
            total.enqueued += c.enqueued;
            total.batches += c.batches;
            total.size_flushes += c.size_flushes;
            total.deadline_flushes += c.deadline_flushes;
            total.drain_flushes += c.drain_flushes;
            total.stalled_batches += c.stalled_batches;
            total.max_queue_depth = total.max_queue_depth.max(c.max_queue_depth);
            total.max_batch = total.max_batch.max(c.max_batch);
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::tensor::QTensor;

    fn req(id: u64) -> Request {
        Request { id, net: 0, image: QTensor::random(1, 4, 6, 2, id) }
    }

    fn req_for(id: u64, net: usize) -> Request {
        Request { id, net, image: QTensor::random(1, 4, 6, 2, id) }
    }

    #[test]
    fn size_trigger_flushes_exactly_at_target() {
        let mut b = DynamicBatcher::new(3, 1e6);
        assert!(b.push(req(0), 0.0).is_none());
        assert!(b.push(req(1), 10.0).is_none());
        let f = b.push(req(2), 20.0).expect("size flush");
        assert_eq!(f.cause, FlushCause::Size);
        assert_eq!(f.len(), 3);
        assert_eq!(f.at_ns, 20.0);
        assert_eq!(f.arrivals_ns, vec![0.0, 10.0, 20.0]);
        assert_eq!(b.pending(), 0);
        assert_eq!(b.counters.size_flushes, 1);
        assert_eq!(b.counters.max_batch, 3);
    }

    #[test]
    fn deadline_trigger_fires_at_exact_expiry() {
        let mut b = DynamicBatcher::new(8, 100.0);
        assert!(b.push(req(0), 50.0).is_none());
        assert!(b.push(req(1), 60.0).is_none());
        // Not yet due.
        assert!(b.poll(149.9).is_none());
        // Polled late: the flush is stamped at the expiry (150), not the
        // poll time (500).
        let f = b.poll(500.0).expect("deadline flush");
        assert_eq!(f.cause, FlushCause::Deadline);
        assert_eq!(f.at_ns, 150.0);
        assert_eq!(f.len(), 2);
        assert_eq!(b.counters.deadline_flushes, 1);
        // Nothing pending → no further deadline flushes.
        assert!(b.poll(1e9).is_none());
    }

    #[test]
    fn drain_empties_the_batcher() {
        let mut b = DynamicBatcher::new(8, 1e6);
        assert!(b.drain(0.0).is_none(), "nothing to drain");
        b.push(req(0), 0.0);
        let f = b.drain(42.0).expect("drain flush");
        assert_eq!(f.cause, FlushCause::Drain);
        assert_eq!(f.at_ns, 42.0);
        assert_eq!(f.len(), 1);
        assert_eq!(b.counters.drain_flushes, 1);
        assert_eq!(b.counters.enqueued, 1);
    }

    #[test]
    fn max_queue_depth_tracks_high_water_mark() {
        let mut b = DynamicBatcher::new(4, 1e6);
        b.push(req(0), 0.0);
        b.push(req(1), 1.0);
        b.push(req(2), 2.0);
        assert_eq!(b.counters.max_queue_depth, 3);
        b.push(req(3), 3.0).expect("size flush");
        b.push(req(4), 4.0);
        assert_eq!(b.counters.max_queue_depth, 4);
        assert_eq!(b.counters.enqueued, 5);
    }

    #[test]
    fn slo_lanes_flush_on_their_own_deadlines() {
        // Lane 0 tolerates 1 ms, lane 1 only 100 ns: a request on each
        // lane at t=0, and by t=500 only lane 1's deadline has expired.
        let mut b = SloBatcher::new(&[1e6, 100.0], 8);
        assert!(b.push(req_for(0, 0), 0.0).is_none());
        assert!(b.push(req_for(1, 1), 0.0).is_none());
        assert_eq!(b.pending(), 2);
        let flushed = b.poll(500.0);
        assert_eq!(flushed.len(), 1);
        let (net, f) = &flushed[0];
        assert_eq!(*net, 1, "only the tight lane is due");
        assert_eq!(f.cause, FlushCause::Deadline);
        assert_eq!(f.at_ns, 100.0, "stamped at the lane's exact expiry");
        assert_eq!(b.pending(), 1, "lane 0 still holds its request");
        // The drain empties the relaxed lane.
        let drained = b.drain(600.0);
        assert_eq!(drained.len(), 1);
        assert_eq!(drained[0].0, 0);
        assert_eq!(drained[0].1.cause, FlushCause::Drain);
    }

    #[test]
    fn slo_lanes_fill_independently() {
        // Size target 2, interleaved arrivals: each lane fills from its
        // own requests only.
        let mut b = SloBatcher::new(&[1e6, 1e6], 2);
        assert!(b.push(req_for(0, 0), 0.0).is_none());
        assert!(b.push(req_for(1, 1), 1.0).is_none());
        let (net, f) = b.push(req_for(2, 0), 2.0).expect("lane 0 fills");
        assert_eq!(net, 0);
        assert_eq!(f.cause, FlushCause::Size);
        assert_eq!(f.requests.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 2]);
        let (net, f) = b.push(req_for(3, 1), 3.0).expect("lane 1 fills");
        assert_eq!(net, 1);
        assert_eq!(f.requests.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 3]);
    }

    #[test]
    fn slo_poll_emits_due_lanes_in_expiry_order() {
        // Lane 1 (50 ns from t=0) expires before lane 0 (100 ns from
        // t=0); polled late, both flush, earliest expiry first.
        let mut b = SloBatcher::new(&[100.0, 50.0], 8);
        b.push(req_for(0, 0), 0.0);
        b.push(req_for(1, 1), 0.0);
        let flushed = b.poll(1e6);
        let order: Vec<(usize, f64)> = flushed.iter().map(|(n, f)| (*n, f.at_ns)).collect();
        assert_eq!(order, vec![(1, 50.0), (0, 100.0)]);
    }

    #[test]
    fn slo_counters_fold_across_lanes() {
        let mut b = SloBatcher::new(&[1e6, 1e6], 2);
        b.push(req_for(0, 0), 0.0);
        b.push(req_for(1, 0), 1.0);
        b.push(req_for(2, 1), 2.0);
        b.drain(10.0);
        let c = b.counters();
        assert_eq!(c.enqueued, 3);
        assert_eq!(c.batches, 2);
        assert_eq!(c.size_flushes, 1);
        assert_eq!(c.drain_flushes, 1);
        assert_eq!(c.max_batch, 2, "per-lane maximum, not a sum");
    }

    #[test]
    #[should_panic(expected = "unknown network")]
    fn slo_rejects_requests_for_unknown_lanes() {
        let mut b = SloBatcher::new(&[1e6], 8);
        b.push(req_for(0, 1), 0.0);
    }
}
