//! Dynamic batcher: size- and deadline-triggered request batching on the
//! simulated clock.
//!
//! Requests accumulate until either the batch reaches the configured
//! size target (*size flush*) or the **oldest** pending request has
//! waited out the batching deadline (*deadline flush*) — the standard
//! serving trade-off between throughput (big batches amortise the
//! per-batch weight-residency warm-up and chip hand-off) and tail
//! latency (no request waits longer than the deadline just to fill a
//! batch). A final *drain flush* empties the batcher at end-of-stream.
//!
//! The batcher is a pure state machine over simulated nanoseconds — no
//! threads, no host clock — so every trigger path is unit-testable and
//! the whole serving schedule stays deterministic. It is also fully
//! engine-agnostic: batching sees only requests and the simulated
//! clock, never the
//! [`InferenceEngine`](crate::coordinator::engine::InferenceEngine)
//! that will execute them, so the same schedule drives functional,
//! analytic and hybrid serves.

use crate::arch::stats::QueueCounters;

use super::Request;

/// Why a batch left the batcher.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FlushCause {
    /// The batch reached the size target.
    Size,
    /// The oldest pending request hit the batching deadline.
    Deadline,
    /// End-of-stream drain.
    Drain,
}

/// One emitted batch: the requests plus their arrival times.
#[derive(Debug)]
pub struct Flush {
    /// What triggered the flush.
    pub cause: FlushCause,
    /// Simulated time the batch left the batcher (ns).
    pub at_ns: f64,
    /// The batched requests, in arrival order.
    pub requests: Vec<Request>,
    /// Arrival time of each request (ns), parallel to `requests`.
    pub arrivals_ns: Vec<f64>,
}

impl Flush {
    /// Number of requests in the batch.
    pub fn len(&self) -> usize {
        self.requests.len()
    }

    /// True when the batch is empty (never emitted by the batcher).
    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }
}

/// The dynamic batcher.
#[derive(Debug)]
pub struct DynamicBatcher {
    max_batch: usize,
    deadline_ns: f64,
    pending: Vec<(Request, f64)>,
    /// Queue / flush counters.
    pub counters: QueueCounters,
}

impl DynamicBatcher {
    /// Batcher with a size target of `max_batch` requests and a batching
    /// deadline of `deadline_ns` simulated nanoseconds.
    ///
    /// # Panics
    /// If `max_batch` is 0 or `deadline_ns` is negative/NaN.
    pub fn new(max_batch: usize, deadline_ns: f64) -> Self {
        assert!(max_batch >= 1, "batch size target must be >= 1");
        assert!(deadline_ns >= 0.0, "deadline must be a non-negative time");
        Self { max_batch, deadline_ns, pending: Vec::new(), counters: QueueCounters::default() }
    }

    /// Requests currently waiting.
    pub fn pending(&self) -> usize {
        self.pending.len()
    }

    /// Arrival time of the oldest pending request, if any (ns).
    pub fn oldest_arrival_ns(&self) -> Option<f64> {
        self.pending.first().map(|&(_, t)| t)
    }

    /// Accept a request arriving at `now_ns`. Returns the flushed batch
    /// when this arrival fills it to the size target.
    ///
    /// Callers should [`poll`](Self::poll) at (or before) `now_ns` first
    /// so an overdue deadline flush is emitted ahead of the new arrival.
    pub fn push(&mut self, req: Request, now_ns: f64) -> Option<Flush> {
        self.pending.push((req, now_ns));
        self.counters.enqueued += 1;
        self.counters.max_queue_depth = self.counters.max_queue_depth.max(self.pending.len());
        if self.pending.len() >= self.max_batch {
            return Some(self.flush(FlushCause::Size, now_ns));
        }
        None
    }

    /// Fire the deadline timer: if the oldest pending request has waited
    /// `deadline_ns` by `now_ns`, flush. The emitted batch is stamped
    /// with the exact deadline expiry, not `now_ns`, so accounting is
    /// independent of how sparsely the clock is polled.
    pub fn poll(&mut self, now_ns: f64) -> Option<Flush> {
        let due = self.oldest_arrival_ns()? + self.deadline_ns;
        if due <= now_ns {
            return Some(self.flush(FlushCause::Deadline, due));
        }
        None
    }

    /// End-of-stream: flush whatever is pending at `now_ns`.
    pub fn drain(&mut self, now_ns: f64) -> Option<Flush> {
        if self.pending.is_empty() {
            return None;
        }
        Some(self.flush(FlushCause::Drain, now_ns))
    }

    fn flush(&mut self, cause: FlushCause, at_ns: f64) -> Flush {
        let (requests, arrivals_ns) = std::mem::take(&mut self.pending).into_iter().unzip();
        let f = Flush { cause, at_ns, requests, arrivals_ns };
        self.counters.batches += 1;
        self.counters.max_batch = self.counters.max_batch.max(f.len());
        match cause {
            FlushCause::Size => self.counters.size_flushes += 1,
            FlushCause::Deadline => self.counters.deadline_flushes += 1,
            FlushCause::Drain => self.counters.drain_flushes += 1,
        }
        f
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::tensor::QTensor;

    fn req(id: u64) -> Request {
        Request { id, image: QTensor::random(1, 4, 6, 2, id) }
    }

    #[test]
    fn size_trigger_flushes_exactly_at_target() {
        let mut b = DynamicBatcher::new(3, 1e6);
        assert!(b.push(req(0), 0.0).is_none());
        assert!(b.push(req(1), 10.0).is_none());
        let f = b.push(req(2), 20.0).expect("size flush");
        assert_eq!(f.cause, FlushCause::Size);
        assert_eq!(f.len(), 3);
        assert_eq!(f.at_ns, 20.0);
        assert_eq!(f.arrivals_ns, vec![0.0, 10.0, 20.0]);
        assert_eq!(b.pending(), 0);
        assert_eq!(b.counters.size_flushes, 1);
        assert_eq!(b.counters.max_batch, 3);
    }

    #[test]
    fn deadline_trigger_fires_at_exact_expiry() {
        let mut b = DynamicBatcher::new(8, 100.0);
        assert!(b.push(req(0), 50.0).is_none());
        assert!(b.push(req(1), 60.0).is_none());
        // Not yet due.
        assert!(b.poll(149.9).is_none());
        // Polled late: the flush is stamped at the expiry (150), not the
        // poll time (500).
        let f = b.poll(500.0).expect("deadline flush");
        assert_eq!(f.cause, FlushCause::Deadline);
        assert_eq!(f.at_ns, 150.0);
        assert_eq!(f.len(), 2);
        assert_eq!(b.counters.deadline_flushes, 1);
        // Nothing pending → no further deadline flushes.
        assert!(b.poll(1e9).is_none());
    }

    #[test]
    fn drain_empties_the_batcher() {
        let mut b = DynamicBatcher::new(8, 1e6);
        assert!(b.drain(0.0).is_none(), "nothing to drain");
        b.push(req(0), 0.0);
        let f = b.drain(42.0).expect("drain flush");
        assert_eq!(f.cause, FlushCause::Drain);
        assert_eq!(f.at_ns, 42.0);
        assert_eq!(f.len(), 1);
        assert_eq!(b.counters.drain_flushes, 1);
        assert_eq!(b.counters.enqueued, 1);
    }

    #[test]
    fn max_queue_depth_tracks_high_water_mark() {
        let mut b = DynamicBatcher::new(4, 1e6);
        b.push(req(0), 0.0);
        b.push(req(1), 1.0);
        b.push(req(2), 2.0);
        assert_eq!(b.counters.max_queue_depth, 3);
        b.push(req(3), 3.0).expect("size flush");
        b.push(req(4), 4.0);
        assert_eq!(b.counters.max_queue_depth, 4);
        assert_eq!(b.counters.enqueued, 5);
    }
}
