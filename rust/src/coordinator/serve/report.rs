//! Serving reports: per-request completions, per-chip accounts and the
//! aggregate view, with self-checking aggregation identities.
//!
//! All times are simulated nanoseconds on the accelerator clock (the
//! same unit [`Stats`] uses); `wall_seconds` is the only host-side
//! number. The cardinal rule is that every aggregate is a fold of the
//! per-request records — [`ServeReport::verify`] re-derives the totals
//! and fails loudly if any roll-up drifted from its parts. The report
//! also records which [`EngineMode`] produced it: bit-accurate runs
//! carry per-request outputs, synthesized (analytic/hybrid) runs carry
//! stats only, and `verify` checks the fidelity bookkeeping matches.

use std::fmt;

use crate::arch::stats::{FaultLedger, QueueCounters, Stats};
use crate::cnn::ref_exec::WideTensor;
use crate::trace::{LayerCostProfile, MetricsRegistry, Trace};

use super::pool::{BatchTiming, ChipResult};
use super::EngineMode;

/// One completed request.
#[derive(Debug)]
pub struct Completion {
    /// Request id.
    pub id: u64,
    /// Network the request targeted (index into the serve's network
    /// slice and into [`ServeReport::networks`]).
    pub net: usize,
    /// Chip that served the request.
    pub chip: usize,
    /// Global sequence number of the batch it rode in.
    pub batch: usize,
    /// Final network output (bit-accurate engines); `None` when the
    /// engine synthesizes stats only.
    pub output: Option<WideTensor>,
    /// Simulated PIM cost of this request alone.
    pub stats: Stats,
    /// Simulated arrival time (ns).
    pub arrival_ns: f64,
    /// When the batcher flushed the request's batch (ns) — the moment
    /// its SLO lane released it toward a chip.
    pub flush_ns: f64,
    /// When its chip started executing it (ns).
    pub start_ns: f64,
    /// When its chip finished it (ns).
    pub finish_ns: f64,
}

impl Completion {
    /// Time spent waiting (batcher + chip queue) before execution (ns).
    pub fn queue_wait_ns(&self) -> f64 {
        self.start_ns - self.arrival_ns
    }

    /// Time spent in the batcher's SLO lane before the flush (ns) —
    /// the wait the per-network deadline bounds.
    pub fn batcher_wait_ns(&self) -> f64 {
        self.flush_ns - self.arrival_ns
    }

    /// End-to-end simulated latency: arrival → finish (ns).
    pub fn latency_ns(&self) -> f64 {
        self.finish_ns - self.arrival_ns
    }

    /// Pure execution (service) time on the chip (ns).
    pub fn service_ns(&self) -> f64 {
        self.finish_ns - self.start_ns
    }
}

/// True when a batcher wait of `wait_ns` breaks a lane deadline of
/// `deadline_ns` — shared by [`ServeReport::assemble`] and
/// [`ServeReport::verify`] so the roll-up and its re-derivation cannot
/// disagree. The epsilon absorbs float noise in the flush stamp.
fn breaks_deadline(wait_ns: f64, deadline_ns: f64) -> bool {
    wait_ns > deadline_ns + 1e-6
}

/// Identity of one served network, supplied by the serve runtime when
/// it assembles the report.
#[derive(Debug, Clone)]
pub(super) struct NetworkMeta {
    /// Display name of the network.
    pub(super) name: String,
    /// The network's SLO-lane flush deadline (ns).
    pub(super) deadline_ns: f64,
}

/// Per-network account: the roll-up the SLO scheduler is judged by.
#[derive(Debug)]
pub struct NetworkReport {
    /// Network index (into the serve's network slice).
    pub net: usize,
    /// Display name of the network.
    pub name: String,
    /// The network's SLO-lane flush deadline (ns).
    pub deadline_ns: f64,
    /// Requests served for this network.
    pub served: u64,
    /// Serial merge of the network's per-request stats.
    pub stats: Stats,
    /// Total batcher (SLO-lane) wait accumulated by this network's
    /// requests (ns).
    pub batcher_wait_ns: f64,
    /// Largest batcher wait any of this network's requests saw (ns).
    pub max_batcher_wait_ns: f64,
    /// Requests whose batcher wait broke the lane deadline. The
    /// batcher flushes lanes at their exact expiry, so this is 0 by
    /// construction — a non-zero count means the scheduler regressed.
    pub deadline_violations: u64,
    /// Sum of end-to-end latencies (ns) — mean = sum / served.
    pub latency_sum_ns: f64,
    /// p95 end-to-end simulated latency (ns; 0 when nothing served).
    pub p95_latency_ns: f64,
}

impl NetworkReport {
    /// Mean end-to-end simulated latency (ms; 0 when nothing served).
    pub fn mean_latency_ms(&self) -> f64 {
        if self.served == 0 {
            0.0
        } else {
            self.latency_sum_ns / self.served as f64 * 1e-6
        }
    }
}

/// Per-chip account.
#[derive(Debug)]
pub struct ChipReport {
    /// Chip index.
    pub chip: usize,
    /// Requests served.
    pub served: u64,
    /// Batches executed.
    pub batches: u64,
    /// Batches that stalled on this chip's full queue (backpressure).
    pub stalled_batches: u64,
    /// False when the failover loop took this chip out of rotation
    /// (its injected-fault rate tripped the health threshold).
    pub healthy: bool,
    /// Serial merge of the chip's per-request stats.
    pub stats: Stats,
    /// Total execution time (ns) — the chip's busy time.
    pub busy_ns: f64,
    /// When the chip finished its last batch (ns; 0 when idle all run).
    pub finish_ns: f64,
    /// Total queue wait accumulated by this chip's requests (ns).
    pub queue_wait_ns: f64,
    /// Weight-residency hits on this chip's engine.
    pub weight_hits: u64,
    /// Weight-residency misses (weight streams) on this chip's engine.
    pub weight_misses: u64,
    /// Per-conv-layer host wall-time profile accumulated across the
    /// chip's whole request stream (bit-accurate engines only;
    /// wall-clock diagnostics, not simulated cost — `serve --verbose`
    /// prints it).
    pub host_profile: Option<Vec<crate::coordinator::functional::HostLayerProfile>>,
    /// Per-network **simulated** layer cost profiles (latency / energy
    /// / op-mix per node), folded across this chip's stream in arrival
    /// order. Recorded only when the serve traces
    /// ([`ServeConfig::trace`](super::ServeConfig::trace)).
    pub layer_costs: Option<Vec<LayerCostProfile>>,
}

impl ChipReport {
    /// Fraction of the run's makespan this chip spent executing.
    pub fn utilisation(&self, makespan_ns: f64) -> f64 {
        if makespan_ns > 0.0 {
            self.busy_ns / makespan_ns
        } else {
            0.0
        }
    }
}

/// Hybrid-mode functional spot-check: sampled requests replayed on a
/// bit-accurate engine, with the observed functional/analytic stat
/// ratios. Both engines draw every cost from the one `DeviceCosts`
/// table, but the analytic model folds in mapping-level parallelism
/// that the serial functional simulation does not — so this is an
/// order-of-magnitude plausibility band ([`SpotCheck::TOLERANCE`]),
/// not an equality check.
#[derive(Debug, Clone, Copy)]
pub struct SpotCheck {
    /// Requests replayed on the functional engine.
    pub checked: u64,
    /// (min, max) functional/analytic total-latency ratio observed.
    pub latency_ratio: (f64, f64),
    /// (min, max) functional/analytic total-energy ratio observed.
    pub energy_ratio: (f64, f64),
}

impl SpotCheck {
    /// Plausibility band every observed ratio must stay inside.
    pub const TOLERANCE: (f64, f64) = (1e-3, 1e3);

    /// Empty check (nothing observed yet).
    pub fn new() -> Self {
        Self {
            checked: 0,
            latency_ratio: (f64::INFINITY, 0.0),
            energy_ratio: (f64::INFINITY, 0.0),
        }
    }

    /// Fold one replay's ratios in.
    pub fn observe(&mut self, latency_ratio: f64, energy_ratio: f64) {
        self.checked += 1;
        self.latency_ratio = (
            self.latency_ratio.0.min(latency_ratio),
            self.latency_ratio.1.max(latency_ratio),
        );
        self.energy_ratio =
            (self.energy_ratio.0.min(energy_ratio), self.energy_ratio.1.max(energy_ratio));
    }

    /// Fold another check's observations in (count sum, band union).
    pub fn absorb(&mut self, other: &SpotCheck) {
        if other.checked == 0 {
            return;
        }
        self.checked += other.checked;
        self.latency_ratio = (
            self.latency_ratio.0.min(other.latency_ratio.0),
            self.latency_ratio.1.max(other.latency_ratio.1),
        );
        self.energy_ratio = (
            self.energy_ratio.0.min(other.energy_ratio.0),
            self.energy_ratio.1.max(other.energy_ratio.1),
        );
    }

    /// True when every observed ratio sits inside [`Self::TOLERANCE`]
    /// (vacuously true when nothing was checked).
    pub fn passed(&self) -> bool {
        let inside =
            |(lo, hi): (f64, f64)| lo >= Self::TOLERANCE.0 && hi <= Self::TOLERANCE.1;
        self.checked == 0 || (inside(self.latency_ratio) && inside(self.energy_ratio))
    }
}

impl Default for SpotCheck {
    fn default() -> Self {
        Self::new()
    }
}

/// Fault-injection and failover account of one serving run. The
/// `ledger` is the fold of every completion's fault counters (an exact
/// integer identity [`ServeReport::verify`] re-derives); the failover
/// fields are filled by the serve runtime as it reacts to chips whose
/// injected-fault rate trips the health threshold.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultSummary {
    /// True when any chip served under an active fault plan.
    pub active: bool,
    /// Aggregate injected/recovered fault counters across every request.
    pub ledger: FaultLedger,
    /// Extra planning rounds the failover loop ran (0 = no chip tripped).
    pub failover_rounds: u64,
    /// Batches drained off unhealthy chips and re-routed.
    pub failed_over_batches: u64,
    /// Requests riding in those re-routed batches.
    pub failed_over_requests: u64,
    /// Chips the failover loop marked unhealthy.
    pub unhealthy_chips: u64,
    /// True when a hybrid serve escalated its spot-check stride in
    /// response to a failover.
    pub spot_check_escalated: bool,
}

/// Summary of one serving run.
#[derive(Debug)]
pub struct ServeReport {
    /// Engine mode the run served on.
    pub engine: EngineMode,
    /// All completions, ordered by finish time (ties by id).
    pub completions: Vec<Completion>,
    /// Per-chip accounts, ordered by chip index.
    pub chips: Vec<ChipReport>,
    /// Per-network accounts, ordered by network index.
    pub networks: Vec<NetworkReport>,
    /// Batcher / queue counters.
    pub counters: QueueCounters,
    /// Functional spot-check of a hybrid run, when one was possible.
    pub spot_check: Option<SpotCheck>,
    /// Fault-injection / failover account of the run.
    pub faults: FaultSummary,
    /// Deterministic event timeline + metrics snapshot of the run,
    /// recorded when [`ServeConfig::trace`](super::ServeConfig::trace)
    /// is on (`None` otherwise — tracing never perturbs the serve).
    pub trace: Option<Trace>,
    /// Host wall-clock the simulation itself took, seconds.
    pub wall_seconds: f64,
}

impl ServeReport {
    /// Build the report from per-chip execution results and their queue
    /// timelines (`timings[chip]` parallel to `results[chip].batches`);
    /// `nets_meta[net]` names each served network and its lane deadline.
    pub(super) fn assemble(
        engine: EngineMode,
        nets_meta: Vec<NetworkMeta>,
        results: Vec<ChipResult>,
        timings: Vec<Vec<BatchTiming>>,
        counters: QueueCounters,
        wall_seconds: f64,
    ) -> Self {
        let mut completions = Vec::new();
        let mut chips = Vec::with_capacity(results.len());
        let mut counters = counters;
        for (result, chip_timings) in results.into_iter().zip(timings) {
            assert_eq!(result.batches.len(), chip_timings.len());
            let mut report = ChipReport {
                chip: result.chip,
                served: 0,
                batches: 0,
                stalled_batches: 0,
                healthy: true,
                stats: Stats::default(),
                busy_ns: 0.0,
                finish_ns: 0.0,
                queue_wait_ns: 0.0,
                weight_hits: result.weight_hits,
                weight_misses: result.weight_misses,
                host_profile: result.host_profile,
                layer_costs: result.layer_costs,
            };
            for (batch, timing) in result.batches.into_iter().zip(chip_timings) {
                report.batches += 1;
                if timing.stalled {
                    report.stalled_batches += 1;
                    counters.stalled_batches += 1;
                }
                report.finish_ns = report.finish_ns.max(timing.finish_ns);
                // Requests in a batch run serially on the chip.
                let mut cursor_ns = timing.start_ns;
                for (req, arrival_ns) in batch.requests.into_iter().zip(batch.arrivals_ns) {
                    let service = req.stats.total_latency_ns();
                    let completion = Completion {
                        id: req.id,
                        net: batch.net,
                        chip: result.chip,
                        batch: batch.seq,
                        output: req.output,
                        stats: req.stats,
                        arrival_ns,
                        flush_ns: batch.flush_ns,
                        start_ns: cursor_ns,
                        finish_ns: cursor_ns + service,
                    };
                    cursor_ns += service;
                    report.served += 1;
                    report.busy_ns += service;
                    report.queue_wait_ns += completion.queue_wait_ns();
                    report.stats.merge_serial(&completion.stats);
                    completions.push(completion);
                }
            }
            chips.push(report);
        }
        completions.sort_by(|a, b| {
            a.finish_ns.total_cmp(&b.finish_ns).then(a.id.cmp(&b.id))
        });
        let networks = nets_meta
            .into_iter()
            .enumerate()
            .map(|(net, meta)| {
                let mut report = NetworkReport {
                    net,
                    name: meta.name,
                    deadline_ns: meta.deadline_ns,
                    served: 0,
                    stats: Stats::default(),
                    batcher_wait_ns: 0.0,
                    max_batcher_wait_ns: 0.0,
                    deadline_violations: 0,
                    latency_sum_ns: 0.0,
                    p95_latency_ns: 0.0,
                };
                let mut latencies = Vec::new();
                for c in completions.iter().filter(|c| c.net == net) {
                    let wait = c.batcher_wait_ns();
                    report.served += 1;
                    report.stats.merge_serial(&c.stats);
                    report.batcher_wait_ns += wait;
                    report.max_batcher_wait_ns = report.max_batcher_wait_ns.max(wait);
                    if breaks_deadline(wait, meta.deadline_ns) {
                        report.deadline_violations += 1;
                    }
                    report.latency_sum_ns += c.latency_ns();
                    latencies.push(c.latency_ns());
                }
                if !latencies.is_empty() {
                    latencies.sort_by(f64::total_cmp);
                    let idx = ((latencies.len() as f64 * 0.95).ceil() as usize)
                        .clamp(1, latencies.len())
                        - 1;
                    report.p95_latency_ns = latencies[idx];
                }
                report
            })
            .collect();
        let mut report = Self {
            engine,
            completions,
            chips,
            networks,
            counters,
            spot_check: None,
            faults: FaultSummary::default(),
            trace: None,
            wall_seconds,
        };
        report.faults.ledger = report.total_stats().faults;
        report.faults.active = !report.faults.ledger.is_zero();
        report
    }

    /// Requests served.
    pub fn served(&self) -> usize {
        self.completions.len()
    }

    /// Simulated makespan: when the last chip went idle (ns).
    pub fn makespan_ns(&self) -> f64 {
        self.chips.iter().fold(0.0f64, |m, c| m.max(c.finish_ns))
    }

    /// Aggregate throughput over the run: requests per simulated second
    /// (0 for an empty run).
    pub fn sim_fps(&self) -> f64 {
        let span = self.makespan_ns();
        if span > 0.0 {
            self.served() as f64 / (span * 1e-9)
        } else {
            0.0
        }
    }

    /// Serial merge of every request's simulated stats.
    pub fn total_stats(&self) -> Stats {
        let mut total = Stats::default();
        for c in &self.chips {
            total.merge_serial(&c.stats);
        }
        total
    }

    /// Total simulated energy across all requests (mJ).
    pub fn total_energy_mj(&self) -> f64 {
        self.total_stats().total_energy_mj()
    }

    /// Mean end-to-end simulated latency (ms; 0 for an empty run).
    pub fn mean_latency_ms(&self) -> f64 {
        if self.completions.is_empty() {
            return 0.0;
        }
        let sum: f64 = self.completions.iter().map(|c| c.latency_ns()).sum();
        sum / self.completions.len() as f64 * 1e-6
    }

    /// p95 end-to-end simulated latency (ms; 0 for an empty run, the
    /// single observation for a one-request run).
    pub fn p95_latency_ms(&self) -> f64 {
        if self.completions.is_empty() {
            return 0.0;
        }
        let mut lat: Vec<f64> = self.completions.iter().map(|c| c.latency_ns()).collect();
        lat.sort_by(f64::total_cmp);
        let idx = ((lat.len() as f64 * 0.95).ceil() as usize).clamp(1, lat.len()) - 1;
        lat[idx] * 1e-6
    }

    /// Fold the report into an integer [`MetricsRegistry`] snapshot.
    ///
    /// Built the deterministic way the report itself is: one
    /// sub-registry per chip (chip-labelled counters and gauges, so
    /// names stay disjoint) merged in chip order, then run-wide
    /// counters and the per-request time histograms. Every counter
    /// re-derives a report aggregate exactly — e.g.
    /// `nandspin_requests_served_total == served()` — so a snapshot can
    /// stand in for the report in dashboards without drift.
    pub fn metrics(&self) -> MetricsRegistry {
        let mut m = MetricsRegistry::new();
        for c in &self.chips {
            let mut chip = MetricsRegistry::new();
            chip.inc(&format!("nandspin_chip_served_total{{chip=\"{}\"}}", c.chip), c.served);
            chip.inc(&format!("nandspin_chip_batches_total{{chip=\"{}\"}}", c.chip), c.batches);
            chip.inc(
                &format!("nandspin_chip_stalled_batches_total{{chip=\"{}\"}}", c.chip),
                c.stalled_batches,
            );
            chip.inc(
                &format!("nandspin_chip_weight_hits_total{{chip=\"{}\"}}", c.chip),
                c.weight_hits,
            );
            chip.inc(
                &format!("nandspin_chip_weight_misses_total{{chip=\"{}\"}}", c.chip),
                c.weight_misses,
            );
            chip.set_gauge(
                &format!("nandspin_chip_healthy{{chip=\"{}\"}}", c.chip),
                i64::from(c.healthy),
            );
            m.merge(&chip);
        }
        m.inc("nandspin_requests_served_total", self.served() as u64);
        m.inc("nandspin_batches_total", self.counters.batches);
        m.inc("nandspin_flushes_total{cause=\"size\"}", self.counters.size_flushes);
        m.inc("nandspin_flushes_total{cause=\"deadline\"}", self.counters.deadline_flushes);
        m.inc("nandspin_flushes_total{cause=\"drain\"}", self.counters.drain_flushes);
        for n in &self.networks {
            m.inc(&format!("nandspin_net_served_total{{net=\"{}\"}}", n.name), n.served);
            m.inc(
                &format!("nandspin_net_deadline_violations_total{{net=\"{}\"}}", n.name),
                n.deadline_violations,
            );
        }
        let fl = &self.faults.ledger;
        m.inc("nandspin_faults_injected_total{kind=\"program\"}", fl.program_faults);
        m.inc("nandspin_faults_injected_total{kind=\"read\"}", fl.read_flips);
        m.inc("nandspin_faults_injected_total{kind=\"and\"}", fl.and_flips);
        m.inc("nandspin_fault_write_retries_total", fl.write_retries);
        m.inc("nandspin_fault_spared_rows_total", fl.spared_rows);
        m.inc("nandspin_failover_rounds_total", self.faults.failover_rounds);
        m.inc("nandspin_failed_over_batches_total", self.faults.failed_over_batches);
        m.inc("nandspin_failed_over_requests_total", self.faults.failed_over_requests);
        m.set_gauge("nandspin_unhealthy_chips", self.faults.unhealthy_chips as i64);
        m.set_gauge("nandspin_makespan_ns", self.makespan_ns() as i64);
        for c in &self.completions {
            m.observe_ns("nandspin_request_latency_ns", c.latency_ns() as u64);
            m.observe_ns("nandspin_request_lane_wait_ns", c.batcher_wait_ns() as u64);
            m.observe_ns("nandspin_request_queue_wait_ns", c.queue_wait_ns() as u64);
        }
        m
    }

    /// Check the aggregation identities: every per-chip, per-network
    /// and aggregate number must equal the fold of its per-request
    /// parts (including each network's deadline-violation count, which
    /// is re-derived from the raw flush stamps), the queue
    /// counters must be consistent with the emitted batches, the output
    /// fidelity must match the engine mode, the fault ledgers (per-chip
    /// and aggregate) must equal the exact integer fold of the
    /// per-request counters with the unhealthy-chip tally matching the
    /// per-chip flags, and a hybrid spot-check (if one ran) must sit
    /// inside its plausibility band.
    pub fn verify(&self) -> Result<(), String> {
        let close = |a: f64, b: f64| (a - b).abs() <= 1e-6 * a.abs().max(b.abs()).max(1.0);
        if self.counters.enqueued != self.served() as u64 {
            return Err(format!(
                "enqueued {} != completions {}",
                self.counters.enqueued,
                self.served()
            ));
        }
        let chip_served: u64 = self.chips.iter().map(|c| c.served).sum();
        if chip_served != self.served() as u64 {
            return Err(format!("chip served sum {} != completions {}", chip_served, self.served()));
        }
        let chip_batches: u64 = self.chips.iter().map(|c| c.batches).sum();
        if chip_batches != self.counters.batches {
            return Err(format!(
                "chip batch sum {} != batcher flushes {}",
                chip_batches, self.counters.batches
            ));
        }
        let flushes = self.counters.size_flushes
            + self.counters.deadline_flushes
            + self.counters.drain_flushes;
        if flushes != self.counters.batches {
            return Err(format!(
                "flush causes {} != batches {}",
                flushes, self.counters.batches
            ));
        }
        let bit_accurate = self.engine.bit_accurate();
        for c in &self.completions {
            if c.output.is_some() != bit_accurate {
                return Err(format!(
                    "request {}: output fidelity does not match the {} engine mode",
                    c.id,
                    self.engine.label()
                ));
            }
        }
        for chip in &self.chips {
            let per_req: Vec<&Completion> =
                self.completions.iter().filter(|c| c.chip == chip.chip).collect();
            if per_req.len() as u64 != chip.served {
                return Err(format!("chip {}: served mismatch", chip.chip));
            }
            let energy: f64 = per_req.iter().map(|c| c.stats.total_energy_fj()).sum();
            if !close(energy, chip.stats.total_energy_fj()) {
                return Err(format!("chip {}: energy roll-up mismatch", chip.chip));
            }
            let busy: f64 = per_req.iter().map(|c| c.service_ns()).sum();
            if !close(busy, chip.busy_ns) {
                return Err(format!("chip {}: busy-time roll-up mismatch", chip.chip));
            }
            let wait: f64 = per_req.iter().map(|c| c.queue_wait_ns()).sum();
            if !close(wait, chip.queue_wait_ns) {
                return Err(format!("chip {}: queue-wait roll-up mismatch", chip.chip));
            }
            let mut fold = Stats::default();
            for c in &per_req {
                fold.merge_serial(&c.stats);
            }
            if fold.faults != chip.stats.faults {
                return Err(format!("chip {}: fault-ledger roll-up mismatch", chip.chip));
            }
        }
        for c in &self.completions {
            if c.net >= self.networks.len() {
                return Err(format!(
                    "request {}: network {} has no per-network account",
                    c.id, c.net
                ));
            }
        }
        let net_served: u64 = self.networks.iter().map(|n| n.served).sum();
        if net_served != self.served() as u64 {
            return Err(format!(
                "network served sum {} != completions {}",
                net_served,
                self.served()
            ));
        }
        for nr in &self.networks {
            let per_req: Vec<&Completion> =
                self.completions.iter().filter(|c| c.net == nr.net).collect();
            if per_req.len() as u64 != nr.served {
                return Err(format!("network {}: served mismatch", nr.net));
            }
            let energy: f64 = per_req.iter().map(|c| c.stats.total_energy_fj()).sum();
            if !close(energy, nr.stats.total_energy_fj()) {
                return Err(format!("network {}: energy roll-up mismatch", nr.net));
            }
            let wait: f64 = per_req.iter().map(|c| c.batcher_wait_ns()).sum();
            if !close(wait, nr.batcher_wait_ns) {
                return Err(format!("network {}: batcher-wait roll-up mismatch", nr.net));
            }
            let max_wait =
                per_req.iter().map(|c| c.batcher_wait_ns()).fold(0.0f64, f64::max);
            if !close(max_wait, nr.max_batcher_wait_ns) {
                return Err(format!("network {}: max batcher-wait mismatch", nr.net));
            }
            let violations = per_req
                .iter()
                .filter(|c| breaks_deadline(c.batcher_wait_ns(), nr.deadline_ns))
                .count() as u64;
            if violations != nr.deadline_violations {
                return Err(format!(
                    "network {}: deadline violations {} != re-derived {}",
                    nr.net, nr.deadline_violations, violations
                ));
            }
            let latency: f64 = per_req.iter().map(|c| c.latency_ns()).sum();
            if !close(latency, nr.latency_sum_ns) {
                return Err(format!("network {}: latency roll-up mismatch", nr.net));
            }
        }
        let total = self.total_stats();
        let req_energy: f64 = self.completions.iter().map(|c| c.stats.total_energy_fj()).sum();
        if !close(total.total_energy_fj(), req_energy) {
            return Err("aggregate energy != sum of per-request energies".into());
        }
        if total.faults != self.faults.ledger {
            return Err("aggregate fault ledger != fold of per-chip ledgers".into());
        }
        if !self.faults.ledger.is_zero() && !self.faults.active {
            return Err("fault counters recorded without an active fault plan".into());
        }
        let unhealthy = self.chips.iter().filter(|c| !c.healthy).count() as u64;
        if unhealthy != self.faults.unhealthy_chips {
            return Err(format!(
                "unhealthy-chip count {} != per-chip flags {}",
                self.faults.unhealthy_chips, unhealthy
            ));
        }
        if let Some(sc) = &self.spot_check {
            if !sc.passed() {
                return Err(format!(
                    "functional spot-check outside plausibility band {:?}: latency {:?}, energy {:?}",
                    SpotCheck::TOLERANCE,
                    sc.latency_ratio,
                    sc.energy_ratio
                ));
            }
        }
        Ok(())
    }
}

impl fmt::Display for ServeReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let makespan = self.makespan_ns();
        writeln!(
            f,
            "{:>5} {:>8} {:>8} {:>8} {:>12} {:>12} {:>10} {:>8} {:>10}",
            "chip", "served", "batches", "stalled", "busy (ms)", "wait (ms)", "E (mJ)", "util", "wt hit/miss"
        )?;
        for c in &self.chips {
            writeln!(
                f,
                "{:>5} {:>8} {:>8} {:>8} {:>12.4} {:>12.4} {:>10.4} {:>7.1}% {:>7}/{}{}",
                c.chip,
                c.served,
                c.batches,
                c.stalled_batches,
                c.busy_ns * 1e-6,
                c.queue_wait_ns * 1e-6,
                c.stats.total_energy_mj(),
                100.0 * c.utilisation(makespan),
                c.weight_hits,
                c.weight_misses,
                if c.healthy { "" } else { "  UNHEALTHY" },
            )?;
        }
        for n in &self.networks {
            writeln!(
                f,
                "net {} ({}): {} served; SLO {:.1} µs, max lane wait {:.1} µs, {} violations; \
                 mean latency {:.4} ms, p95 {:.4} ms",
                n.net,
                n.name,
                n.served,
                n.deadline_ns * 1e-3,
                n.max_batcher_wait_ns * 1e-3,
                n.deadline_violations,
                n.mean_latency_ms(),
                n.p95_latency_ns * 1e-6,
            )?;
        }
        writeln!(
            f,
            "aggregate: {} requests in {} batches ({} size / {} deadline / {} drain flushes)",
            self.served(),
            self.counters.batches,
            self.counters.size_flushes,
            self.counters.deadline_flushes,
            self.counters.drain_flushes,
        )?;
        writeln!(
            f,
            "engine: {}{}",
            self.engine.label(),
            if self.engine.bit_accurate() { " (bit-accurate)" } else { " (synthesized stats)" },
        )?;
        if self.faults.active {
            let fl = &self.faults;
            writeln!(
                f,
                "faults: {} program / {} read / {} and injected; {} write retries, {} rows \
                 spared; {} batches ({} requests) failed over in {} rounds; {} unhealthy chips{}",
                fl.ledger.program_faults,
                fl.ledger.read_flips,
                fl.ledger.and_flips,
                fl.ledger.write_retries,
                fl.ledger.spared_rows,
                fl.failed_over_batches,
                fl.failed_over_requests,
                fl.failover_rounds,
                fl.unhealthy_chips,
                if fl.spot_check_escalated { "; spot-check stride escalated" } else { "" },
            )?;
        }
        if let Some(sc) = &self.spot_check {
            writeln!(
                f,
                "spot-check: {} functional replays; functional/analytic latency {:.3}–{:.3}×, \
                 energy {:.3}–{:.3}× — {}",
                sc.checked,
                sc.latency_ratio.0,
                sc.latency_ratio.1,
                sc.energy_ratio.0,
                sc.energy_ratio.1,
                if sc.passed() { "PLAUSIBLE" } else { "OUT OF BAND" },
            )?;
        }
        writeln!(
            f,
            "latency: mean {:.4} ms, p95 {:.4} ms; makespan {:.4} ms; {:.1} FPS; {:.4} mJ total",
            self.mean_latency_ms(),
            self.p95_latency_ms(),
            makespan * 1e-6,
            self.sim_fps(),
            self.total_energy_mj(),
        )?;
        write!(f, "host wall-clock: {:.3} s", self.wall_seconds)
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests may panic on impossible states
mod tests {
    use super::super::batcher::FlushCause;
    use super::super::pool::{BatchTiming, ChipResult, ExecutedBatch, ExecutedRequest};
    use super::*;
    use crate::arch::stats::Phase;

    fn req(id: u64, lat_ns: f64, energy_fj: f64) -> ExecutedRequest {
        let mut stats = Stats::default();
        stats.record(Phase::Convolution, energy_fj, lat_ns);
        ExecutedRequest { id, output: Some(WideTensor::zeros(1, 1, 1)), stats, layer_stats: None }
    }

    /// Hand-build a two-chip result set with known numbers. Lane
    /// deadline 15 ns: the deepest batcher wait is request 2's 10 ns
    /// (arrived 10, flushed 20), so the SLO holds with margin.
    fn synthetic_report_with_deadline(deadline_ns: f64) -> ServeReport {
        let results = vec![
            ChipResult {
                chip: 0,
                batches: vec![ExecutedBatch {
                    seq: 0,
                    net: 0,
                    cause: FlushCause::Size,
                    flush_ns: 0.0,
                    arrivals_ns: vec![0.0, 0.0],
                    est_cost_ns: 0.0,
                    est_finish_ns: 0.0,
                    requests: vec![req(0, 100.0, 10.0), req(1, 50.0, 5.0)],
                }],
                weight_hits: 1,
                weight_misses: 1,
                host_profile: None,
                layer_costs: None,
            },
            ChipResult {
                chip: 1,
                batches: vec![ExecutedBatch {
                    seq: 1,
                    net: 0,
                    cause: FlushCause::Drain,
                    flush_ns: 20.0,
                    arrivals_ns: vec![10.0],
                    est_cost_ns: 0.0,
                    est_finish_ns: 0.0,
                    requests: vec![req(2, 200.0, 20.0)],
                }],
                weight_hits: 0,
                weight_misses: 1,
                host_profile: None,
                layer_costs: None,
            },
        ];
        let timings = vec![
            vec![BatchTiming { enqueue_ns: 0.0, start_ns: 0.0, finish_ns: 150.0, stalled: false }],
            vec![BatchTiming { enqueue_ns: 20.0, start_ns: 20.0, finish_ns: 220.0, stalled: false }],
        ];
        let counters = QueueCounters {
            enqueued: 3,
            batches: 2,
            size_flushes: 1,
            drain_flushes: 1,
            max_queue_depth: 2,
            max_batch: 2,
            ..QueueCounters::default()
        };
        let meta = vec![NetworkMeta { name: "synthetic".into(), deadline_ns }];
        ServeReport::assemble(EngineMode::Functional, meta, results, timings, counters, 0.01)
    }

    fn synthetic_report() -> ServeReport {
        synthetic_report_with_deadline(15.0)
    }

    #[test]
    fn per_request_timing_is_serial_within_a_batch() {
        let r = synthetic_report();
        let by_id = |id: u64| r.completions.iter().find(|c| c.id == id).unwrap();
        assert_eq!(by_id(0).start_ns, 0.0);
        assert_eq!(by_id(0).finish_ns, 100.0);
        assert_eq!(by_id(1).start_ns, 100.0, "second request waits for the first");
        assert_eq!(by_id(1).finish_ns, 150.0);
        assert_eq!(by_id(2).start_ns, 20.0);
        assert_eq!(by_id(2).queue_wait_ns(), 10.0, "arrived at 10, started at 20");
    }

    #[test]
    fn aggregation_identities_hold() {
        let r = synthetic_report();
        r.verify().expect("identities");
        assert_eq!(r.served(), 3);
        assert_eq!(r.makespan_ns(), 220.0);
        assert_eq!(r.chips[0].busy_ns, 150.0);
        assert_eq!(r.chips[1].busy_ns, 200.0);
        let total = r.total_stats();
        assert_eq!(total.total_energy_fj(), 35.0);
        assert!((r.sim_fps() - 3.0 / (220.0 * 1e-9)).abs() < 1e-3);
    }

    #[test]
    fn verify_catches_a_broken_rollup() {
        let mut r = synthetic_report();
        r.chips[0].busy_ns += 1.0;
        assert!(r.verify().is_err(), "tampered roll-up must fail verification");
        let mut r2 = synthetic_report();
        r2.counters.enqueued += 1;
        assert!(r2.verify().is_err());
    }

    #[test]
    fn per_network_rollup_counts_waits_and_violations() {
        let r = synthetic_report();
        assert_eq!(r.networks.len(), 1);
        let n = &r.networks[0];
        assert_eq!(n.name, "synthetic");
        assert_eq!(n.served, 3);
        // Waits: ids 0/1 flushed at arrival (0 ns), id 2 waited 10 ns.
        assert_eq!(n.batcher_wait_ns, 10.0);
        assert_eq!(n.max_batcher_wait_ns, 10.0);
        assert_eq!(n.deadline_violations, 0, "10 ns wait inside the 15 ns SLO");
        assert!((n.mean_latency_ms() - (100.0 + 150.0 + 210.0) / 3.0 * 1e-6).abs() < 1e-12);
        assert!((n.p95_latency_ns - 210.0).abs() < 1e-12);
        // A tighter lane deadline flags the deep wait — and verify
        // agrees because it re-derives the count from the same stamps.
        let tight = synthetic_report_with_deadline(5.0);
        assert_eq!(tight.networks[0].deadline_violations, 1);
        tight.verify().expect("violations are an account, not a verify failure");
    }

    #[test]
    fn verify_catches_a_tampered_network_rollup() {
        let mut r = synthetic_report();
        r.networks[0].batcher_wait_ns += 1.0;
        assert!(r.verify().is_err(), "tampered per-network wait must fail verification");
        let mut r2 = synthetic_report();
        r2.networks[0].deadline_violations = 7;
        assert!(r2.verify().is_err(), "violation count is re-derived from flush stamps");
        let mut r3 = synthetic_report();
        r3.completions[0].net = 1;
        assert!(r3.verify().is_err(), "completions must map onto a network account");
    }

    #[test]
    fn verify_catches_fidelity_mismatches() {
        // A functional-mode report whose completions lost their outputs.
        let mut r = synthetic_report();
        r.completions[0].output = None;
        assert!(r.verify().is_err(), "functional completions must carry outputs");
        // An analytic-mode report must NOT carry outputs.
        let mut r2 = synthetic_report();
        r2.engine = EngineMode::Analytic;
        assert!(r2.verify().is_err(), "synthesized completions must not carry outputs");
        for c in &mut r2.completions {
            c.output = None;
        }
        r2.verify().expect("outputless analytic report verifies");
    }

    #[test]
    fn verify_enforces_the_spot_check_band() {
        let mut r = synthetic_report();
        let mut sc = SpotCheck::new();
        sc.observe(1.5, 0.8);
        assert!(sc.passed());
        r.spot_check = Some(sc);
        r.verify().expect("in-band spot check");
        let mut bad = SpotCheck::new();
        bad.observe(1e6, 1.0);
        assert!(!bad.passed());
        r.spot_check = Some(bad);
        assert!(r.verify().is_err(), "out-of-band spot check must fail verify");
    }

    #[test]
    fn fault_ledger_rolls_up_and_is_verified() {
        // Give one request injected faults and recovery work: the
        // aggregate ledger must be their exact fold, the report counts
        // as fault-active, and tampering any fault account fails verify.
        let mut results = vec![ChipResult {
            chip: 0,
            batches: vec![ExecutedBatch {
                seq: 0,
                net: 0,
                cause: FlushCause::Drain,
                flush_ns: 0.0,
                arrivals_ns: vec![0.0, 0.0],
                est_cost_ns: 0.0,
                est_finish_ns: 0.0,
                requests: vec![req(0, 100.0, 10.0), req(1, 50.0, 5.0)],
            }],
            weight_hits: 1,
            weight_misses: 1,
            host_profile: None,
            layer_costs: None,
        }];
        results[0].batches[0].requests[0].stats.faults.program_faults = 4;
        results[0].batches[0].requests[0].stats.faults.write_retries = 2;
        results[0].batches[0].requests[1].stats.faults.read_flips = 3;
        let timings = vec![vec![BatchTiming {
            enqueue_ns: 0.0,
            start_ns: 0.0,
            finish_ns: 150.0,
            stalled: false,
        }]];
        let counters = QueueCounters {
            enqueued: 2,
            batches: 1,
            drain_flushes: 1,
            max_queue_depth: 2,
            max_batch: 2,
            ..QueueCounters::default()
        };
        let meta = vec![NetworkMeta { name: "faulty".into(), deadline_ns: 100.0 }];
        let r =
            ServeReport::assemble(EngineMode::Functional, meta, results, timings, counters, 0.0);
        assert!(r.faults.active, "non-zero ledger marks the run fault-active");
        assert_eq!(r.faults.ledger.program_faults, 4);
        assert_eq!(r.faults.ledger.read_flips, 3);
        assert_eq!(r.faults.ledger.write_retries, 2);
        assert_eq!(r.faults.ledger.injected(), 7);
        r.verify().expect("fault identities hold");
        let text = format!("{r}");
        assert!(text.contains("faults: 4 program / 3 read / 0 and injected"), "{text}");

        let mut tampered = r;
        tampered.faults.ledger.program_faults += 1;
        assert!(tampered.verify().is_err(), "tampered aggregate ledger must fail");
        tampered.faults.ledger.program_faults -= 1;
        tampered.chips[0].stats.faults.read_flips += 1;
        assert!(tampered.verify().is_err(), "tampered per-chip ledger must fail");
    }

    #[test]
    fn unhealthy_chip_flags_must_match_the_summary() {
        let mut r = synthetic_report();
        assert!(r.chips.iter().all(|c| c.healthy), "chips start healthy");
        r.verify().expect("healthy report verifies");
        r.chips[1].healthy = false;
        assert!(r.verify().is_err(), "flagged chip without a summary count must fail");
        r.faults.unhealthy_chips = 1;
        r.verify().expect("flag and summary agree");
        let text = format!("{r}");
        assert!(text.contains("UNHEALTHY"), "{text}");
    }

    #[test]
    fn completions_are_ordered_by_finish_time() {
        let r = synthetic_report();
        let finishes: Vec<f64> = r.completions.iter().map(|c| c.finish_ns).collect();
        let mut sorted = finishes.clone();
        sorted.sort_by(f64::total_cmp);
        assert_eq!(finishes, sorted);
    }

    #[test]
    fn latency_percentiles_cover_the_tail() {
        let r = synthetic_report();
        // Latencies: id0 100, id1 150, id2 210 (arrived 10, finished 220).
        assert!((r.mean_latency_ms() - (100.0 + 150.0 + 210.0) / 3.0 * 1e-6).abs() < 1e-12);
        assert!((r.p95_latency_ms() - 210.0 * 1e-6).abs() < 1e-12);
    }

    #[test]
    fn empty_report_has_sane_aggregates() {
        // Zero-request streams must neither panic nor divide by zero.
        let r = ServeReport::assemble(
            EngineMode::Functional,
            vec![],
            vec![],
            vec![],
            QueueCounters::default(),
            0.0,
        );
        assert_eq!(r.served(), 0);
        assert_eq!(r.makespan_ns(), 0.0);
        assert_eq!(r.sim_fps(), 0.0);
        assert_eq!(r.mean_latency_ms(), 0.0);
        assert_eq!(r.p95_latency_ms(), 0.0);
        assert_eq!(r.total_energy_mj(), 0.0);
        r.verify().expect("empty report verifies");
        let text = format!("{r}");
        assert!(text.contains("0 requests"), "{text}");
    }

    #[test]
    fn single_request_report_percentiles_collapse() {
        let results = vec![ChipResult {
            chip: 0,
            batches: vec![ExecutedBatch {
                seq: 0,
                net: 0,
                cause: FlushCause::Drain,
                flush_ns: 0.0,
                arrivals_ns: vec![0.0],
                est_cost_ns: 0.0,
                est_finish_ns: 0.0,
                requests: vec![req(0, 40.0, 4.0)],
            }],
            weight_hits: 0,
            weight_misses: 1,
            host_profile: None,
            layer_costs: None,
        }];
        let timings = vec![vec![BatchTiming {
            enqueue_ns: 0.0,
            start_ns: 0.0,
            finish_ns: 40.0,
            stalled: false,
        }]];
        let counters = QueueCounters {
            enqueued: 1,
            batches: 1,
            drain_flushes: 1,
            max_queue_depth: 1,
            max_batch: 1,
            ..QueueCounters::default()
        };
        let meta = vec![NetworkMeta { name: "one".into(), deadline_ns: 100.0 }];
        let r =
            ServeReport::assemble(EngineMode::Functional, meta, results, timings, counters, 0.0);
        r.verify().expect("single-request report verifies");
        assert_eq!(r.served(), 1);
        // Mean and p95 are the one observation — no index over/underflow.
        assert!((r.mean_latency_ms() - 40.0 * 1e-6).abs() < 1e-15);
        assert!((r.p95_latency_ms() - 40.0 * 1e-6).abs() < 1e-15);
        assert!((r.sim_fps() - 1.0 / (40.0 * 1e-9)).abs() < 1e-3);
    }
}
