//! Closed-form batching laws, derived from the analytic op streams.
//!
//! Serving a batch of `n` requests on one weight-resident chip costs
//! exactly one *cold* inference (weights streamed over chip I/O — the
//! paper's latency condition) plus `n − 1` *warm* inferences (weights
//! resident — Table 3's steady-state throughput condition):
//!
//! ```text
//!   latency(n) = cold + (n − 1) · warm          (energy analogous)
//!   energy/request(n) = cold_e/n + (1 − 1/n) · warm_e  →  warm_e
//! ```
//!
//! [`BatchLaw`] evaluates both curves from two
//! [`AnalyticModel`](crate::coordinator::analytic::AnalyticModel)
//! evaluations (`weights_resident` off and on) — the same closed forms
//! [`AnalyticEngine`](crate::coordinator::engine::AnalyticEngine)
//! synthesizes per-request stats from, so an analytic serve reproduces
//! the law *exactly* (up to floating-point summation order) and the
//! scheduler can be verified against the cost model it schedules by:
//! the serve runtime builds its routing
//! [`CostTable`](super::router::CostTable) from these laws, and the
//! batching-law tests assert the simulated aggregates land back on the
//! curves.

use crate::arch::config::ArchConfig;
use crate::cnn::network::Network;
use crate::cnn::ref_exec::ModelParams;
use crate::coordinator::analytic::AnalyticModel;

/// Weight precision a serve synthesizes `net` at: the widest supplied
/// conv-kernel precision, falling back to the network's input
/// precision — the identical rule
/// [`AnalyticEngine`](crate::coordinator::engine::AnalyticEngine)
/// applies per request, so laws derived here match the engine's cache.
pub fn serving_wbits(net: &Network, params: Option<&ModelParams>) -> u8 {
    params
        .and_then(|p| p.conv_weights.iter().map(|k| k.bits).max())
        .unwrap_or(net.input_bits)
}

/// The closed-form batch-latency / energy-amortisation law of one
/// network on one chip operating point.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BatchLaw {
    /// Latency of one inference with the weight stream charged (ns).
    pub cold_latency_ns: f64,
    /// Latency of one inference with weights resident (ns).
    pub warm_latency_ns: f64,
    /// Energy of one inference with the weight stream charged (fJ).
    pub cold_energy_fj: f64,
    /// Energy of one inference with weights resident (fJ).
    pub warm_energy_fj: f64,
}

impl BatchLaw {
    /// Derive the law for `net` at weight precision `wbits` on the
    /// `cfg` operating point: two closed-form evaluations, one per
    /// residency state, default calibration (the state the serve
    /// pool's engines run in).
    pub fn derive(cfg: &ArchConfig, net: &Network, wbits: u8) -> Self {
        let mut cold_model = AnalyticModel::new(cfg.clone());
        cold_model.cal.weights_resident = false;
        let mut warm_model = AnalyticModel::new(cfg.clone());
        warm_model.cal.weights_resident = true;
        let cold = cold_model.network_stats(net, wbits);
        let warm = warm_model.network_stats(net, wbits);
        Self {
            cold_latency_ns: cold.total_latency_ns(),
            warm_latency_ns: warm.total_latency_ns(),
            cold_energy_fj: cold.total_energy_fj(),
            warm_energy_fj: warm.total_energy_fj(),
        }
    }

    /// Serial latency of a batch of `n` on one chip: one cold inference
    /// then `n − 1` warm ones (0 for an empty batch).
    pub fn batch_latency_ns(&self, n: usize) -> f64 {
        if n == 0 {
            0.0
        } else {
            self.cold_latency_ns + (n as f64 - 1.0) * self.warm_latency_ns
        }
    }

    /// Energy of a batch of `n` on one chip (fJ; 0 for an empty batch).
    pub fn batch_energy_fj(&self, n: usize) -> f64 {
        if n == 0 {
            0.0
        } else {
            self.cold_energy_fj + (n as f64 - 1.0) * self.warm_energy_fj
        }
    }

    /// Amortised energy per request at batch size `n` (fJ): decreases
    /// monotonically toward the warm floor as the one-time weight
    /// stream spreads across the batch.
    pub fn energy_per_request_fj(&self, n: usize) -> f64 {
        if n == 0 {
            0.0
        } else {
            self.batch_energy_fj(n) / n as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::network::small_cnn;

    #[test]
    fn law_is_anchored_at_cold_and_amortises_toward_warm() {
        let net = small_cnn(3);
        let law = BatchLaw::derive(&ArchConfig::paper(), &net, 3);
        assert!(law.warm_latency_ns < law.cold_latency_ns, "resident weights skip the stream");
        assert!(law.warm_energy_fj < law.cold_energy_fj);
        assert_eq!(law.batch_latency_ns(1), law.cold_latency_ns);
        assert_eq!(law.batch_latency_ns(0), 0.0);
        let l4 = law.batch_latency_ns(4);
        assert!((l4 - (law.cold_latency_ns + 3.0 * law.warm_latency_ns)).abs() < 1e-9 * l4);
        // Per-request energy decreases monotonically and stays above
        // the warm floor.
        let e1 = law.energy_per_request_fj(1);
        let e4 = law.energy_per_request_fj(4);
        let e16 = law.energy_per_request_fj(16);
        assert!(e1 > e4 && e4 > e16, "{e1} {e4} {e16}");
        assert!(e16 > law.warm_energy_fj);
    }

    #[test]
    fn serving_wbits_prefers_supplied_weights() {
        use crate::cnn::ref_exec::ModelParams;
        let net = small_cnn(3);
        assert_eq!(serving_wbits(&net, None), net.input_bits);
        let params = ModelParams::random(&net, 5, 9);
        assert_eq!(serving_wbits(&net, Some(&params)), 5);
    }
}
