//! The engine abstraction: one [`InferenceEngine`] trait over both
//! execution paths.
//!
//! Historically the coordinator exposed two unrelated types — the
//! bit-accurate [`FunctionalEngine`] and the closed-form
//! [`AnalyticModel`] — and the serving runtime hardcoded the former,
//! which meant the paper's full-size benchmark networks
//! (AlexNet/VGG19/ResNet50) could never be *served*, only costed in
//! one-shot sweeps. This module collapses the split:
//!
//! * [`InferenceEngine`] is the common contract: plan a network,
//!   execute requests (accumulating [`Stats`]), and manage weight
//!   residency for the Table 3 serving condition.
//! * [`FunctionalEngine`] implements it at [`Fidelity::BitAccurate`]:
//!   every layer runs on simulated subarrays and the outputs are
//!   bit-exact with the golden executor.
//! * [`AnalyticEngine`] implements it at [`Fidelity::Synthesized`]: a
//!   stateful wrapper around [`AnalyticModel`] that synthesizes each
//!   request's latency/energy from the closed-form op streams —
//!   deterministic, drawn from the same `DeviceCosts` table, with the
//!   same cold-then-warm weight-residency behaviour.
//! * [`EngineFactory`] builds either kind for a given [`ArchConfig`];
//!   the serve pool uses it to stay engine-generic (one factory = one
//!   homogeneous chip pool).
//!
//! Both engines draw every cost from the single L1 `DeviceCosts` table,
//! so a request executed functionally and the same request synthesized
//! analytically must land within the same order of magnitude — the
//! hybrid serve mode (`EngineMode::Hybrid`) exploits exactly that to
//! spot-check analytic runs against functional replays.

use crate::arch::config::ArchConfig;
use crate::arch::stats::Stats;
use crate::bank::controller::WeightResidency;
use crate::cnn::layer::Layer;
use crate::cnn::network::Network;
use crate::cnn::ref_exec::{ModelParams, WideTensor};
use crate::cnn::tensor::QTensor;
use crate::coordinator::analytic::{AnalyticModel, Calibration};
use crate::coordinator::functional::{FunctionalEngine, HostLayerProfile};
use crate::device::fault::FaultPlan;

/// The two engine implementations the factory can build.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    /// Bit-accurate execution on simulated subarrays
    /// ([`FunctionalEngine`]).
    Functional,
    /// Closed-form op-stream synthesis ([`AnalyticEngine`]).
    Analytic,
}

impl EngineKind {
    /// Human/CLI label.
    pub fn label(self) -> &'static str {
        match self {
            EngineKind::Functional => "functional",
            EngineKind::Analytic => "analytic",
        }
    }
}

/// Fidelity an engine executes at.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fidelity {
    /// Every layer executed on simulated subarrays; outputs are
    /// bit-exact with the golden executor.
    BitAccurate,
    /// Latency/energy synthesized from closed-form op streams; no
    /// output tensors are produced.
    Synthesized,
}

/// What an engine would do with a network, before running anything.
#[derive(Debug, Clone)]
pub struct ExecutionPlan {
    /// Network the plan was built for.
    pub network: String,
    /// Nodes in the execution schedule.
    pub nodes: usize,
    /// Total multiply-accumulates of one inference.
    pub total_macs: u64,
    /// Fidelity the engine executes at.
    pub fidelity: Fidelity,
    /// Whether this engine can run the network at all.
    pub supported: bool,
    /// Why `supported` is false, when it is.
    pub unsupported_reason: Option<String>,
}

/// One executed request: optional bit-accurate outputs plus the
/// request's own simulated cost.
#[derive(Debug)]
pub struct Execution {
    /// All node outputs in schedule order ([`Fidelity::BitAccurate`]
    /// engines); `None` when the engine synthesizes stats only.
    pub outputs: Option<Vec<WideTensor>>,
    /// Simulated PIM cost of this request alone.
    pub stats: Stats,
    /// Per-node simulated cost deltas (one [`Stats`] per network node,
    /// in schedule order; they sum serially to `stats`). Recorded only
    /// when layer recording is enabled
    /// ([`InferenceEngine::set_layer_recording`]) — `None` otherwise,
    /// keeping the default path allocation-free.
    pub layer_stats: Option<Vec<Stats>>,
}

/// The common engine contract the serving runtime is generic over.
///
/// An engine is stateful: it accumulates [`Stats`] across requests and,
/// once [`make_weights_resident`](InferenceEngine::make_weights_resident)
/// has been called, streams each layer's weights over chip I/O only on
/// first touch (the Table 3 serving condition), re-streaming when the
/// served network changes.
pub trait InferenceEngine: Send {
    /// Which implementation this is.
    fn kind(&self) -> EngineKind;

    /// Plan `net` without executing: schedule size, fidelity, and
    /// whether this engine supports the network at all.
    fn plan(&self, net: &Network) -> ExecutionPlan;

    /// Switch to the Table 3 serving condition: weights are streamed
    /// once and reused across subsequent requests of the same network.
    fn make_weights_resident(&mut self);

    /// Weight-residency tracker, if the engine is in serving mode.
    fn residency(&self) -> Option<&WeightResidency>;

    /// Execute one request. Bit-accurate engines require `params`;
    /// synthesized engines use them only to pick the weight precision
    /// (falling back to the network's input precision).
    fn execute(
        &mut self,
        net: &Network,
        params: Option<&ModelParams>,
        input: &QTensor,
    ) -> Execution;

    /// Pin this engine's intra-request host-worker budget (threads used
    /// *inside* one request). Affects host wall time only — simulated
    /// outputs and [`Stats`] are worker-count-invariant. The serving
    /// pool calls this with each replica's share of the one
    /// `host_workers` budget; engines without intra-request parallelism
    /// (the analytic engine) ignore it.
    fn set_host_workers(&mut self, _workers: usize) {}

    /// Host wall-time profile of the most recent request, per conv
    /// layer, for engines that measure one (`None` otherwise).
    fn host_profile(&self) -> Option<&[HostLayerProfile]> {
        None
    }

    /// Enable (or disable) per-layer simulated cost recording: when on,
    /// every [`execute`](InferenceEngine::execute) also returns one
    /// zero-based [`Stats`] delta per network node in
    /// [`Execution::layer_stats`]. Off by default — the trace hook is a
    /// no-op sink, so untraced serves do no extra work. Recording does
    /// not change `Execution::stats` by a single bit: the deltas are
    /// observations of the same accumulation, not a different fold.
    fn set_layer_recording(&mut self, _on: bool) {}

    /// Install a fault-injection plan ([`FaultPlan`]). Engines that
    /// simulate individual device operations inject the plan's
    /// stochastic faults and charge the recovery work; engines that
    /// synthesize closed-form stats (the analytic engine) have no
    /// per-op fault surface and ignore it.
    fn set_fault_plan(&mut self, _plan: FaultPlan) {}
}

/// Bit width of a non-negative value (engine-local copy of the
/// functional coordinator's helper).
fn bit_width(v: i64) -> usize {
    debug_assert!(v >= 0);
    (64 - (v as u64).leading_zeros()).max(1) as usize
}

/// Why `net` cannot run on the functional engine, if it cannot.
///
/// Oversized feature maps are no longer a limit — the multi-tile
/// mapping ([`crate::mapping::TilePlan`]) shards them across subarrays
/// with halo exchange. What remains are genuine per-window and
/// per-layout capacity limits: a conv window must fit inside a single
/// subarray (and its weight buffer), the cross-writing accumulator must
/// keep at least two operand slots at the layer's worst-case precision,
/// and pooling's in-array row layout must fit the subarray height.
///
/// Unlike the old first-failure string, *every* violating layer is
/// reported, each naming the node, the layer, and the required vs.
/// available resource.
fn functional_limit(cfg: &ArchConfig, net: &Network) -> Option<String> {
    let mut problems: Vec<String> = Vec::new();
    // `FunctionalEngine::take_subarray` floors the weight buffer at 16
    // rows; keep the two in sync.
    let buffer_rows = cfg.buffer_rows.max(16);
    let shapes = net.shapes();
    // Conservative activation-width estimate, tracked through the graph
    // (weights assumed 8-bit — the widest `ModelParams` precision).
    let mut bits = net.input_bits as usize;
    for (i, node) in net.nodes.iter().enumerate() {
        let in_shape = match node.input {
            Some(j) => shapes[j],
            None if i == 0 => net.input,
            None => shapes[i - 1],
        };
        let (in_c, _, _) = in_shape;
        let name = node.layer.mnemonic();
        match node.layer {
            Layer::Conv { kh, kw, .. } => {
                if kw > cfg.cols {
                    problems.push(format!(
                        "node {i} ({name}): {kh}x{kw} window needs {kw} columns, \
                         subarray has {}",
                        cfg.cols
                    ));
                }
                if kh > cfg.rows {
                    problems.push(format!(
                        "node {i} ({name}): {kh}x{kw} window needs {kh} rows, \
                         subarray has {}",
                        cfg.rows
                    ));
                }
                if kh > buffer_rows {
                    problems.push(format!(
                        "node {i} ({name}): {kh}x{kw} window needs {kh} weight-buffer rows, \
                         buffer has {buffer_rows}"
                    ));
                }
                // Accumulator precision bound at 8-bit weights.
                let bound = (((1i64 << bits.min(32)) - 1) * 255)
                    .saturating_mul((in_c * kh * kw) as i64);
                let acc_bits = bit_width(bound).max(24);
                if (cfg.rows / acc_bits).saturating_sub(2) < 2 {
                    problems.push(format!(
                        "node {i} ({name}): {acc_bits}-bit accumulation needs {} rows \
                         for 2 operand slots, subarray has {}",
                        4 * acc_bits,
                        cfg.rows
                    ));
                }
                bits = acc_bits;
            }
            Layer::MaxPool { .. } => {
                let need = (2 * bits.max(1)).div_ceil(8) * 8 + 2;
                if need > cfg.rows {
                    problems.push(format!(
                        "node {i} ({name}): comparison layout at {bits}-bit activations \
                         needs {need} rows, subarray has {}",
                        cfg.rows
                    ));
                }
            }
            Layer::AvgPool { k, .. } => {
                let b = bits.max(1);
                let sum_base = ((k * k * b).div_ceil(8) + 1) * 8;
                let need = sum_base + b + bit_width((k * k) as i64);
                if need > cfg.rows {
                    problems.push(format!(
                        "node {i} ({name}): {k}x{k} window sum at {b}-bit activations \
                         needs {need} rows, subarray has {}",
                        cfg.rows
                    ));
                }
            }
            Layer::Quantize { bits: qb } => bits = qb as usize,
            Layer::Residual { .. } => bits += 1,
            Layer::BatchNorm | Layer::Relu => {}
        }
    }
    if problems.is_empty() {
        None
    } else {
        Some(problems.join("; "))
    }
}

impl InferenceEngine for FunctionalEngine {
    fn kind(&self) -> EngineKind {
        EngineKind::Functional
    }

    fn plan(&self, net: &Network) -> ExecutionPlan {
        let unsupported_reason = functional_limit(self.cfg(), net);
        ExecutionPlan {
            network: net.name.clone(),
            nodes: net.nodes.len(),
            total_macs: net.total_macs(),
            fidelity: Fidelity::BitAccurate,
            supported: unsupported_reason.is_none(),
            unsupported_reason,
        }
    }

    fn make_weights_resident(&mut self) {
        FunctionalEngine::make_weights_resident(self);
    }

    fn residency(&self) -> Option<&WeightResidency> {
        FunctionalEngine::residency(self)
    }

    fn execute(
        &mut self,
        net: &Network,
        params: Option<&ModelParams>,
        input: &QTensor,
    ) -> Execution {
        let params = params.expect("the functional engine needs model parameters");
        // Run against a zero-based stats accumulator and fold the run
        // into the engine's running total afterwards. A subtraction
        // delta on the growing f64 accumulator would differ in final
        // ulps depending on engine history; zero-basing makes each
        // request's stats a pure function of (config, params, input,
        // residency state) — the bit-reproducibility the serve pool's
        // worker split and the hybrid replay rely on.
        let total = std::mem::take(&mut self.stats);
        let outputs = self.run(net, params, input);
        let run_stats = std::mem::replace(&mut self.stats, total);
        self.stats.merge_serial(&run_stats);
        // Layer deltas are snapshots of the zero-based run above, so
        // they are pure functions of the request too.
        let layer_stats = self.layer_recording().then(|| self.take_layer_stats());
        Execution { outputs: Some(outputs), stats: run_stats, layer_stats }
    }

    fn set_host_workers(&mut self, workers: usize) {
        FunctionalEngine::set_host_workers(self, workers);
    }

    fn set_layer_recording(&mut self, on: bool) {
        FunctionalEngine::set_layer_recording(self, on);
    }

    fn host_profile(&self) -> Option<&[HostLayerProfile]> {
        Some(FunctionalEngine::host_profile(self))
    }

    fn set_fault_plan(&mut self, plan: FaultPlan) {
        FunctionalEngine::set_fault_plan(self, plan);
    }
}

/// Per-network synthesis cache of the analytic engine: one closed-form
/// evaluation in each residency state, reused for every request.
#[derive(Debug, Clone)]
struct NetCache {
    /// Structural fingerprint ([`Network::fingerprint`]) of the cached
    /// network — the same identity [`FunctionalEngine`] keys residency
    /// on. (The old `(name, nodes.len())` pair collided for different
    /// networks sharing a name and node count.)
    identity: u64,
    /// Weight precision the cache was built for.
    wbits: u8,
    /// Calibration the stats were synthesized with (a knob change
    /// invalidates the cache).
    cal: Calibration,
    /// Per-inference stats with the weight stream charged.
    cold: Stats,
    /// Per-inference stats with weights resident (stream skipped).
    warm: Stats,
    /// Per-node stats behind `cold`, in schedule order (they fold
    /// serially to `cold` — the exact same additions, so the totals
    /// agree bit-for-bit).
    cold_layers: Vec<Stats>,
    /// Per-node stats behind `warm`.
    warm_layers: Vec<Stats>,
    /// Conv layers (residency tags) in the network.
    conv_layers: usize,
}

/// Stateful serving wrapper around [`AnalyticModel`]: implements
/// [`InferenceEngine`] by synthesizing each request's latency/energy
/// from the closed-form op streams.
///
/// Per-request stats are deterministic: the first request after a
/// network switch is charged the cold (weight-streaming) evaluation,
/// every subsequent request of the same network the warm
/// (weights-resident) one — mirroring [`FunctionalEngine`]'s residency
/// behaviour, with the same hit/miss bookkeeping. Without
/// [`make_weights_resident`](InferenceEngine::make_weights_resident),
/// every request charges the cold evaluation (the paper's latency
/// condition).
#[derive(Debug, Clone)]
pub struct AnalyticEngine {
    /// The closed-form model requests are synthesized from. Calibration
    /// knobs may be adjusted here; `cal.weights_resident` is overridden
    /// per request by the engine's own residency state.
    pub model: AnalyticModel,
    /// Accumulated cost statistics across executed requests.
    pub stats: Stats,
    residency: Option<WeightResidency>,
    cache: Option<NetCache>,
    record_layer_costs: bool,
}

impl AnalyticEngine {
    /// New engine for `cfg` with default calibration.
    pub fn new(cfg: ArchConfig) -> Self {
        Self {
            model: AnalyticModel::new(cfg),
            stats: Stats::default(),
            residency: None,
            cache: None,
            record_layer_costs: false,
        }
    }

    /// (Re)build the synthesis cache when the network, the weight
    /// precision or a calibration knob changes. A network or precision
    /// switch also evicts resident weights (they would have to be
    /// re-streamed); a pure calibration change re-costs the op streams
    /// but leaves residency intact.
    fn ensure_cache(&mut self, net: &Network, wbits: u8) {
        let identity = net.fingerprint();
        let (stale, switched) = match &self.cache {
            Some(c) => (
                c.identity != identity || c.wbits != wbits || c.cal != self.model.cal,
                c.identity != identity || c.wbits != wbits,
            ),
            None => (true, false),
        };
        if !stale {
            return;
        }
        if switched {
            if let Some(r) = self.residency.as_mut() {
                r.evict_all();
            }
        }
        let mut cold_model = self.model.clone();
        cold_model.cal.weights_resident = false;
        let mut warm_model = self.model.clone();
        warm_model.cal.weights_resident = true;
        let conv_layers =
            net.nodes.iter().filter(|n| matches!(n.layer, Layer::Conv { .. })).count();
        // `network_stats` is the serial fold of `network_layer_stats`,
        // so caching the per-node vector and folding it here yields the
        // exact totals the old single-call path produced.
        let fold = |layers: &[Stats]| {
            let mut total = Stats::default();
            for s in layers {
                total.merge_serial(s);
            }
            total
        };
        let cold_layers = cold_model.network_layer_stats(net, wbits);
        let warm_layers = warm_model.network_layer_stats(net, wbits);
        self.cache = Some(NetCache {
            identity,
            wbits,
            cal: self.model.cal,
            cold: fold(&cold_layers),
            warm: fold(&warm_layers),
            cold_layers,
            warm_layers,
            conv_layers,
        });
    }
}

impl InferenceEngine for AnalyticEngine {
    fn kind(&self) -> EngineKind {
        EngineKind::Analytic
    }

    fn plan(&self, net: &Network) -> ExecutionPlan {
        ExecutionPlan {
            network: net.name.clone(),
            nodes: net.nodes.len(),
            total_macs: net.total_macs(),
            fidelity: Fidelity::Synthesized,
            supported: true,
            unsupported_reason: None,
        }
    }

    fn make_weights_resident(&mut self) {
        if self.residency.is_none() {
            self.residency = Some(WeightResidency::new());
        }
    }

    fn residency(&self) -> Option<&WeightResidency> {
        self.residency.as_ref()
    }

    fn execute(
        &mut self,
        net: &Network,
        params: Option<&ModelParams>,
        input: &QTensor,
    ) -> Execution {
        assert_eq!(
            (input.c, input.h, input.w),
            net.input,
            "input shape does not match the network"
        );
        let wbits = params
            .and_then(|p| p.conv_weights.iter().map(|k| k.bits).max())
            .unwrap_or(net.input_bits);
        self.ensure_cache(net, wbits);
        let cache = self.cache.as_ref().expect("cache populated by ensure_cache");
        // Same bookkeeping as the functional engine: one residency tag
        // per conv layer, all of which miss on the first touch of a
        // network and hit afterwards.
        let warm = match self.residency.as_mut() {
            Some(r) => {
                let mut any_miss = false;
                for tag in 0..cache.conv_layers {
                    if r.acquire(tag) {
                        any_miss = true;
                    }
                }
                !any_miss
            }
            None => false,
        };
        let delta = if warm { cache.warm.clone() } else { cache.cold.clone() };
        let layer_stats = self.record_layer_costs.then(|| {
            if warm {
                cache.warm_layers.clone()
            } else {
                cache.cold_layers.clone()
            }
        });
        self.stats.merge_serial(&delta);
        Execution { outputs: None, stats: delta, layer_stats }
    }

    fn set_layer_recording(&mut self, on: bool) {
        self.record_layer_costs = on;
    }
}

/// Builds engines of one kind for one operating point — the seam that
/// keeps the serve pool engine-generic (one factory = one homogeneous
/// chip pool).
#[derive(Debug, Clone)]
pub struct EngineFactory {
    cfg: ArchConfig,
    kind: EngineKind,
    fault: Option<FaultPlan>,
}

impl EngineFactory {
    /// Factory building `kind` engines for `cfg`.
    pub fn new(cfg: ArchConfig, kind: EngineKind) -> Self {
        Self { cfg, kind, fault: None }
    }

    /// Kind of engine this factory builds.
    pub fn kind(&self) -> EngineKind {
        self.kind
    }

    /// Operating point the engines simulate.
    pub fn cfg(&self) -> &ArchConfig {
        &self.cfg
    }

    /// Install a fault plan on every engine this factory builds (an
    /// inactive plan clears it). The serve pool uses this to give each
    /// chip its own seeded fault stream.
    pub fn set_fault_plan(&mut self, plan: FaultPlan) {
        self.fault = plan.is_active().then_some(plan);
    }

    /// The factory's fault plan, if an active one is installed.
    pub fn fault_plan(&self) -> Option<&FaultPlan> {
        self.fault.as_ref()
    }

    /// Build a fresh engine.
    pub fn build(&self) -> Box<dyn InferenceEngine> {
        let mut engine: Box<dyn InferenceEngine> = match self.kind {
            EngineKind::Functional => Box::new(FunctionalEngine::new(self.cfg.clone())),
            EngineKind::Analytic => Box::new(AnalyticEngine::new(self.cfg.clone())),
        };
        if let Some(plan) = self.fault {
            engine.set_fault_plan(plan);
        }
        engine
    }

    /// Plan `net` on a fresh engine of this factory's kind.
    pub fn plan(&self, net: &Network) -> ExecutionPlan {
        self.build().plan(net)
    }
}

/// A pool of simulated PIM chips: one [`EngineFactory`] per chip, so
/// every chip can simulate its own operating point (capacity, bus
/// width, …) while the pool stays engine-generic. All factories build
/// the same [`EngineKind`] — fidelity is a property of the serve, not
/// of an individual chip.
#[derive(Debug, Clone)]
pub struct PoolSpec {
    factories: Vec<EngineFactory>,
}

impl PoolSpec {
    /// Pool of `chips` identical chips at operating point `cfg`.
    ///
    /// # Panics
    /// If `chips` is 0.
    pub fn homogeneous(cfg: ArchConfig, kind: EngineKind, chips: usize) -> Self {
        assert!(chips >= 1, "need at least one chip");
        Self { factories: (0..chips).map(|_| EngineFactory::new(cfg.clone(), kind)).collect() }
    }

    /// Heterogeneous pool: one chip per `ArchConfig`, in order.
    ///
    /// # Panics
    /// If `cfgs` is empty.
    pub fn heterogeneous(cfgs: Vec<ArchConfig>, kind: EngineKind) -> Self {
        assert!(!cfgs.is_empty(), "need at least one chip");
        Self { factories: cfgs.into_iter().map(|cfg| EngineFactory::new(cfg, kind)).collect() }
    }

    /// Pool of `chips` chips sharing an existing factory's operating
    /// point and kind.
    ///
    /// # Panics
    /// If `chips` is 0.
    pub fn replicate(factory: EngineFactory, chips: usize) -> Self {
        assert!(chips >= 1, "need at least one chip");
        Self { factories: vec![factory; chips] }
    }

    /// Number of chips in the pool.
    pub fn chips(&self) -> usize {
        self.factories.len()
    }

    /// The factory (operating point) of chip `chip`.
    pub fn factory(&self, chip: usize) -> &EngineFactory {
        &self.factories[chip]
    }

    /// Mutable access to chip `chip`'s factory — used to install
    /// per-chip fault plans or adjust an operating point before the
    /// pool is served.
    pub fn factory_mut(&mut self, chip: usize) -> &mut EngineFactory {
        &mut self.factories[chip]
    }

    /// All per-chip factories, in chip order.
    pub fn factories(&self) -> &[EngineFactory] {
        &self.factories
    }

    /// Engine kind every chip in the pool builds.
    pub fn kind(&self) -> EngineKind {
        self.factories[0].kind()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::stats::Phase;
    use crate::cnn::network::{alexnet, micro_cnn, small_cnn};
    use crate::cnn::ref_exec;

    fn input_for(net: &Network, seed: u64) -> QTensor {
        QTensor::random(net.input.0, net.input.1, net.input.2, net.input_bits, seed)
    }

    #[test]
    fn factory_builds_the_requested_kind() {
        let cfg = ArchConfig::paper();
        for kind in [EngineKind::Functional, EngineKind::Analytic] {
            let engine = EngineFactory::new(cfg.clone(), kind).build();
            assert_eq!(engine.kind(), kind);
            assert!(engine.residency().is_none(), "engines start in latency mode");
        }
    }

    #[test]
    fn functional_plan_accepts_full_size_networks_via_tiling() {
        let factory = EngineFactory::new(ArchConfig::paper(), EngineKind::Functional);
        let small = factory.plan(&small_cnn(3));
        assert!(small.supported, "{:?}", small.unsupported_reason);
        assert_eq!(small.fidelity, Fidelity::BitAccurate);
        // The multi-tile mapping makes the full-size benchmarks
        // runnable bit-accurately: wide feature maps are sharded, not
        // rejected.
        for net in [alexnet(8), crate::cnn::network::vgg19(8)] {
            let plan = factory.plan(&net);
            assert!(plan.supported, "{}: {:?}", net.name, plan.unsupported_reason);
        }
        // The analytic engine takes anything.
        let analytic = EngineFactory::new(ArchConfig::paper(), EngineKind::Analytic);
        let plan = analytic.plan(&alexnet(8));
        assert!(plan.supported);
        assert_eq!(plan.fidelity, Fidelity::Synthesized);
        assert!(plan.total_macs > 0);
    }

    #[test]
    fn functional_limit_reports_every_violation_with_resources() {
        // ResNet50 at 8 bits still cannot run bit-accurately: the 7x7
        // average-pool's in-array window sum does not fit the subarray
        // height at 8-bit activations. The report must name the node,
        // the layer, and required vs. available rows.
        let factory = EngineFactory::new(ArchConfig::paper(), EngineKind::Functional);
        let plan = factory.plan(&crate::cnn::network::resnet50(8));
        assert!(!plan.supported);
        let reason = plan.unsupported_reason.expect("reason");
        assert!(reason.contains("avgpool"), "names the layer: {reason}");
        assert!(reason.contains("rows"), "names the resource: {reason}");
        // A network with several violations reports all of them, not
        // just the first: a 20x200 kernel trips both the column limit
        // and the weight-buffer height at once.
        let net = Network {
            name: "giant-kernel".into(),
            input: (1, 300, 300),
            input_bits: 3,
            nodes: vec![crate::cnn::network::Node {
                layer: Layer::Conv { out_c: 2, kh: 20, kw: 200, stride: 1, pad: 0 },
                input: None,
            }],
        };
        let plan = factory.plan(&net);
        let reason = plan.unsupported_reason.expect("reason");
        assert!(reason.contains("columns"), "{reason}");
        assert!(reason.contains("weight-buffer"), "{reason}");
        assert!(reason.matches("node 0").count() >= 2, "all violations listed: {reason}");
    }

    #[test]
    fn functional_execute_via_trait_is_bit_exact() {
        let net = micro_cnn(3);
        let params = ModelParams::random(&net, 3, 5);
        let input = input_for(&net, 6);
        let golden = ref_exec::execute(&net, &params, &input);
        let mut engine =
            EngineFactory::new(ArchConfig::paper(), EngineKind::Functional).build();
        let exec = engine.execute(&net, Some(&params), &input);
        assert_eq!(exec.outputs.as_ref().expect("bit-accurate"), &golden);
        assert!(exec.stats.total_latency_ns() > 0.0);
        assert!(exec.stats.ops.ands > 0);
    }

    #[test]
    fn analytic_engine_is_deterministic_and_outputless() {
        let net = small_cnn(4);
        let input = input_for(&net, 9);
        let mut engine = AnalyticEngine::new(ArchConfig::paper());
        let a = engine.execute(&net, None, &input);
        let b = engine.execute(&net, None, &input);
        assert!(a.outputs.is_none() && b.outputs.is_none());
        assert_eq!(a.stats, b.stats, "no residency: every request streams weights");
        assert!(a.stats.total_latency_ns() > 0.0);
        // Accumulated stats are the serial fold of the two requests.
        assert!(
            (engine.stats.total_energy_fj() - 2.0 * a.stats.total_energy_fj()).abs()
                < 1e-9 * engine.stats.total_energy_fj()
        );
    }

    #[test]
    fn layer_recording_deltas_fold_to_request_totals() {
        let net = micro_cnn(3);
        let params = ModelParams::random(&net, 3, 5);
        let input = input_for(&net, 6);
        // Analytic: the per-node vector folds to the request stats
        // bit-for-bit (the cache total *is* that fold), and recording
        // does not change the request stats themselves.
        let mut engine = AnalyticEngine::new(ArchConfig::paper());
        let off = engine.execute(&net, None, &input);
        assert!(off.layer_stats.is_none(), "recording is off by default");
        InferenceEngine::set_layer_recording(&mut engine, true);
        let exec = engine.execute(&net, None, &input);
        assert_eq!(exec.stats, off.stats, "recording must not perturb stats");
        let layers = exec.layer_stats.expect("recording on");
        assert_eq!(layers.len(), net.nodes.len());
        let mut fold = Stats::default();
        for s in &layers {
            fold.merge_serial(s);
        }
        assert_eq!(fold.total_latency_ns().to_bits(), exec.stats.total_latency_ns().to_bits());
        assert_eq!(fold.ops, exec.stats.ops);
        // Functional: node deltas cover everything except the
        // pre-schedule input load; node-attributed op counts match the
        // request's exactly (every AND happens inside some node).
        let mut engine = EngineFactory::new(ArchConfig::paper(), EngineKind::Functional).build();
        engine.set_layer_recording(true);
        let exec = engine.execute(&net, Some(&params), &input);
        let layers = exec.layer_stats.expect("recording on");
        assert_eq!(layers.len(), net.nodes.len());
        let mut fold = Stats::default();
        for s in &layers {
            fold.merge_serial(s);
        }
        assert_eq!(fold.ops.ands, exec.stats.ops.ands);
        assert!(fold.total_latency_ns() > 0.0);
        assert!(fold.total_latency_ns() <= exec.stats.total_latency_ns());
    }

    #[test]
    fn analytic_residency_amortises_the_weight_stream() {
        let net = small_cnn(4);
        let input = input_for(&net, 9);
        let mut engine = AnalyticEngine::new(ArchConfig::paper());
        InferenceEngine::make_weights_resident(&mut engine);
        let cold = engine.execute(&net, None, &input);
        let warm = engine.execute(&net, None, &input);
        assert!(warm.stats.total_latency_ns() < cold.stats.total_latency_ns());
        assert!(
            warm.stats[Phase::LoadData].latency_ns < cold.stats[Phase::LoadData].latency_ns,
            "warm requests must skip the weight stream"
        );
        let convs = net
            .nodes
            .iter()
            .filter(|n| matches!(n.layer, Layer::Conv { .. }))
            .count();
        let r = engine.residency().expect("resident mode");
        assert_eq!(r.misses as usize, convs);
        assert_eq!(r.hits as usize, convs);
    }

    #[test]
    fn analytic_calibration_change_invalidates_the_synthesis_cache() {
        let net = small_cnn(3);
        let input = input_for(&net, 4);
        let mut engine = AnalyticEngine::new(ArchConfig::paper());
        let before = engine.execute(&net, None, &input);
        // Disable the cross-writing pipeline: same op mix, slower — the
        // cached synthesis must be rebuilt, not served stale.
        engine.model.cal.cross_writing_pipeline = false;
        let after = engine.execute(&net, None, &input);
        assert!(
            after.stats.total_latency_ns() > before.stats.total_latency_ns(),
            "calibration change must re-cost the op streams"
        );
        assert_eq!(after.stats.ops, before.stats.ops, "op mix is calibration-independent");
    }

    #[test]
    fn analytic_cache_keys_on_structure_not_name_and_length() {
        // Same name, same node count, different structure: the old
        // `(name, nodes.len())` cache key served stale stats here.
        let a = small_cnn(4);
        let mut b = small_cnn(4);
        if let Layer::Conv { stride, .. } = &mut b.nodes[5].layer {
            *stride = 2;
        } else {
            panic!("expected a conv at node 5");
        }
        assert_eq!(a.name, b.name);
        assert_eq!(a.nodes.len(), b.nodes.len());
        let input = input_for(&a, 8);
        let mut engine = AnalyticEngine::new(ArchConfig::paper());
        let ea = engine.execute(&a, None, &input);
        let eb = engine.execute(&b, None, &input);
        assert_ne!(
            ea.stats, eb.stats,
            "structurally different network must be re-costed, not served stale"
        );
    }

    #[test]
    fn pool_spec_carries_one_operating_point_per_chip() {
        let mut fat = ArchConfig::paper();
        fat.capacity_mb = 64;
        let mut thin = ArchConfig::paper();
        thin.capacity_mb = 16;
        thin.bus_width_bits = 32;
        let pool = PoolSpec::heterogeneous(vec![fat, thin], EngineKind::Analytic);
        assert_eq!(pool.chips(), 2);
        assert_eq!(pool.kind(), EngineKind::Analytic);
        assert_eq!(pool.factory(0).cfg().capacity_mb, 64);
        assert_eq!(pool.factory(1).cfg().capacity_mb, 16);
        assert_eq!(pool.factory(1).cfg().bus_width_bits, 32);
        let homo = PoolSpec::homogeneous(ArchConfig::paper(), EngineKind::Functional, 3);
        assert_eq!(homo.chips(), 3);
        assert!(homo.factories().iter().all(|f| f.kind() == EngineKind::Functional));
        let rep = PoolSpec::replicate(homo.factory(0).clone(), 2);
        assert_eq!(rep.chips(), 2);
        assert_eq!(rep.kind(), EngineKind::Functional);
    }

    #[test]
    fn analytic_network_switch_evicts_resident_weights() {
        let micro = micro_cnn(3);
        let small = small_cnn(3);
        let mut engine = AnalyticEngine::new(ArchConfig::paper());
        InferenceEngine::make_weights_resident(&mut engine);
        engine.execute(&micro, None, &input_for(&micro, 1));
        engine.execute(&small, None, &input_for(&small, 2));
        let r = engine.residency().expect("resident mode");
        assert_eq!(r.hits, 0, "network switch must not hit stale weights");
        // Switching back misses again.
        engine.execute(&micro, None, &input_for(&micro, 3));
        let r = engine.residency().expect("resident mode");
        assert_eq!(r.hits, 0);
    }
}
