//! The inference coordinator: the paper's system contribution at L3.
//!
//! One engine abstraction, two implementations sharing one cost model:
//!
//! * [`engine::InferenceEngine`] — the common contract: plan a network,
//!   execute requests, manage weight residency. Everything above
//!   (serving, CLI, benches) is generic over it.
//! * [`functional::FunctionalEngine`] — implements it bit-accurately:
//!   every layer runs on simulated NAND-SPIN subarrays (small networks;
//!   outputs are checked against the golden executor and the PJRT
//!   artifact).
//! * [`engine::AnalyticEngine`] — implements it in closed form, as a
//!   stateful serving wrapper around [`analytic::AnalyticModel`]: the
//!   op-count model for the full-scale benchmark networks
//!   (AlexNet / VGG19 / ResNet50) and the design-space sweeps that
//!   generate the paper's figures.
//!
//! On top sits the [`serve`](mod@serve) subsystem: the batched
//! multi-chip serving runtime (per-network SLO batching lanes →
//! cost-aware shard router scheduling on closed-form batching laws →
//! weight-resident engine pools built by an [`engine::EngineFactory`],
//! one `ArchConfig` per chip) that models the Table 3 steady-state
//! deployment for either engine, plus a hybrid mode that serves
//! analytically and spot-checks against functional replays.

pub mod analytic;
pub mod engine;
pub mod functional;
pub mod serve;

pub use analytic::{AnalyticModel, Calibration};
pub use engine::{
    AnalyticEngine, EngineFactory, EngineKind, Execution, ExecutionPlan, Fidelity,
    InferenceEngine, PoolSpec,
};
pub use functional::{FunctionalEngine, HostLayerProfile};
pub use serve::{serve, serve_pool};
pub use serve::{
    BatchLaw, ChipReport, Completion, CostTable, EngineMode, FaultSummary, NetworkReport,
    Request, RouteDecision, ServeConfig, ServeReport, ServedNetwork, SloPolicy, SpotCheck,
};

use crate::arch::area::AreaModel;
use crate::arch::config::ArchConfig;
use crate::arch::stats::Stats;
use crate::cnn::network::Network;
use crate::cnn::ref_exec::{ModelParams, WideTensor};
use crate::cnn::tensor::QTensor;
use crate::metrics::Metrics;

/// High-level façade over the two engines.
#[derive(Debug, Clone)]
pub struct Coordinator {
    /// Architecture configuration.
    pub cfg: ArchConfig,
}

impl Coordinator {
    /// Coordinator for `cfg`.
    pub fn new(cfg: ArchConfig) -> Self {
        Self { cfg }
    }

    /// Paper operating point.
    pub fn paper() -> Self {
        Self::new(ArchConfig::paper())
    }

    /// Analytic inference stats for a network at weight precision `wbits`.
    pub fn analytic_stats(&self, net: &Network, wbits: u8) -> Stats {
        AnalyticModel::new(self.cfg.clone()).network_stats(net, wbits)
    }

    /// Analytic metrics (FPS / GOPS / efficiency) for a network.
    pub fn analytic_metrics(&self, net: &Network, wbits: u8) -> Metrics {
        let stats = self.analytic_stats(net, wbits);
        let area = AreaModel::default().total_mm2(&self.cfg);
        Metrics::from_stats(
            format!("NAND-SPIN/{}/w{}i{}", net.name, wbits, net.input_bits),
            net.total_ops() as f64,
            &stats,
            area,
        )
    }

    /// Steady-state throughput metrics: weights resident across the
    /// batch (loaded once), per-image cost excludes the weight stream —
    /// the serving condition Table 3's FPS numbers describe.
    pub fn throughput_metrics(&self, net: &Network, wbits: u8) -> Metrics {
        let mut model = AnalyticModel::new(self.cfg.clone());
        model.cal.weights_resident = true;
        let stats = model.network_stats(net, wbits);
        let area = AreaModel::default().total_mm2(&self.cfg);
        Metrics::from_stats(
            format!("NAND-SPIN/{}/w{}i{} (resident)", net.name, wbits, net.input_bits),
            net.total_ops() as f64,
            &stats,
            area,
        )
    }

    /// Serve a request stream through the batched multi-chip runtime
    /// (see [`serve()`](fn@serve::serve)) at this coordinator's
    /// operating point. `params` may be `None` for analytic-only serves
    /// (full-size networks).
    pub fn serve(
        &self,
        scfg: &ServeConfig,
        net: &Network,
        params: Option<&ModelParams>,
        requests: Vec<Request>,
    ) -> ServeReport {
        serve::serve(&self.cfg, scfg, net, params, requests)
    }

    /// Engine factory for this coordinator's operating point.
    pub fn engine_factory(&self, kind: EngineKind) -> EngineFactory {
        EngineFactory::new(self.cfg.clone(), kind)
    }

    /// Bit-accurate functional run; returns all node outputs plus stats.
    pub fn functional_run(
        &self,
        net: &Network,
        params: &ModelParams,
        input: &QTensor,
    ) -> (Vec<WideTensor>, Stats) {
        let mut eng = FunctionalEngine::new(self.cfg.clone());
        let outs = eng.run(net, params, input);
        (outs, eng.stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::network::{resnet50, small_cnn};
    use crate::cnn::ref_exec;

    #[test]
    fn analytic_metrics_have_positive_fps() {
        let c = Coordinator::paper();
        let m = c.analytic_metrics(&resnet50(8), 8);
        assert!(m.fps() > 1.0 && m.fps() < 100_000.0, "fps {}", m.fps());
        assert!(m.gops() > 0.0);
    }

    #[test]
    fn functional_run_agrees_with_golden() {
        let net = small_cnn(3);
        let params = ModelParams::random(&net, 3, 5);
        let input = QTensor::random(2, 14, 22, 3, 6);
        let golden = ref_exec::execute(&net, &params, &input);
        let (outs, stats) = Coordinator::paper().functional_run(&net, &params, &input);
        assert_eq!(outs.last(), golden.last());
        assert!(stats.ops.ands > 0);
    }
}
