//! Multi-worker inference service: the L3 serving loop.
//!
//! A bounded request queue feeds `workers` threads, each owning its own
//! functional engine (one engine ≙ one PIM chip); completions stream
//! back with per-request simulated latency/energy plus host-side queue
//! timing. This is the process topology a deployment would run — the
//! paper's accelerator behind a batching front-end. (Thread-based: the
//! build is offline, so no async runtime; the queue discipline is FIFO
//! with backpressure from the bounded channel.)

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::Instant;

use crate::arch::config::ArchConfig;
use crate::arch::stats::Stats;
use crate::cnn::network::Network;
use crate::cnn::ref_exec::{ModelParams, WideTensor};
use crate::cnn::tensor::QTensor;

use super::functional::FunctionalEngine;

/// One inference request.
#[derive(Debug)]
pub struct Request {
    /// Caller-assigned id.
    pub id: u64,
    /// Input image.
    pub image: QTensor,
}

/// One completed inference.
#[derive(Debug)]
pub struct Completion {
    /// Request id.
    pub id: u64,
    /// Final network output.
    pub output: WideTensor,
    /// Simulated PIM stats for this inference.
    pub stats: Stats,
    /// Host wall-clock the request spent queued + executing, seconds.
    pub host_seconds: f64,
    /// Worker that served the request.
    pub worker: usize,
}

/// Summary of a served batch.
#[derive(Debug)]
pub struct ServeReport {
    /// All completions, in completion order.
    pub completions: Vec<Completion>,
    /// Total host wall-clock, seconds.
    pub wall_seconds: f64,
}

impl ServeReport {
    /// Aggregate simulated PIM latency (ms) across requests.
    pub fn total_sim_ms(&self) -> f64 {
        self.completions.iter().map(|c| c.stats.total_latency_ms()).sum()
    }

    /// Simulated steady-state throughput: requests per simulated second,
    /// with per-chip parallelism across workers.
    pub fn sim_fps(&self, workers: usize) -> f64 {
        let per_chip_ms = self.total_sim_ms() / workers.max(1) as f64;
        self.completions.len() as f64 / (per_chip_ms * 1e-3)
    }
}

/// Serve `requests` on `workers` parallel engines (one simulated PIM
/// chip each) with a bounded FIFO queue.
///
/// # Panics
/// If a worker thread panics (functional-engine divergence).
pub fn serve(
    cfg: &ArchConfig,
    net: &Network,
    params: &ModelParams,
    requests: Vec<Request>,
    workers: usize,
) -> ServeReport {
    let started = Instant::now();
    let (req_tx, req_rx) = mpsc::sync_channel::<(Request, Instant)>(workers * 2);
    let req_rx = Arc::new(Mutex::new(req_rx));
    let (done_tx, done_rx) = mpsc::channel::<Completion>();

    let n = requests.len();
    thread::scope(|scope| {
        for w in 0..workers.max(1) {
            let req_rx = Arc::clone(&req_rx);
            let done_tx = done_tx.clone();
            let cfg = cfg.clone();
            let net = net.clone();
            let params = params.clone();
            scope.spawn(move || {
                loop {
                    let msg = req_rx.lock().expect("queue lock").recv();
                    let Ok((req, enqueued)) = msg else { break };
                    let mut engine = FunctionalEngine::new(cfg.clone());
                    let outs = engine.run(&net, &params, &req.image);
                    let output = outs.into_iter().last().expect("non-empty network");
                    done_tx
                        .send(Completion {
                            id: req.id,
                            output,
                            stats: engine.stats,
                            host_seconds: enqueued.elapsed().as_secs_f64(),
                            worker: w,
                        })
                        .expect("completion channel");
                }
            });
        }
        drop(done_tx);

        // Feed the queue (backpressure via the bounded channel).
        for req in requests {
            req_tx.send((req, Instant::now())).expect("request channel");
        }
        drop(req_tx);
    });

    let completions: Vec<Completion> = done_rx.into_iter().collect();
    assert_eq!(completions.len(), n, "all requests must complete");
    ServeReport { completions, wall_seconds: started.elapsed().as_secs_f64() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::network::small_cnn;
    use crate::cnn::ref_exec;

    #[test]
    fn serves_all_requests_correctly_across_workers() {
        let net = small_cnn(3);
        let params = ModelParams::random(&net, 3, 2);
        let images: Vec<QTensor> =
            (0..6).map(|i| QTensor::random(2, 14, 22, 3, 100 + i)).collect();
        let requests = images
            .iter()
            .enumerate()
            .map(|(i, img)| Request { id: i as u64, image: img.clone() })
            .collect();
        let report = serve(&ArchConfig::paper(), &net, &params, requests, 3);
        assert_eq!(report.completions.len(), 6);
        // Every completion matches the golden executor, regardless of
        // which worker served it.
        for c in &report.completions {
            let golden = ref_exec::execute(&net, &params, &images[c.id as usize]);
            assert_eq!(&c.output, golden.last().unwrap(), "request {}", c.id);
            assert!(c.stats.total_latency_ns() > 0.0);
        }
        // Multiple workers actually participated.
        let distinct: std::collections::HashSet<usize> =
            report.completions.iter().map(|c| c.worker).collect();
        assert!(distinct.len() >= 2, "expected >=2 workers, got {distinct:?}");
        assert!(report.sim_fps(3) > 0.0);
    }

    #[test]
    fn single_worker_is_fifo_correct() {
        let net = small_cnn(2);
        let params = ModelParams::random(&net, 2, 5);
        let requests = (0..3)
            .map(|i| Request { id: i, image: QTensor::random(2, 14, 22, 2, 7 + i) })
            .collect();
        let report = serve(&ArchConfig::paper(), &net, &params, requests, 1);
        let ids: Vec<u64> = report.completions.iter().map(|c| c.id).collect();
        assert_eq!(ids, vec![0, 1, 2], "single worker preserves FIFO order");
    }
}
