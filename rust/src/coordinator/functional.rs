//! Bit-accurate functional execution of a quantized CNN on simulated
//! NAND-SPIN subarrays.
//!
//! Every layer is executed with *real* subarray operations — erase,
//! program, read, AND + bit-count, and the composed primitives of
//! Figs. 8–11 — on real bit contents; results are read back from the
//! arrays. The outputs must equal [`crate::cnn::ref_exec`] bit-for-bit
//! (checked by integration tests and the `cnn_inference` example), while
//! the accumulated [`Stats`] reflect the same op mix the analytic model
//! counts.
//!
//! Feature maps wider or taller than one subarray are sharded across
//! multiple scratch subarrays by the multi-tile mapping of §4.2
//! ([`TilePlan`]): each tile holds one input slab (its fresh region
//! plus the halo rows/columns shared with its neighbours, re-sent
//! through the bank buffer and charged as in-mat transfer), runs the
//! unchanged bit-plane conv stepper, and the per-tile window sums are
//! stitched back into full-width partials before accumulation — so the
//! accumulator op stream, and therefore the outputs, are independent of
//! the tiling. This is what lets the bit-accurate path run the
//! full-scale benchmarks (AlexNet, VGG19) instead of only the small
//! presets.

use crate::arch::config::ArchConfig;
use crate::arch::stats::{OpLedger, Phase, Stats};
use crate::bank::controller::WeightResidency;
use crate::cnn::layer::Layer;
use crate::cnn::network::Network;
use crate::cnn::quantize::{BnParams, QuantParams};
use crate::cnn::ref_exec::{avg_pool_scale, ModelParams, WideTensor};
use crate::cnn::tensor::{Kernel4, QTensor};
use crate::device::energy::DeviceCosts;
use crate::device::fault::{fault_ctx, mix, FaultPlan};
use crate::mapping::{ConvMapping, PoolSplit, TileExtent, TilePlan};
use crate::subarray::conv::{
    bitplane_conv_counts_tiled, window_sum_planes, BitKernel, ConvGeometry, KernelTiling,
};
use crate::subarray::primitives::{add_columns, compare_columns, multiply_columns, CompareScratch};
use crate::subarray::Subarray;
use crate::util::{pack_columns, unpack_columns};

use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Instant;

/// Minimum bits reserved per accumulator operand slot; a conv layer
/// whose accumulated total needs more precision widens its slots to the
/// exact bound (see [`FunctionalEngine::conv_layer`]).
const ACC_BITS: usize = 24;

/// Bit width of a non-negative value.
fn width_of(v: i64) -> usize {
    debug_assert!(v >= 0);
    (64 - (v as u64).leading_zeros()).max(1) as usize
}

/// Largest value in a tensor (≥ 0 datapath).
fn tensor_width(t: &WideTensor) -> usize {
    width_of(t.data.iter().copied().max().unwrap_or(0))
}

/// All-ones mask over the low `n` bits (`n ≤ 128`).
#[inline]
fn low_mask(n: usize) -> u128 {
    if n >= 128 {
        u128::MAX
    } else {
        (1u128 << n) - 1
    }
}

/// Zero-padded view of an input tensor: index `(c, y, x)` over the
/// padded `(h + 2·pad) × (w + 2·pad)` extent without materialising a
/// padded clone per conv layer (padding is free in NAND-SPIN — padded
/// cells are simply MTJs left in the erased state, so no host copy is
/// ever needed either).
struct PaddedView<'a> {
    t: &'a WideTensor,
    pad: usize,
    /// Channels (same as the underlying tensor).
    c: usize,
    /// Padded height.
    h: usize,
    /// Padded width.
    w: usize,
}

impl<'a> PaddedView<'a> {
    fn new(t: &'a WideTensor, pad: usize) -> Self {
        Self { t, pad, c: t.c, h: t.h + 2 * pad, w: t.w + 2 * pad }
    }

    /// Value at padded coordinates (0 inside the border).
    #[inline]
    fn at(&self, c: usize, y: usize, x: usize) -> i64 {
        if y < self.pad || x < self.pad {
            return 0;
        }
        let (iy, ix) = (y - self.pad, x - self.pad);
        if iy >= self.t.h || ix >= self.t.w {
            return 0;
        }
        self.t.at(c, iy, ix)
    }
}

/// Bit-plane slab of `x`: one `u128` word per slab row, where bit `j`
/// of word `y` is bit `n` of `x(ic, in_y0 + y, in_x0 + j)` over the
/// tile's input rectangle. The single-tile case reproduces
/// [`QTensor::bitplane_rows`] exactly (values are `< 2^ibits` on the
/// quantized datapath, so selecting bit `n` directly equals quantizing
/// first).
fn slab_rows(x: &PaddedView<'_>, ic: usize, n: usize, tile: &TileExtent) -> Vec<u128> {
    let mut rows = Vec::with_capacity(tile.in_h);
    for y in 0..tile.in_h {
        let mut word = 0u128;
        for j in 0..tile.in_w {
            word |= (((x.at(ic, tile.in_y0 + y, tile.in_x0 + j) >> n) & 1) as u128) << j;
        }
        rows.push(word);
    }
    rows
}

/// Charge an inter-layer / off-chip transfer into `stats` — the free
/// function form of [`FunctionalEngine::charge_transfer`], usable from
/// the per-filter worker passes that record into their own ledger
/// entry instead of the engine's accumulated stats.
fn charge_transfer_into(
    costs: &DeviceCosts,
    bus_width_bits: usize,
    stats: &mut Stats,
    bits: u64,
    phase: Phase,
) {
    let cycles = bits.div_ceil(bus_width_bits as u64);
    let e = match phase {
        Phase::LoadData => costs.global_bus_energy_per_bit_fj,
        _ => costs.bus_energy_per_bit_fj,
    };
    if phase == Phase::LoadData {
        stats.ops.global_bus_bits += bits;
    } else {
        stats.ops.local_bus_bits += bits;
    }
    stats.record(phase, e * bits as f64, cycles as f64 * costs.bus_cycle_ns);
}

/// Host wall-time profile of one conv layer's bit-accurate execution —
/// the `serve --verbose` breakdown that shows where the *host* (not the
/// simulated device) spends its time: slab loading, the parallel
/// filter passes, and within them the conv stepper vs the cross-writing
/// accumulation. All figures are wall-clock measurements and therefore
/// machine-dependent; simulated `Stats` never depend on them.
#[derive(Debug, Clone)]
pub struct HostLayerProfile {
    /// Node index within the network.
    pub node: usize,
    /// Human-readable layer shape (`oc×ic×kh×kw`).
    pub label: String,
    /// Worker threads the filter fan-out actually used.
    pub workers: usize,
    /// Tiles in the layer's multi-tile plan.
    pub tiles: usize,
    /// Wall time of the (tile, channel, bit-plane) slab loads, ns.
    pub load_ns: u64,
    /// Wall time of the whole filter fan-out (all workers), ns.
    pub pass_ns: u64,
    /// Conv-stepper time summed over workers, ns.
    pub conv_ns: u64,
    /// Accumulation time summed over workers, ns.
    pub acc_ns: u64,
}

/// The functional engine.
pub struct FunctionalEngine {
    cfg: ArchConfig,
    /// Accumulated cost statistics.
    pub stats: Stats,
    /// Weight-residency tracker (serving mode). `None` — the default —
    /// streams weights on every inference, the paper's latency condition.
    residency: Option<WeightResidency>,
    /// Conv layers encountered so far in the current `run` (residency
    /// tag).
    conv_seq: usize,
    /// Structural fingerprint ([`Network::fingerprint`]) of the network
    /// whose weights are resident; a different network evicts them.
    resident_net: Option<u64>,
    /// Reusable subarray allocations: every layer used to build fresh
    /// subarrays (one per input bit-plane, per pooling batch, per
    /// affine-transform call); the pool hands the same allocations back
    /// out after a cost-free [`Subarray::clear_state`], so steady-state
    /// serving does no per-layer allocation of row storage.
    scratch: Vec<Subarray>,
    /// Tile-capacity override for conv planning (testing hook): plan
    /// feature-map tiles as if each scratch subarray had only
    /// `(rows, cols)` cells. `None` — the default — uses the real
    /// subarray size.
    tile_cap: Option<(usize, usize)>,
    /// Intra-request worker budget for the per-filter fan-out. `None`
    /// — the default — resolves the `NANDSPIN_HOST_WORKERS`
    /// environment variable, then the host's available parallelism.
    /// The serving pool sets this explicitly so request-split and
    /// intra-request parallelism share one budget.
    host_workers: Option<usize>,
    /// When false (testing hook), degenerate-shape fast paths (1×1
    /// kernels) fall back to the generic stepper; outputs and `Stats`
    /// must be bit-identical either way.
    fast_paths: bool,
    /// Per-conv-layer host wall-time profile of the most recent `run`.
    profile: Vec<HostLayerProfile>,
    /// When true, `run` snapshots `stats` around every node and keeps
    /// the per-node deltas in `layer_stats` (trace hook; off by
    /// default so untraced runs do no extra work).
    record_layer_costs: bool,
    /// Per-node simulated cost deltas of the most recent `run`
    /// (empty unless `record_layer_costs`).
    layer_stats: Vec<Stats>,
    /// Active fault-injection plan ([`FunctionalEngine::set_fault_plan`]).
    /// `None` — the default, and any plan with all-zero rates — keeps
    /// every code path bit-identical to the fault-free model.
    fault: Option<FaultPlan>,
    /// Fault context epoch of the current `run`: a hash of the input
    /// tensor, so each request draws an independent fault stream that
    /// is a pure function of the request (never of replica chunking,
    /// warm-up replays or host worker count).
    fault_epoch: u64,
    /// Per-run sequence number of scratch-subarray checkouts; combined
    /// with the epoch it gives every logical use of a scratch subarray
    /// its own fault context in deterministic program order.
    fault_seq: u64,
}

/// Upper bound on pooled scratch subarrays (a conv layer holds
/// `channels × activation-bits` planes live at once; beyond this the
/// extras are simply dropped).
const SCRATCH_POOL_CAP: usize = 256;

impl FunctionalEngine {
    /// New engine for `cfg`.
    pub fn new(cfg: ArchConfig) -> Self {
        cfg.validate().expect("invalid config");
        Self {
            cfg,
            stats: Stats::default(),
            residency: None,
            conv_seq: 0,
            resident_net: None,
            scratch: Vec::new(),
            tile_cap: None,
            host_workers: None,
            fast_paths: true,
            profile: Vec::new(),
            record_layer_costs: false,
            layer_stats: Vec::new(),
            fault: None,
            fault_epoch: 0,
            fault_seq: 0,
        }
    }

    /// Install a fault-injection plan: subsequent runs inject the
    /// plan's stochastic device faults (and recover them through the
    /// charged write-verify-retry loop). An inactive plan (all-zero
    /// rates) installs nothing. Fault events are a pure function of
    /// `(plan, input, layer, filter)`, so runs are bit-identical across
    /// repeats and at every host worker count.
    pub fn set_fault_plan(&mut self, plan: FaultPlan) {
        self.fault = plan.is_active().then_some(plan);
    }

    /// The installed fault plan, if any.
    pub fn fault_plan(&self) -> Option<&FaultPlan> {
        self.fault.as_ref()
    }

    /// Pin the intra-request worker budget: the per-filter fan-out of
    /// each conv layer uses at most `workers` host threads. Changes
    /// host wall time only — outputs and [`Stats`] are bit-identical at
    /// every worker count (each filter pass records into its own ledger
    /// entry, merged in deterministic filter order). The serving pool
    /// calls this with its per-replica share so serve-level request
    /// splitting and intra-request parallelism never oversubscribe the
    /// one `ServeConfig::host_workers` / `NANDSPIN_HOST_WORKERS`
    /// budget.
    pub fn set_host_workers(&mut self, workers: usize) {
        self.host_workers = Some(workers.max(1));
    }

    /// Disable degenerate-shape fast paths (testing hook): 1×1 conv
    /// layers run the generic tiled stepper instead of the flat-buffer
    /// fast path. Outputs and [`Stats`] must be bit-identical either
    /// way — asserted by the fast-path equivalence property tests.
    pub fn disable_fast_paths(&mut self) {
        self.fast_paths = false;
    }

    /// Host wall-time profile of the most recent [`FunctionalEngine::run`],
    /// one entry per conv layer. Wall-clock figures — machine-dependent,
    /// never part of the simulated result.
    pub fn host_profile(&self) -> &[HostLayerProfile] {
        &self.profile
    }

    /// Enable (or disable) per-node simulated cost recording: each
    /// subsequent [`FunctionalEngine::run`] keeps a zero-based
    /// [`Stats`] delta per network node, retrievable via
    /// [`FunctionalEngine::take_layer_stats`]. Recording only
    /// *observes* the one stats accumulation (snapshot + `delta_since`
    /// around each node), so outputs and totals are bit-identical with
    /// it on or off.
    pub fn set_layer_recording(&mut self, on: bool) {
        self.record_layer_costs = on;
        if !on {
            self.layer_stats.clear();
        }
    }

    /// True when per-node cost recording is enabled.
    pub fn layer_recording(&self) -> bool {
        self.record_layer_costs
    }

    /// Take the per-node simulated cost deltas of the most recent
    /// [`FunctionalEngine::run`] (empty unless recording is enabled;
    /// one [`Stats`] per node, in schedule order). The pre-schedule
    /// input load is charged before any node and is not attributed.
    pub fn take_layer_stats(&mut self) -> Vec<Stats> {
        std::mem::take(&mut self.layer_stats)
    }

    /// Effective intra-request worker budget: the explicit setting,
    /// else `NANDSPIN_HOST_WORKERS`, else the host's parallelism.
    fn effective_workers(&self) -> usize {
        if let Some(w) = self.host_workers {
            return w.max(1);
        }
        if let Ok(v) = std::env::var("NANDSPIN_HOST_WORKERS") {
            if let Ok(w) = v.trim().parse::<usize>() {
                return w.max(1);
            }
        }
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }

    /// Force the conv tile planner to treat each scratch subarray as
    /// having at most `rows × cols` cells (clamped to the real subarray
    /// size), so feature maps that would fit one subarray are sharded
    /// across several tiles anyway. Device ops still execute on
    /// full-size subarrays and the stitched accumulation is
    /// tiling-independent, so outputs are bit-identical to the untiled
    /// run — only the tiling plan (and its documented halo-transfer
    /// overhead) changes. This is the test hook behind the
    /// tiled-vs-untiled equivalence properties.
    pub fn force_tile_capacity(&mut self, rows: usize, cols: usize) {
        self.tile_cap = Some((rows.clamp(8, self.cfg.rows), cols.clamp(1, self.cfg.cols)));
    }

    /// Architecture configuration the engine simulates.
    pub fn cfg(&self) -> &ArchConfig {
        &self.cfg
    }

    /// Switch the engine to the Table 3 serving condition: each conv
    /// layer's weights are streamed over chip I/O once and then stay
    /// resident in the subarray buffers across subsequent inferences of
    /// the *same network*. Running a different network (by structural
    /// fingerprint, [`Network::fingerprint`]) evicts the resident set
    /// and re-streams; note that two distinct `ModelParams` for one
    /// architecture are indistinguishable here — a serving pool pairs
    /// each engine with one parameter set.
    pub fn make_weights_resident(&mut self) {
        if self.residency.is_none() {
            self.residency = Some(WeightResidency::new());
        }
    }

    /// Residency tracker, if the engine is in serving mode.
    pub fn residency(&self) -> Option<&WeightResidency> {
        self.residency.as_ref()
    }

    /// Take a cleared subarray from the scratch pool (or build one).
    /// With a fault plan active, the checkout installs a fresh fault
    /// context derived from the run's input epoch and the checkout
    /// sequence number — deterministic program order, so the fault
    /// stream never depends on pool history or worker count.
    fn take_subarray(&mut self) -> Subarray {
        let mut s = match self.scratch.pop() {
            Some(mut s) => {
                s.clear_state();
                s
            }
            None => Subarray::new(
                self.cfg.rows,
                self.cfg.cols,
                self.cfg.buffer_rows.max(16),
                self.cfg.costs,
            ),
        };
        match self.fault {
            Some(plan) => {
                let ctx = fault_ctx(&[self.fault_epoch, self.fault_seq]);
                self.fault_seq += 1;
                s.set_fault(plan, ctx);
            }
            None => s.clear_fault(),
        }
        s
    }

    /// Return a subarray to the scratch pool for reuse.
    fn recycle_subarray(&mut self, sub: Subarray) {
        if self.scratch.len() < SCRATCH_POOL_CAP {
            self.scratch.push(sub);
        }
    }

    /// Charge an inter-layer / off-chip transfer.
    fn charge_transfer(&mut self, bits: u64, phase: Phase) {
        let bus = self.cfg.bus_width_bits;
        charge_transfer_into(&self.cfg.costs, bus, &mut self.stats, bits, phase);
    }

    /// Store `values` (non-negative, `bits` wide) vertically in `sub` at
    /// rows `base..base+bits`, one value per column. The
    /// horizontal→vertical conversion is one packed 128×128 bit-matrix
    /// transpose ([`pack_columns`]); the charged device ops (one
    /// strip-rewrite per bit row) are unchanged.
    fn store_vertical(
        &mut self,
        sub: &mut Subarray,
        base: usize,
        bits: usize,
        values: &[i64],
        phase: Phase,
    ) {
        assert!(values.len() <= sub.cols());
        let planes = pack_columns(values);
        for (b, &word) in planes.iter().enumerate().take(bits) {
            sub.write_row(base + b, word, &mut self.stats, phase);
        }
    }

    /// Read back `cols` vertical values of `bits` bits at `base` (one
    /// charged row read per bit, one packed transpose to reassemble).
    fn load_vertical(
        &mut self,
        sub: &Subarray,
        base: usize,
        bits: usize,
        cols: usize,
        phase: Phase,
    ) -> Vec<i64> {
        debug_assert!(bits <= 63, "vertical values must fit i64");
        let mut rows = Vec::with_capacity(bits);
        for b in 0..bits {
            rows.push(sub.read_row(base + b, &mut self.stats, phase));
        }
        unpack_columns(&rows, cols)
    }

    /// Run `net` with `params` on `input`, returning all node outputs
    /// (identical to [`crate::cnn::ref_exec::execute`]).
    pub fn run(&mut self, net: &Network, params: &ModelParams, input: &QTensor) -> Vec<WideTensor> {
        assert_eq!((input.c, input.h, input.w), net.input);
        self.conv_seq = 0;
        self.profile.clear();
        self.layer_stats.clear();
        if self.fault.is_some() {
            // Fault epoch: a pure function of the request's input, so
            // every request draws its own stream and a replay of the
            // same request replays the same faults.
            self.fault_epoch = input.data().iter().fold(
                fault_ctx(&[
                    input.c as u64,
                    input.h as u64,
                    input.w as u64,
                    input.bits as u64,
                ]),
                |acc, &v| mix(acc ^ v as u64),
            );
            self.fault_seq = 0;
        }
        if self.residency.is_some() {
            let identity = net.fingerprint();
            if self.resident_net != Some(identity) {
                if let Some(r) = self.residency.as_mut() {
                    r.evict_all();
                }
                self.resident_net = Some(identity);
            }
        }
        let input_wide = WideTensor::from_q(input);
        // Off-chip load of the input image.
        self.charge_transfer(
            (input.c * input.h * input.w * input.bits as usize) as u64,
            Phase::LoadData,
        );
        let mut outs: Vec<WideTensor> = Vec::with_capacity(net.nodes.len());
        let (mut ci, mut bi, mut qi) = (0usize, 0usize, 0usize);
        let mut act_bits = net.input_bits as usize;

        for (i, node) in net.nodes.iter().enumerate() {
            // Borrow the source tensor in place — per-node clones of
            // multi-megabyte feature maps were pure host overhead.
            let src: &WideTensor = match node.input {
                Some(j) => &outs[j],
                None if i == 0 => &input_wide,
                None => &outs[i - 1],
            };
            // Trace hook: snapshot around the node so its charged cost
            // can be attributed. Pure observation of the one
            // accumulator — the fold of charges is unchanged.
            let snap = self.record_layer_costs.then(|| self.stats.clone());
            let out = match node.layer {
                Layer::Conv { out_c, kh, kw, stride, pad } => {
                    let k = &params.conv_weights[ci];
                    ci += 1;
                    let _ = out_c;
                    let y = self.conv_layer(src, act_bits, k, kh, kw, stride, pad, i == 0, i);
                    act_bits = tensor_width(&y);
                    y
                }
                Layer::MaxPool { k, stride } => self.maxpool_layer(src, act_bits, k, stride),
                Layer::AvgPool { k, stride } => {
                    let y = self.avgpool_layer(src, act_bits, k, stride);
                    act_bits = tensor_width(&y);
                    y
                }
                Layer::BatchNorm => {
                    let p = &params.bn[bi];
                    bi += 1;
                    let y = self.bn_layer(src, act_bits, p);
                    act_bits = tensor_width(&y);
                    y
                }
                Layer::Relu => {
                    // Values are non-negative on the unsigned datapath;
                    // charge the MSB-check pass (§4.2).
                    let groups = ((src.c * src.h * src.w) as u64)
                        .div_ceil(self.cfg.cols as u64);
                    let c = self.cfg.costs;
                    self.stats.ops.reads += groups;
                    self.stats.record(
                        Phase::Other,
                        groups as f64 * self.cfg.cols as f64 * c.read_energy_per_bit_fj,
                        groups as f64 * c.read_latency_ns,
                    );
                    src.clone()
                }
                Layer::Quantize { bits } => {
                    let p = params.quant[qi];
                    qi += 1;
                    let y = self.quantize_layer(src, act_bits, p);
                    act_bits = bits as usize;
                    y
                }
                Layer::Residual { from } => {
                    let y = self.residual_layer(src, &outs[from], act_bits);
                    act_bits = tensor_width(&y);
                    y
                }
            };
            if let Some(snap) = snap {
                self.layer_stats.push(self.stats.delta_since(&snap));
            }
            outs.push(out);
        }
        outs
    }

    // ================================================================
    // Convolution (Fig. 8 + Eq. 1 + cross-writing accumulation)
    // ================================================================

    #[allow(clippy::too_many_arguments)]
    fn conv_layer(
        &mut self,
        x: &WideTensor,
        ibits: usize,
        k: &Kernel4,
        kh: usize,
        kw: usize,
        stride: usize,
        pad: usize,
        first: bool,
        node: usize,
    ) -> WideTensor {
        // Zero padding is free in NAND-SPIN: padded cells are simply
        // MTJs left in the erased (AP = 0) state. The padded extent is
        // an offset *view* over the input — no padded clone of the
        // feature map is ever materialised on the host.
        let x = PaddedView::new(x, pad);
        let geo = ConvGeometry { in_h: x.h, in_w: x.w, stride };
        let oh = geo.out_h(kh);
        let ow = geo.out_w(kw);
        let mbits = k.bits as usize;

        // Multi-tile mapping (§4.2, Fig. 9): shard the (padded) feature
        // map into input slabs of at most one subarray each, with halo
        // overlap so every output window is computed whole inside one
        // tile.
        let (cap_rows, cap_cols) = self.tile_cap.unwrap_or((self.cfg.rows, self.cfg.cols));
        let plan =
            TilePlan::new(x.h, x.w, kh, kw, stride, cap_rows, cap_cols).unwrap_or_else(|| {
                panic!(
                    "{kh}x{kw} conv window exceeds one {}x{} subarray",
                    self.cfg.rows, self.cfg.cols
                )
            });

        // The analytic model spreads this layer over `active_units()`
        // subarrays working in parallel; the functional engine executes
        // the identical op stream serially on a few scratch subarrays.
        // To keep hybrid spot-checks meaningful, the conv-phase latency
        // delta of the layer is divided by the mapped parallelism at
        // the end (energy and op counts are extensive and untouched).
        let conv_lat_before = self.stats[Phase::Convolution].latency_ns;
        let split = PoolSplit::of(&self.cfg);
        let map = ConvMapping::plan(
            &self.cfg,
            (x.c, x.h, x.w),
            k.oc,
            kh,
            kw,
            stride,
            ibits.min(u8::MAX as usize) as u8,
            split.compute,
        );

        // --- load every (tile, channel, bit-plane) slab: fresh
        // elements arrive over the layer's input path, halo
        // rows/columns are re-sent through the bank buffer from slabs
        // already resident (in-mat transfer). The slab *images* are
        // kept as plain row words shared read-only by every filter
        // pass; the charged device ops of the load (one strip write
        // per 8 slab rows) are replayed through a single pooled
        // subarray — write charges depend only on the written bits,
        // never on prior contents, so one loader charges exactly what
        // one-subarray-per-slab did.
        let phase = if first { Phase::LoadData } else { Phase::DataTransfer };
        let load_t0 = Instant::now();
        let mut slabs: Vec<Vec<Vec<Vec<u128>>>> = Vec::with_capacity(plan.count()); // [t][ic][n]
        let mut loader = self.take_subarray();
        for tile in &plan.tiles {
            let (fresh, halo) = (tile.fresh_elems() as u64, tile.halo_elems() as u64);
            let mut per_ch = Vec::with_capacity(x.c);
            for ic in 0..x.c {
                let mut per_bit = Vec::with_capacity(ibits);
                for n in 0..ibits {
                    let rows = slab_rows(&x, ic, n, tile);
                    self.charge_transfer(fresh, phase);
                    if halo > 0 {
                        self.charge_transfer(halo, Phase::DataTransfer);
                    }
                    // Whole-strip writes (8 rows at a time).
                    for (strip, chunk) in rows.chunks(8).enumerate() {
                        let mut data = [0u128; 8];
                        data[..chunk.len()].copy_from_slice(chunk);
                        loader.write_strip(strip, &data, &mut self.stats, phase);
                    }
                    per_bit.push(rows);
                }
                per_ch.push(per_bit);
            }
            slabs.push(per_ch);
        }
        self.recycle_subarray(loader);
        let load_ns = load_t0.elapsed().as_nanos() as u64;

        // --- weights arrive over the global bus once per layer; a
        // resident engine (serving mode) holds them across inferences,
        // so only the first touch of each conv layer is charged.
        let tag = self.conv_seq;
        self.conv_seq += 1;
        let need_stream = match self.residency.as_mut() {
            Some(r) => r.acquire(tag),
            None => true,
        };
        if need_stream {
            self.charge_transfer((k.oc * k.ic * kh * kw * mbits) as u64, Phase::LoadData);
        }

        let mut y = WideTensor::zeros(k.oc, oh, ow);
        // Output columns are accumulated in groups of one subarray
        // width. Grouping always follows the *real* subarray (never the
        // tile-capacity override), so the accumulator op stream — and
        // with it every output — is independent of the tiling plan.
        let group_w = self.cfg.cols;
        let groups = ow.div_ceil(group_w).max(1);
        // Accumulator slot precision: the layer's accumulated total is
        // bounded by (2^n−1)(2^m−1)·in_c·kh·kw; slots widen beyond the
        // 24-bit default when a full-size layer needs it (AlexNet's FC6
        // at 8 bits reaches 30 bits — the fixed-width fold would
        // silently truncate).
        let bound = (((1i64 << ibits.min(32)) - 1) * ((1i64 << mbits.min(16)) - 1))
            .saturating_mul((x.c * kh * kw) as i64);
        let acc_bits = width_of(bound).max(ACC_BITS);
        let acc_cols = ow.min(group_w);

        let count_bits = width_of((kh * kw) as i64) as u64;
        // Window-sum plane count of every pass: the drain width
        // ⌈log2(kh+1)⌉ plus fold headroom ⌈log2(kw+1)⌉ (matches
        // `window_sum_planes`).
        let drain_bits = (32 - (kh as u32).leading_zeros()) as usize;
        let nplanes = drain_bits + (usize::BITS - kw.leading_zeros()) as usize;
        let tile_geos: Vec<ConvGeometry> = plan
            .tiles
            .iter()
            .map(|t| ConvGeometry { in_h: t.in_h, in_w: t.in_w, stride })
            .collect();

        // --- per-filter fan-out. Every `oc` pass is independent: it
        // reads the shared slabs, runs on a worker-private compute
        // subarray + accumulator, and records its device-op charges
        // into its own zero-based `Stats`. The ledger then folds the
        // per-pass stats in ascending `oc` order — the sequential path
        // (workers == 1) goes through the identical per-pass/ledger
        // machinery, so outputs, `Stats`, energy and latency are
        // bit-identical at every worker count.
        let ctx = PassContext {
            slabs: &slabs,
            plan: &plan,
            tile_geos: &tile_geos,
            k,
            in_c: x.c,
            ibits,
            mbits,
            kh,
            kw,
            oh,
            ow,
            group_w,
            groups,
            nplanes,
            count_bits,
            costs: self.cfg.costs,
            bus_width_bits: self.cfg.bus_width_bits,
            sub_cols: self.cfg.cols,
            // The 1×1 fast path hand-charges the op stream without real
            // subarray senses, so it cannot inject faults — a fault
            // plan routes through the generic stepper instead.
            fast_1x1: self.fast_paths
                && self.fault.is_none()
                && kh == 1
                && kw == 1
                && stride == 1,
            fault: self.fault,
            fault_epoch: self.fault_epoch,
            node: node as u64,
        };
        let workers = self.effective_workers().min(k.oc).max(1);
        let pass_t0 = Instant::now();
        // Lane subarrays get their per-filter fault context inside
        // `run_oc_pass` (so sequential and parallel schedules draw the
        // same streams); the checkout-time contexts they consume here
        // are never used for a draw, so the sequence number is restored
        // afterwards to keep post-conv checkouts worker-count
        // independent.
        let seq_snap = self.fault_seq;
        let mut results: Vec<OcPassResult> = Vec::with_capacity(k.oc);
        if workers <= 1 {
            let mut sub = self.take_subarray();
            let mut acc = ColumnAccumulator::new(self.take_subarray(), acc_cols, acc_bits);
            for oc in 0..k.oc {
                results.push(run_oc_pass(&ctx, oc, &mut sub, &mut acc));
            }
            self.recycle_subarray(sub);
            self.recycle_subarray(acc.into_subarray());
        } else {
            let mut lanes: Vec<(Subarray, ColumnAccumulator)> = (0..workers)
                .map(|_| {
                    let sub = self.take_subarray();
                    let acc = ColumnAccumulator::new(self.take_subarray(), acc_cols, acc_bits);
                    (sub, acc)
                })
                .collect();
            let next = AtomicUsize::new(0);
            let (ctx_ref, next_ref, oc_count) = (&ctx, &next, k.oc);
            let per_worker: Vec<Vec<OcPassResult>> = std::thread::scope(|scope| {
                let handles: Vec<_> = lanes
                    .iter_mut()
                    .map(|(sub, acc)| {
                        scope.spawn(move || {
                            let mut local = Vec::new();
                            loop {
                                let oc = next_ref.fetch_add(1, Ordering::Relaxed);
                                if oc >= oc_count {
                                    break;
                                }
                                local.push(run_oc_pass(ctx_ref, oc, sub, acc));
                            }
                            local
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().expect("conv worker panicked")).collect()
            });
            for chunk in per_worker {
                results.extend(chunk);
            }
            for (sub, acc) in lanes {
                self.recycle_subarray(sub);
                self.recycle_subarray(acc.into_subarray());
            }
        }
        self.fault_seq = seq_snap;
        let pass_ns = pass_t0.elapsed().as_nanos() as u64;

        // Deterministic merge: outputs scatter by filter index; the
        // ledger replays every per-pass stats delta in ascending `oc`
        // order regardless of which worker finished when.
        results.sort_unstable_by_key(|r| r.oc);
        let mut ledger = OpLedger::new();
        let (mut conv_ns, mut acc_ns) = (0u64, 0u64);
        for r in results {
            conv_ns += r.conv_ns;
            acc_ns += r.acc_ns;
            let base = r.oc * oh * ow;
            y.data[base..base + oh * ow].copy_from_slice(&r.out);
            ledger.push(r.oc, r.stats);
        }
        ledger.merge_into(&mut self.stats);

        self.profile.push(HostLayerProfile {
            node,
            label: format!("{}x{}x{}x{}", k.oc, k.ic, kh, kw),
            workers,
            tiles: plan.count(),
            load_ns,
            pass_ns,
            conv_ns,
            acc_ns,
        });

        // Spot-check parity with the analytic mapping (see above):
        // divide this layer's conv-phase latency by its parallelism.
        let units = map.active_units().max(1) as f64;
        let conv_lat_after = self.stats[Phase::Convolution].latency_ns;
        self.stats[Phase::Convolution].latency_ns =
            conv_lat_before + (conv_lat_after - conv_lat_before) / units;
        y
    }

    // ================================================================
    // Pooling
    // ================================================================

    fn maxpool_layer(&mut self, x: &WideTensor, bits: usize, k: usize, stride: usize) -> WideTensor {
        let oh = (x.h - k) / stride + 1;
        let ow = (x.w - k) / stride + 1;
        let mut y = WideTensor::zeros(x.c, oh, ow);
        let cols = self.cfg.cols;
        let b = bits.max(1);
        // Row layout: A (current max) at 0.., B (candidate) at b..,
        // tag/result in the first strip after the operands.
        let scratch_strip = (2 * b).div_ceil(8);
        let scratch = CompareScratch {
            tag_row: scratch_strip * 8,
            result_row: scratch_strip * 8 + 1,
            buf_tag: 0,
            buf_diff: 1,
        };

        for c in 0..x.c {
            // Batch output positions into column groups.
            let positions: Vec<(usize, usize)> =
                (0..oh).flat_map(|r| (0..ow).map(move |q| (r, q))).collect();
            for batch in positions.chunks(cols) {
                let mut sub = self.take_subarray();
                // Window element (0,0) seeds the running max.
                let seed: Vec<i64> = batch
                    .iter()
                    .map(|&(r, q)| x.at(c, r * stride, q * stride))
                    .collect();
                self.charge_transfer((seed.len() * b) as u64, Phase::DataTransfer);
                self.store_vertical(&mut sub, 0, b, &seed, Phase::Pooling);
                let mut cur = seed;
                for idx in 1..k * k {
                    let (dy, dx) = (idx / k, idx % k);
                    let cand: Vec<i64> = batch
                        .iter()
                        .map(|&(r, q)| x.at(c, r * stride + dy, q * stride + dx))
                        .collect();
                    self.charge_transfer((cand.len() * b) as u64, Phase::DataTransfer);
                    self.store_vertical(&mut sub, b, b, &cand, Phase::Pooling);
                    // result bit = 1 ⇔ candidate > current max.
                    let result = compare_columns(
                        &mut sub,
                        b,
                        0,
                        b,
                        scratch,
                        &mut self.stats,
                        Phase::Pooling,
                    );
                    // Masked select copy back into A (read both, rewrite).
                    for bit in 0..b {
                        let a_row = sub.read_row(bit, &mut self.stats, Phase::Pooling);
                        let b_row = sub.read_row(b + bit, &mut self.stats, Phase::Pooling);
                        let merged = (b_row & result) | (a_row & !result);
                        sub.write_row(bit, merged, &mut self.stats, Phase::Pooling);
                    }
                    for (j, cv) in cand.iter().enumerate() {
                        if (result >> j) & 1 == 1 {
                            cur[j] = *cv;
                        }
                    }
                }
                // Read the winners back out.
                let vals = self.load_vertical(&sub, 0, b, batch.len(), Phase::Pooling);
                // Under fault injection a sense flip can legitimately
                // diverge the array's winner from the host-tracked one.
                debug_assert!(
                    sub.fault_active() || vals == cur,
                    "in-array max must match tracked max"
                );
                for (&(r, q), v) in batch.iter().zip(&vals) {
                    *y.at_mut(c, r, q) = *v;
                }
                self.recycle_subarray(sub);
            }
        }
        y
    }

    fn avgpool_layer(&mut self, x: &WideTensor, bits: usize, k: usize, stride: usize) -> WideTensor {
        let (mul, shift) = avg_pool_scale(k);
        let oh = (x.h - k) / stride + 1;
        let ow = (x.w - k) / stride + 1;
        let mut y = WideTensor::zeros(x.c, oh, ow);
        let cols = self.cfg.cols;
        let b = bits.max(1);

        for c in 0..x.c {
            let positions: Vec<(usize, usize)> =
                (0..oh).flat_map(|r| (0..ow).map(move |q| (r, q))).collect();
            for batch in positions.chunks(cols) {
                // Sum the k² window elements with one multi-operand add.
                let mut sub = self.take_subarray();
                let mut bases = Vec::with_capacity(k * k);
                for idx in 0..k * k {
                    let (dy, dx) = (idx / k, idx % k);
                    let vals: Vec<i64> = batch
                        .iter()
                        .map(|&(r, q)| x.at(c, r * stride + dy, q * stride + dx))
                        .collect();
                    self.charge_transfer((vals.len() * b) as u64, Phase::DataTransfer);
                    let base = idx * b;
                    self.store_vertical(&mut sub, base, b, &vals, Phase::Pooling);
                    bases.push(base);
                }
                let sum_base = ((k * k * b).div_ceil(8) + 1) * 8;
                let sum_w =
                    add_columns(&mut sub, &bases, b, sum_base, &mut self.stats, Phase::Pooling);
                let sums = self.load_vertical(&sub, sum_base, sum_w, batch.len(), Phase::Pooling);
                self.recycle_subarray(sub);
                // avg = (sum·mul + 2^(shift−1)) >> shift via the in-memory
                // multiply + rounding-add.
                let avgs = self.scale_shift(
                    &sums,
                    sum_w,
                    mul,
                    1i64 << (shift - 1),
                    shift,
                    Phase::Pooling,
                );
                for (&(r, q), v) in batch.iter().zip(&avgs) {
                    *y.at_mut(c, r, q) = *v;
                }
            }
        }
        y
    }

    // ================================================================
    // Affine transforms (BN / quantize) — Fig. 10 multiply + Fig. 9 add
    // ================================================================

    /// In-memory `(v·mul + add + 2^(shift−1)·0) >> shift` for a batch of
    /// column values (`add` already contains any rounding term).
    fn scale_shift(
        &mut self,
        values: &[i64],
        vbits: usize,
        mul: u32,
        add: i64,
        shift: u8,
        phase: Phase,
    ) -> Vec<i64> {
        assert!(add >= 0, "unsigned datapath");
        let mut sub = self.take_subarray();
        let vbits = vbits.max(1);
        self.store_vertical(&mut sub, 0, vbits, values, phase);
        // Multiplier bits into the buffer (shared across columns).
        let mbits = width_of(mul as i64).max(1);
        let mut buf_rows = Vec::with_capacity(mbits);
        for j in 0..mbits {
            let word = if (mul >> j) & 1 == 1 { u128::MAX } else { 0 };
            sub.buffer_write(j, word, &mut self.stats, phase);
            buf_rows.push(j);
        }
        let prod_base = (vbits.div_ceil(8) + 1) * 8;
        let prod_w = multiply_columns(
            &mut sub,
            0,
            vbits,
            &buf_rows,
            prod_base,
            &mut self.stats,
            phase,
        );
        let (res_base, res_w) = if add > 0 {
            // Write the additive constant as a second operand and add.
            let abits = width_of(add).max(prod_w);
            let add_base = prod_base + ((prod_w.div_ceil(8) + 1) * 8).max(abits.div_ceil(8) * 8);
            let addv = vec![add; values.len()];
            self.store_vertical(&mut sub, add_base, abits, &addv, phase);
            // Pad product operand width to match: add_columns wants equal
            // widths, so treat both as `abits`-wide (upper product rows
            // are erased ⇒ zero).
            let sum_base = add_base + (abits.div_ceil(8) + 1) * 8;
            assert!(sum_base + abits + 2 <= self.cfg.rows, "layout overflow");
            let w = add_columns(
                &mut sub,
                &[prod_base, add_base],
                abits.max(prod_w),
                sum_base,
                &mut self.stats,
                phase,
            );
            (sum_base, w)
        } else {
            (prod_base, prod_w)
        };
        // Shift = read from row `shift` upward.
        let hi = res_w.saturating_sub(shift as usize).max(1);
        let out = self.load_vertical(&sub, res_base + shift as usize, hi, values.len(), phase);
        self.recycle_subarray(sub);
        out
    }

    fn bn_layer(&mut self, x: &WideTensor, bits: usize, p: &BnParams) -> WideTensor {
        let mut y = WideTensor::zeros(x.c, x.h, x.w);
        let hw = x.h * x.w;
        for c in 0..x.c {
            let mut out = Vec::with_capacity(hw);
            for batch in x.data[c * hw..(c + 1) * hw].chunks(self.cfg.cols) {
                out.extend(self.scale_shift(
                    batch,
                    bits,
                    p.mul[c],
                    p.add[c],
                    p.shift,
                    Phase::BatchNorm,
                ));
            }
            y.data[c * hw..(c + 1) * hw].copy_from_slice(&out);
        }
        y
    }

    fn quantize_layer(&mut self, x: &WideTensor, bits: usize, p: QuantParams) -> WideTensor {
        let max = ((1u64 << p.bits) - 1) as i64;
        let mut y = WideTensor::zeros(x.c, x.h, x.w);
        for (i, chunk) in x.data.chunks(self.cfg.cols).enumerate() {
            let shifted =
                self.scale_shift(chunk, bits, p.mul, p.add, p.shift, Phase::Quantization);
            // Saturation: the high rows above `p.bits` were read as part
            // of `shifted`; clamp columns that overflow (the hardware
            // selects the all-ones pattern via the overflow OR).
            for (j, v) in shifted.iter().enumerate() {
                y.data[i * self.cfg.cols + j] = (*v).min(max);
            }
        }
        y
    }

    fn residual_layer(&mut self, a: &WideTensor, b: &WideTensor, bits: usize) -> WideTensor {
        assert_eq!((a.c, a.h, a.w), (b.c, b.h, b.w));
        let wa = tensor_width(a).max(bits);
        let wb = tensor_width(b).max(bits);
        let w = wa.max(wb);
        let mut y = WideTensor::zeros(a.c, a.h, a.w);
        for (i, (ca, cb)) in a
            .data
            .chunks(self.cfg.cols)
            .zip(b.data.chunks(self.cfg.cols))
            .enumerate()
        {
            let mut sub = self.take_subarray();
            self.store_vertical(&mut sub, 0, w, ca, Phase::Convolution);
            let b_base = (w.div_ceil(8) + 1) * 8;
            self.store_vertical(&mut sub, b_base, w, cb, Phase::Convolution);
            let res_base = b_base + (w.div_ceil(8) + 1) * 8;
            let rw = add_columns(
                &mut sub,
                &[0, b_base],
                w,
                res_base,
                &mut self.stats,
                Phase::Convolution,
            );
            let vals = self.load_vertical(&sub, res_base, rw, ca.len(), Phase::Convolution);
            self.recycle_subarray(sub);
            y.data[i * self.cfg.cols..i * self.cfg.cols + vals.len()].copy_from_slice(&vals);
        }
        y
    }
}

/// Read-only inputs shared by every per-filter pass of one conv layer.
/// Everything mutable in a pass is worker-private (compute subarray,
/// accumulator, the pass's own `Stats`), which is what makes the
/// filter fan-out race-free without locks.
struct PassContext<'a> {
    /// Loaded bit-plane slab images, `[tile][channel][bit] → rows`.
    slabs: &'a [Vec<Vec<Vec<u128>>>],
    plan: &'a TilePlan,
    tile_geos: &'a [ConvGeometry],
    k: &'a Kernel4,
    in_c: usize,
    ibits: usize,
    mbits: usize,
    kh: usize,
    kw: usize,
    oh: usize,
    ow: usize,
    group_w: usize,
    groups: usize,
    nplanes: usize,
    count_bits: u64,
    costs: DeviceCosts,
    bus_width_bits: usize,
    /// Real subarray column count (device-op charges scale with it).
    sub_cols: usize,
    /// Take the flat-buffer 1×1 fast path (charge stream identical to
    /// the generic stepper, asserted by property tests). Never taken
    /// with a fault plan active.
    fast_1x1: bool,
    /// Active fault plan, if any; each filter pass installs a context
    /// derived from `(fault_epoch, node, oc)` on its lane.
    fault: Option<FaultPlan>,
    /// The run's input-derived fault epoch.
    fault_epoch: u64,
    /// Node index of this conv layer within the network.
    node: u64,
}

/// One filter pass's outcome: its zero-based stats delta (a ledger
/// entry), the filter's output feature map (`oh × ow`, row-major) and
/// the host wall time split between conv stepping and accumulation.
struct OcPassResult {
    oc: usize,
    stats: Stats,
    out: Vec<i64>,
    conv_ns: u64,
    acc_ns: u64,
}

/// Execute one filter (`oc`) pass on worker-private state.
fn run_oc_pass(
    ctx: &PassContext<'_>,
    oc: usize,
    sub: &mut Subarray,
    acc: &mut ColumnAccumulator,
) -> OcPassResult {
    if let Some(plan) = ctx.fault {
        // Per-pass fault context: a pure function of (input epoch,
        // layer, filter), so which lane or worker runs the pass — and
        // in what order — never changes the injected faults.
        let pass = fault_ctx(&[ctx.fault_epoch, ctx.node, oc as u64]);
        sub.set_fault(plan, pass);
        acc.set_fault(plan, mix(pass ^ 0xACC));
    }
    if ctx.fast_1x1 {
        run_oc_pass_1x1(ctx, oc, acc)
    } else {
        run_oc_pass_generic(ctx, oc, sub, acc)
    }
}

/// The generic tiled pass: one bit-plane convolution per
/// (weight-plane, channel, input-plane) per tile; each tile's window
/// sums are stitched into full-output-width planes, so the partials
/// pushed into the accumulator are identical to an untiled run.
/// `stitched[or][g]` is the packed window-sum planes of output row
/// `or`, column group `g`.
fn run_oc_pass_generic(
    ctx: &PassContext<'_>,
    oc: usize,
    sub: &mut Subarray,
    acc: &mut ColumnAccumulator,
) -> OcPassResult {
    let mut stats = Stats::default();
    let conv_t0 = Instant::now();
    let mut partials: Vec<(usize, Vec<Vec<Vec<u128>>>)> =
        Vec::with_capacity(ctx.mbits * ctx.in_c * ctx.ibits);
    for m in 0..ctx.mbits {
        for ic in 0..ctx.in_c {
            let kernel = BitKernel::new(ctx.kh, ctx.kw, ctx.k.bitplane(oc, ic, m as u8));
            // One tiling per distinct slab width (grid column), shared
            // across every input bit-plane `n` and every row of tiles.
            let col_tilings: Vec<KernelTiling> = (0..ctx.plan.tiles_w)
                .map(|tw| kernel.tilings(ctx.plan.tiles[tw].in_w))
                .collect();
            for n in 0..ctx.ibits {
                let mut stitched = vec![vec![vec![0u128; ctx.nplanes]; ctx.groups]; ctx.oh];
                for (t, tile) in ctx.plan.tiles.iter().enumerate() {
                    // Mirror the already-charged slab image into the
                    // private compute subarray (cost-free host copy —
                    // the load was charged once on the shared stream).
                    sub.host_load_rows(0, &ctx.slabs[t][ic][n]);
                    let counts = bitplane_conv_counts_tiled(
                        sub,
                        0,
                        ctx.tile_geos[t],
                        &col_tilings[t % ctx.plan.tiles_w],
                        &mut stats,
                        Phase::Convolution,
                    );
                    let sums = window_sum_planes(&counts, ctx.tile_geos[t], ctx.kh, ctx.kw);
                    // In-mat transfer of the drained counts to the
                    // accumulation subarray (the tile's owned share of
                    // the output).
                    charge_transfer_into(
                        &ctx.costs,
                        ctx.bus_width_bits,
                        &mut stats,
                        (tile.out_h * tile.out_w) as u64 * ctx.count_bits,
                        Phase::DataTransfer,
                    );
                    // Stitch: keep only the windows this tile owns
                    // (slab extension computes a few extra
                    // columns/rows owned by neighbours) and place them
                    // at their global output column.
                    let owned = low_mask(tile.out_w);
                    for ry in 0..tile.out_h {
                        let dst = &mut stitched[tile.out_y0 + ry];
                        for (p, &word) in sums[ry].iter().enumerate() {
                            let w = word & owned;
                            if w == 0 {
                                continue;
                            }
                            let mut j = 0;
                            while j < tile.out_w {
                                let gc = tile.out_x0 + j;
                                let (g, off) = (gc / ctx.group_w, gc % ctx.group_w);
                                let take = (ctx.group_w - off).min(tile.out_w - j);
                                dst[g][p] |= ((w >> j) & low_mask(take)) << off;
                                j += take;
                            }
                        }
                    }
                }
                partials.push((n + m, stitched));
            }
        }
    }
    let conv_ns = conv_t0.elapsed().as_nanos() as u64;
    let acc_t0 = Instant::now();
    let mut out = vec![0i64; ctx.oh * ctx.ow];
    for or in 0..ctx.oh {
        for g in 0..ctx.groups {
            acc.reset(&mut stats);
            for (shift, sums) in &partials {
                acc.push_planes(&sums[or][g], *shift, &mut stats);
            }
            let row_vals = acc.finish(&mut stats);
            let gw = ctx.group_w.min(ctx.ow - g * ctx.group_w);
            for ocx in 0..gw {
                out[or * ctx.ow + g * ctx.group_w + ocx] = row_vals[ocx] as i64;
            }
        }
    }
    let acc_ns = acc_t0.elapsed().as_nanos() as u64;
    OcPassResult { oc, stats, out, conv_ns, acc_ns }
}

/// 1×1-conv (stride 1) fast path — the shape of every FC-as-conv
/// layer, which dominates AlexNet/VGG19 host time at ⟨8:8⟩. The window
/// sum of a 1×1 kernel is just `input-bit AND weight-bit`, so the pass
/// skips `BitKernel`/`KernelTiling` construction, the stepper and
/// `window_sum_planes` entirely and keeps the single window-sum plane
/// per (pass, row, group) in one flat buffer — no nested per-pass
/// allocations. The *charge stream* replays the generic stepper's
/// sequence record for record (one buffer load for the single period,
/// then per output row one buffer read, one AND, one count accumulate
/// and one drain cycle — all content-independent), so `Stats` stay
/// bit-identical to the generic path.
fn run_oc_pass_1x1(ctx: &PassContext<'_>, oc: usize, acc: &mut ColumnAccumulator) -> OcPassResult {
    let mut stats = Stats::default();
    let conv_t0 = Instant::now();
    let passes = ctx.mbits * ctx.in_c * ctx.ibits;
    let mut shifts = Vec::with_capacity(passes);
    let mut flat = vec![0u128; passes * ctx.oh * ctx.groups];
    let c = &ctx.costs;
    let colsf = ctx.sub_cols as f64;
    let mut pi = 0usize;
    for m in 0..ctx.mbits {
        for ic in 0..ctx.in_c {
            let wbit = (ctx.k.at(oc, ic, 0, 0) >> m) & 1 == 1;
            for n in 0..ctx.ibits {
                let base = pi * ctx.oh * ctx.groups;
                for (t, tile) in ctx.plan.tiles.iter().enumerate() {
                    debug_assert_eq!((tile.out_h, tile.out_w), (tile.in_h, tile.in_w));
                    debug_assert_eq!((tile.out_y0, tile.out_x0), (tile.in_y0, tile.in_x0));
                    stats.ops.buffer_accesses += 1;
                    stats.record(
                        Phase::Convolution,
                        c.buffer_energy_per_bit_fj * colsf,
                        c.buffer_latency_ns,
                    );
                    for _ in 0..tile.out_h {
                        stats.ops.buffer_accesses += 1;
                        stats.record(Phase::Convolution, c.buffer_energy_per_bit_fj * colsf, 0.0);
                        stats.ops.ands += 1;
                        stats.record(
                            Phase::Convolution,
                            c.and_energy_per_bit_fj * colsf,
                            c.and_latency_ns,
                        );
                        stats.ops.bitcounts += 1;
                        stats.record(Phase::Convolution, c.bitcount_energy_per_bit_fj * colsf, 0.0);
                        stats.record(
                            Phase::Convolution,
                            c.bitcount_energy_per_bit_fj * colsf,
                            c.bitcount_latency_ns,
                        );
                    }
                    charge_transfer_into(
                        c,
                        ctx.bus_width_bits,
                        &mut stats,
                        (tile.out_h * tile.out_w) as u64 * ctx.count_bits,
                        Phase::DataTransfer,
                    );
                    if !wbit {
                        continue;
                    }
                    let rows = &ctx.slabs[t][ic][n];
                    let owned = low_mask(tile.out_w);
                    for ry in 0..tile.out_h {
                        let w = rows[ry] & owned;
                        if w == 0 {
                            continue;
                        }
                        let dst = &mut flat[base + (tile.out_y0 + ry) * ctx.groups..];
                        let mut j = 0;
                        while j < tile.out_w {
                            let gc = tile.out_x0 + j;
                            let (g, off) = (gc / ctx.group_w, gc % ctx.group_w);
                            let take = (ctx.group_w - off).min(tile.out_w - j);
                            dst[g] |= ((w >> j) & low_mask(take)) << off;
                            j += take;
                        }
                    }
                }
                shifts.push(n + m);
                pi += 1;
            }
        }
    }
    let conv_ns = conv_t0.elapsed().as_nanos() as u64;
    let acc_t0 = Instant::now();
    let mut out = vec![0i64; ctx.oh * ctx.ow];
    for or in 0..ctx.oh {
        for g in 0..ctx.groups {
            acc.reset(&mut stats);
            for (p, &shift) in shifts.iter().enumerate() {
                let w = flat[(p * ctx.oh + or) * ctx.groups + g];
                // `push_planes` trims trailing zero planes, so a
                // single-plane slice charges exactly what the generic
                // path's `[w, 0]` pair does.
                acc.push_planes(std::slice::from_ref(&w), shift, &mut stats);
            }
            let row_vals = acc.finish(&mut stats);
            let gw = ctx.group_w.min(ctx.ow - g * ctx.group_w);
            for ocx in 0..gw {
                out[or * ctx.ow + g * ctx.group_w + ocx] = row_vals[ocx] as i64;
            }
        }
    }
    let acc_ns = acc_t0.elapsed().as_nanos() as u64;
    OcPassResult { oc, stats, out, conv_ns, acc_ns }
}

/// Cross-writing accumulation subarray: partial counts are written as
/// vertical operands at their 2^(n+m) row offset (the paper's "shift by
/// writing to different rows") and folded with multi-operand in-memory
/// addition when the operand slots fill up.
struct ColumnAccumulator {
    sub: Subarray,
    cols: usize,
    used: usize,
    slots: usize,
    /// Bits per operand slot (≥ [`ACC_BITS`]; widened per layer so the
    /// fold never truncates the accumulated total).
    acc_bits: usize,
}

impl ColumnAccumulator {
    fn new(sub: Subarray, cols: usize, acc_bits: usize) -> Self {
        let acc_bits = acc_bits.max(ACC_BITS);
        // Leave room for the fold result; cap the operand count so the
        // fold's carry headroom (6 bits) is never exceeded.
        let slots = (sub.num_rows() / acc_bits).saturating_sub(2).min(64);
        assert!(slots >= 2, "accumulator precision {acc_bits} leaves too few operand slots");
        Self { sub, cols, used: 0, slots, acc_bits }
    }

    fn reset(&mut self, stats: &mut Stats) {
        // Erase all operand strips (fresh accumulation).
        for s in 0..self.sub.strip_rows() {
            self.sub.erase_strip(s, stats, Phase::Convolution);
        }
        self.used = 0;
    }

    /// Push one partial, already packed as bit planes (`planes[b]` bit
    /// `col` = bit `b` of column `col`'s value), shifted by `shift`
    /// rows. Programs exactly the rows the old per-column path did:
    /// one program step per non-zero plane up to the operand's width.
    fn push_planes(&mut self, planes: &[u128], shift: usize, stats: &mut Stats) {
        if self.used == self.slots {
            self.fold(stats);
        }
        let base = self.used * self.acc_bits;
        // Operand width = highest non-zero plane (the per-column max's
        // bit width — same bound the scalar path derived).
        let mut cb = planes.len();
        while cb > 0 && planes[cb - 1] == 0 {
            cb -= 1;
        }
        assert!(shift + cb <= self.acc_bits, "operand exceeds slot width");
        for (b, &word) in planes[..cb].iter().enumerate() {
            if word != 0 {
                let row = base + shift + b;
                self.sub.program_row(row / 8, row % 8, word, stats, Phase::Convolution);
            }
        }
        self.used += 1;
    }

    /// Fold all used slots into slot 0.
    fn fold(&mut self, stats: &mut Stats) {
        if self.used <= 1 {
            return;
        }
        let bases: Vec<usize> = (0..self.used).map(|s| s * self.acc_bits).collect();
        let res_base = self.slots * self.acc_bits;
        let res_base = res_base.div_ceil(8) * 8;
        let w =
            add_columns(&mut self.sub, &bases, self.acc_bits, res_base, stats, Phase::Convolution);
        assert!(w <= self.acc_bits + 6);
        // Read the fold result, clear operands, rewrite into slot 0.
        let mut rows = Vec::with_capacity(w.min(self.acc_bits));
        for b in 0..w.min(self.acc_bits) {
            rows.push(self.sub.read_row(res_base + b, stats, Phase::Convolution));
        }
        for s in 0..(self.used * self.acc_bits).div_ceil(8) {
            self.sub.erase_strip(s, stats, Phase::Convolution);
        }
        for (b, &word) in rows.iter().enumerate() {
            if word != 0 {
                self.sub.program_row(b / 8, b % 8, word, stats, Phase::Convolution);
            }
        }
        self.used = 1;
    }

    /// Fold and read out the per-column totals (sparse set-bit walk of
    /// each row word instead of a per-column scan).
    fn finish(&mut self, stats: &mut Stats) -> Vec<u64> {
        self.fold(stats);
        let mut vals = vec![0u64; self.cols];
        for b in 0..self.acc_bits {
            let mut word = self.sub.read_row(b, stats, Phase::Convolution);
            while word != 0 {
                let col = word.trailing_zeros() as usize;
                if col < self.cols {
                    vals[col] |= 1u64 << b;
                }
                word &= word - 1;
            }
        }
        vals
    }

    /// Install a fault context on the accumulation subarray (see
    /// [`Subarray::set_fault`]).
    fn set_fault(&mut self, plan: FaultPlan, ctx: u64) {
        self.sub.set_fault(plan, ctx);
    }

    /// Release the underlying subarray back to the caller's pool.
    fn into_subarray(self) -> Subarray {
        self.sub
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::network::{micro_cnn, small_cnn};
    use crate::cnn::ref_exec;

    fn check_network(net: &Network, w_bits: u8, seed: u64) {
        let params = ModelParams::random(net, w_bits, seed);
        let input = QTensor::random(net.input.0, net.input.1, net.input.2, net.input_bits, seed + 1);
        let golden = ref_exec::execute(net, &params, &input);
        let mut eng = FunctionalEngine::new(ArchConfig::paper());
        let got = eng.run(net, &params, &input);
        assert_eq!(got.len(), golden.len());
        for (i, (a, b)) in got.iter().zip(&golden).enumerate() {
            assert_eq!(a, b, "node {i} ({}) mismatch", net.nodes[i].layer.mnemonic());
        }
        // The run must have exercised the array: ANDs, programs, erases.
        assert!(eng.stats.ops.ands > 0);
        assert!(eng.stats.ops.erases > 0);
        assert!(eng.stats.total_latency_ns() > 0.0);
    }

    #[test]
    fn micro_cnn_matches_golden() {
        check_network(&micro_cnn(4), 2, 11);
    }

    #[test]
    fn small_cnn_matches_golden_bit_exactly() {
        check_network(&small_cnn(4), 4, 42);
    }

    #[test]
    fn small_cnn_other_seeds() {
        check_network(&small_cnn(3), 3, 1234);
    }

    #[test]
    fn scratch_pool_reuse_is_deterministic() {
        // Second request reuses pooled subarrays; outputs and the
        // zero-based per-request stats must be bitwise identical to the
        // first (cleared state == fresh state, and request stats are a
        // pure function of the request — not of engine history).
        use crate::coordinator::engine::InferenceEngine;
        let net = small_cnn(3);
        let params = ModelParams::random(&net, 3, 21);
        let img = QTensor::random(net.input.0, net.input.1, net.input.2, net.input_bits, 22);
        let mut eng = FunctionalEngine::new(ArchConfig::paper());
        let a = eng.execute(&net, Some(&params), &img);
        let b = eng.execute(&net, Some(&params), &img);
        assert_eq!(a.outputs, b.outputs, "pooled scratch must not change outputs");
        assert_eq!(a.stats, b.stats, "per-request stats must not depend on history");
    }

    #[test]
    fn same_name_same_length_network_still_evicts() {
        // Regression for the old `(name, nodes.len())` residency key:
        // same name, same node count, different structure must evict.
        let a = micro_cnn(4);
        let mut b = micro_cnn(4);
        if let crate::cnn::layer::Layer::Conv { stride, .. } = &mut b.nodes[0].layer {
            *stride = 2;
        }
        assert_eq!(a.name, b.name);
        assert_eq!(a.nodes.len(), b.nodes.len());
        let pa = ModelParams::random(&a, 3, 1);
        let pb = ModelParams::random(&b, 3, 2);
        let ia = QTensor::random(a.input.0, a.input.1, a.input.2, a.input_bits, 3);
        let ib = QTensor::random(b.input.0, b.input.1, b.input.2, b.input_bits, 4);
        let mut eng = FunctionalEngine::new(ArchConfig::paper());
        eng.make_weights_resident();
        eng.run(&a, &pa, &ia);
        eng.run(&b, &pb, &ib);
        let r = eng.residency().expect("resident mode");
        assert_eq!(r.hits, 0, "structurally different network must not hit");
        assert_eq!(r.misses, 2, "both first touches must stream");
    }

    #[test]
    fn resident_weights_are_charged_once() {
        let net = micro_cnn(4);
        let params = ModelParams::random(&net, 3, 7);
        let img = QTensor::random(net.input.0, net.input.1, net.input.2, net.input_bits, 8);

        // Streaming engine: two runs cost exactly twice one run.
        let mut stream = FunctionalEngine::new(ArchConfig::paper());
        stream.run(&net, &params, &img);
        let one = stream.stats.clone();
        stream.run(&net, &params, &img);
        assert!(
            (stream.stats.total_latency_ns() - 2.0 * one.total_latency_ns()).abs()
                < 1e-9 * stream.stats.total_latency_ns()
        );

        // Resident engine: identical outputs, second run strictly cheaper
        // (weight stream skipped), and the residency tracker records the
        // miss-then-hit pattern.
        let mut resident = FunctionalEngine::new(ArchConfig::paper());
        resident.make_weights_resident();
        let a = resident.run(&net, &params, &img);
        let warm_snap = resident.stats.clone();
        let b = resident.run(&net, &params, &img);
        assert_eq!(a, b);
        let warm = resident.stats.delta_since(&warm_snap);
        assert!(warm.total_latency_ns() < one.total_latency_ns());
        assert!(
            warm[crate::arch::stats::Phase::LoadData].latency_ns
                < one[crate::arch::stats::Phase::LoadData].latency_ns,
            "warm run must skip the weight stream"
        );
        let r = resident.residency().expect("resident mode");
        assert_eq!(r.misses as usize, r.resident_layers());
        assert_eq!(r.hits, r.misses, "second pass hits every conv layer");
    }

    #[test]
    fn switching_networks_evicts_resident_weights() {
        let micro = micro_cnn(4);
        let micro_params = ModelParams::random(&micro, 3, 7);
        let micro_img =
            QTensor::random(micro.input.0, micro.input.1, micro.input.2, micro.input_bits, 8);
        let small = small_cnn(3);
        let small_params = ModelParams::random(&small, 3, 9);
        let small_img =
            QTensor::random(small.input.0, small.input.1, small.input.2, small.input_bits, 10);

        let mut eng = FunctionalEngine::new(ArchConfig::paper());
        eng.make_weights_resident();
        eng.run(&micro, &micro_params, &micro_img);
        eng.run(&small, &small_params, &small_img);
        // The network switch evicted micro's weights, so small's conv
        // layers all missed: no stale hits were recorded.
        let r = eng.residency().expect("resident mode");
        assert_eq!(r.hits, 0, "different network must not hit micro's resident weights");
        // And switching back misses again (micro was evicted).
        eng.run(&micro, &micro_params, &micro_img);
        let r = eng.residency().expect("resident mode");
        assert_eq!(r.hits, 0);
        assert_eq!(r.misses as usize, 1 + 2 + 1, "micro + small convs + micro again");
    }
}
