//! Deterministic observability: event timelines, a metrics registry
//! and per-layer simulated cost profiles for the serving runtime.
//!
//! Everything in this module is stamped on the **simulated clock** (the
//! same `f64` nanosecond timeline the batcher, router and
//! [`ServeReport`](crate::coordinator::serve::ServeReport) use), never
//! on host wall time. Because the serving runtime plans batches before
//! execution and assembles completions with a deterministic serial
//! cursor afterwards, a trace built from that metadata is bit-identical
//! across host worker counts and across runs at a fixed fault seed —
//! the repo's core determinism guarantee extends to telemetry itself.
//!
//! Three pieces:
//!
//! * [`Trace`] / [`TraceEvent`] — the event timeline. One span chain per
//!   served request (`arrival → lane_wait → flush → route → queue_wait
//!   → execute → complete`) plus batch, fault/failover and spot-check
//!   events, exportable as JSONL ([`export::to_jsonl`]) or Chrome
//!   trace-event / Perfetto JSON ([`export::to_chrome_json`]).
//! * [`MetricsRegistry`] — integer-only counters, gauges and
//!   fixed-bucket histograms. No floats whose value depends on merge
//!   order: registries merge commutatively, mirroring how
//!   [`Stats`](crate::arch::stats::Stats) merges stay order-canonical.
//!   Exportable as a Prometheus-style text snapshot.
//! * [`LayerCostProfile`] / [`LayerCost`] — per-layer **simulated**
//!   latency/energy/op-mix from either engine, folded across a chip's
//!   whole request stream in arrival order (the canonical f64 fold
//!   order, so profiles are bit-identical at any worker count).

pub mod export;
pub mod metrics;

pub use metrics::{Histogram, MetricsRegistry, TIME_BUCKETS_NS};

use crate::arch::stats::Stats;

/// How an event occupies the timeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TracePhase {
    /// A duration span (`ph: "X"` in Chrome trace-event terms).
    Span,
    /// A point-in-time marker (`ph: "i"`).
    Instant,
}

/// One argument value attached to a [`TraceEvent`].
#[derive(Debug, Clone, PartialEq)]
pub enum TraceValue {
    /// Unsigned integer argument (counts, ids).
    U64(u64),
    /// Simulated-clock / cost argument in whatever unit the key names.
    F64(f64),
    /// Text argument (network names, flush causes).
    Str(String),
}

impl From<u64> for TraceValue {
    fn from(v: u64) -> Self {
        TraceValue::U64(v)
    }
}

impl From<f64> for TraceValue {
    fn from(v: f64) -> Self {
        TraceValue::F64(v)
    }
}

impl From<String> for TraceValue {
    fn from(v: String) -> Self {
        TraceValue::Str(v)
    }
}

impl From<&str> for TraceValue {
    fn from(v: &str) -> Self {
        TraceValue::Str(v.to_string())
    }
}

/// One timeline event on the simulated clock.
///
/// `pid` selects the track: 0 is the scheduler plane (arrivals, lane
/// waits, flushes, route decisions), `chip + 1` is that chip's
/// execution plane. `tid` is the request id for request-scoped events
/// and the batch sequence number for batch-scoped ones.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    /// Event start on the simulated clock (ns).
    pub ts_ns: f64,
    /// Span duration (ns); 0 for instants.
    pub dur_ns: f64,
    /// Span or instant.
    pub phase: TracePhase,
    /// Event name (one of the fixed vocabulary, e.g. `"execute"`).
    pub name: &'static str,
    /// Event category (`"request"`, `"batch"`, `"fault"`, `"check"`).
    pub cat: &'static str,
    /// Track: 0 = scheduler plane, `chip + 1` = chip plane.
    pub pid: u64,
    /// Request id or batch sequence number.
    pub tid: u64,
    /// Event arguments, emitted in this (fixed) order.
    pub args: Vec<(&'static str, TraceValue)>,
}

impl TraceEvent {
    /// A duration span `[ts_ns, ts_ns + dur_ns]`.
    pub fn span(name: &'static str, cat: &'static str, ts_ns: f64, dur_ns: f64) -> Self {
        Self { ts_ns, dur_ns, phase: TracePhase::Span, name, cat, pid: 0, tid: 0, args: Vec::new() }
    }

    /// A point-in-time marker at `ts_ns`.
    pub fn instant(name: &'static str, cat: &'static str, ts_ns: f64) -> Self {
        Self {
            ts_ns,
            dur_ns: 0.0,
            phase: TracePhase::Instant,
            name,
            cat,
            pid: 0,
            tid: 0,
            args: Vec::new(),
        }
    }

    /// Place the event on track `pid`, lane `tid` (builder style).
    pub fn on(mut self, pid: u64, tid: u64) -> Self {
        self.pid = pid;
        self.tid = tid;
        self
    }

    /// Attach an argument (builder style; emission order is push order).
    pub fn arg(mut self, key: &'static str, value: impl Into<TraceValue>) -> Self {
        self.args.push((key, value.into()));
        self
    }
}

/// A complete serve timeline plus its metrics snapshot.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Trace {
    /// Track names, indexed by `pid` (0 = scheduler, then one per chip).
    pub tracks: Vec<String>,
    /// Events sorted by timestamp (stable — equal timestamps keep the
    /// deterministic construction order).
    pub events: Vec<TraceEvent>,
    /// Integer metrics snapshot folded from the same report the
    /// timeline was built from.
    pub metrics: MetricsRegistry,
}

impl Trace {
    /// Stable-sort events by timestamp. Construction order is
    /// deterministic, and a stable sort keeps it on ties, so the final
    /// event order — and every byte of the exports — is reproducible.
    pub fn sort_events(&mut self) {
        self.events.sort_by(|a, b| a.ts_ns.total_cmp(&b.ts_ns));
    }

    /// Number of events with `name`.
    pub fn count(&self, name: &str) -> usize {
        self.events.iter().filter(|e| e.name == name).count()
    }
}

/// Per-layer simulated cost of one node: the latency / energy / op-mix
/// [`Stats`] delta the engine charged while executing that node, summed
/// across every request in the profile's stream.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerCost {
    /// Node index in the network's layer list.
    pub node: usize,
    /// Layer mnemonic (e.g. `"conv"`, `"maxpool"`).
    pub label: String,
    /// Simulated cost charged to this node, summed over the stream.
    pub stats: Stats,
}

/// Per-layer simulated cost profile of one network on one chip,
/// accumulated across the chip's whole request stream in arrival order.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerCostProfile {
    /// Network index into the serve's network list.
    pub net: usize,
    /// Network name.
    pub network: String,
    /// Requests folded into this profile.
    pub requests: u64,
    /// One entry per network node, in node order.
    pub layers: Vec<LayerCost>,
}

impl LayerCostProfile {
    /// Fold one request's per-node stats deltas into the profile.
    /// `layer_stats` is indexed by node; missing trailing nodes are
    /// ignored (engines emit one entry per node, so lengths match).
    pub fn fold_request(&mut self, layer_stats: &[Stats]) {
        self.requests += 1;
        for (layer, s) in self.layers.iter_mut().zip(layer_stats) {
            layer.stats.merge_serial(s);
        }
    }

    /// Absorb another profile of the same network (e.g. a failover
    /// round's partial profile), request counts and per-node stats
    /// summing serially.
    pub fn absorb(&mut self, other: &LayerCostProfile) {
        debug_assert_eq!(self.net, other.net, "absorbing a different network's profile");
        self.requests += other.requests;
        for (layer, o) in self.layers.iter_mut().zip(&other.layers) {
            layer.stats.merge_serial(&o.stats);
        }
    }

    /// Total simulated latency across layers (ns).
    pub fn total_latency_ns(&self) -> f64 {
        self.layers.iter().map(|l| l.stats.total_latency_ns()).sum()
    }

    /// Total simulated energy across layers (fJ).
    pub fn total_energy_fj(&self) -> f64 {
        self.layers.iter().map(|l| l.stats.total_energy_fj()).sum()
    }
}

/// Merge per-network layer-cost profiles from `from` into `into`
/// (matching on network index, appending unseen networks). Used when a
/// chip's stream arrives in several rounds (failover re-routes).
pub fn merge_layer_costs(
    into: &mut Option<Vec<LayerCostProfile>>,
    from: Option<Vec<LayerCostProfile>>,
) {
    let Some(from) = from else { return };
    match into {
        None => *into = Some(from),
        Some(acc) => {
            for p in from {
                match acc.iter_mut().find(|q| q.net == p.net) {
                    Some(q) => q.absorb(&p),
                    None => acc.push(p),
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::stats::Phase;

    fn stats(lat: f64, en: f64) -> Stats {
        let mut s = Stats::default();
        s.record(Phase::Convolution, en, lat);
        s.ops.ands += 1;
        s
    }

    fn profile(net: usize) -> LayerCostProfile {
        LayerCostProfile {
            net,
            network: format!("net{net}"),
            requests: 0,
            layers: (0..2)
                .map(|node| LayerCost { node, label: "conv".into(), stats: Stats::default() })
                .collect(),
        }
    }

    #[test]
    fn fold_and_absorb_accumulate_per_node() {
        let mut p = profile(0);
        p.fold_request(&[stats(1.0, 10.0), stats(2.0, 20.0)]);
        p.fold_request(&[stats(1.0, 10.0), stats(2.0, 20.0)]);
        let mut q = profile(0);
        q.fold_request(&[stats(1.0, 10.0), stats(2.0, 20.0)]);
        p.absorb(&q);
        assert_eq!(p.requests, 3);
        assert_eq!(p.layers[0].stats.total_latency_ns(), 3.0);
        assert_eq!(p.layers[1].stats.total_energy_fj(), 60.0);
        assert_eq!(p.layers[0].stats.ops.ands, 3);
        assert_eq!(p.total_latency_ns(), 9.0);
    }

    #[test]
    fn merge_layer_costs_matches_by_net_and_appends() {
        let mut a = Some(vec![profile(0)]);
        let mut one = profile(0);
        one.fold_request(&[stats(1.0, 1.0), stats(1.0, 1.0)]);
        merge_layer_costs(&mut a, Some(vec![one, profile(3)]));
        let a = a.expect("merged");
        assert_eq!(a.len(), 2);
        assert_eq!(a[0].requests, 1);
        assert_eq!(a[1].net, 3);
        let mut none = None;
        merge_layer_costs(&mut none, Some(vec![profile(1)]));
        assert!(none.is_some());
    }

    #[test]
    fn event_sort_is_stable_on_ties() {
        let mut t = Trace::default();
        t.events.push(TraceEvent::instant("b", "x", 5.0));
        t.events.push(TraceEvent::instant("a", "x", 5.0));
        t.events.push(TraceEvent::instant("c", "x", 1.0));
        t.sort_events();
        let names: Vec<_> = t.events.iter().map(|e| e.name).collect();
        assert_eq!(names, ["c", "b", "a"], "ties keep construction order");
        assert_eq!(t.count("a"), 1);
    }
}
