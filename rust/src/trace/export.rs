//! Deterministic exporters for [`Trace`]: JSONL event logs and Chrome
//! trace-event / Perfetto JSON.
//!
//! JSON is emitted by hand (the build is offline — no serde). Every
//! number is formatted with Rust's shortest-roundtrip `Display`, so
//! bit-identical inputs produce byte-identical files; there is no
//! wall-clock or host-dependent value anywhere in an export.

use super::{Trace, TraceEvent, TracePhase, TraceValue};

/// Escape a string for inclusion inside a JSON string literal.
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Format an `f64` for JSON: shortest-roundtrip decimal, with
/// non-finite values (which no deterministic timeline should produce)
/// clamped to 0 so the output stays valid JSON.
fn fmt_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "0".to_string()
    }
}

fn fmt_args(args: &[(&'static str, TraceValue)]) -> String {
    let mut out = String::from("{");
    for (i, (key, value)) in args.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!("\"{key}\":"));
        match value {
            TraceValue::U64(v) => out.push_str(&v.to_string()),
            TraceValue::F64(v) => out.push_str(&fmt_f64(*v)),
            TraceValue::Str(s) => out.push_str(&format!("\"{}\"", json_escape(s))),
        }
    }
    out.push('}');
    out
}

fn ph(e: &TraceEvent) -> &'static str {
    match e.phase {
        TracePhase::Span => "X",
        TracePhase::Instant => "i",
    }
}

/// One JSON object per line, one line per event, timestamps in
/// simulated nanoseconds. The stable machine-readable form of the
/// timeline (the Chrome export divides down to microseconds).
pub fn to_jsonl(trace: &Trace) -> String {
    let mut out = String::new();
    for e in &trace.events {
        let track = trace.tracks.get(e.pid as usize).map(String::as_str).unwrap_or("");
        out.push_str(&format!(
            "{{\"ts_ns\":{},\"dur_ns\":{},\"ph\":\"{}\",\"name\":\"{}\",\"cat\":\"{}\",\
             \"track\":\"{}\",\"pid\":{},\"tid\":{},\"args\":{}}}\n",
            fmt_f64(e.ts_ns),
            fmt_f64(e.dur_ns),
            ph(e),
            e.name,
            e.cat,
            json_escape(track),
            e.pid,
            e.tid,
            fmt_args(&e.args),
        ));
    }
    out
}

/// Chrome trace-event JSON (the format `chrome://tracing` and Perfetto
/// load). Spans are `ph:"X"` complete events, instants `ph:"i"` with
/// thread scope; `ts`/`dur` are simulated microseconds. Each track gets
/// a `process_name` metadata record so the UI names the planes.
pub fn to_chrome_json(trace: &Trace) -> String {
    let mut out = String::from("{\"displayTimeUnit\":\"ns\",\"traceEvents\":[");
    let mut first = true;
    let mut push = |out: &mut String, first: &mut bool, record: String| {
        if !*first {
            out.push(',');
        }
        *first = false;
        out.push_str(&record);
    };
    for (pid, name) in trace.tracks.iter().enumerate() {
        push(
            &mut out,
            &mut first,
            format!(
                "{{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":{pid},\"tid\":0,\
                 \"args\":{{\"name\":\"{}\"}}}}",
                json_escape(name)
            ),
        );
    }
    for e in &trace.events {
        let scope = if e.phase == TracePhase::Instant { ",\"s\":\"t\"" } else { "" };
        let dur = if e.phase == TracePhase::Span {
            format!(",\"dur\":{}", fmt_f64(e.dur_ns / 1_000.0))
        } else {
            String::new()
        };
        push(
            &mut out,
            &mut first,
            format!(
                "{{\"name\":\"{}\",\"cat\":\"{}\",\"ph\":\"{}\",\"ts\":{}{dur}{scope},\
                 \"pid\":{},\"tid\":{},\"args\":{}}}",
                e.name,
                e.cat,
                ph(e),
                fmt_f64(e.ts_ns / 1_000.0),
                e.pid,
                e.tid,
                fmt_args(&e.args),
            ),
        );
    }
    out.push_str("]}");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::TraceEvent;

    fn sample() -> Trace {
        let mut t = Trace {
            tracks: vec!["scheduler".into(), "chip 0".into()],
            ..Trace::default()
        };
        t.events.push(
            TraceEvent::instant("arrival", "request", 0.0).on(0, 7).arg("net", "small_cnn"),
        );
        t.events.push(
            TraceEvent::span("execute", "request", 100.5, 250.25)
                .on(1, 7)
                .arg("batch", 3u64)
                .arg("est_cost_ns", 123.5),
        );
        t
    }

    #[test]
    fn escape_covers_quotes_and_controls() {
        assert_eq!(json_escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
        assert_eq!(json_escape("\u{1}"), "\\u0001");
        assert_eq!(fmt_f64(f64::NAN), "0");
        assert_eq!(fmt_f64(1.5), "1.5");
    }

    #[test]
    fn jsonl_is_one_object_per_event() {
        let t = sample();
        let out = to_jsonl(&t);
        assert_eq!(out.lines().count(), 2);
        assert!(out.contains("\"ph\":\"i\"") && out.contains("\"ph\":\"X\""));
        assert!(out.contains("\"track\":\"chip 0\""));
        assert!(out.contains("\"args\":{\"net\":\"small_cnn\"}"));
        assert!(out.contains("\"dur_ns\":250.25"));
    }

    #[test]
    fn chrome_json_has_metadata_and_microsecond_times() {
        let t = sample();
        let out = to_chrome_json(&t);
        assert!(out.starts_with("{\"displayTimeUnit\":\"ns\",\"traceEvents\":["));
        assert!(out.ends_with("]}"));
        assert_eq!(out.matches("\"ph\":\"M\"").count(), 2, "one metadata record per track");
        assert!(out.contains("\"args\":{\"name\":\"scheduler\"}"));
        // 100.5 ns span start -> 0.1005 µs; 250.25 ns -> 0.25025 µs.
        assert!(out.contains("\"ts\":0.1005"), "{out}");
        assert!(out.contains("\"dur\":0.25025"));
        assert!(out.contains("\"s\":\"t\""), "instants carry thread scope");
    }

    #[test]
    fn exports_are_deterministic() {
        let t = sample();
        assert_eq!(to_jsonl(&t), to_jsonl(&t.clone()));
        assert_eq!(to_chrome_json(&t), to_chrome_json(&t.clone()));
    }
}
