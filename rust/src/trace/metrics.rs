//! Integer-only metrics registry: counters, gauges and fixed-bucket
//! histograms with deterministic, order-commutative merges.
//!
//! Everything is a `u64`/`i64` — there is no float anywhere whose value
//! could depend on merge order, so per-chip registries can be folded in
//! any grouping and still produce byte-identical snapshots (the same
//! property [`OpLedger`](crate::arch::stats::OpLedger) gives `Stats`).
//! Labels are embedded Prometheus-style in the metric name itself
//! (`nandspin_chip_served_total{chip="0"}`), and `BTreeMap` storage
//! makes iteration — and therefore the text export — canonical.

use std::collections::BTreeMap;

/// Default histogram bucket upper bounds for simulated-time
/// observations, in nanoseconds: one decade per bucket from 100 ns to
/// 10 s, plus the implicit `+Inf` bucket.
pub const TIME_BUCKETS_NS: [u64; 9] = [
    100,
    1_000,
    10_000,
    100_000,
    1_000_000,
    10_000_000,
    100_000_000,
    1_000_000_000,
    10_000_000_000,
];

/// A fixed-bucket integer histogram.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Histogram {
    /// Bucket upper bounds, ascending; an implicit `+Inf` bucket
    /// follows the last bound.
    pub bounds: Vec<u64>,
    /// Per-bucket observation counts (`bounds.len() + 1` entries; the
    /// last is the overflow/`+Inf` bucket). Non-cumulative.
    pub buckets: Vec<u64>,
    /// Total observations.
    pub count: u64,
    /// Integer sum of all observed values.
    pub sum: u64,
}

impl Histogram {
    /// Empty histogram over `bounds`.
    pub fn new(bounds: &[u64]) -> Self {
        debug_assert!(bounds.windows(2).all(|w| w[0] < w[1]), "bounds must ascend");
        Self { bounds: bounds.to_vec(), buckets: vec![0; bounds.len() + 1], count: 0, sum: 0 }
    }

    /// Record one observation.
    pub fn observe(&mut self, value: u64) {
        let idx = self.bounds.iter().position(|&b| value <= b).unwrap_or(self.bounds.len());
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum = self.sum.saturating_add(value);
    }

    /// Add another histogram's observations (commutative integer adds).
    ///
    /// # Panics
    /// If the bucket bounds differ — merging histograms of different
    /// shapes is a logic error, not a recoverable condition.
    pub fn merge(&mut self, other: &Histogram) {
        assert_eq!(self.bounds, other.bounds, "histogram bucket bounds must match");
        for (b, o) in self.buckets.iter_mut().zip(&other.buckets) {
            *b += o;
        }
        self.count += other.count;
        self.sum = self.sum.saturating_add(other.sum);
    }
}

/// Counters, gauges and histograms keyed by Prometheus-style names.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct MetricsRegistry {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, i64>,
    histograms: BTreeMap<String, Histogram>,
}

impl MetricsRegistry {
    /// Empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// True when nothing has been recorded.
    pub fn is_empty(&self) -> bool {
        self.counters.is_empty() && self.gauges.is_empty() && self.histograms.is_empty()
    }

    /// Add `by` to counter `name` (creating it at zero).
    pub fn inc(&mut self, name: &str, by: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += by;
    }

    /// Set gauge `name` to `value`.
    pub fn set_gauge(&mut self, name: &str, value: i64) {
        self.gauges.insert(name.to_string(), value);
    }

    /// Record a simulated-time observation (ns) into histogram `name`,
    /// creating it with [`TIME_BUCKETS_NS`] bounds if absent.
    pub fn observe_ns(&mut self, name: &str, value_ns: u64) {
        self.histograms
            .entry(name.to_string())
            .or_insert_with(|| Histogram::new(&TIME_BUCKETS_NS))
            .observe(value_ns);
    }

    /// Counter value (0 if absent).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    /// Gauge value, if set.
    pub fn gauge(&self, name: &str) -> Option<i64> {
        self.gauges.get(name).copied()
    }

    /// Histogram, if present.
    pub fn histogram(&self, name: &str) -> Option<&Histogram> {
        self.histograms.get(name)
    }

    /// Fold `other` into this registry: counters and histograms add
    /// commutatively; a gauge in `other` overwrites the same-named
    /// gauge here (merge inputs keep gauge names disjoint — per-chip
    /// gauges embed the chip label — so the fold order never shows).
    pub fn merge(&mut self, other: &MetricsRegistry) {
        for (name, v) in &other.counters {
            *self.counters.entry(name.clone()).or_insert(0) += v;
        }
        for (name, v) in &other.gauges {
            self.gauges.insert(name.clone(), *v);
        }
        for (name, h) in &other.histograms {
            match self.histograms.get_mut(name) {
                Some(mine) => mine.merge(h),
                None => {
                    self.histograms.insert(name.clone(), h.clone());
                }
            }
        }
    }

    /// A point-in-time copy: the snapshot no longer changes when the
    /// live registry keeps accumulating.
    pub fn snapshot(&self) -> MetricsRegistry {
        self.clone()
    }

    /// Prometheus text exposition. Deterministic byte-for-byte: names
    /// iterate in `BTreeMap` order and every value is an integer.
    pub fn to_prometheus(&self) -> String {
        let mut out = String::new();
        let mut last_base = String::new();
        let mut type_line = |out: &mut String, last: &mut String, name: &str, kind: &str| {
            let base = name.split('{').next().unwrap_or(name);
            if base != last {
                out.push_str(&format!("# TYPE {base} {kind}\n"));
                *last = base.to_string();
            }
        };
        for (name, v) in &self.counters {
            type_line(&mut out, &mut last_base, name, "counter");
            out.push_str(&format!("{name} {v}\n"));
        }
        last_base.clear();
        for (name, v) in &self.gauges {
            type_line(&mut out, &mut last_base, name, "gauge");
            out.push_str(&format!("{name} {v}\n"));
        }
        for (name, h) in &self.histograms {
            out.push_str(&format!("# TYPE {name} histogram\n"));
            let mut cumulative = 0u64;
            for (bound, count) in h.bounds.iter().zip(&h.buckets) {
                cumulative += count;
                out.push_str(&format!("{name}_bucket{{le=\"{bound}\"}} {cumulative}\n"));
            }
            out.push_str(&format!("{name}_bucket{{le=\"+Inf\"}} {}\n", h.count));
            out.push_str(&format!("{name}_sum {}\n", h.sum));
            out.push_str(&format!("{name}_count {}\n", h.count));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_buckets_and_overflow() {
        let mut h = Histogram::new(&[10, 100]);
        for v in [5, 10, 11, 100, 101, 5_000] {
            h.observe(v);
        }
        assert_eq!(h.buckets, [2, 2, 2], "le=10, le=100, +Inf");
        assert_eq!(h.count, 6);
        assert_eq!(h.sum, 5 + 10 + 11 + 100 + 101 + 5_000);
    }

    #[test]
    fn merge_is_commutative() {
        let build = |vals: &[u64], served: u64| {
            let mut m = MetricsRegistry::new();
            m.inc("served_total", served);
            for &v in vals {
                m.observe_ns("latency_ns", v);
            }
            m
        };
        let a = build(&[50, 2_000], 2);
        let b = build(&[900_000], 1);
        let mut ab = a.clone();
        ab.merge(&b);
        let mut ba = b.clone();
        ba.merge(&a);
        assert_eq!(ab, ba);
        assert_eq!(ab.counter("served_total"), 3);
        assert_eq!(ab.histogram("latency_ns").map(|h| h.count), Some(3));
    }

    #[test]
    fn snapshot_is_frozen() {
        let mut m = MetricsRegistry::new();
        m.inc("x", 1);
        let snap = m.snapshot();
        m.inc("x", 5);
        m.set_gauge("g", -3);
        assert_eq!(snap.counter("x"), 1);
        assert_eq!(m.counter("x"), 6);
        assert_eq!(snap.gauge("g"), None);
        assert_eq!(m.gauge("g"), Some(-3));
    }

    #[test]
    fn prometheus_text_is_canonical() {
        let mut m = MetricsRegistry::new();
        m.inc("nandspin_chip_served_total{chip=\"1\"}", 3);
        m.inc("nandspin_chip_served_total{chip=\"0\"}", 2);
        m.set_gauge("nandspin_makespan_ns", 42);
        m.observe_ns("nandspin_request_latency_ns", 150);
        let text = m.to_prometheus();
        let type_lines = text.lines().filter(|l| l.starts_with("# TYPE")).count();
        assert_eq!(type_lines, 3, "one TYPE line per metric family:\n{text}");
        let c0 = text.find("chip=\"0\"").expect("chip 0 row");
        let c1 = text.find("chip=\"1\"").expect("chip 1 row");
        assert!(c0 < c1, "BTreeMap order sorts labels");
        assert!(text.contains("nandspin_request_latency_ns_bucket{le=\"1000\"} 1"));
        assert!(text.contains("nandspin_request_latency_ns_bucket{le=\"+Inf\"} 1"));
        assert!(text.contains("nandspin_request_latency_ns_sum 150"));
        assert_eq!(text, m.snapshot().to_prometheus(), "snapshot exports identically");
    }
}
