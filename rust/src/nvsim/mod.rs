//! NVSim-like analytic estimator for array + periphery area and static
//! power.
//!
//! The paper feeds its SPICE-characterised macros into a modified NVSim
//! to obtain array-level latency/energy/area. Dynamic per-op scalars live
//! in [`crate::device::energy`]; this module reproduces the *structural*
//! part: bottom-up area composition from cells, per-subarray periphery,
//! per-mat and per-bank resources, plus the PIM add-on circuits.
//!
//! Unit constants are *effective calibrated* values (µm² at 45 nm),
//! chosen so the paper configuration (64 MB, 4×4×4×4 hierarchy, 256×128
//! subarrays) lands on the published end-points:
//!
//! * total area ≈ 64.5 mm² (Table 3),
//! * PIM add-on ≈ 8.9 % of the base memory array (§5.3 "Area"),
//! * add-on split ≈ 47 % computation units / 4 % buffer / 21 %
//!   controller+mux / 28 % other circuits (Fig. 17).
//!
//! Everything scales structurally (per bit / per column / per subarray /
//! per mat / per bank), so capacity and bus sweeps re-use the same model.


use crate::arch::config::ArchConfig;

/// Feature size in µm (45 nm PDK).
pub const FEATURE_UM: f64 = 0.045;

/// Effective NAND-SPIN cell size in F² (1T-1MTJ with shared heavy-metal
/// strip; the NAND-style organisation is what keeps this low — §2.1).
pub const CELL_F2: f64 = 20.0;

/// Calibrated per-structure unit areas (µm², 45 nm effective).
#[derive(Debug, Clone, Copy)]
pub struct UnitAreas {
    /// Row decoder + word-line drivers, per subarray.
    pub row_decoder: f64,
    /// Standard sense path (pre-charge SA per column), per subarray.
    pub sense_amps: f64,
    /// Write drivers + column select, per subarray.
    pub write_drivers: f64,
    /// Local buffer + in-mat bus, per mat.
    pub mat_overhead: f64,
    /// Global buffer + controller + I/O, per bank.
    pub bank_overhead: f64,
    /// PIM add-on: one bit-counter unit (counter + shift + write-back),
    /// per column.
    pub bitcount_unit: f64,
    /// PIM add-on: weight buffer, per subarray.
    pub weight_buffer: f64,
    /// PIM add-on: controller extensions + output multiplexers,
    /// per subarray.
    pub ctrl_mux: f64,
    /// PIM add-on: SPCSA extension (FU input, dual-mode sensing) and
    /// misc. wiring, per column.
    pub spcsa_extra: f64,
}

impl Default for UnitAreas {
    fn default() -> Self {
        Self {
            row_decoder: 400.0,
            sense_amps: 900.0,
            write_drivers: 300.0,
            mat_overhead: 6000.0,
            bank_overhead: 80_000.0,
            bitcount_unit: 1.183,
            weight_buffer: 12.9,
            ctrl_mux: 67.6,
            spcsa_extra: 0.705,
        }
    }
}

/// Area breakdown in mm².
#[derive(Debug, Clone, Copy)]
pub struct AreaBreakdown {
    /// MTJ cell array.
    pub cells_mm2: f64,
    /// Base memory periphery (decoders, SAs, drivers, mat/bank resources).
    pub base_periphery_mm2: f64,
    /// PIM add-on: bit-counter computation units.
    pub addon_compute_mm2: f64,
    /// PIM add-on: weight buffers.
    pub addon_buffer_mm2: f64,
    /// PIM add-on: controller extensions + multiplexers.
    pub addon_ctrl_mux_mm2: f64,
    /// PIM add-on: SPCSA extensions and other circuits.
    pub addon_other_mm2: f64,
}

impl AreaBreakdown {
    /// Base (memory-only) area.
    pub fn base_mm2(&self) -> f64 {
        self.cells_mm2 + self.base_periphery_mm2
    }

    /// Total PIM add-on area.
    pub fn addon_mm2(&self) -> f64 {
        self.addon_compute_mm2
            + self.addon_buffer_mm2
            + self.addon_ctrl_mux_mm2
            + self.addon_other_mm2
    }

    /// Total chip area.
    pub fn total_mm2(&self) -> f64 {
        self.base_mm2() + self.addon_mm2()
    }

    /// Add-on as a fraction of the base memory array (§5.3: ~8.9 %).
    pub fn overhead_ratio(&self) -> f64 {
        self.addon_mm2() / self.base_mm2()
    }

    /// Fig. 17 fractions of the add-on: (compute, buffer, ctrl+mux, other).
    pub fn addon_fractions(&self) -> (f64, f64, f64, f64) {
        let a = self.addon_mm2();
        (
            self.addon_compute_mm2 / a,
            self.addon_buffer_mm2 / a,
            self.addon_ctrl_mux_mm2 / a,
            self.addon_other_mm2 / a,
        )
    }
}

/// The NVSim-like model.
#[derive(Debug, Clone, Default)]
pub struct NvSimModel {
    /// Unit-area constants.
    pub units: UnitAreas,
}

impl NvSimModel {
    /// Estimate the area breakdown for `cfg`.
    pub fn area(&self, cfg: &ArchConfig) -> AreaBreakdown {
        let u = &self.units;
        let bits = (cfg.capacity_mb * 1024 * 1024 * 8) as f64;
        let subarrays = cfg.total_subarrays() as f64;
        let mats = (cfg.num_banks() * cfg.mats_in_bank()) as f64;
        let banks = cfg.num_banks() as f64;
        let cols = subarrays * cfg.cols as f64;

        let um2_to_mm2 = 1e-6;
        let cell_um2 = CELL_F2 * FEATURE_UM * FEATURE_UM;

        // Bus width scales the wiring part of mat/bank overheads
        // (relative to the 128-bit reference point).
        let bus_scale = 0.5 + 0.5 * cfg.bus_width_bits as f64 / 128.0;

        let cells_mm2 = bits * cell_um2 * um2_to_mm2;
        let base_periphery_mm2 = (subarrays
            * (u.row_decoder + u.sense_amps + u.write_drivers)
            + mats * u.mat_overhead * bus_scale
            + banks * u.bank_overhead * bus_scale)
            * um2_to_mm2;

        // Weight buffer scales with its configured rows (16-row reference).
        let buf_scale = cfg.buffer_rows as f64 / 16.0;

        AreaBreakdown {
            cells_mm2,
            base_periphery_mm2,
            addon_compute_mm2: cols * u.bitcount_unit * um2_to_mm2,
            addon_buffer_mm2: subarrays * u.weight_buffer * buf_scale * um2_to_mm2,
            addon_ctrl_mux_mm2: subarrays * u.ctrl_mux * um2_to_mm2,
            addon_other_mm2: cols * u.spcsa_extra * um2_to_mm2,
        }
    }

    /// Static (leakage) power in mW — NVM cells leak nothing; periphery
    /// leaks per subarray.
    pub fn leakage_mw(&self, cfg: &ArchConfig) -> f64 {
        cfg.total_subarrays() as f64 * cfg.costs.leakage_uw_per_subarray * 1e-3
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_config_lands_on_published_endpoints() {
        let m = NvSimModel::default();
        let a = m.area(&ArchConfig::paper());
        // Table 3: 64.5 mm² (±5 %).
        assert!((a.total_mm2() - 64.5).abs() / 64.5 < 0.05, "total {}", a.total_mm2());
        // §5.3: ~8.9 % overhead (±1 pt).
        assert!((a.overhead_ratio() - 0.089).abs() < 0.01, "ratio {}", a.overhead_ratio());
        // Fig. 17: 47 / 4 / 21 / 28 (±3 pts each).
        let (c, b, m_, o) = a.addon_fractions();
        assert!((c - 0.47).abs() < 0.03, "compute {c}");
        assert!((b - 0.04).abs() < 0.03, "buffer {b}");
        assert!((m_ - 0.21).abs() < 0.03, "ctrl+mux {m_}");
        assert!((o - 0.28).abs() < 0.03, "other {o}");
    }

    #[test]
    fn area_scales_with_capacity() {
        let m = NvSimModel::default();
        let mut cfg = ArchConfig::paper();
        cfg.capacity_mb = 32;
        let half = m.area(&cfg).total_mm2();
        cfg.capacity_mb = 64;
        let full = m.area(&cfg).total_mm2();
        assert!((full / half - 2.0).abs() < 0.05);
    }

    #[test]
    fn wider_bus_adds_area() {
        let m = NvSimModel::default();
        let mut cfg = ArchConfig::paper();
        cfg.bus_width_bits = 512;
        let wide = m.area(&cfg).total_mm2();
        assert!(wide > m.area(&ArchConfig::paper()).total_mm2());
    }

    #[test]
    fn leakage_positive_and_small() {
        let m = NvSimModel::default();
        let l = m.leakage_mw(&ArchConfig::paper());
        assert!(l > 0.0 && l < 1000.0, "{l} mW");
    }
}
