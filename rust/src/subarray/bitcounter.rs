//! Per-column bit-counter units (Fig. 3b).
//!
//! Each column has a small counter that accumulates the number of `1`
//! outputs its SA has produced since the last reset. The composed
//! primitives use two counter affordances the paper describes:
//!
//! * read out the LSBs of all counters as a 128-bit row (to write back a
//!   sum/product/comparison bit), and
//! * right-shift every counter by one (carry propagation to the next
//!   bit-position step, Figs. 9–10).
//!
//! ## Representation (§Perf)
//!
//! The bank is stored *bit-sliced*: `planes[b]` holds bit `b` of all 128
//! counters packed in one `u128`. Accumulating an SA output row is then
//! a ripple-carry add of a 1-bit vector across the planes — O(log count)
//! word ops instead of a 128-iteration scalar walk — and `lsbs()` /
//! `shift_right()` become O(1)/O(planes) word moves. This is also
//! exactly how the hardware lays the counters out across the column
//! pitch; see ARCHITECTURE.md §"Packed bit-plane host representation"
//! (the `functional` bench tracks the packed-vs-scalar accumulate
//! ratio in `BENCH_functional.json`).

/// Counter capacity in bits (values up to 2^16−1 — the primitives bound
/// counts by the operand-slot count ≤ 30, so 16 bits is ample headroom).
const PLANES: usize = 16;

/// Bank of per-column bit counters (bit-sliced).
#[derive(Debug, Clone)]
pub struct BitCounterBank {
    planes: [u128; PLANES],
    cols: usize,
    col_mask: u128,
}

impl BitCounterBank {
    /// `cols` counters, all zero.
    pub fn new(cols: usize) -> Self {
        assert!(cols >= 1 && cols <= 128);
        let col_mask = if cols == 128 { u128::MAX } else { (1u128 << cols) - 1 };
        Self { planes: [0; PLANES], cols, col_mask }
    }

    /// Number of columns.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Accumulate one SA output row: counter *j* increments if bit *j* of
    /// `sa_out` is set. Ripple-carry across the bit planes.
    #[inline]
    pub fn accumulate(&mut self, sa_out: u128) {
        let mut carry = sa_out & self.col_mask;
        for p in &mut self.planes {
            if carry == 0 {
                return;
            }
            let sum = *p ^ carry;
            carry &= *p;
            *p = sum;
        }
        debug_assert_eq!(carry, 0, "bit-counter overflow (> {PLANES} bits)");
    }

    /// Add an arbitrary per-column value (used when a counter is
    /// initialised from a transferred partial count).
    pub fn add_value(&mut self, col: usize, value: u32) {
        assert!(col < self.cols);
        for bit in 0..PLANES.min(32) {
            if (value >> bit) & 1 == 1 {
                // Add 2^bit to column `col`: ripple from plane `bit`.
                let mut carry = 1u128 << col;
                for p in self.planes.iter_mut().skip(bit) {
                    if carry == 0 {
                        break;
                    }
                    let sum = *p ^ carry;
                    carry &= *p;
                    *p = sum;
                }
            }
        }
    }

    /// LSBs of all counters packed as a row word.
    #[inline]
    pub fn lsbs(&self) -> u128 {
        self.planes[0]
    }

    /// Right-shift every counter by one (drop the LSB that was just
    /// written back; the rest is the carry into the next bit position).
    #[inline]
    pub fn shift_right(&mut self) {
        self.planes.rotate_left(1);
        self.planes[PLANES - 1] = 0;
    }

    /// Reset all counters to zero.
    #[inline]
    pub fn reset(&mut self) {
        self.planes = [0; PLANES];
    }

    /// Raw counter values (reconstructed; diagnostic / test path).
    pub fn values(&self) -> Vec<u32> {
        (0..self.cols)
            .map(|col| {
                self.planes
                    .iter()
                    .enumerate()
                    .fold(0u32, |acc, (b, &p)| acc | ((((p >> col) & 1) as u32) << b))
            })
            .collect()
    }

    /// True if every counter is zero (all carries drained).
    #[inline]
    pub fn is_zero(&self) -> bool {
        self.planes.iter().all(|&p| p == 0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulate_counts_per_column() {
        let mut b = BitCounterBank::new(128);
        b.accumulate(0b1011);
        b.accumulate(0b0011);
        assert_eq!(&b.values()[..4], &[2, 2, 0, 1]);
    }

    #[test]
    fn lsb_and_shift_implement_binary_readout() {
        let mut b = BitCounterBank::new(8);
        // Column 0 counts to 5 (0b101), column 1 to 2 (0b010).
        for _ in 0..5 {
            b.accumulate(0b01);
        }
        for _ in 0..2 {
            b.accumulate(0b10);
        }
        let mut out = [0u32; 2];
        for bitpos in 0..3 {
            let lsbs = b.lsbs();
            out[0] |= ((lsbs & 1) as u32) << bitpos;
            out[1] |= (((lsbs >> 1) & 1) as u32) << bitpos;
            b.shift_right();
        }
        assert_eq!(out, [5, 2]);
        assert!(b.is_zero());
    }

    #[test]
    fn reset_clears() {
        let mut b = BitCounterBank::new(4);
        b.accumulate(u128::MAX >> (128 - 4));
        b.reset();
        assert!(b.is_zero());
    }

    #[test]
    fn add_value_matches_accumulate_loop() {
        let mut a = BitCounterBank::new(16);
        let mut b = BitCounterBank::new(16);
        a.add_value(3, 13);
        for _ in 0..13 {
            b.accumulate(1 << 3);
        }
        assert_eq!(a.values(), b.values());
    }

    #[test]
    fn bitsliced_matches_scalar_reference() {
        // Randomised cross-check against a plain scalar counter array.
        let mut bank = BitCounterBank::new(128);
        let mut reference = vec![0u32; 128];
        let mut state = 0x1234_5678_9abc_def0u64;
        for _ in 0..200 {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            let row = (state as u128) << 64 | state.wrapping_mul(0x9e37) as u128;
            bank.accumulate(row);
            for (col, r) in reference.iter_mut().enumerate() {
                *r += ((row >> col) & 1) as u32;
            }
        }
        assert_eq!(bank.values(), reference);
    }

    #[test]
    fn column_mask_ignores_out_of_range_bits() {
        let mut b = BitCounterBank::new(8);
        b.accumulate(u128::MAX);
        assert_eq!(b.values(), vec![1; 8]);
    }
}
