//! Bitwise convolution stepper (Fig. 8).
//!
//! One subarray holds one *bit-plane* of the input feature map (row *r*
//! of the map in MTJ row `base + r`). The 1-bit weight matrix is written
//! once into the weight buffer, tiled across the columns with period
//! `Kw`; each *period* `p` shifts the tiling by one column (the paper's
//! "slide the weight matrix to the next position").
//!
//! Within a period, activating input row `r0+kr` against buffer row `kr`
//! ANDs the whole row in parallel and the per-column bit-counters
//! accumulate over the `Kh` kernel rows. Column `j`'s counter then holds
//! `Σ_kr I[r0+kr][j] · W[kr][(j−p) mod Kw]` — the *vertical* partial of
//! the window starting at any column `c ≡ p (mod Kw)`. The horizontal
//! fold across the `Kw` columns of each window is done by in-memory
//! addition in the accumulation subarray (cross-writing scheme, Fig. 12);
//! here we expose the raw counts as packed bit planes
//! ([`PeriodCounts`]) plus word-parallel fold helpers
//! ([`window_sum_planes`], [`window_sums`]) used by tests and by the
//! functional coordinator. See ARCHITECTURE.md §"Packed bit-plane host
//! representation" for why none of this changes the device-op stream.

use crate::arch::stats::{Phase, Stats};

use super::array::Subarray;

/// A 1-bit weight matrix (kernel bit-plane), `kh × kw`, row-major.
#[derive(Debug, Clone)]
pub struct BitKernel {
    /// Kernel height.
    pub kh: usize,
    /// Kernel width.
    pub kw: usize,
    bits: Vec<bool>,
}

impl BitKernel {
    /// Build from a row-major bit vector.
    ///
    /// # Panics
    /// If `bits.len() != kh * kw`.
    pub fn new(kh: usize, kw: usize, bits: Vec<bool>) -> Self {
        assert_eq!(bits.len(), kh * kw);
        Self { kh, kw, bits }
    }

    /// Bit at kernel position (kr, kc).
    #[inline]
    pub fn at(&self, kr: usize, kc: usize) -> bool {
        self.bits[kr * self.kw + kc]
    }

    /// Tile kernel row `kr` across `cols` columns with column offset `p`:
    /// bit `j` of the word = `W[kr][(j − p) mod kw]`.
    pub fn tile_row(&self, kr: usize, p: usize, cols: usize) -> u128 {
        let mut word = 0u128;
        for j in 0..cols {
            let kc = (j + self.kw - p % self.kw) % self.kw;
            if self.at(kr, kc) {
                word |= 1 << j;
            }
        }
        word
    }

    /// Precompute every (period, kernel-row) tiling word for a `cols`
    /// wide subarray. The conv stepper consults the same tiling once
    /// per buffer load, so building it bit-by-bit *per call* (the old
    /// path — once per input bit-plane per output channel) wasted the
    /// bulk of the host time; a [`KernelTiling`] is built once per
    /// (kernel bit-plane, width) and shared across all input bit-planes.
    pub fn tilings(&self, cols: usize) -> KernelTiling {
        let mut rows = Vec::with_capacity(self.kw * self.kh);
        for p in 0..self.kw {
            for kr in 0..self.kh {
                rows.push(self.tile_row(kr, p, cols));
            }
        }
        KernelTiling { kh: self.kh, kw: self.kw, cols, rows }
    }
}

/// Cached per-period tilings of one [`BitKernel`] over a fixed column
/// width (see [`BitKernel::tilings`]).
#[derive(Debug, Clone)]
pub struct KernelTiling {
    kh: usize,
    kw: usize,
    cols: usize,
    /// `rows[p * kh + kr]` = `tile_row(kr, p, cols)`.
    rows: Vec<u128>,
}

impl KernelTiling {
    /// Kernel height.
    pub fn kh(&self) -> usize {
        self.kh
    }

    /// Kernel width (also the period count).
    pub fn kw(&self) -> usize {
        self.kw
    }

    /// Column width the tiling was built for.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Tiling word of kernel row `kr` at period `p`.
    #[inline]
    pub fn row(&self, p: usize, kr: usize) -> u128 {
        self.rows[p * self.kh + kr]
    }
}

/// Raw bit-counter contents after one (output-row, period) pass.
///
/// The counts are stored *bit-sliced*, exactly as the hardware drains
/// them: `planes[b]` holds bit `b` of every column's count packed in
/// one word (`planes[b]` bit `j` = bit `b` of column `j`'s count).
/// This keeps the host representation word-parallel end to end — the
/// drain, the window fold and the accumulator push all operate on
/// whole row words, never on per-column integers.
#[derive(Debug, Clone)]
pub struct PeriodCounts {
    /// Sliding period (column offset of the weight tiling).
    pub period: usize,
    /// Output row index (input row window start / stride).
    pub out_row: usize,
    /// Columns the counts cover (the input width of the pass).
    pub in_w: usize,
    /// Bit-sliced per-column counter values (LSB plane first).
    pub planes: Vec<u128>,
}

impl PeriodCounts {
    /// Per-column counter values, reconstructed from the bit planes
    /// (diagnostic / test path — the hot path stays on `planes`).
    pub fn counts(&self) -> Vec<u32> {
        (0..self.in_w)
            .map(|j| {
                self.planes
                    .iter()
                    .enumerate()
                    .fold(0u32, |acc, (b, &w)| acc | ((((w >> j) & 1) as u32) << b))
            })
            .collect()
    }
}

/// Geometry of one bit-plane convolution.
#[derive(Debug, Clone, Copy)]
pub struct ConvGeometry {
    /// Input feature-map height (rows stored in the subarray).
    pub in_h: usize,
    /// Input feature-map width (≤ subarray columns).
    pub in_w: usize,
    /// Convolution stride.
    pub stride: usize,
}

impl ConvGeometry {
    /// Output height for a `kh`-tall kernel.
    pub fn out_h(&self, kh: usize) -> usize {
        if self.in_h < kh {
            0
        } else {
            (self.in_h - kh) / self.stride + 1
        }
    }

    /// Output width for a `kw`-wide kernel.
    pub fn out_w(&self, kw: usize) -> usize {
        if self.in_w < kw {
            0
        } else {
            (self.in_w - kw) / self.stride + 1
        }
    }
}

/// Run the bitwise convolution of the stored input bit-plane against
/// `kernel`, producing the per-column counts of every (output-row,
/// period) pass. The weight buffer is loaded once per period and reused
/// across all output rows — the paper's weight-reuse scheme.
///
/// `base` is the MTJ row holding input row 0.
pub fn bitplane_conv_counts(
    sub: &mut Subarray,
    base: usize,
    geo: ConvGeometry,
    kernel: &BitKernel,
    stats: &mut Stats,
    phase: Phase,
) -> Vec<PeriodCounts> {
    let tiling = kernel.tilings(geo.in_w);
    bitplane_conv_counts_tiled(sub, base, geo, &tiling, stats, phase)
}

/// [`bitplane_conv_counts`] with the weight tilings precomputed — the
/// hot-path entry: the functional coordinator builds one
/// [`KernelTiling`] per kernel bit-plane and reuses it across every
/// input bit-plane, instead of re-deriving the tiling words bit-by-bit
/// on each pass. The device-op sequence (and thus [`Stats`]) is
/// identical to the untiled entry point.
pub fn bitplane_conv_counts_tiled(
    sub: &mut Subarray,
    base: usize,
    geo: ConvGeometry,
    tiling: &KernelTiling,
    stats: &mut Stats,
    phase: Phase,
) -> Vec<PeriodCounts> {
    let (kh, kw) = (tiling.kh(), tiling.kw());
    assert!(geo.in_w <= sub.cols(), "input width exceeds subarray columns");
    assert_eq!(tiling.cols(), geo.in_w, "tiling width mismatch");
    assert!(base + geo.in_h <= sub.num_rows());
    assert!(kh <= sub.buffer.rows(), "kernel taller than weight buffer");

    let out_h = geo.out_h(kh);
    let out_w = geo.out_w(kw);
    let mut results = Vec::with_capacity(out_h * kw.min(out_w.max(1)));

    // Periods actually used by some output column.
    let mut used = vec![false; kw];
    for oc in 0..out_w {
        used[(oc * geo.stride) % kw] = true;
    }

    // Count ≤ kh per column, so ⌈log2(kh+1)⌉ drain cycles.
    let count_bits = 32 - (kh as u32).leading_zeros();
    let in_mask = if geo.in_w == 128 { u128::MAX } else { (1u128 << geo.in_w) - 1 };

    for (p, _) in used.iter().enumerate().filter(|(_, &u)| u) {
        // One buffer load per period, reused for every output row.
        for kr in 0..kh {
            sub.buffer_write(kr, tiling.row(p, kr), stats, phase);
        }
        for or in 0..out_h {
            sub.counters.reset();
            let r0 = base + or * geo.stride;
            for kr in 0..kh {
                sub.and_count(r0 + kr, kr, stats, phase);
            }
            // Drain the counters bit-serially (LSB + shift), as the
            // hardware does when streaming counts to the accumulation
            // subarray. Each drained word already *is* one bit plane
            // of all 128 per-column counts — keep it packed.
            let mut planes = Vec::with_capacity(count_bits as usize);
            for _ in 0..count_bits {
                planes.push(sub.counter_lsbs_shift(stats, phase) & in_mask);
            }
            results.push(PeriodCounts { period: p, out_row: or, in_w: geo.in_w, planes });
        }
    }
    results
}

/// Bit-sliced sum of the `kw` column-shifted copies of `planes`:
/// result column `c` holds `Σ_{kc<kw} value(c + kc)` (columns past the
/// input width contribute zero). One ripple-carry pass of word ops per
/// shift — the word-parallel form of the horizontal window fold.
fn fold_shifted(planes: &[u128], kw: usize, width: usize) -> Vec<u128> {
    let mut acc = vec![0u128; width];
    acc[..planes.len().min(width)].copy_from_slice(&planes[..planes.len().min(width)]);
    for kc in 1..kw {
        let mut carry = 0u128;
        for (b, a) in acc.iter_mut().enumerate() {
            let y = planes.get(b).map_or(0, |&w| w >> kc);
            let x = *a;
            *a = x ^ y ^ carry;
            carry = (x & y) | (carry & (x ^ y));
        }
        debug_assert_eq!(carry, 0, "window fold overflow: width too small");
    }
    acc
}

/// Pure word-parallel fold of [`PeriodCounts`] into per-output-row
/// window-sum bit planes: in row `or`'s result, bit `oc` of plane `b`
/// is bit `b` of `Σ_kc counts(period = oc·s mod kw)[oc·s + kc]` —
/// i.e. the planes are already packed in *output-column* space, ready
/// to program into the accumulation subarray one word per row.
///
/// In hardware this fold is the in-memory addition in the accumulation
/// subarray; the functional coordinator charges it there.
pub fn window_sum_planes(
    counts: &[PeriodCounts],
    geo: ConvGeometry,
    kh: usize,
    kw: usize,
) -> Vec<Vec<u128>> {
    let out_h = geo.out_h(kh);
    let out_w = geo.out_w(kw);
    let count_bits = counts.iter().map(|pc| pc.planes.len()).max().unwrap_or(0);
    // Headroom for the kw-way fold: sums stay below kw · 2^count_bits.
    let width = count_bits + (usize::BITS - kw.leading_zeros()) as usize;
    let mut out = vec![vec![0u128; width]; out_h];
    if out_w == 0 {
        return out;
    }
    for pc in counts {
        if pc.out_row >= out_h {
            continue;
        }
        let f = fold_shifted(&pc.planes, kw, width);
        let o = &mut out[pc.out_row];
        if geo.stride == 1 {
            // Output column oc reads input column oc; this period's
            // valid positions are oc ≡ p (mod kw) — a periodic mask.
            let mut sel = 0u128;
            let mut oc = pc.period;
            while oc < out_w {
                sel |= 1 << oc;
                oc += kw;
            }
            for (b, w) in f.iter().enumerate() {
                o[b] |= w & sel;
            }
        } else {
            // Strided gather: move the bit at input column oc·s to
            // output bit oc, for this period's output columns.
            for oc in 0..out_w {
                let c0 = oc * geo.stride;
                if c0 % kw != pc.period {
                    continue;
                }
                for (b, w) in f.iter().enumerate() {
                    o[b] |= ((w >> c0) & 1) << oc;
                }
            }
        }
    }
    out
}

/// Per-column window sums (`out[or][oc]`), reconstructed from
/// [`window_sum_planes`] — the diagnostic / reference view; the hot
/// path consumes the packed planes directly.
pub fn window_sums(
    counts: &[PeriodCounts],
    geo: ConvGeometry,
    kernel: &BitKernel,
) -> Vec<Vec<u32>> {
    let out_w = geo.out_w(kernel.kw);
    window_sum_planes(counts, geo, kernel.kh, kernel.kw)
        .into_iter()
        .map(|planes| {
            (0..out_w)
                .map(|oc| {
                    planes
                        .iter()
                        .enumerate()
                        .fold(0u32, |acc, (b, &w)| acc | ((((w >> oc) & 1) as u32) << b))
                })
                .collect()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::energy::DeviceCosts;

    fn sub() -> Subarray {
        Subarray::new(256, 128, 16, DeviceCosts::default())
    }

    /// Direct reference: 1-bit conv as nested loops.
    fn ref_conv(
        input: &[Vec<bool>],
        kernel: &BitKernel,
        stride: usize,
    ) -> Vec<Vec<u32>> {
        let in_h = input.len();
        let in_w = input[0].len();
        let out_h = (in_h - kernel.kh) / stride + 1;
        let out_w = (in_w - kernel.kw) / stride + 1;
        let mut out = vec![vec![0u32; out_w]; out_h];
        for or in 0..out_h {
            for oc in 0..out_w {
                let mut s = 0;
                for kr in 0..kernel.kh {
                    for kc in 0..kernel.kw {
                        s += (input[or * stride + kr][oc * stride + kc]
                            && kernel.at(kr, kc)) as u32;
                    }
                }
                out[or][oc] = s;
            }
        }
        out
    }

    fn store_input(sub: &mut Subarray, base: usize, input: &[Vec<bool>]) {
        let mut st = Stats::default();
        for (r, row) in input.iter().enumerate() {
            let mut word = 0u128;
            for (j, &b) in row.iter().enumerate() {
                if b {
                    word |= 1 << j;
                }
            }
            sub.write_row(base + r, word, &mut st, Phase::LoadData);
        }
    }

    fn pseudo_input(h: usize, w: usize, seed: u64) -> Vec<Vec<bool>> {
        let mut state = seed.wrapping_mul(0x9e37_79b9_7f4a_7c15) | 1;
        (0..h)
            .map(|_| {
                (0..w)
                    .map(|_| {
                        state ^= state << 13;
                        state ^= state >> 7;
                        state ^= state << 17;
                        state & 1 == 1
                    })
                    .collect()
            })
            .collect()
    }

    fn check(h: usize, w: usize, kh: usize, kw: usize, stride: usize, seed: u64) {
        let input = pseudo_input(h, w, seed);
        let kbits = pseudo_input(kh, kw, seed.wrapping_add(1)).concat();
        let kernel = BitKernel::new(kh, kw, kbits);
        let mut s = sub();
        store_input(&mut s, 0, &input);
        let geo = ConvGeometry { in_h: h, in_w: w, stride };
        let mut st = Stats::default();
        let counts = bitplane_conv_counts(&mut s, 0, geo, &kernel, &mut st, Phase::Convolution);
        let got = window_sums(&counts, geo, &kernel);
        assert_eq!(got, ref_conv(&input, &kernel, stride), "{h}x{w} k{kh}x{kw} s{stride}");
        assert!(st.ops.ands > 0);
    }

    #[test]
    fn matches_reference_2x2_on_2x5() {
        // The paper's own worked example size (Fig. 8).
        check(2, 5, 2, 2, 1, 42);
    }

    #[test]
    fn matches_reference_3x3() {
        check(8, 16, 3, 3, 1, 7);
    }

    #[test]
    fn matches_reference_strided() {
        check(12, 24, 3, 3, 2, 99);
        check(11, 23, 5, 5, 2, 123);
    }

    #[test]
    fn matches_reference_11x11_alexnet_like() {
        check(20, 40, 11, 11, 4, 5);
    }

    #[test]
    fn tilings_match_tile_row() {
        let kernel = BitKernel::new(3, 5, pseudo_input(3, 5, 77).concat());
        for &cols in &[1usize, 5, 37, 127, 128] {
            let tiling = kernel.tilings(cols);
            assert_eq!((tiling.kh(), tiling.kw(), tiling.cols()), (3, 5, cols));
            for p in 0..5 {
                for kr in 0..3 {
                    assert_eq!(
                        tiling.row(p, kr),
                        kernel.tile_row(kr, p, cols),
                        "p={p} kr={kr} cols={cols}"
                    );
                }
            }
        }
    }

    #[test]
    fn tiled_stepper_is_bit_and_stats_identical_to_untiled() {
        let input = pseudo_input(12, 30, 9);
        let kernel = BitKernel::new(3, 3, pseudo_input(3, 3, 10).concat());
        let geo = ConvGeometry { in_h: 12, in_w: 30, stride: 2 };
        let mut s1 = sub();
        let mut s2 = sub();
        store_input(&mut s1, 0, &input);
        store_input(&mut s2, 0, &input);
        let mut st1 = Stats::default();
        let mut st2 = Stats::default();
        let a = bitplane_conv_counts(&mut s1, 0, geo, &kernel, &mut st1, Phase::Convolution);
        let tiling = kernel.tilings(geo.in_w);
        let b = bitplane_conv_counts_tiled(&mut s2, 0, geo, &tiling, &mut st2, Phase::Convolution);
        assert_eq!(st1, st2, "device-op stream must be identical");
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!((x.period, x.out_row, &x.planes), (y.period, y.out_row, &y.planes));
        }
    }

    #[test]
    fn period_counts_planes_reconstruct_per_column_counts() {
        let input = pseudo_input(9, 21, 31);
        let kernel = BitKernel::new(4, 3, pseudo_input(4, 3, 32).concat());
        let mut s = sub();
        store_input(&mut s, 0, &input);
        let geo = ConvGeometry { in_h: 9, in_w: 21, stride: 1 };
        let mut st = Stats::default();
        let counts = bitplane_conv_counts(&mut s, 0, geo, &kernel, &mut st, Phase::Convolution);
        for pc in &counts {
            // Scalar reference: count matches per column directly.
            let expect: Vec<u32> = (0..21)
                .map(|j| {
                    (0..4)
                        .map(|kr| {
                            let row = pc.out_row + kr; // stride 1
                            let kc = (j + 3 - pc.period % 3) % 3;
                            (input[row][j] && kernel.at(kr, kc)) as u32
                        })
                        .sum()
                })
                .collect();
            assert_eq!(pc.counts(), expect, "period {} row {}", pc.period, pc.out_row);
        }
    }

    #[test]
    fn window_sum_planes_match_scalar_fold() {
        // The packed fold vs the pre-refactor per-column scalar fold.
        for &(h, w, kh, kw, stride, seed) in &[
            (8usize, 16usize, 3usize, 3usize, 1usize, 3u64),
            (10, 128, 3, 5, 1, 4),
            (12, 31, 5, 3, 2, 5),
            (9, 24, 2, 2, 3, 6),
            (11, 127, 4, 7, 2, 7),
        ] {
            let input = pseudo_input(h, w, seed);
            let kernel = BitKernel::new(kh, kw, pseudo_input(kh, kw, seed + 1).concat());
            let mut s = sub();
            store_input(&mut s, 0, &input);
            let geo = ConvGeometry { in_h: h, in_w: w, stride };
            let mut st = Stats::default();
            let counts =
                bitplane_conv_counts(&mut s, 0, geo, &kernel, &mut st, Phase::Convolution);
            // Scalar reference fold over reconstructed per-column counts.
            let out_h = geo.out_h(kh);
            let out_w = geo.out_w(kw);
            let mut expect = vec![vec![0u32; out_w]; out_h];
            for pc in &counts {
                let cols = pc.counts();
                for oc in 0..out_w {
                    let c0 = oc * stride;
                    if c0 % kw != pc.period {
                        continue;
                    }
                    expect[pc.out_row][oc] = (0..kw).map(|kc| cols[c0 + kc]).sum();
                }
            }
            assert_eq!(
                window_sums(&counts, geo, &kernel),
                expect,
                "{h}x{w} k{kh}x{kw} s{stride}"
            );
            // And the packed planes carry the same values bit-sliced.
            let planes = window_sum_planes(&counts, geo, kh, kw);
            for or in 0..out_h {
                for oc in 0..out_w {
                    let v = planes[or]
                        .iter()
                        .enumerate()
                        .fold(0u32, |acc, (b, &wd)| acc | ((((wd >> oc) & 1) as u32) << b));
                    assert_eq!(v, expect[or][oc], "or={or} oc={oc}");
                }
            }
        }
    }

    #[test]
    fn weight_buffer_loaded_once_per_period() {
        let input = pseudo_input(10, 20, 3);
        let kernel = BitKernel::new(3, 3, pseudo_input(3, 3, 4).concat());
        let mut s = sub();
        store_input(&mut s, 0, &input);
        let mut st = Stats::default();
        let geo = ConvGeometry { in_h: 10, in_w: 20, stride: 1 };
        bitplane_conv_counts(&mut s, 0, geo, &kernel, &mut st, Phase::Convolution);
        // 3 periods × 3 kernel rows of buffer loads; AND ops dominate.
        assert_eq!(st.ops.buffer_accesses as usize, 3 * 3 + st.ops.ands as usize);
    }
}
