//! Bitwise convolution stepper (Fig. 8).
//!
//! One subarray holds one *bit-plane* of the input feature map (row *r*
//! of the map in MTJ row `base + r`). The 1-bit weight matrix is written
//! once into the weight buffer, tiled across the columns with period
//! `Kw`; each *period* `p` shifts the tiling by one column (the paper's
//! "slide the weight matrix to the next position").
//!
//! Within a period, activating input row `r0+kr` against buffer row `kr`
//! ANDs the whole row in parallel and the per-column bit-counters
//! accumulate over the `Kh` kernel rows. Column `j`'s counter then holds
//! `Σ_kr I[r0+kr][j] · W[kr][(j−p) mod Kw]` — the *vertical* partial of
//! the window starting at any column `c ≡ p (mod Kw)`. The horizontal
//! fold across the `Kw` columns of each window is done by in-memory
//! addition in the accumulation subarray (cross-writing scheme, Fig. 12);
//! here we expose the raw per-column counts plus a pure fold helper used
//! by tests and by the functional coordinator.

use crate::arch::stats::{Phase, Stats};

use super::array::Subarray;

/// A 1-bit weight matrix (kernel bit-plane), `kh × kw`, row-major.
#[derive(Debug, Clone)]
pub struct BitKernel {
    /// Kernel height.
    pub kh: usize,
    /// Kernel width.
    pub kw: usize,
    bits: Vec<bool>,
}

impl BitKernel {
    /// Build from a row-major bit vector.
    ///
    /// # Panics
    /// If `bits.len() != kh * kw`.
    pub fn new(kh: usize, kw: usize, bits: Vec<bool>) -> Self {
        assert_eq!(bits.len(), kh * kw);
        Self { kh, kw, bits }
    }

    /// Bit at kernel position (kr, kc).
    #[inline]
    pub fn at(&self, kr: usize, kc: usize) -> bool {
        self.bits[kr * self.kw + kc]
    }

    /// Tile kernel row `kr` across `cols` columns with column offset `p`:
    /// bit `j` of the word = `W[kr][(j − p) mod kw]`.
    pub fn tile_row(&self, kr: usize, p: usize, cols: usize) -> u128 {
        let mut word = 0u128;
        for j in 0..cols {
            let kc = (j + self.kw - p % self.kw) % self.kw;
            if self.at(kr, kc) {
                word |= 1 << j;
            }
        }
        word
    }
}

/// Raw bit-counter contents after one (output-row, period) pass.
#[derive(Debug, Clone)]
pub struct PeriodCounts {
    /// Sliding period (column offset of the weight tiling).
    pub period: usize,
    /// Output row index (input row window start / stride).
    pub out_row: usize,
    /// Per-column counter values.
    pub counts: Vec<u32>,
}

/// Geometry of one bit-plane convolution.
#[derive(Debug, Clone, Copy)]
pub struct ConvGeometry {
    /// Input feature-map height (rows stored in the subarray).
    pub in_h: usize,
    /// Input feature-map width (≤ subarray columns).
    pub in_w: usize,
    /// Convolution stride.
    pub stride: usize,
}

impl ConvGeometry {
    /// Output height for a `kh`-tall kernel.
    pub fn out_h(&self, kh: usize) -> usize {
        if self.in_h < kh {
            0
        } else {
            (self.in_h - kh) / self.stride + 1
        }
    }

    /// Output width for a `kw`-wide kernel.
    pub fn out_w(&self, kw: usize) -> usize {
        if self.in_w < kw {
            0
        } else {
            (self.in_w - kw) / self.stride + 1
        }
    }
}

/// Run the bitwise convolution of the stored input bit-plane against
/// `kernel`, producing the per-column counts of every (output-row,
/// period) pass. The weight buffer is loaded once per period and reused
/// across all output rows — the paper's weight-reuse scheme.
///
/// `base` is the MTJ row holding input row 0.
pub fn bitplane_conv_counts(
    sub: &mut Subarray,
    base: usize,
    geo: ConvGeometry,
    kernel: &BitKernel,
    stats: &mut Stats,
    phase: Phase,
) -> Vec<PeriodCounts> {
    assert!(geo.in_w <= sub.cols(), "input width exceeds subarray columns");
    assert!(base + geo.in_h <= sub.num_rows());
    assert!(kernel.kh <= sub.buffer.rows(), "kernel taller than weight buffer");

    let out_h = geo.out_h(kernel.kh);
    let out_w = geo.out_w(kernel.kw);
    let mut results = Vec::with_capacity(out_h * kernel.kw.min(out_w.max(1)));

    // Periods actually used by some output column.
    let mut used = vec![false; kernel.kw];
    for oc in 0..out_w {
        used[(oc * geo.stride) % kernel.kw] = true;
    }

    for (p, _) in used.iter().enumerate().filter(|(_, &u)| u) {
        // One buffer load per period, reused for every output row.
        for kr in 0..kernel.kh {
            let word = kernel.tile_row(kr, p, geo.in_w);
            sub.buffer_write(kr, word, stats, phase);
        }
        for or in 0..out_h {
            sub.counters.reset();
            let r0 = base + or * geo.stride;
            for kr in 0..kernel.kh {
                sub.and_count(r0 + kr, kr, stats, phase);
            }
            // Drain the counters bit-serially (LSB + shift), as the
            // hardware does when streaming counts to the accumulation
            // subarray. Count ≤ kh, so ⌈log2(kh+1)⌉ drain cycles.
            // §Perf: iterate only the set bits of each drained plane
            // instead of walking all columns.
            let count_bits = 32 - (kernel.kh as u32).leading_zeros();
            let in_mask =
                if geo.in_w == 128 { u128::MAX } else { (1u128 << geo.in_w) - 1 };
            let mut counts = vec![0u32; geo.in_w];
            for bitpos in 0..count_bits {
                let mut lsbs = sub.counter_lsbs_shift(stats, phase) & in_mask;
                while lsbs != 0 {
                    let j = lsbs.trailing_zeros() as usize;
                    counts[j] |= 1 << bitpos;
                    lsbs &= lsbs - 1;
                }
            }
            results.push(PeriodCounts { period: p, out_row: or, counts });
        }
    }
    results
}

/// Pure fold of [`PeriodCounts`] into window sums:
/// `out[or][oc] = Σ_kc counts(period = oc·s mod kw)[oc·s + kc]`.
///
/// In hardware this fold is the in-memory addition in the accumulation
/// subarray; the functional coordinator charges it there.
pub fn window_sums(
    counts: &[PeriodCounts],
    geo: ConvGeometry,
    kernel: &BitKernel,
) -> Vec<Vec<u32>> {
    let out_h = geo.out_h(kernel.kh);
    let out_w = geo.out_w(kernel.kw);
    let mut out = vec![vec![0u32; out_w]; out_h];
    for pc in counts {
        for oc in 0..out_w {
            let c0 = oc * geo.stride;
            if c0 % kernel.kw != pc.period {
                continue;
            }
            out[pc.out_row][oc] = (0..kernel.kw).map(|kc| pc.counts[c0 + kc]).sum();
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::energy::DeviceCosts;

    fn sub() -> Subarray {
        Subarray::new(256, 128, 16, DeviceCosts::default())
    }

    /// Direct reference: 1-bit conv as nested loops.
    fn ref_conv(
        input: &[Vec<bool>],
        kernel: &BitKernel,
        stride: usize,
    ) -> Vec<Vec<u32>> {
        let in_h = input.len();
        let in_w = input[0].len();
        let out_h = (in_h - kernel.kh) / stride + 1;
        let out_w = (in_w - kernel.kw) / stride + 1;
        let mut out = vec![vec![0u32; out_w]; out_h];
        for or in 0..out_h {
            for oc in 0..out_w {
                let mut s = 0;
                for kr in 0..kernel.kh {
                    for kc in 0..kernel.kw {
                        s += (input[or * stride + kr][oc * stride + kc]
                            && kernel.at(kr, kc)) as u32;
                    }
                }
                out[or][oc] = s;
            }
        }
        out
    }

    fn store_input(sub: &mut Subarray, base: usize, input: &[Vec<bool>]) {
        let mut st = Stats::default();
        for (r, row) in input.iter().enumerate() {
            let mut word = 0u128;
            for (j, &b) in row.iter().enumerate() {
                if b {
                    word |= 1 << j;
                }
            }
            sub.write_row(base + r, word, &mut st, Phase::LoadData);
        }
    }

    fn pseudo_input(h: usize, w: usize, seed: u64) -> Vec<Vec<bool>> {
        let mut state = seed.wrapping_mul(0x9e37_79b9_7f4a_7c15) | 1;
        (0..h)
            .map(|_| {
                (0..w)
                    .map(|_| {
                        state ^= state << 13;
                        state ^= state >> 7;
                        state ^= state << 17;
                        state & 1 == 1
                    })
                    .collect()
            })
            .collect()
    }

    fn check(h: usize, w: usize, kh: usize, kw: usize, stride: usize, seed: u64) {
        let input = pseudo_input(h, w, seed);
        let kbits = pseudo_input(kh, kw, seed.wrapping_add(1)).concat();
        let kernel = BitKernel::new(kh, kw, kbits);
        let mut s = sub();
        store_input(&mut s, 0, &input);
        let geo = ConvGeometry { in_h: h, in_w: w, stride };
        let mut st = Stats::default();
        let counts = bitplane_conv_counts(&mut s, 0, geo, &kernel, &mut st, Phase::Convolution);
        let got = window_sums(&counts, geo, &kernel);
        assert_eq!(got, ref_conv(&input, &kernel, stride), "{h}x{w} k{kh}x{kw} s{stride}");
        assert!(st.ops.ands > 0);
    }

    #[test]
    fn matches_reference_2x2_on_2x5() {
        // The paper's own worked example size (Fig. 8).
        check(2, 5, 2, 2, 1, 42);
    }

    #[test]
    fn matches_reference_3x3() {
        check(8, 16, 3, 3, 1, 7);
    }

    #[test]
    fn matches_reference_strided() {
        check(12, 24, 3, 3, 2, 99);
        check(11, 23, 5, 5, 2, 123);
    }

    #[test]
    fn matches_reference_11x11_alexnet_like() {
        check(20, 40, 11, 11, 4, 5);
    }

    #[test]
    fn weight_buffer_loaded_once_per_period() {
        let input = pseudo_input(10, 20, 3);
        let kernel = BitKernel::new(3, 3, pseudo_input(3, 3, 4).concat());
        let mut s = sub();
        store_input(&mut s, 0, &input);
        let mut st = Stats::default();
        let geo = ConvGeometry { in_h: 10, in_w: 20, stride: 1 };
        bitplane_conv_counts(&mut s, 0, geo, &kernel, &mut st, Phase::Convolution);
        // 3 periods × 3 kernel rows of buffer loads; AND ops dominate.
        assert_eq!(st.ops.buffer_accesses as usize, 3 * 3 + st.ops.ands as usize);
    }
}
