//! Subarray weight buffer (Fig. 3b).
//!
//! A small row buffer with a private data port: weight rows are written
//! once over the bus and then reused for every AND across the whole input
//! matrix held in the subarray, which is the paper's key data-movement
//! saving ("requiring only one writing operation into the buffer ...").
//! The comparison primitive (Fig. 11) also uses two buffer rows as
//! scratch (tag / tag-inverted).


/// Weight / scratch buffer attached to one subarray.
#[derive(Debug, Clone)]
pub struct WeightBuffer {
    rows: Vec<u128>,
}

impl WeightBuffer {
    /// Buffer with `rows` rows, zero-initialised.
    pub fn new(rows: usize) -> Self {
        Self { rows: vec![0; rows] }
    }

    /// Number of rows.
    pub fn rows(&self) -> usize {
        self.rows.len()
    }

    /// Write a full row.
    ///
    /// # Panics
    /// If `row` is out of range.
    #[inline]
    pub fn write(&mut self, row: usize, data: u128) {
        self.rows[row] = data;
    }

    /// Read a full row.
    #[inline]
    pub fn read(&self, row: usize) -> u128 {
        self.rows[row]
    }

    /// Zero every row (host-side scratch-pool reset; no cost charged).
    pub fn clear(&mut self) {
        self.rows.fill(0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut b = WeightBuffer::new(4);
        b.write(2, 0xdead_beef);
        assert_eq!(b.read(2), 0xdead_beef);
        assert_eq!(b.read(0), 0);
    }
}
