//! The subarray functional + timing model: memory-mode ops
//! (erase / program / read) and compute-mode ops (AND + bit-count),
//! each charging the calibrated device costs into a [`Stats`] record.
//!
//! With a [`FaultPlan`] installed ([`Subarray::set_fault`]) the charged
//! ops additionally inject deterministic, seeded faults: program steps
//! can drop an intended bit (transient STT failure), senses can return
//! a flipped bit (SPCSA decision flip), and rows can carry a stuck-at-0
//! cell. [`Subarray::write_strip`] then verifies every write through
//! the (fault-prone) sense path and retries a bounded number of times —
//! each retry charged as a real erase + program rewrite — before
//! sparing an unrecoverable row with a charged remap. Without a plan
//! (or with all-zero rates) every code path is bit-identical to the
//! fault-free model.

use std::cell::Cell;

use crate::arch::stats::{Phase, Stats};
use crate::device::energy::DeviceCosts;
use crate::device::fault::{nth_set_bit, FaultPlan};
use crate::device::nand_spin::MTJS_PER_DEVICE;

use super::bitcounter::BitCounterBank;
use super::buffer::WeightBuffer;

// Domain-separation salts for the stateless fault draws.
const SALT_STUCK: u64 = 0x57;
const SALT_STUCK_POS: u64 = 0x58;
const SALT_PROGRAM: u64 = 0x509;
const SALT_PROGRAM_BIT: u64 = 0x50A;
const SALT_READ: u64 = 0x2EAD;
const SALT_READ_POS: u64 = 0x2EAE;
const SALT_AND: u64 = 0xA4D;
const SALT_AND_POS: u64 = 0xA4E;

/// Installed fault-injection state: the plan, a logical-context id
/// (what this subarray is being used *as* — faults are keyed on it so
/// the event stream is independent of worker scheduling), a per-context
/// op counter (`Cell`, because senses take `&self`) and the strips
/// already remapped onto spares.
#[derive(Debug, Clone)]
struct FaultState {
    plan: FaultPlan,
    ctx: u64,
    ops: Cell<u64>,
    spared: Vec<bool>,
}

impl FaultState {
    #[inline]
    fn next_op(&self) -> u64 {
        let n = self.ops.get();
        self.ops.set(n + 1);
        n
    }

    /// Stuck-at-0 mask for `row`: a pure function of `(plan, ctx, row)`,
    /// so the same logical row is stuck the same way for its whole
    /// context lifetime. Spared strips are defect-free.
    fn stuck_mask(&self, row: usize, cols: usize) -> u128 {
        if self.plan.rates.stuck_at == 0.0 || self.spared[row / MTJS_PER_DEVICE] {
            return 0;
        }
        if self.plan.unit(self.ctx, row as u64, SALT_STUCK) < self.plan.rates.stuck_at {
            1u128 << self.plan.pick(self.ctx, row as u64, SALT_STUCK_POS, cols as u32)
        } else {
            0
        }
    }
}

/// One NAND-SPIN subarray (paper: 256 rows × 128 columns).
#[derive(Debug, Clone)]
pub struct Subarray {
    /// MTJ rows; bit *j* of `rows[r]` is the stored bit at (row r, col j).
    rows: Vec<u128>,
    /// Per-column bit counters.
    pub counters: BitCounterBank,
    /// Weight / scratch buffer.
    pub buffer: WeightBuffer,
    cols: usize,
    col_mask: u128,
    costs: DeviceCosts,
    fault: Option<FaultState>,
}

impl Subarray {
    /// Build a subarray of `rows × cols` MTJs with the given cost scalars.
    ///
    /// # Panics
    /// If `cols` is 0 or > 128 or `rows` is not a multiple of 8.
    pub fn new(rows: usize, cols: usize, buffer_rows: usize, costs: DeviceCosts) -> Self {
        assert!(cols > 0 && cols <= 128, "cols must fit a u128 row word");
        assert_eq!(rows % MTJS_PER_DEVICE, 0, "rows must be whole strips");
        let col_mask = if cols == 128 { u128::MAX } else { (1u128 << cols) - 1 };
        Self {
            rows: vec![0; rows],
            counters: BitCounterBank::new(cols),
            buffer: WeightBuffer::new(buffer_rows),
            cols,
            col_mask,
            costs,
            fault: None,
        }
    }

    /// Install fault injection under `plan` for logical context `ctx`,
    /// resetting the per-context op counter and spare map. An inactive
    /// plan (all-zero rates) installs nothing — execution stays
    /// bit-identical to the fault-free model.
    pub fn set_fault(&mut self, plan: FaultPlan, ctx: u64) {
        if !plan.is_active() {
            self.fault = None;
            return;
        }
        let strips = self.strip_rows();
        match &mut self.fault {
            Some(f) => {
                f.plan = plan;
                f.ctx = ctx;
                f.ops.set(0);
                f.spared.fill(false);
            }
            None => {
                self.fault = Some(FaultState {
                    plan,
                    ctx,
                    ops: Cell::new(0),
                    spared: vec![false; strips],
                });
            }
        }
    }

    /// Remove any installed fault injection.
    pub fn clear_fault(&mut self) {
        self.fault = None;
    }

    /// True when an active fault plan is installed.
    pub fn fault_active(&self) -> bool {
        self.fault.is_some()
    }

    /// Number of MTJ rows.
    pub fn num_rows(&self) -> usize {
        self.rows.len()
    }

    /// Number of columns.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Number of strip-rows (rows / 8).
    pub fn strip_rows(&self) -> usize {
        self.rows.len() / MTJS_PER_DEVICE
    }

    /// Device cost scalars in force.
    pub fn costs(&self) -> &DeviceCosts {
        &self.costs
    }

    // ----------------------------------------------------------------
    // Memory mode (Fig. 5a–c, Table 1)
    // ----------------------------------------------------------------

    /// SOT erase of strip-row `strip`: clears the 8 MTJ rows
    /// `8·strip .. 8·strip+8` across all columns.
    pub fn erase_strip(&mut self, strip: usize, stats: &mut Stats, phase: Phase) {
        let base = strip * MTJS_PER_DEVICE;
        for r in base..base + MTJS_PER_DEVICE {
            self.rows[r] = 0;
        }
        stats.ops.erases += 1;
        stats.record(
            phase,
            self.costs.row_erase_energy_fj(self.cols),
            self.costs.erase_latency_ns,
        );
    }

    /// STT program step: within strip-row `strip`, program MTJ position
    /// `pos` (0..8) across all columns whose bit in `bits` is `1`
    /// (the column signals `C_x` of Table 1). Unipolar: only sets bits.
    pub fn program_row(
        &mut self,
        strip: usize,
        pos: usize,
        bits: u128,
        stats: &mut Stats,
        phase: Phase,
    ) {
        assert!(pos < MTJS_PER_DEVICE);
        let intended = bits & self.col_mask;
        let r = strip * MTJS_PER_DEVICE + pos;
        let mut stored = intended;
        if let Some(f) = &self.fault {
            let op = f.next_op();
            stored &= !f.stuck_mask(r, self.cols);
            if stored != 0 && f.plan.unit(f.ctx, op, SALT_PROGRAM) < f.plan.rates.program_fail {
                let k = f.plan.pick(f.ctx, op, SALT_PROGRAM_BIT, stored.count_ones());
                stored &= !nth_set_bit(stored, k);
                stats.faults.program_faults += 1;
            }
        }
        self.rows[r] |= stored;
        // The controller drives every intended column's STT current
        // whether or not the MTJ actually switches, so the charge is
        // for the intended bits.
        let switched = intended.count_ones() as u64;
        stats.ops.program_steps += 1;
        stats.ops.programmed_bits += switched;
        stats.record(
            phase,
            self.costs.program_energy_per_bit_fj() * switched as f64,
            self.costs.program_latency_per_bit_ns,
        );
    }

    /// Full row-of-devices write (§3.2): one erase + up to 8 program
    /// steps, writing `data[pos]` into MTJ position `pos` of every device
    /// in strip-row `strip`.
    ///
    /// Program steps whose column word is all-zero are skipped: with every
    /// `C_x` blocked no STT current flows anywhere, so the controller can
    /// elide the word-line cycle entirely (a standard NAND-style
    /// optimisation; the erase already left those MTJs in the `0` state).
    pub fn write_strip(
        &mut self,
        strip: usize,
        data: &[u128; MTJS_PER_DEVICE],
        stats: &mut Stats,
        phase: Phase,
    ) {
        self.write_strip_once(strip, data, stats, phase);
        if self.fault.is_some() {
            self.verify_and_recover(strip, data, stats, phase);
        }
    }

    /// One erase + program pass of [`Subarray::write_strip`], without
    /// the write-verify loop.
    fn write_strip_once(
        &mut self,
        strip: usize,
        data: &[u128; MTJS_PER_DEVICE],
        stats: &mut Stats,
        phase: Phase,
    ) {
        self.erase_strip(strip, stats, phase);
        for (pos, &bits) in data.iter().enumerate() {
            if bits & self.col_mask != 0 {
                self.program_row(strip, pos, bits, stats, phase);
            }
        }
    }

    /// Read back every programmed position of `strip` through the
    /// (fault-prone) sense path and compare against the intended bits,
    /// charging one read per verified row. All-zero rows are skipped:
    /// the erase left them 0 and stuck-at-0 cannot corrupt a 0.
    fn verify_strip(
        &mut self,
        strip: usize,
        data: &[u128; MTJS_PER_DEVICE],
        stats: &mut Stats,
        phase: Phase,
    ) -> bool {
        let base = strip * MTJS_PER_DEVICE;
        let mut ok = true;
        for (pos, &bits) in data.iter().enumerate() {
            let intended = bits & self.col_mask;
            if intended == 0 {
                continue;
            }
            if self.read_row(base + pos, stats, phase) != intended {
                ok = false;
            }
        }
        ok
    }

    /// The write-verify-retry loop: bounded rewrite attempts (each
    /// charged as a real erase + program pass), then row-sparing — the
    /// strip is remapped onto a spare (stuck cells no longer apply) and
    /// one final clean rewrite is charged and stored exactly.
    fn verify_and_recover(
        &mut self,
        strip: usize,
        data: &[u128; MTJS_PER_DEVICE],
        stats: &mut Stats,
        phase: Phase,
    ) {
        let limit = match &self.fault {
            Some(f) => f.plan.write_retry_limit,
            None => return,
        };
        if self.verify_strip(strip, data, stats, phase) {
            return;
        }
        for _ in 0..limit {
            stats.faults.write_retries += 1;
            self.write_strip_once(strip, data, stats, phase);
            if self.verify_strip(strip, data, stats, phase) {
                return;
            }
        }
        // Unrecoverable under the retry budget: remap to a spare strip.
        // The remap is charged as one more full rewrite; the spare
        // passed manufacturing test, so the store is exact (the failed
        // attempts above already charged the transient-fault energy).
        stats.faults.spared_rows += 1;
        if let Some(f) = self.fault.as_mut() {
            f.spared[strip] = true;
        }
        self.erase_strip(strip, stats, phase);
        for (pos, &bits) in data.iter().enumerate() {
            let b = bits & self.col_mask;
            if b != 0 {
                self.rows[strip * MTJS_PER_DEVICE + pos] = b;
                let switched = b.count_ones() as u64;
                stats.ops.program_steps += 1;
                stats.ops.programmed_bits += switched;
                stats.record(
                    phase,
                    self.costs.program_energy_per_bit_fj() * switched as f64,
                    self.costs.program_latency_per_bit_ns,
                );
            }
        }
    }

    /// Convenience: write one logical MTJ row (erase-modify-write of its
    /// strip). Real hardware would schedule whole-strip writes; the
    /// coordinator only uses this on scratch rows it owns exclusively, so
    /// the read-back is free of side effects but the *cost* charged is a
    /// full strip rewrite, keeping the accounting honest.
    pub fn write_row(&mut self, row: usize, bits: u128, stats: &mut Stats, phase: Phase) {
        let strip = row / MTJS_PER_DEVICE;
        let pos = row % MTJS_PER_DEVICE;
        let base = strip * MTJS_PER_DEVICE;
        let mut data = [0u128; MTJS_PER_DEVICE];
        for (i, d) in data.iter_mut().enumerate() {
            *d = self.rows[base + i];
        }
        data[pos] = bits & self.col_mask;
        self.write_strip(strip, &data, stats, phase);
    }

    /// Read MTJ row `row` via the SPCSAs (Fig. 5c): returns the stored
    /// bits of all columns.
    pub fn read_row(&self, row: usize, stats: &mut Stats, phase: Phase) -> u128 {
        stats.ops.reads += 1;
        stats.record(
            phase,
            self.costs.read_energy_per_bit_fj * self.cols as f64,
            self.costs.read_latency_ns,
        );
        let word = self.rows[row];
        if let Some(f) = &self.fault {
            let op = f.next_op();
            if f.plan.unit(f.ctx, op, SALT_READ) < f.plan.rates.read_flip {
                stats.faults.read_flips += 1;
                return word ^ (1u128 << f.plan.pick(f.ctx, op, SALT_READ_POS, self.cols as u32));
            }
        }
        word
    }

    /// Peek without charging costs (testing / debugging only).
    pub fn peek_row(&self, row: usize) -> u128 {
        self.rows[row]
    }

    /// Host-side reset to the freshly-built state (all MTJs erased,
    /// counters and buffer cleared) **without charging any cost** —
    /// used by the coordinator's scratch pool to reuse one allocation
    /// across layers instead of building a new subarray per use. The
    /// simulated device never does this; every modelled erase/program
    /// still goes through the charged ops above.
    pub fn clear_state(&mut self) {
        self.rows.fill(0);
        self.counters.reset();
        self.buffer.clear();
    }

    /// Host-side bulk row image load **without charging any cost**:
    /// copies `data` into MTJ rows `base..base + data.len()` (masked to
    /// this subarray's columns), leaving counters and buffer untouched.
    ///
    /// This exists for the intra-request fan-out in the functional
    /// coordinator: the charged load of a `(tile, channel, bit-plane)`
    /// slab happens exactly once on the shared charge stream, after
    /// which each worker mirrors the already-paid-for bits into its
    /// private compute subarray. Pairs with [`Subarray::clear_state`] —
    /// neither models a device operation.
    ///
    /// # Panics
    /// If `base + data.len()` exceeds the row count.
    pub fn host_load_rows(&mut self, base: usize, data: &[u128]) {
        for (i, &w) in data.iter().enumerate() {
            self.rows[base + i] = w & self.col_mask;
        }
    }

    // ----------------------------------------------------------------
    // Compute mode (Fig. 5d)
    // ----------------------------------------------------------------

    /// Row-parallel AND (Fig. 5d): the SAs sense row `row` with the `FU`
    /// inputs driven per-column by `operand`; returns the 128 SA outputs.
    /// Does *not* touch the counters — callers decide whether to count.
    pub fn and_row(&self, row: usize, operand: u128, stats: &mut Stats, phase: Phase) -> u128 {
        stats.ops.ands += 1;
        stats.record(
            phase,
            self.costs.and_energy_per_bit_fj * self.cols as f64,
            self.costs.and_latency_ns,
        );
        let word = self.rows[row] & operand & self.col_mask;
        if let Some(f) = &self.fault {
            let op = f.next_op();
            if f.plan.unit(f.ctx, op, SALT_AND) < f.plan.rates.read_flip {
                stats.faults.and_flips += 1;
                return word ^ (1u128 << f.plan.pick(f.ctx, op, SALT_AND_POS, self.cols as u32));
            }
        }
        word
    }

    /// AND row `row` against buffer row `buf_row` and accumulate the SA
    /// outputs into the bit-counters — the paper's fused convolution step.
    pub fn and_count(&mut self, row: usize, buf_row: usize, stats: &mut Stats, phase: Phase) {
        let operand = self.buffer.read(buf_row);
        stats.ops.buffer_accesses += 1;
        stats.record(
            phase,
            self.costs.buffer_energy_per_bit_fj * self.cols as f64,
            0.0, // buffer read overlaps the SA pre-charge
        );
        let out = self.and_row(row, operand, stats, phase);
        self.count(out, stats, phase);
    }

    /// Read row `row` (FU high — plain read) and accumulate into counters;
    /// the addition primitive's inner step (Fig. 9).
    pub fn read_count(&mut self, row: usize, stats: &mut Stats, phase: Phase) {
        let out = self.read_row(row, stats, phase);
        self.count(out, stats, phase);
    }

    /// Accumulate an SA output row into the bit-counters.
    pub fn count(&mut self, sa_out: u128, stats: &mut Stats, phase: Phase) {
        self.counters.accumulate(sa_out);
        stats.ops.bitcounts += 1;
        stats.record(
            phase,
            self.costs.bitcount_energy_per_bit_fj * self.cols as f64,
            0.0, // pipelined under the sense latency
        );
    }

    /// Read the counter LSBs and right-shift (the write-back + carry step
    /// of Figs. 9–10). Charges one standalone bit-counter cycle.
    pub fn counter_lsbs_shift(&mut self, stats: &mut Stats, phase: Phase) -> u128 {
        let lsbs = self.counters.lsbs();
        self.counters.shift_right();
        stats.record(
            phase,
            self.costs.bitcount_energy_per_bit_fj * self.cols as f64,
            self.costs.bitcount_latency_ns,
        );
        lsbs
    }

    /// Write a row into the weight buffer through its private port.
    pub fn buffer_write(&mut self, buf_row: usize, data: u128, stats: &mut Stats, phase: Phase) {
        self.buffer.write(buf_row, data & self.col_mask);
        stats.ops.buffer_accesses += 1;
        stats.record(
            phase,
            self.costs.buffer_energy_per_bit_fj * self.cols as f64,
            self.costs.buffer_latency_ns,
        );
    }

    /// Column mask for this subarray width.
    pub fn col_mask(&self) -> u128 {
        self.col_mask
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::NandSpinDevice;

    fn sub() -> Subarray {
        Subarray::new(256, 128, 4, DeviceCosts::default())
    }

    #[test]
    fn strip_write_read_roundtrip() {
        let mut s = sub();
        let mut st = Stats::default();
        let data = [1u128, 2, 4, 8, 16, 32, 64, 0xff];
        s.write_strip(3, &data, &mut st, Phase::LoadData);
        for (pos, &d) in data.iter().enumerate() {
            assert_eq!(s.read_row(3 * 8 + pos, &mut st, Phase::Other), d);
        }
        assert_eq!(st.ops.erases, 1);
        assert_eq!(st.ops.program_steps, 8);
    }

    #[test]
    fn write_costs_match_paper_model() {
        let mut s = sub();
        let mut st = Stats::default();
        let data = [u128::MAX; 8];
        s.write_strip(0, &data, &mut st, Phase::LoadData);
        // Latency: 2.4 ns erase + 8 × 5 ns program = 42.4 ns.
        assert!((st[Phase::LoadData].latency_ns - 42.4).abs() < 1e-9);
        // Energy: 128 devices × (180 fJ erase + 840 fJ program all-ones).
        let expect = 128.0 * (180.0 + 840.0);
        assert!((st[Phase::LoadData].energy_fj - expect).abs() < 1e-6);
    }

    #[test]
    fn and_matches_logic() {
        let mut s = sub();
        let mut st = Stats::default();
        s.write_row(8, 0b1100, &mut st, Phase::LoadData);
        let out = s.and_row(8, 0b1010, &mut st, Phase::Convolution);
        assert_eq!(out, 0b1000);
    }

    #[test]
    fn and_count_uses_buffer_operand() {
        let mut s = sub();
        let mut st = Stats::default();
        s.write_row(0, 0b0110, &mut st, Phase::LoadData);
        s.buffer_write(0, 0b1110, &mut st, Phase::LoadData);
        s.and_count(0, 0, &mut st, Phase::Convolution);
        assert_eq!(&s.counters.values()[..4], &[0, 1, 1, 0]);
    }

    #[test]
    fn row_word_model_matches_device_model() {
        // Bit-exactness cross-check: drive the same write pattern through
        // the u128-row subarray and through explicit NandSpinDevice strips.
        let mut s = sub();
        let mut st = Stats::default();
        let pattern: [u128; 8] =
            [0xdead, 0xbeef, 0x1234, 0x5678, 0x9abc, 0xdef0, 0x0f0f, 0xf0f0];
        s.write_strip(5, &pattern, &mut st, Phase::LoadData);

        let mut devices = vec![NandSpinDevice::default(); 128];
        for (col, dev) in devices.iter_mut().enumerate() {
            let mut byte = 0u8;
            for (pos, &row) in pattern.iter().enumerate() {
                byte |= (((row >> col) & 1) as u8) << pos;
            }
            dev.write_byte(byte);
        }
        for pos in 0..8 {
            let row = s.peek_row(5 * 8 + pos);
            for (col, dev) in devices.iter().enumerate() {
                assert_eq!((row >> col) & 1 == 1, dev.read(pos), "col {col} pos {pos}");
            }
        }
    }

    #[test]
    fn clear_state_restores_fresh_state_without_cost() {
        let mut s = sub();
        let mut st = Stats::default();
        s.write_row(9, 0xabcd, &mut st, Phase::LoadData);
        s.buffer_write(1, 0x77, &mut st, Phase::LoadData);
        s.count(0b101, &mut st, Phase::Convolution);
        let before = st.clone();
        s.clear_state();
        assert_eq!(st, before, "host reset must charge nothing");
        assert_eq!(s.peek_row(9), 0);
        assert_eq!(s.buffer.read(1), 0);
        assert!(s.counters.is_zero());
    }

    #[test]
    fn host_load_rows_is_uncharged_and_masked() {
        let mut s = Subarray::new(16, 8, 2, DeviceCosts::default());
        let st = Stats::default();
        s.host_load_rows(4, &[u128::MAX, 0b1010_1010]);
        assert_eq!(st, Stats::default(), "host load must charge nothing");
        assert_eq!(s.peek_row(4), 0xff, "words are masked to the column width");
        assert_eq!(s.peek_row(5), 0b1010_1010);
        assert_eq!(s.peek_row(6), 0, "rows outside the image stay untouched");
    }

    #[test]
    fn narrow_subarray_masks_columns() {
        let mut s = Subarray::new(16, 8, 2, DeviceCosts::default());
        let mut st = Stats::default();
        s.program_row(0, 0, u128::MAX, &mut st, Phase::LoadData);
        assert_eq!(s.peek_row(0), 0xff);
        assert_eq!(st.ops.programmed_bits, 8);
    }

    // ----------------------------------------------------------------
    // Fault injection and the write-verify-retry loop.
    // ----------------------------------------------------------------

    use crate::device::fault::{FaultPlan, FaultRates};

    fn exercise(s: &mut Subarray) -> Stats {
        let mut st = Stats::default();
        let data = [0xdeadu128, 0xbeef, 0x1234, 0x5678, 0x9abc, 0xdef0, 0x0f0f, 0xf0f0];
        s.write_strip(2, &data, &mut st, Phase::LoadData);
        for r in 0..16 {
            s.read_row(r, &mut st, Phase::Other);
            s.and_row(r, 0xffff, &mut st, Phase::Convolution);
        }
        st
    }

    #[test]
    fn zero_rate_plan_is_bit_identical_to_no_plan() {
        let mut clean = sub();
        let mut planned = sub();
        planned.set_fault(FaultPlan::disabled(), 7);
        assert!(!planned.fault_active(), "inactive plans install nothing");
        let a = exercise(&mut clean);
        let b = exercise(&mut planned);
        assert_eq!(a, b, "zero-rate plan must charge identically");
        assert!(b.faults.is_zero());
        for r in 0..32 {
            assert_eq!(clean.peek_row(r), planned.peek_row(r), "row {r}");
        }
    }

    #[test]
    fn fault_stream_is_deterministic_per_context() {
        let plan = FaultPlan::new(42, FaultRates::uniform(0.3));
        let run = |ctx: u64| {
            let mut s = sub();
            s.set_fault(plan, ctx);
            let st = exercise(&mut s);
            (st, (0..32).map(|r| s.peek_row(r)).collect::<Vec<_>>())
        };
        assert_eq!(run(1), run(1), "same (plan, ctx) replays the same faults");
        assert_ne!(run(1), run(2), "contexts draw independent streams");
    }

    #[test]
    fn certain_program_failure_retries_then_spares_with_charged_recovery() {
        let plan = FaultPlan::new(
            9,
            FaultRates { program_fail: 1.0, read_flip: 0.0, stuck_at: 0.0 },
        );
        let mut clean = sub();
        let mut faulty = sub();
        faulty.set_fault(plan, 0);
        let data = [0xffu128; 8];
        let mut st_clean = Stats::default();
        let mut st = Stats::default();
        clean.write_strip(0, &data, &mut st_clean, Phase::LoadData);
        faulty.write_strip(0, &data, &mut st, Phase::LoadData);
        // Every attempt drops a bit, so the bounded retries exhaust and
        // the strip is spared — after which the store is exact.
        assert_eq!(st.faults.write_retries, plan.write_retry_limit as u64);
        assert_eq!(st.faults.spared_rows, 1);
        assert!(st.faults.program_faults > 0);
        for pos in 0..8 {
            assert_eq!(faulty.peek_row(pos), 0xff, "spared strip stores exactly");
        }
        // Recovery is charged: retries + remap show up as real erase /
        // program / verify-read energy and latency.
        assert!(st.ops.erases > st_clean.ops.erases);
        assert!(st.ops.reads > st_clean.ops.reads, "verify reads are charged");
        assert!(st.total_energy_fj() > st_clean.total_energy_fj());
        assert!(st.total_latency_ns() > st_clean.total_latency_ns());
    }

    #[test]
    fn transient_failures_recover_within_the_retry_budget() {
        // At a moderate rate strips verify clean within the bounded
        // retries and nothing is spared.
        let plan = FaultPlan::new(
            3,
            FaultRates { program_fail: 0.08, read_flip: 0.0, stuck_at: 0.0 },
        );
        let mut s = sub();
        s.set_fault(plan, 1);
        let mut st = Stats::default();
        let mut data = [0u128; 8];
        data[0] = 0xffff_ffff;
        data[1] = 0xf00d;
        for strip in 0..32 {
            s.write_strip(strip, &data, &mut st, Phase::LoadData);
            for (pos, &d) in data.iter().enumerate() {
                assert_eq!(
                    s.peek_row(strip * 8 + pos),
                    d,
                    "strip {strip}: write-verify must leave the intended bits"
                );
            }
        }
        assert!(st.faults.program_faults > 0, "8 % over 64+ programs must fault");
        assert!(st.faults.write_retries > 0, "faulted strips must retry");
        assert_eq!(st.faults.spared_rows, 0, "transients recover without sparing");
    }

    #[test]
    fn read_flips_corrupt_the_sense_not_the_cell() {
        let plan = FaultPlan::new(
            11,
            FaultRates { program_fail: 0.0, read_flip: 1.0, stuck_at: 0.0 },
        );
        let mut s = sub();
        let mut st = Stats::default();
        s.write_row(8, 0b1100, &mut st, Phase::LoadData);
        s.set_fault(plan, 5);
        let stored = s.peek_row(8);
        let sensed = s.read_row(8, &mut st, Phase::Other);
        assert_eq!((sensed ^ stored).count_ones(), 1, "exactly one decision flips");
        assert_eq!(s.peek_row(8), stored, "the stored cell is untouched");
        let and = s.and_row(8, 0b1010, &mut st, Phase::Convolution);
        assert_eq!((and ^ 0b1000u128).count_ones(), 1);
        assert_eq!(st.faults.read_flips, 1);
        assert_eq!(st.faults.and_flips, 1);
    }

    #[test]
    fn stuck_cells_are_stable_and_recovered_by_sparing() {
        let plan = FaultPlan::new(
            21,
            FaultRates { program_fail: 0.0, read_flip: 0.0, stuck_at: 1.0 },
        );
        let mut s = sub();
        s.set_fault(plan, 3);
        let mut st = Stats::default();
        // Direct program: the stuck bit never sets, and it is the same
        // bit every time.
        s.program_row(4, 0, u128::MAX, &mut st, Phase::LoadData);
        let first = s.peek_row(32);
        assert_eq!(first.count_ones(), 127, "one cell stuck at 0");
        s.program_row(4, 0, u128::MAX, &mut st, Phase::LoadData);
        assert_eq!(s.peek_row(32), first, "the defect is stable per row");
        // A verified strip write hits the stuck cells, exhausts the
        // retries and spares the strip — after which it stores exactly.
        let data = [u128::MAX; 8];
        s.write_strip(6, &data, &mut st, Phase::LoadData);
        assert_eq!(st.faults.spared_rows, 1);
        for pos in 0..8 {
            assert_eq!(s.peek_row(6 * 8 + pos), u128::MAX, "spared strip is clean");
        }
    }
}
