//! The subarray functional + timing model: memory-mode ops
//! (erase / program / read) and compute-mode ops (AND + bit-count),
//! each charging the calibrated device costs into a [`Stats`] record.


use crate::arch::stats::{Phase, Stats};
use crate::device::energy::DeviceCosts;
use crate::device::nand_spin::MTJS_PER_DEVICE;

use super::bitcounter::BitCounterBank;
use super::buffer::WeightBuffer;

/// One NAND-SPIN subarray (paper: 256 rows × 128 columns).
#[derive(Debug, Clone)]
pub struct Subarray {
    /// MTJ rows; bit *j* of `rows[r]` is the stored bit at (row r, col j).
    rows: Vec<u128>,
    /// Per-column bit counters.
    pub counters: BitCounterBank,
    /// Weight / scratch buffer.
    pub buffer: WeightBuffer,
    cols: usize,
    col_mask: u128,
    costs: DeviceCosts,
}

impl Subarray {
    /// Build a subarray of `rows × cols` MTJs with the given cost scalars.
    ///
    /// # Panics
    /// If `cols` is 0 or > 128 or `rows` is not a multiple of 8.
    pub fn new(rows: usize, cols: usize, buffer_rows: usize, costs: DeviceCosts) -> Self {
        assert!(cols > 0 && cols <= 128, "cols must fit a u128 row word");
        assert_eq!(rows % MTJS_PER_DEVICE, 0, "rows must be whole strips");
        let col_mask = if cols == 128 { u128::MAX } else { (1u128 << cols) - 1 };
        Self {
            rows: vec![0; rows],
            counters: BitCounterBank::new(cols),
            buffer: WeightBuffer::new(buffer_rows),
            cols,
            col_mask,
            costs,
        }
    }

    /// Number of MTJ rows.
    pub fn num_rows(&self) -> usize {
        self.rows.len()
    }

    /// Number of columns.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Number of strip-rows (rows / 8).
    pub fn strip_rows(&self) -> usize {
        self.rows.len() / MTJS_PER_DEVICE
    }

    /// Device cost scalars in force.
    pub fn costs(&self) -> &DeviceCosts {
        &self.costs
    }

    // ----------------------------------------------------------------
    // Memory mode (Fig. 5a–c, Table 1)
    // ----------------------------------------------------------------

    /// SOT erase of strip-row `strip`: clears the 8 MTJ rows
    /// `8·strip .. 8·strip+8` across all columns.
    pub fn erase_strip(&mut self, strip: usize, stats: &mut Stats, phase: Phase) {
        let base = strip * MTJS_PER_DEVICE;
        for r in base..base + MTJS_PER_DEVICE {
            self.rows[r] = 0;
        }
        stats.ops.erases += 1;
        stats.record(
            phase,
            self.costs.row_erase_energy_fj(self.cols),
            self.costs.erase_latency_ns,
        );
    }

    /// STT program step: within strip-row `strip`, program MTJ position
    /// `pos` (0..8) across all columns whose bit in `bits` is `1`
    /// (the column signals `C_x` of Table 1). Unipolar: only sets bits.
    pub fn program_row(
        &mut self,
        strip: usize,
        pos: usize,
        bits: u128,
        stats: &mut Stats,
        phase: Phase,
    ) {
        assert!(pos < MTJS_PER_DEVICE);
        let bits = bits & self.col_mask;
        let r = strip * MTJS_PER_DEVICE + pos;
        self.rows[r] |= bits;
        let switched = bits.count_ones() as u64;
        stats.ops.program_steps += 1;
        stats.ops.programmed_bits += switched;
        stats.record(
            phase,
            self.costs.program_energy_per_bit_fj() * switched as f64,
            self.costs.program_latency_per_bit_ns,
        );
    }

    /// Full row-of-devices write (§3.2): one erase + up to 8 program
    /// steps, writing `data[pos]` into MTJ position `pos` of every device
    /// in strip-row `strip`.
    ///
    /// Program steps whose column word is all-zero are skipped: with every
    /// `C_x` blocked no STT current flows anywhere, so the controller can
    /// elide the word-line cycle entirely (a standard NAND-style
    /// optimisation; the erase already left those MTJs in the `0` state).
    pub fn write_strip(
        &mut self,
        strip: usize,
        data: &[u128; MTJS_PER_DEVICE],
        stats: &mut Stats,
        phase: Phase,
    ) {
        self.erase_strip(strip, stats, phase);
        for (pos, &bits) in data.iter().enumerate() {
            if bits & self.col_mask != 0 {
                self.program_row(strip, pos, bits, stats, phase);
            }
        }
    }

    /// Convenience: write one logical MTJ row (erase-modify-write of its
    /// strip). Real hardware would schedule whole-strip writes; the
    /// coordinator only uses this on scratch rows it owns exclusively, so
    /// the read-back is free of side effects but the *cost* charged is a
    /// full strip rewrite, keeping the accounting honest.
    pub fn write_row(&mut self, row: usize, bits: u128, stats: &mut Stats, phase: Phase) {
        let strip = row / MTJS_PER_DEVICE;
        let pos = row % MTJS_PER_DEVICE;
        let base = strip * MTJS_PER_DEVICE;
        let mut data = [0u128; MTJS_PER_DEVICE];
        for (i, d) in data.iter_mut().enumerate() {
            *d = self.rows[base + i];
        }
        data[pos] = bits & self.col_mask;
        self.write_strip(strip, &data, stats, phase);
    }

    /// Read MTJ row `row` via the SPCSAs (Fig. 5c): returns the stored
    /// bits of all columns.
    pub fn read_row(&self, row: usize, stats: &mut Stats, phase: Phase) -> u128 {
        stats.ops.reads += 1;
        stats.record(
            phase,
            self.costs.read_energy_per_bit_fj * self.cols as f64,
            self.costs.read_latency_ns,
        );
        self.rows[row]
    }

    /// Peek without charging costs (testing / debugging only).
    pub fn peek_row(&self, row: usize) -> u128 {
        self.rows[row]
    }

    /// Host-side reset to the freshly-built state (all MTJs erased,
    /// counters and buffer cleared) **without charging any cost** —
    /// used by the coordinator's scratch pool to reuse one allocation
    /// across layers instead of building a new subarray per use. The
    /// simulated device never does this; every modelled erase/program
    /// still goes through the charged ops above.
    pub fn clear_state(&mut self) {
        self.rows.fill(0);
        self.counters.reset();
        self.buffer.clear();
    }

    /// Host-side bulk row image load **without charging any cost**:
    /// copies `data` into MTJ rows `base..base + data.len()` (masked to
    /// this subarray's columns), leaving counters and buffer untouched.
    ///
    /// This exists for the intra-request fan-out in the functional
    /// coordinator: the charged load of a `(tile, channel, bit-plane)`
    /// slab happens exactly once on the shared charge stream, after
    /// which each worker mirrors the already-paid-for bits into its
    /// private compute subarray. Pairs with [`Subarray::clear_state`] —
    /// neither models a device operation.
    ///
    /// # Panics
    /// If `base + data.len()` exceeds the row count.
    pub fn host_load_rows(&mut self, base: usize, data: &[u128]) {
        for (i, &w) in data.iter().enumerate() {
            self.rows[base + i] = w & self.col_mask;
        }
    }

    // ----------------------------------------------------------------
    // Compute mode (Fig. 5d)
    // ----------------------------------------------------------------

    /// Row-parallel AND (Fig. 5d): the SAs sense row `row` with the `FU`
    /// inputs driven per-column by `operand`; returns the 128 SA outputs.
    /// Does *not* touch the counters — callers decide whether to count.
    pub fn and_row(&self, row: usize, operand: u128, stats: &mut Stats, phase: Phase) -> u128 {
        stats.ops.ands += 1;
        stats.record(
            phase,
            self.costs.and_energy_per_bit_fj * self.cols as f64,
            self.costs.and_latency_ns,
        );
        self.rows[row] & operand & self.col_mask
    }

    /// AND row `row` against buffer row `buf_row` and accumulate the SA
    /// outputs into the bit-counters — the paper's fused convolution step.
    pub fn and_count(&mut self, row: usize, buf_row: usize, stats: &mut Stats, phase: Phase) {
        let operand = self.buffer.read(buf_row);
        stats.ops.buffer_accesses += 1;
        stats.record(
            phase,
            self.costs.buffer_energy_per_bit_fj * self.cols as f64,
            0.0, // buffer read overlaps the SA pre-charge
        );
        let out = self.and_row(row, operand, stats, phase);
        self.count(out, stats, phase);
    }

    /// Read row `row` (FU high — plain read) and accumulate into counters;
    /// the addition primitive's inner step (Fig. 9).
    pub fn read_count(&mut self, row: usize, stats: &mut Stats, phase: Phase) {
        let out = self.read_row(row, stats, phase);
        self.count(out, stats, phase);
    }

    /// Accumulate an SA output row into the bit-counters.
    pub fn count(&mut self, sa_out: u128, stats: &mut Stats, phase: Phase) {
        self.counters.accumulate(sa_out);
        stats.ops.bitcounts += 1;
        stats.record(
            phase,
            self.costs.bitcount_energy_per_bit_fj * self.cols as f64,
            0.0, // pipelined under the sense latency
        );
    }

    /// Read the counter LSBs and right-shift (the write-back + carry step
    /// of Figs. 9–10). Charges one standalone bit-counter cycle.
    pub fn counter_lsbs_shift(&mut self, stats: &mut Stats, phase: Phase) -> u128 {
        let lsbs = self.counters.lsbs();
        self.counters.shift_right();
        stats.record(
            phase,
            self.costs.bitcount_energy_per_bit_fj * self.cols as f64,
            self.costs.bitcount_latency_ns,
        );
        lsbs
    }

    /// Write a row into the weight buffer through its private port.
    pub fn buffer_write(&mut self, buf_row: usize, data: u128, stats: &mut Stats, phase: Phase) {
        self.buffer.write(buf_row, data & self.col_mask);
        stats.ops.buffer_accesses += 1;
        stats.record(
            phase,
            self.costs.buffer_energy_per_bit_fj * self.cols as f64,
            self.costs.buffer_latency_ns,
        );
    }

    /// Column mask for this subarray width.
    pub fn col_mask(&self) -> u128 {
        self.col_mask
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::NandSpinDevice;

    fn sub() -> Subarray {
        Subarray::new(256, 128, 4, DeviceCosts::default())
    }

    #[test]
    fn strip_write_read_roundtrip() {
        let mut s = sub();
        let mut st = Stats::default();
        let data = [1u128, 2, 4, 8, 16, 32, 64, 0xff];
        s.write_strip(3, &data, &mut st, Phase::LoadData);
        for (pos, &d) in data.iter().enumerate() {
            assert_eq!(s.read_row(3 * 8 + pos, &mut st, Phase::Other), d);
        }
        assert_eq!(st.ops.erases, 1);
        assert_eq!(st.ops.program_steps, 8);
    }

    #[test]
    fn write_costs_match_paper_model() {
        let mut s = sub();
        let mut st = Stats::default();
        let data = [u128::MAX; 8];
        s.write_strip(0, &data, &mut st, Phase::LoadData);
        // Latency: 2.4 ns erase + 8 × 5 ns program = 42.4 ns.
        assert!((st[Phase::LoadData].latency_ns - 42.4).abs() < 1e-9);
        // Energy: 128 devices × (180 fJ erase + 840 fJ program all-ones).
        let expect = 128.0 * (180.0 + 840.0);
        assert!((st[Phase::LoadData].energy_fj - expect).abs() < 1e-6);
    }

    #[test]
    fn and_matches_logic() {
        let mut s = sub();
        let mut st = Stats::default();
        s.write_row(8, 0b1100, &mut st, Phase::LoadData);
        let out = s.and_row(8, 0b1010, &mut st, Phase::Convolution);
        assert_eq!(out, 0b1000);
    }

    #[test]
    fn and_count_uses_buffer_operand() {
        let mut s = sub();
        let mut st = Stats::default();
        s.write_row(0, 0b0110, &mut st, Phase::LoadData);
        s.buffer_write(0, 0b1110, &mut st, Phase::LoadData);
        s.and_count(0, 0, &mut st, Phase::Convolution);
        assert_eq!(&s.counters.values()[..4], &[0, 1, 1, 0]);
    }

    #[test]
    fn row_word_model_matches_device_model() {
        // Bit-exactness cross-check: drive the same write pattern through
        // the u128-row subarray and through explicit NandSpinDevice strips.
        let mut s = sub();
        let mut st = Stats::default();
        let pattern: [u128; 8] =
            [0xdead, 0xbeef, 0x1234, 0x5678, 0x9abc, 0xdef0, 0x0f0f, 0xf0f0];
        s.write_strip(5, &pattern, &mut st, Phase::LoadData);

        let mut devices = vec![NandSpinDevice::default(); 128];
        for (col, dev) in devices.iter_mut().enumerate() {
            let mut byte = 0u8;
            for (pos, &row) in pattern.iter().enumerate() {
                byte |= (((row >> col) & 1) as u8) << pos;
            }
            dev.write_byte(byte);
        }
        for pos in 0..8 {
            let row = s.peek_row(5 * 8 + pos);
            for (col, dev) in devices.iter().enumerate() {
                assert_eq!((row >> col) & 1 == 1, dev.read(pos), "col {col} pos {pos}");
            }
        }
    }

    #[test]
    fn clear_state_restores_fresh_state_without_cost() {
        let mut s = sub();
        let mut st = Stats::default();
        s.write_row(9, 0xabcd, &mut st, Phase::LoadData);
        s.buffer_write(1, 0x77, &mut st, Phase::LoadData);
        s.count(0b101, &mut st, Phase::Convolution);
        let before = st.clone();
        s.clear_state();
        assert_eq!(st, before, "host reset must charge nothing");
        assert_eq!(s.peek_row(9), 0);
        assert_eq!(s.buffer.read(1), 0);
        assert!(s.counters.is_zero());
    }

    #[test]
    fn host_load_rows_is_uncharged_and_masked() {
        let mut s = Subarray::new(16, 8, 2, DeviceCosts::default());
        let st = Stats::default();
        s.host_load_rows(4, &[u128::MAX, 0b1010_1010]);
        assert_eq!(st, Stats::default(), "host load must charge nothing");
        assert_eq!(s.peek_row(4), 0xff, "words are masked to the column width");
        assert_eq!(s.peek_row(5), 0b1010_1010);
        assert_eq!(s.peek_row(6), 0, "rows outside the image stay untouched");
    }

    #[test]
    fn narrow_subarray_masks_columns() {
        let mut s = Subarray::new(16, 8, 2, DeviceCosts::default());
        let mut st = Stats::default();
        s.program_row(0, 0, u128::MAX, &mut st, Phase::LoadData);
        assert_eq!(s.peek_row(0), 0xff);
        assert_eq!(st.ops.programmed_bits, 8);
    }
}
