//! NAND-SPIN subarray: the elementary compute/storage unit (Fig. 3b/4a).
//!
//! A subarray is `rows × cols` MTJs organised as `rows/8` strip-rows of
//! `cols` NAND-SPIN devices, with one SPCSA and one bit-counter per
//! column, plus a small weight buffer with a private data port.
//!
//! Rows are modelled as `u128` words (bit *j* = column *j*), which makes a
//! row-parallel AND a single machine op while remaining bit-exact with the
//! device model in [`crate::device`] (cross-checked in tests).

pub mod array;
pub mod bitcounter;
pub mod buffer;
pub mod conv;
pub mod primitives;

pub use array::Subarray;
pub use bitcounter::BitCounterBank;
pub use buffer::WeightBuffer;
