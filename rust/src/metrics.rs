//! Derived architecture-level metrics: the quantities the paper's
//! evaluation plots (peak GOPS, GOPS/mm², frames/s, GOPS/W, efficiency
//! normalised to area).


use crate::arch::stats::Stats;

/// Evaluation-ready metric bundle for one accelerator run/design point.
#[derive(Debug, Clone)]
pub struct Metrics {
    /// Descriptive label (design + model + precision).
    pub label: String,
    /// Total operations performed (MAC counted as 2 ops, paper style).
    pub ops: f64,
    /// End-to-end latency in ms (one inference).
    pub latency_ms: f64,
    /// Energy in mJ (one inference).
    pub energy_mj: f64,
    /// Chip area in mm².
    pub area_mm2: f64,
}

impl Metrics {
    /// From a stats record plus op count and area.
    pub fn from_stats(label: impl Into<String>, ops: f64, stats: &Stats, area_mm2: f64) -> Self {
        Self {
            label: label.into(),
            ops,
            latency_ms: stats.total_latency_ms(),
            energy_mj: stats.total_energy_mj(),
            area_mm2,
        }
    }

    /// Throughput in frames per second (single-frame latency inverse).
    pub fn fps(&self) -> f64 {
        1000.0 / self.latency_ms
    }

    /// Performance in GOPS.
    pub fn gops(&self) -> f64 {
        self.ops / (self.latency_ms * 1e-3) / 1e9
    }

    /// Performance normalised to area — Fig. 15's y-axis (GOPS/mm²).
    pub fn gops_per_mm2(&self) -> f64 {
        self.gops() / self.area_mm2
    }

    /// Energy efficiency in GOPS/W.
    pub fn gops_per_watt(&self) -> f64 {
        let watts = self.energy_mj * 1e-3 / (self.latency_ms * 1e-3);
        self.gops() / watts
    }

    /// Energy efficiency normalised to area — Fig. 14's y-axis
    /// (GOPS/W/mm²).
    pub fn efficiency_per_mm2(&self) -> f64 {
        self.gops_per_watt() / self.area_mm2
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::stats::Phase;

    #[test]
    fn derived_metrics_are_consistent() {
        let mut s = Stats::default();
        s.record(Phase::Convolution, 1e12, 1e6); // 1 mJ, 1 ms
        let m = Metrics::from_stats("test", 2e9, &s, 10.0);
        assert!((m.fps() - 1000.0).abs() < 1e-9);
        assert!((m.gops() - 2000.0).abs() < 1e-6);
        assert!((m.gops_per_mm2() - 200.0).abs() < 1e-6);
        // 1 mJ in 1 ms = 1 W → GOPS/W = 2000.
        assert!((m.gops_per_watt() - 2000.0).abs() < 1e-6);
    }
}
