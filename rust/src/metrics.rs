//! Derived architecture-level metrics: the quantities the paper's
//! evaluation plots (peak GOPS, GOPS/mm², frames/s, GOPS/W, efficiency
//! normalised to area).
//!
//! Every derived quantity guards its divisors: a degenerate design
//! point (zero latency, energy or area) yields `0.0`, never `inf` or
//! `NaN`, so bench tables and sweep printouts stay finite.

use crate::arch::stats::Stats;

/// Evaluation-ready metric bundle for one accelerator run/design point.
#[derive(Debug, Clone)]
pub struct Metrics {
    /// Descriptive label (design + model + precision).
    pub label: String,
    /// Total operations performed (MAC counted as 2 ops, paper style).
    pub ops: f64,
    /// End-to-end latency in ms (one inference).
    pub latency_ms: f64,
    /// Energy in mJ (one inference).
    pub energy_mj: f64,
    /// Chip area in mm².
    pub area_mm2: f64,
}

/// `num / den`, or `0.0` when the denominator is zero, negative or
/// non-finite — the well-defined value for a degenerate design point.
fn guarded_div(num: f64, den: f64) -> f64 {
    if den > 0.0 && den.is_finite() {
        num / den
    } else {
        0.0
    }
}

impl Metrics {
    /// From a stats record plus op count and area.
    pub fn from_stats(label: impl Into<String>, ops: f64, stats: &Stats, area_mm2: f64) -> Self {
        Self {
            label: label.into(),
            ops,
            latency_ms: stats.total_latency_ms(),
            energy_mj: stats.total_energy_mj(),
            area_mm2,
        }
    }

    /// Throughput in frames per second (single-frame latency inverse);
    /// 0 for a zero-latency record.
    pub fn fps(&self) -> f64 {
        guarded_div(1000.0, self.latency_ms)
    }

    /// Performance in GOPS; 0 for a zero-latency record.
    pub fn gops(&self) -> f64 {
        guarded_div(self.ops, self.latency_ms * 1e-3) / 1e9
    }

    /// Performance normalised to area — Fig. 15's y-axis (GOPS/mm²);
    /// 0 for a zero-area record.
    pub fn gops_per_mm2(&self) -> f64 {
        guarded_div(self.gops(), self.area_mm2)
    }

    /// Energy efficiency in GOPS/W; 0 when latency or energy is zero
    /// (no power to normalise by).
    pub fn gops_per_watt(&self) -> f64 {
        let watts = guarded_div(self.energy_mj * 1e-3, self.latency_ms * 1e-3);
        guarded_div(self.gops(), watts)
    }

    /// Energy efficiency normalised to area — Fig. 14's y-axis
    /// (GOPS/W/mm²); 0 for a degenerate record.
    pub fn efficiency_per_mm2(&self) -> f64 {
        guarded_div(self.gops_per_watt(), self.area_mm2)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::stats::Phase;

    #[test]
    fn derived_metrics_are_consistent() {
        let mut s = Stats::default();
        s.record(Phase::Convolution, 1e12, 1e6); // 1 mJ, 1 ms
        let m = Metrics::from_stats("test", 2e9, &s, 10.0);
        assert!((m.fps() - 1000.0).abs() < 1e-9);
        assert!((m.gops() - 2000.0).abs() < 1e-6);
        assert!((m.gops_per_mm2() - 200.0).abs() < 1e-6);
        // 1 mJ in 1 ms = 1 W → GOPS/W = 2000.
        assert!((m.gops_per_watt() - 2000.0).abs() < 1e-6);
    }

    #[test]
    fn degenerate_design_points_stay_finite() {
        // Zero-latency (and zero-energy) stats: every rate is 0, not inf/NaN.
        let m = Metrics::from_stats("empty", 2e9, &Stats::default(), 10.0);
        for v in [m.fps(), m.gops(), m.gops_per_mm2(), m.gops_per_watt(), m.efficiency_per_mm2()] {
            assert_eq!(v, 0.0, "degenerate metric must be exactly 0");
            assert!(v.is_finite());
        }
        // Zero area: the per-area normalisations are 0, the rest intact.
        let mut s = Stats::default();
        s.record(Phase::Convolution, 1e12, 1e6);
        let m = Metrics::from_stats("no-area", 2e9, &s, 0.0);
        assert!((m.fps() - 1000.0).abs() < 1e-9);
        assert_eq!(m.gops_per_mm2(), 0.0);
        assert_eq!(m.efficiency_per_mm2(), 0.0);
        // Zero energy at finite latency: watts is 0 → GOPS/W guards to 0.
        let mut s = Stats::default();
        s.record(Phase::Convolution, 0.0, 1e6);
        let m = Metrics::from_stats("no-energy", 2e9, &s, 10.0);
        assert_eq!(m.gops_per_watt(), 0.0);
        assert!(m.gops() > 0.0);
    }
}
