//! Tiling of feature-map bit-planes onto subarrays and the conv-layer
//! parallelism calculation.
//!
//! Two views of the same mapping (§4.2, Fig. 9) live here:
//!
//! * [`Tiling`] / [`ConvMapping`] — the *counting* view the analytic
//!   model uses: how many subarrays one layer occupies and how its
//!   filters parallelise over the pool.
//! * [`TilePlan`] / [`TileExtent`] — the *geometric* view the
//!   functional engine executes: the exact input slab (with halo
//!   columns/rows) each tile loads, and the exact output rectangle it
//!   owns. Both are derived from one axis decomposition
//!   ([`plan_axis`]), so the counts always agree with the enumerated
//!   plan.

use crate::arch::config::ArchConfig;
use crate::cnn::layer::Shape;

/// Greatest common divisor.
fn gcd(a: usize, b: usize) -> usize {
    if b == 0 {
        a
    } else {
        gcd(b, a % b)
    }
}

/// One tile of a 1-D convolution axis (height or width): the output
/// interval it owns and the input slab (fresh region + halo overlap
/// with the previous tile) it must hold to compute it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AxisTile {
    /// First output index owned by this tile.
    pub out0: usize,
    /// Number of output indices owned.
    pub out_n: usize,
    /// First input index of the slab (`out0 · stride`; tiles are never
    /// extended to the left so window arithmetic inside the slab stays
    /// aligned with the sliding-period schedule).
    pub in0: usize,
    /// Slab length in input elements (≤ the subarray capacity).
    pub in_n: usize,
    /// Leading slab elements that overlap the previous tile's slab —
    /// the halo that is re-sent through the bank buffer instead of
    /// loaded fresh. `0` for the first tile.
    pub halo: usize,
}

impl AxisTile {
    /// Input elements loaded fresh (not part of any earlier slab).
    pub fn fresh(&self) -> usize {
        self.in_n - self.halo
    }
}

/// Decompose one conv axis of `len` input elements (kernel `k`, stride
/// `stride`) into tiles of at most `cap` input elements. Returns `None`
/// when even a single window does not fit (`k > cap` with a non-empty
/// output).
///
/// Invariants (pinned by property tests):
/// * every output index is owned by exactly one tile, in order;
/// * each slab starts at `out0 · stride` and is at most `cap` long;
/// * consecutive slabs overlap by `halo = max(0, k − stride)` for
///   interior full tiles (and never more than `k − 1`);
/// * when `stride ≤ k` the fresh regions partition `0..len` exactly.
pub fn plan_axis(len: usize, k: usize, stride: usize, cap: usize) -> Option<Vec<AxisTile>> {
    let stride = stride.max(1);
    let ol = if len >= k { (len - k) / stride + 1 } else { 0 };
    if ol == 0 {
        // Degenerate: no output. One slab holding what fits.
        return Some(vec![AxisTile { out0: 0, out_n: 0, in0: 0, in_n: len.min(cap), halo: 0 }]);
    }
    if k > cap {
        return None;
    }
    let to_max = (cap - k) / stride + 1;
    let nt = ol.div_ceil(to_max);
    let mut tiles = Vec::with_capacity(nt);
    for i in 0..nt {
        let out0 = i * to_max;
        let out_n = to_max.min(ol - out0);
        let in0 = out0 * stride;
        let in_end = (out0 + out_n - 1) * stride + k;
        tiles.push(AxisTile { out0, out_n, in0, in_n: in_end - in0, halo: 0 });
    }
    // Close inter-slab gaps (stride > k) and cover the input tail, as
    // far as capacity allows, so fresh regions partition the axis.
    for i in 0..nt {
        let limit = if i + 1 < nt { tiles[i + 1].in0 } else { len };
        let in0 = tiles[i].in0;
        tiles[i].in_n = tiles[i].in_n.max(limit.min(in0 + cap) - in0);
    }
    for i in 1..nt {
        let prev_end = tiles[i - 1].in0 + tiles[i - 1].in_n;
        tiles[i].halo = prev_end.saturating_sub(tiles[i].in0);
    }
    Some(tiles)
}

/// One 2-D tile of a convolution layer: output rectangle owned and
/// input slab (with halo) required, in feature-map coordinates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TileExtent {
    /// First output column owned.
    pub out_x0: usize,
    /// Output columns owned.
    pub out_w: usize,
    /// First output row owned.
    pub out_y0: usize,
    /// Output rows owned.
    pub out_h: usize,
    /// First input column of the slab.
    pub in_x0: usize,
    /// Slab width (≤ subarray columns).
    pub in_w: usize,
    /// First input row of the slab.
    pub in_y0: usize,
    /// Slab height (≤ subarray rows).
    pub in_h: usize,
    /// Leading slab columns shared with the tile to the left.
    pub halo_w: usize,
    /// Leading slab rows shared with the tile above.
    pub halo_h: usize,
}

impl TileExtent {
    /// Slab elements that are loaded fresh from the source feature map
    /// (`(in_w − halo_w) · (in_h − halo_h)`); over a full [`TilePlan`]
    /// these partition the map when `stride ≤ k` on both axes.
    pub fn fresh_elems(&self) -> usize {
        (self.in_w - self.halo_w) * (self.in_h - self.halo_h)
    }

    /// Slab elements that are halo — re-sent through the bank buffer
    /// from slabs already resident rather than loaded fresh.
    pub fn halo_elems(&self) -> usize {
        self.in_w * self.in_h - self.fresh_elems()
    }
}

/// The enumerated multi-tile mapping of one conv layer's feature map
/// onto `rows × cols` subarray slabs (Fig. 9 executed literally): the
/// grid product of the two axis decompositions from [`plan_axis`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TilePlan {
    /// Tiles in row-major order (`tiles_h × tiles_w`).
    pub tiles: Vec<TileExtent>,
    /// Column-axis tile count.
    pub tiles_w: usize,
    /// Row-axis tile count.
    pub tiles_h: usize,
}

impl TilePlan {
    /// Plan an `h × w` (already padded) feature map for a `kh × kw`
    /// kernel at `stride` onto subarrays of `rows × cols` capacity.
    /// `None` when a single window exceeds one subarray.
    pub fn new(
        h: usize,
        w: usize,
        kh: usize,
        kw: usize,
        stride: usize,
        rows: usize,
        cols: usize,
    ) -> Option<Self> {
        let ax_h = plan_axis(h, kh, stride, rows)?;
        let ax_w = plan_axis(w, kw, stride, cols)?;
        let mut tiles = Vec::with_capacity(ax_h.len() * ax_w.len());
        for th in &ax_h {
            for tw in &ax_w {
                tiles.push(TileExtent {
                    out_x0: tw.out0,
                    out_w: tw.out_n,
                    out_y0: th.out0,
                    out_h: th.out_n,
                    in_x0: tw.in0,
                    in_w: tw.in_n,
                    in_y0: th.in0,
                    in_h: th.in_n,
                    halo_w: tw.halo,
                    halo_h: th.halo,
                });
            }
        }
        Some(Self { tiles, tiles_w: ax_w.len(), tiles_h: ax_h.len() })
    }

    /// Total tiles per bit-plane.
    pub fn count(&self) -> usize {
        self.tiles.len()
    }

    /// `true` when the plan is the single-tile (untiled) case.
    pub fn is_single(&self) -> bool {
        self.tiles.len() == 1
    }

    /// Total halo elements exchanged per bit-plane load (the documented
    /// tiling overhead on the local bus: `ic · ibits · halo_elems()`
    /// extra bits per conv layer).
    pub fn halo_elems(&self) -> usize {
        self.tiles.iter().map(TileExtent::halo_elems).sum()
    }
}

/// Tiling of one H×W bit-plane over `rows × cols` subarrays.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Tiling {
    /// Column tiles (width direction).
    pub tiles_w: usize,
    /// Row tiles (height direction).
    pub tiles_h: usize,
}

impl Tiling {
    /// Tile an `h × w` bit-plane for a `kh × kw` kernel at `stride`:
    /// the tile counts of the enumerated [`TilePlan`] (halo-aware on
    /// both axes). Falls back to the coarse ceiling division when a
    /// single window exceeds one subarray (the analytic model still
    /// wants a unit count there even though the functional engine
    /// rejects the layer).
    pub fn of(h: usize, w: usize, kh: usize, kw: usize, stride: usize, cfg: &ArchConfig) -> Self {
        match TilePlan::new(h, w, kh, kw, stride, cfg.rows, cfg.cols) {
            Some(p) => Self { tiles_w: p.tiles_w, tiles_h: p.tiles_h },
            None => {
                let usable_w = cfg.cols.saturating_sub(kw.saturating_sub(1)).max(1);
                Self { tiles_w: w.div_ceil(usable_w.min(w)), tiles_h: h.div_ceil(cfg.rows) }
            }
        }
    }

    /// Total tiles (subarrays per bit-plane).
    pub fn count(&self) -> usize {
        self.tiles_w * self.tiles_h
    }
}

/// Complete mapping of one convolution layer onto the pool.
#[derive(Debug, Clone, Copy)]
pub struct ConvMapping {
    /// Tiling of each input bit-plane.
    pub tiling: Tiling,
    /// Subarrays needed to hold one copy of the input bit-planes
    /// (`in_c × ibits × tiles`).
    pub plane_units: usize,
    /// Replication factor: how many copies of the plane set run in
    /// parallel, each handling a slice of the output channels.
    pub replication: usize,
    /// Filters processed sequentially per replica: `⌈out_c / R⌉`.
    pub serial_filters: usize,
    /// Sliding periods actually used (`kw / gcd(kw, stride)`).
    pub periods: usize,
}

impl ConvMapping {
    /// Map a conv layer (`in_shape`, kernel `kh×kw`, `stride`) with
    /// `ibits`-bit activations and `out_c` filters onto `avail`
    /// compute subarrays.
    #[allow(clippy::too_many_arguments)]
    pub fn plan(
        cfg: &ArchConfig,
        in_shape: Shape,
        out_c: usize,
        kh: usize,
        kw: usize,
        stride: usize,
        ibits: u8,
        avail: usize,
    ) -> Self {
        let (in_c, h, w) = in_shape;
        let tiling = Tiling::of(h, w, kh, kw, stride, cfg);
        let plane_units = (in_c * ibits as usize * tiling.count()).max(1);
        let replication = (avail / plane_units).clamp(1, out_c.max(1));
        let serial_filters = out_c.div_ceil(replication);
        let periods = kw / gcd(kw, stride.max(1));
        Self { tiling, plane_units, replication, serial_filters, periods }
    }

    /// Subarrays actually busy computing this layer.
    pub fn active_units(&self) -> usize {
        self.plane_units * self.replication
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::config::ArchConfig;

    #[test]
    fn small_plane_fits_one_subarray() {
        let cfg = ArchConfig::paper();
        let t = Tiling::of(28, 28, 3, 3, 1, &cfg);
        assert_eq!(t.count(), 1);
    }

    #[test]
    fn wide_plane_tiles_in_width() {
        let cfg = ArchConfig::paper();
        let t = Tiling::of(224, 224, 3, 3, 1, &cfg);
        assert_eq!(t.tiles_h, 1);
        assert_eq!(t.tiles_w, 2); // 222 outputs / 126 per tile → 2
    }

    #[test]
    fn tall_plane_tiles_in_height() {
        let cfg = ArchConfig::paper();
        // 510 output rows / 254 per 256-row subarray → 3 halo-aware tiles.
        let t = Tiling::of(512, 64, 3, 3, 1, &cfg);
        assert_eq!(t.tiles_h, 3);
    }

    #[test]
    fn periods_account_for_stride() {
        let cfg = ArchConfig::paper();
        // stride 1: all kw periods; stride 4 on kw=11 → gcd 1 → 11;
        // stride 2 on kw=2 → 1 period.
        let m = ConvMapping::plan(&cfg, (3, 224, 224), 64, 11, 11, 4, 8, 1 << 13);
        assert_eq!(m.periods, 11);
        let m2 = ConvMapping::plan(&cfg, (3, 224, 224), 64, 2, 2, 2, 8, 1 << 13);
        assert_eq!(m2.periods, 1);
    }

    #[test]
    fn replication_uses_available_pool() {
        let cfg = ArchConfig::paper();
        // 3 channels × 8 bits × 2 tiles = 48 plane units; 8192 avail →
        // replication capped by out_c.
        let m = ConvMapping::plan(&cfg, (3, 224, 224), 64, 3, 3, 1, 8, 8192);
        assert_eq!(m.plane_units, 48);
        assert_eq!(m.replication, 64, "capped at out_c");
        assert_eq!(m.serial_filters, 1);
        // Scarce pool → replication 1, filters serial.
        let m2 = ConvMapping::plan(&cfg, (3, 224, 224), 64, 3, 3, 1, 8, 50);
        assert_eq!(m2.replication, 1);
        assert_eq!(m2.serial_filters, 64);
    }

    #[test]
    fn alexnet_conv1_plan_is_two_width_tiles_with_stride_halo() {
        // 227-wide input, 11×11 kernel, stride 4 on a 128-col subarray:
        // 55 output cols, 30 per tile → 2 tiles; the first slab ends at
        // 29·4 + 11 = 127, the second starts at 30·4 = 120, so they
        // overlap by kw − stride = 7 cols.
        let p = TilePlan::new(227, 227, 11, 11, 4, 256, 128).expect("fits");
        assert_eq!((p.tiles_h, p.tiles_w), (1, 2));
        let t0 = p.tiles[0];
        let t1 = p.tiles[1];
        assert_eq!((t0.out_x0, t0.out_w, t0.in_x0, t0.in_w, t0.halo_w), (0, 30, 0, 127, 0));
        assert_eq!((t1.out_x0, t1.out_w, t1.in_x0), (30, 25, 120));
        assert_eq!(t1.in_x0 + t1.in_w, 227, "last slab covers the input tail");
        assert_eq!(t1.halo_w, 7);
        // Fresh loads partition the input exactly.
        let fresh: usize = p.tiles.iter().map(TileExtent::fresh_elems).sum();
        assert_eq!(fresh, 227 * 227);
    }

    #[test]
    fn oversized_window_is_rejected_not_mistiled() {
        assert!(plan_axis(300, 200, 1, 128).is_none());
        assert!(TilePlan::new(300, 300, 3, 200, 1, 256, 128).is_none());
        // ...but a degenerate no-output axis still yields one slab.
        let t = plan_axis(5, 9, 1, 128).expect("degenerate");
        assert_eq!(t.len(), 1);
        assert_eq!((t[0].out_n, t[0].in_n), (0, 5));
    }
}
