//! Tiling of feature-map bit-planes onto subarrays and the conv-layer
//! parallelism calculation.

use crate::arch::config::ArchConfig;
use crate::cnn::layer::Shape;

/// Greatest common divisor.
fn gcd(a: usize, b: usize) -> usize {
    if b == 0 {
        a
    } else {
        gcd(b, a % b)
    }
}

/// Tiling of one H×W bit-plane over `rows × cols` subarrays.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Tiling {
    /// Column tiles (width direction).
    pub tiles_w: usize,
    /// Row tiles (height direction).
    pub tiles_h: usize,
}

impl Tiling {
    /// Tile an `h × w` bit-plane. A `kw−1`-column halo is kept per column
    /// tile so windows never straddle tiles.
    pub fn of(h: usize, w: usize, kw: usize, cfg: &ArchConfig) -> Self {
        let usable_w = cfg.cols.saturating_sub(kw.saturating_sub(1)).max(1);
        Self { tiles_w: w.div_ceil(usable_w.min(w)), tiles_h: h.div_ceil(cfg.rows) }
    }

    /// Total tiles (subarrays per bit-plane).
    pub fn count(&self) -> usize {
        self.tiles_w * self.tiles_h
    }
}

/// Complete mapping of one convolution layer onto the pool.
#[derive(Debug, Clone, Copy)]
pub struct ConvMapping {
    /// Tiling of each input bit-plane.
    pub tiling: Tiling,
    /// Subarrays needed to hold one copy of the input bit-planes
    /// (`in_c × ibits × tiles`).
    pub plane_units: usize,
    /// Replication factor: how many copies of the plane set run in
    /// parallel, each handling a slice of the output channels.
    pub replication: usize,
    /// Filters processed sequentially per replica: `⌈out_c / R⌉`.
    pub serial_filters: usize,
    /// Sliding periods actually used (`kw / gcd(kw, stride)`).
    pub periods: usize,
}

impl ConvMapping {
    /// Map a conv layer (`in_shape`, kernel `kh×kw`, `stride`) with
    /// `ibits`-bit activations and `out_c` filters onto `avail`
    /// compute subarrays.
    pub fn plan(
        cfg: &ArchConfig,
        in_shape: Shape,
        out_c: usize,
        kw: usize,
        stride: usize,
        ibits: u8,
        avail: usize,
    ) -> Self {
        let (in_c, h, w) = in_shape;
        let tiling = Tiling::of(h, w, kw, cfg);
        let plane_units = (in_c * ibits as usize * tiling.count()).max(1);
        let replication = (avail / plane_units).clamp(1, out_c.max(1));
        let serial_filters = out_c.div_ceil(replication);
        let periods = kw / gcd(kw, stride.max(1));
        Self { tiling, plane_units, replication, serial_filters, periods }
    }

    /// Subarrays actually busy computing this layer.
    pub fn active_units(&self) -> usize {
        self.plane_units * self.replication
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::config::ArchConfig;

    #[test]
    fn small_plane_fits_one_subarray() {
        let cfg = ArchConfig::paper();
        let t = Tiling::of(28, 28, 3, &cfg);
        assert_eq!(t.count(), 1);
    }

    #[test]
    fn wide_plane_tiles_in_width() {
        let cfg = ArchConfig::paper();
        let t = Tiling::of(224, 224, 3, &cfg);
        assert_eq!(t.tiles_h, 1);
        assert_eq!(t.tiles_w, 2); // 224 / (128−2) → 2
    }

    #[test]
    fn tall_plane_tiles_in_height() {
        let cfg = ArchConfig::paper();
        let t = Tiling::of(512, 64, 3, &cfg);
        assert_eq!(t.tiles_h, 2);
    }

    #[test]
    fn periods_account_for_stride() {
        let cfg = ArchConfig::paper();
        // stride 1: all kw periods; stride 4 on kw=11 → gcd 1 → 11;
        // stride 2 on kw=2 → 1 period.
        let m = ConvMapping::plan(&cfg, (3, 224, 224), 64, 11, 4, 8, 1 << 13);
        assert_eq!(m.periods, 11);
        let m2 = ConvMapping::plan(&cfg, (3, 224, 224), 64, 2, 2, 8, 1 << 13);
        assert_eq!(m2.periods, 1);
    }

    #[test]
    fn replication_uses_available_pool() {
        let cfg = ArchConfig::paper();
        // 3 channels × 8 bits × 2 tiles = 48 plane units; 8192 avail →
        // replication capped by out_c.
        let m = ConvMapping::plan(&cfg, (3, 224, 224), 64, 3, 1, 8, 8192);
        assert_eq!(m.plane_units, 48);
        assert_eq!(m.replication, 64, "capped at out_c");
        assert_eq!(m.serial_filters, 1);
        // Scarce pool → replication 1, filters serial.
        let m2 = ConvMapping::plan(&cfg, (3, 224, 224), 64, 3, 1, 8, 50);
        assert_eq!(m2.replication, 1);
        assert_eq!(m2.serial_filters, 64);
    }
}
