//! The paper's data-mapping scheme (§4.1–4.2): bit-planes across
//! subarrays, weight reuse through the subarray buffer, cross-writing
//! partial-sum placement, and the parallelism bookkeeping the scheduler
//! uses.

pub mod tiling;

pub use tiling::{ConvMapping, TileExtent, TilePlan, Tiling};

use crate::arch::config::ArchConfig;

/// How the subarray pool is partitioned between convolution (bit-plane
/// holders) and accumulation (cross-writing partial-sum sinks).
///
/// The cross-writing scheme (Fig. 12) pairs producer subarrays with
/// accumulation subarrays so partial sums are written in parallel
/// "without cache operations"; we model that as an even split, which is
/// the steady-state of the paper's Period-1/Period-2 pipeline.
#[derive(Debug, Clone, Copy)]
pub struct PoolSplit {
    /// Subarrays holding input bit-planes and running AND/bit-count.
    pub compute: usize,
    /// Subarrays accumulating partial sums via in-memory addition.
    pub accumulate: usize,
}

impl PoolSplit {
    /// Split the configured pool.
    pub fn of(cfg: &ArchConfig) -> Self {
        let total = cfg.total_subarrays();
        Self { compute: total / 2, accumulate: total - total / 2 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_covers_pool() {
        let cfg = ArchConfig::paper();
        let s = PoolSplit::of(&cfg);
        assert_eq!(s.compute + s.accumulate, cfg.total_subarrays());
        assert!(s.compute >= 1);
    }
}
