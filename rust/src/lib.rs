//! # nandspin — NAND-SPIN Processing-in-MRAM CNN accelerator
//!
//! Reproduction of Zhao et al., *"NAND-SPIN-Based Processing-in-MRAM
//! Architecture for Convolutional Neural Network Acceleration"*
//! (Sci China Inf Sci, 2022).
//!
//! The crate is organised bottom-up, mirroring the paper's device → circuit
//! → architecture evaluation flow:
//!
//! * [`device`] — MTJ / NAND-SPIN strip functional model, SPCSA sense
//!   amplifier, macrospin switching-margin model, and the calibrated per-op
//!   latency/energy scalars reported in §5.1 of the paper.
//! * [`subarray`] — the 256×128 NAND-SPIN subarray with per-column
//!   bit-counters and a weight buffer; memory-mode ops (erase/program/read)
//!   and compute-mode ops (row AND + bit-count), plus the composed
//!   in-memory primitives: bitwise convolution, addition, multiplication
//!   and comparison (paper Figs. 8–11).
//! * [`mat`] / [`bank`] — the hierarchy of Fig. 2: 4×4 subarrays per mat
//!   with a local buffer and shared bus, 4×4 mats per bank with a global
//!   buffer and controller.
//! * [`nvsim`] — an NVSim-like analytic estimator for periphery
//!   latency/energy/area (the paper used a modified NVSim).
//! * [`arch`] — architecture configuration, statistics accounting with the
//!   Fig. 16 breakdown categories, and the Fig. 17 area model.
//! * [`cnn`] — integer tensors, bit-plane decomposition, quantization
//!   (Eq. 2), batch-norm (Eq. 3), layer IR, AlexNet/VGG19/ResNet50 presets,
//!   and a pure-Rust golden executor.
//! * [`mapping`] — the paper's data-mapping scheme: bit-planes across
//!   subarrays, weight reuse via the subarray buffer, and the cross-writing
//!   partial-sum placement.
//! * [`coordinator`] — the inference scheduler: one
//!   [`InferenceEngine`](coordinator::InferenceEngine) trait with a
//!   bit-accurate implementation (functional mode) and a closed-form
//!   one (full-scale analytic mode), producing the paper's metrics.
//! * [`baselines`] — analytic cost models for DRISA, PRIME, STT-CiM,
//!   MRIMA and IMCE, calibrated to their published Table-3 operating
//!   points.
//! * [`trace`] — deterministic observability: simulated-clock event
//!   timelines, an integer metrics registry, and per-layer simulated
//!   cost profiles, with JSONL / Chrome-trace / Prometheus exporters
//!   (`serve --trace` / `--metrics-out`).
//! * [`runtime`] — artifact runtime for the AOT-lowered JAX/Pallas
//!   artifacts (`artifacts/*.hlo.txt`); execution needs a PJRT backend,
//!   which the offline build stubs out (callers degrade gracefully).
//! * [`workload`] — synthetic image / workload generators.
//!
//! ## Serving
//!
//! On top of the engine trait, [`coordinator::serve`](mod@coordinator::serve)
//! is the deployment topology: several networks share one serve, each
//! batching in its own SLO lane
//! ([`SloPolicy`](coordinator::serve::SloPolicy): size- and
//! per-network-deadline-triggered flushes), and a cost-aware shard
//! router assigns every batch to the earliest-finish chip of a
//! possibly heterogeneous pool
//! ([`PoolSpec`](coordinator::PoolSpec): one `ArchConfig` per chip),
//! using each network's closed-form batching law
//! ([`BatchLaw`](coordinator::serve::BatchLaw)) on each chip's own
//! operating point. Each chip serves its bounded queue on a
//! weight-resident engine — the Table 3 steady-state condition — with
//! per-request, per-chip, per-network and aggregate latency/energy/SLO
//! accounting in [`ServeReport`](coordinator::serve::ServeReport). The
//! pool builds functional or analytic engines via
//! [`EngineFactory`](coordinator::EngineFactory), so the paper's
//! full-size benchmarks (AlexNet/VGG19/ResNet50) serve at closed-form
//! speed, and a hybrid mode spot-checks analytic stats against
//! functional replays.
//!
//! ## Orientation for new contributors
//!
//! Start with `ARCHITECTURE.md` at the repository root for the full L1
//! (device) → L2 (subarray/mat/bank) → L3 (coordinator/serving) map and
//! the design rationale, and `README.md` for the build/run quickstart.
//! The deepest invariant in the codebase: the functional engine, the
//! analytic model and the golden executor must agree — bit-for-bit for
//! outputs ([`cnn::ref_exec`] vs [`coordinator::FunctionalEngine`]) and
//! op-for-op for costs (both engines charge the one calibrated cost
//! model in [`device::energy`]). Most tests are phrased as one of those
//! two agreements.

#![warn(missing_docs)]

pub mod arch;
pub mod bank;
pub mod baselines;
pub mod cnn;
pub mod coordinator;
pub mod device;
pub mod mapping;
pub mod mat;
pub mod metrics;
pub mod nvsim;
pub mod runtime;
pub mod subarray;
pub mod trace;
pub mod util;
pub mod workload;

pub use arch::config::ArchConfig;
pub use arch::stats::{Phase, Stats};
pub use cnn::network::Network;
pub use coordinator::Coordinator;
