//! Mat level (Fig. 2 / Fig. 3a): a grid of subarrays sharing a local data
//! buffer and an in-mat bus.

pub mod bus;

pub use bus::Bus;

use crate::arch::config::ArchConfig;
use crate::arch::stats::{Phase, Stats};
use crate::subarray::Subarray;

/// One mat: `subarrays_per_mat` subarrays, a local buffer and a shared
/// in-mat bus.
#[derive(Debug, Clone)]
pub struct Mat {
    /// Subarrays, row-major over the (4×4) grid.
    pub subarrays: Vec<Subarray>,
    /// In-mat bus.
    pub bus: Bus,
}

impl Mat {
    /// Build a mat per `cfg`.
    pub fn new(cfg: &ArchConfig) -> Self {
        let n = cfg.subarrays_in_mat();
        let subarrays = (0..n)
            .map(|_| Subarray::new(cfg.rows, cfg.cols, cfg.buffer_rows, cfg.costs))
            .collect();
        Self { bus: Bus::local(cfg), subarrays }
    }

    /// Number of subarrays.
    pub fn len(&self) -> usize {
        self.subarrays.len()
    }

    /// True if the mat has no subarrays (never for valid configs).
    pub fn is_empty(&self) -> bool {
        self.subarrays.is_empty()
    }

    /// Move `bits`-wide data from one subarray's counters/SAs to another
    /// subarray over the in-mat bus (the paper's "in-mat data movement"
    /// of partial sums). Only the cost is charged here; the functional
    /// payload travels in the coordinator, which owns both endpoints.
    pub fn transfer(&mut self, bits: u64, stats: &mut Stats, phase: Phase) {
        self.bus.transfer(bits, stats, phase);
    }

    /// Split-borrow two distinct subarrays mutably.
    ///
    /// # Panics
    /// If `a == b` or out of range.
    pub fn pair_mut(&mut self, a: usize, b: usize) -> (&mut Subarray, &mut Subarray) {
        assert_ne!(a, b, "pair_mut needs distinct subarrays");
        if a < b {
            let (lo, hi) = self.subarrays.split_at_mut(b);
            (&mut lo[a], &mut hi[0])
        } else {
            let (lo, hi) = self.subarrays.split_at_mut(a);
            (&mut hi[0], &mut lo[b])
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mat_has_grid_of_subarrays() {
        let cfg = ArchConfig::paper();
        let m = Mat::new(&cfg);
        assert_eq!(m.len(), 16);
        assert_eq!(m.subarrays[0].num_rows(), 256);
    }

    #[test]
    fn pair_mut_borrows_disjoint() {
        let cfg = ArchConfig::paper();
        let mut m = Mat::new(&cfg);
        let mut st = Stats::default();
        let (a, b) = m.pair_mut(0, 5);
        a.buffer_write(0, 1, &mut st, Phase::Other);
        b.buffer_write(0, 2, &mut st, Phase::Other);
        assert_eq!(m.subarrays[0].buffer.read(0), 1);
        assert_eq!(m.subarrays[5].buffer.read(0), 2);
        let (b2, a2) = m.pair_mut(5, 0);
        assert_eq!(b2.buffer.read(0), 2);
        assert_eq!(a2.buffer.read(0), 1);
    }
}
