//! Shared data-bus model with width-limited throughput.
//!
//! The paper's §5.2 sensitivity study (Fig. 13b) shows peak performance
//! scaling linearly with the bus width because the bus feeds weight data
//! to the subarray buffers; this model reproduces that behaviour: a
//! transfer of `n` bits takes `⌈n / width⌉` bus cycles.


use crate::arch::config::ArchConfig;
use crate::arch::stats::{Phase, Stats};

/// Bus scope: in-mat (short wires) or global/inter-mat (long wires).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BusScope {
    /// In-mat bus connecting subarrays with the local buffer.
    Local,
    /// Global bus connecting mats with the global buffer and I/O.
    Global,
}

/// A width-limited shared bus.
#[derive(Debug, Clone)]
pub struct Bus {
    /// Bus width in bits.
    pub width_bits: usize,
    /// Cycle time in ns.
    pub cycle_ns: f64,
    /// Energy per transferred bit in fJ.
    pub energy_per_bit_fj: f64,
    scope: BusScope,
}

impl Bus {
    /// In-mat bus per the configuration.
    pub fn local(cfg: &ArchConfig) -> Self {
        Self {
            width_bits: cfg.bus_width_bits,
            cycle_ns: cfg.costs.bus_cycle_ns,
            energy_per_bit_fj: cfg.costs.bus_energy_per_bit_fj,
            scope: BusScope::Local,
        }
    }

    /// Global (inter-mat / I/O) bus per the configuration.
    pub fn global(cfg: &ArchConfig) -> Self {
        Self {
            width_bits: cfg.bus_width_bits,
            cycle_ns: cfg.costs.bus_cycle_ns,
            energy_per_bit_fj: cfg.costs.global_bus_energy_per_bit_fj,
            scope: BusScope::Global,
        }
    }

    /// Scope of this bus.
    pub fn scope(&self) -> BusScope {
        self.scope
    }

    /// Cycles needed to move `bits` bits.
    pub fn cycles(&self, bits: u64) -> u64 {
        bits.div_ceil(self.width_bits as u64)
    }

    /// Latency in ns to move `bits` bits.
    pub fn latency_ns(&self, bits: u64) -> f64 {
        self.cycles(bits) as f64 * self.cycle_ns
    }

    /// Charge a transfer of `bits` bits.
    pub fn transfer(&self, bits: u64, stats: &mut Stats, phase: Phase) {
        match self.scope {
            BusScope::Local => stats.ops.local_bus_bits += bits,
            BusScope::Global => stats.ops.global_bus_bits += bits,
        }
        stats.record(phase, self.energy_per_bit_fj * bits as f64, self.latency_ns(bits));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cycles_round_up() {
        let cfg = ArchConfig::paper();
        let bus = Bus::local(&cfg);
        assert_eq!(bus.cycles(1), 1);
        assert_eq!(bus.cycles(128), 1);
        assert_eq!(bus.cycles(129), 2);
        assert_eq!(bus.cycles(0), 0);
    }

    #[test]
    fn wider_bus_is_faster() {
        let mut cfg = ArchConfig::paper();
        cfg.bus_width_bits = 32;
        let narrow = Bus::local(&cfg);
        cfg.bus_width_bits = 256;
        let wide = Bus::local(&cfg);
        assert!(wide.latency_ns(1024) < narrow.latency_ns(1024));
    }

    #[test]
    fn global_bus_costs_more_energy() {
        let cfg = ArchConfig::paper();
        let mut s1 = Stats::default();
        let mut s2 = Stats::default();
        Bus::local(&cfg).transfer(1000, &mut s1, Phase::DataTransfer);
        Bus::global(&cfg).transfer(1000, &mut s2, Phase::DataTransfer);
        assert!(s2.total_energy_fj() > s1.total_energy_fj());
        assert_eq!(s1.ops.local_bus_bits, 1000);
        assert_eq!(s2.ops.global_bus_bits, 1000);
    }
}
