//! Bank controller: generates the control-signal schedule (Table 1) for
//! memory and compute operations and tracks issue statistics.
//!
//! The controller in the paper sequences the per-operation signal sets
//! (WE/ER/Cx/Ry/FU/REF); the functional simulator applies those semantics
//! directly in [`crate::subarray`], so what remains architecturally
//! visible here is the *schedule*: which op class was issued, the
//! signal-level invariants checked by [`SignalSet::validate`], and the
//! bank-level weight-residency bookkeeping ([`WeightResidency`]) the
//! serving runtime uses to stream each layer's weights once per chip.

use std::collections::HashSet;

/// Operation classes the controller can issue (Table 1 rows).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OpClass {
    /// SOT strip erase.
    Erase,
    /// STT program step.
    Program,
    /// SPCSA read.
    Read,
    /// SPCSA AND (compute mode).
    And,
}

/// Control-signal levels for one operation (Table 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SignalSet {
    /// Write-enable path transistor.
    pub we: bool,
    /// Erase path transistor.
    pub er: bool,
    /// Column select (`C_x`) — data-dependent during program.
    pub cx: bool,
    /// Row select (`R_y`).
    pub ry: bool,
    /// Function input to the SA: high for read, operand value for AND.
    pub fu: bool,
    /// Reference-branch enable.
    pub refb: bool,
}

impl SignalSet {
    /// Canonical signal set for an op class (Table 1), with `data` giving
    /// the data-dependent levels (program bit `D`, AND operand `W`).
    pub fn for_op(op: OpClass, data: bool) -> Self {
        match op {
            OpClass::Erase => Self { we: true, er: true, cx: false, ry: false, fu: false, refb: false },
            OpClass::Program => Self { we: true, er: false, cx: data, ry: true, fu: false, refb: false },
            OpClass::Read => Self { we: false, er: true, cx: false, ry: true, fu: true, refb: true },
            OpClass::And => Self { we: false, er: true, cx: false, ry: true, fu: data, refb: true },
        }
    }

    /// Check electrical invariants: the write path and the sense path are
    /// mutually exclusive; sensing requires the reference branch.
    pub fn validate(&self) -> Result<(), &'static str> {
        if self.we && self.refb {
            return Err("write path and sense path enabled simultaneously");
        }
        if self.fu && !self.refb {
            return Err("FU driven while the SA reference branch is off");
        }
        if self.we && self.er && (self.cx || self.ry) {
            return Err("erase must deselect all word/column lines");
        }
        Ok(())
    }
}

/// Controller issue statistics.
#[derive(Debug, Clone, Copy, Default)]
pub struct Controller {
    /// Erase ops issued.
    pub issued_erases: u64,
    /// Program steps issued.
    pub issued_programs: u64,
    /// Read ops issued.
    pub issued_reads: u64,
    /// AND ops issued.
    pub issued_ands: u64,
    /// Bus transfers issued.
    pub issued_transfers: u64,
}

impl Controller {
    /// Record an issue of `op`, returning the validated signal set.
    pub fn issue(&mut self, op: OpClass, data: bool) -> SignalSet {
        let sig = SignalSet::for_op(op, data);
        debug_assert!(sig.validate().is_ok());
        match op {
            OpClass::Erase => self.issued_erases += 1,
            OpClass::Program => self.issued_programs += 1,
            OpClass::Read => self.issued_reads += 1,
            OpClass::And => self.issued_ands += 1,
        }
        sig
    }
}

/// Bank-level weight-residency tracker: which layers' weight matrices are
/// currently held in the chip's subarray weight buffers.
///
/// The Table 3 serving condition loads a network's weights once and then
/// reuses them for every image of the batch; prior designs (and our
/// latency mode) re-stream them per inference. The serving runtime
/// ([`crate::coordinator::serve`](mod@crate::coordinator::serve))
/// gives each chip's engine one tracker:
/// the first inference misses on every conv layer (weights cross the
/// chip I/O and are charged to the load phase), subsequent inferences
/// hit and skip the stream entirely.
#[derive(Debug, Clone, Default)]
pub struct WeightResidency {
    resident: HashSet<usize>,
    /// Layer-weight lookups satisfied from resident buffers.
    pub hits: u64,
    /// Layer-weight lookups that required a stream from off-chip.
    pub misses: u64,
}

impl WeightResidency {
    /// Fresh tracker with nothing resident.
    pub fn new() -> Self {
        Self::default()
    }

    /// Request layer `layer`'s weights. Returns `true` when a load is
    /// needed (miss — the weights become resident afterwards), `false`
    /// when they are already held on-chip (hit).
    pub fn acquire(&mut self, layer: usize) -> bool {
        if self.resident.insert(layer) {
            self.misses += 1;
            true
        } else {
            self.hits += 1;
            false
        }
    }

    /// Evict everything (e.g. when the served network changes).
    pub fn evict_all(&mut self) {
        self.resident.clear();
    }

    /// Number of layers currently resident.
    pub fn resident_layers(&self) -> usize {
        self.resident.len()
    }

    /// Fraction of lookups served from resident weights.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_signal_sets_are_valid() {
        for op in [OpClass::Erase, OpClass::Program, OpClass::Read, OpClass::And] {
            for data in [false, true] {
                SignalSet::for_op(op, data).validate().unwrap();
            }
        }
    }

    #[test]
    fn table1_levels_match_paper() {
        let erase = SignalSet::for_op(OpClass::Erase, false);
        assert!(erase.we && erase.er && !erase.fu && !erase.refb);
        let prog1 = SignalSet::for_op(OpClass::Program, true);
        assert!(prog1.we && !prog1.er && prog1.cx && prog1.ry);
        let read = SignalSet::for_op(OpClass::Read, true);
        assert!(!read.we && read.er && read.fu && read.refb);
        let and0 = SignalSet::for_op(OpClass::And, false);
        assert!(!and0.fu && and0.refb, "AND with W=0 holds FU low");
    }

    #[test]
    fn controller_counts_issues() {
        let mut c = Controller::default();
        c.issue(OpClass::Erase, false);
        c.issue(OpClass::Program, true);
        c.issue(OpClass::And, false);
        assert_eq!((c.issued_erases, c.issued_programs, c.issued_ands), (1, 1, 1));
    }

    #[test]
    fn residency_misses_once_then_hits() {
        let mut r = WeightResidency::new();
        // First pass over a 3-conv network: all misses.
        assert!(r.acquire(0) && r.acquire(1) && r.acquire(2));
        assert_eq!((r.hits, r.misses), (0, 3));
        assert_eq!(r.resident_layers(), 3);
        // Second pass: all hits.
        assert!(!r.acquire(0) && !r.acquire(1) && !r.acquire(2));
        assert_eq!((r.hits, r.misses), (3, 3));
        assert!((r.hit_rate() - 0.5).abs() < 1e-12);
        r.evict_all();
        assert_eq!(r.resident_layers(), 0);
        assert!(r.acquire(0), "evicted weights must reload");
    }

    #[test]
    fn invalid_combinations_rejected() {
        let bad = SignalSet { we: true, er: false, cx: false, ry: false, fu: true, refb: true };
        assert!(bad.validate().is_err());
        let bad2 = SignalSet { we: false, er: false, cx: false, ry: false, fu: true, refb: false };
        assert!(bad2.validate().is_err());
    }
}
