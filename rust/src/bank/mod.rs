//! Bank level (Fig. 2): a grid of mats with a global buffer and the
//! controller that schedules computations and communications.

pub mod controller;

pub use controller::{Controller, WeightResidency};

use crate::arch::config::ArchConfig;
use crate::arch::stats::{Phase, Stats};
use crate::mat::{Bus, Mat};

/// One fully-functional bank group: `mats_per_bank` mats, a global data
/// buffer and the shared global bus.
#[derive(Debug, Clone)]
pub struct Bank {
    /// Mats, row-major over the (4×4) grid.
    pub mats: Vec<Mat>,
    /// Global (inter-mat / I/O) bus.
    pub global_bus: Bus,
    /// Controller state.
    pub controller: Controller,
}

impl Bank {
    /// Build a bank per `cfg`.
    pub fn new(cfg: &ArchConfig) -> Self {
        let mats = (0..cfg.mats_in_bank()).map(|_| Mat::new(cfg)).collect();
        Self {
            mats,
            global_bus: Bus::global(cfg),
            controller: Controller::default(),
        }
    }

    /// Number of mats.
    pub fn len(&self) -> usize {
        self.mats.len()
    }

    /// True if empty (never for valid configs).
    pub fn is_empty(&self) -> bool {
        self.mats.is_empty()
    }

    /// Charge an inter-mat or I/O transfer of `bits` bits on the global
    /// bus (data entering/leaving the bank or crossing mats).
    pub fn transfer(&mut self, bits: u64, stats: &mut Stats, phase: Phase) {
        self.controller.issued_transfers += 1;
        self.global_bus.transfer(bits, stats, phase);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bank_geometry() {
        let cfg = ArchConfig::paper();
        let b = Bank::new(&cfg);
        assert_eq!(b.len(), 16);
        assert_eq!(b.mats[0].len(), 16);
    }

    #[test]
    fn transfer_counts_and_charges() {
        let cfg = ArchConfig::paper();
        let mut b = Bank::new(&cfg);
        let mut st = Stats::default();
        b.transfer(256, &mut st, Phase::DataTransfer);
        assert_eq!(b.controller.issued_transfers, 1);
        assert_eq!(st.ops.global_bus_bits, 256);
        assert!(st[Phase::DataTransfer].latency_ns > 0.0);
    }
}
