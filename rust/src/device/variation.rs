//! Process-variation analysis of the SPCSA sensing path.
//!
//! The paper's §3.2 leans on the reliability-enhanced separated
//! pre-charge SA (Zhang et al., TMAG 2017) and §4.1 notes that designs
//! which compute by activating *two* word lines "may cause logic
//! failures … hard to guarantee reliability" — which is why NAND-SPIN
//! computes with a single selected cell against a fixed reference.
//!
//! This module quantifies that argument: Monte-Carlo over log-normal
//! resistance variation of the MTJ and the reference branch, measuring
//! the read/AND decision error rate of (a) the single-cell SPCSA scheme
//! and (b) a two-cell bit-line scheme (two series cells vs a 1.5R
//! reference), reproducing the reliability gap the paper claims.

use crate::device::mtj::MtjParams;
use crate::util::Rng;

/// One Monte-Carlo estimate.
#[derive(Debug, Clone, Copy)]
pub struct ErrorRates {
    /// Single-cell SPCSA read error rate (proposed scheme).
    pub single_cell: f64,
    /// Two-cell series bit-line compute error rate (prior-art scheme).
    pub dual_cell: f64,
}

/// Sample a log-normal factor with standard deviation `sigma` (of the
/// underlying normal) using Box–Muller on the deterministic PRNG.
fn lognormal(rng: &mut Rng, sigma: f64) -> f64 {
    let u1 = (rng.next_u64() as f64 + 1.0) / (u64::MAX as f64 + 2.0);
    let u2 = (rng.next_u64() as f64 + 1.0) / (u64::MAX as f64 + 2.0);
    let z = (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
    (sigma * z).exp()
}

/// Monte-Carlo the sensing error rates at relative resistance-variation
/// `sigma` with `trials` samples per scheme.
pub fn sensing_error_rates(params: &MtjParams, sigma: f64, trials: u32, seed: u64) -> ErrorRates {
    let mut rng = Rng::seed_from_u64(seed);
    let (rl, rh) = (params.r_low_ohm(), params.r_high_ohm());
    let rref = params.r_ref_ohm();

    let mut single_err = 0u32;
    let mut dual_err = 0u32;
    for _ in 0..trials {
        // --- single-cell SPCSA: cell vs (R_H + R_L)/2 reference.
        let bit = rng.gen_bool();
        let cell = if bit { rl } else { rh } * lognormal(&mut rng, sigma);
        let reference = rref * lognormal(&mut rng, sigma);
        let sensed = cell < reference;
        if sensed != bit {
            single_err += 1;
        }

        // --- dual-cell series scheme (e.g. bit-line AND): two cells in
        // series vs a reference between (R_H+R_L) and 2R_L; decision
        // margins are halved relative to the swing.
        let a = rng.gen_bool();
        let b = rng.gen_bool();
        let r1 = if a { rl } else { rh } * lognormal(&mut rng, sigma);
        let r2 = if b { rl } else { rh } * lognormal(&mut rng, sigma);
        let dual_ref = (2.0 * rl + (rl + rh)) / 2.0 * lognormal(&mut rng, sigma);
        let sensed_and = r1 + r2 < dual_ref;
        if sensed_and != (a && b) {
            dual_err += 1;
        }
    }
    ErrorRates {
        single_cell: single_err as f64 / trials as f64,
        dual_cell: dual_err as f64 / trials as f64,
    }
}

/// Sweep of sigma values for reporting (CLI / EXPERIMENTS.md).
pub fn margin_sweep(params: &MtjParams, seed: u64) -> Vec<(f64, ErrorRates)> {
    [0.02, 0.05, 0.08, 0.10, 0.15]
        .iter()
        .map(|&s| (s, sensing_error_rates(params, s, 200_000, seed)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_variation_means_no_errors() {
        let r = sensing_error_rates(&MtjParams::default(), 0.0, 10_000, 1);
        assert_eq!(r.single_cell, 0.0);
        assert_eq!(r.dual_cell, 0.0);
    }

    #[test]
    fn single_cell_is_more_reliable_than_dual_cell() {
        // The paper's reliability argument: at realistic variation the
        // single-cell SPCSA scheme must have a lower error rate than the
        // two-cell series scheme.
        for sigma in [0.05, 0.08, 0.10, 0.15] {
            let r = sensing_error_rates(&MtjParams::default(), sigma, 100_000, 7);
            assert!(
                r.single_cell <= r.dual_cell,
                "sigma {sigma}: single {} vs dual {}",
                r.single_cell,
                r.dual_cell
            );
        }
    }

    #[test]
    fn small_variation_is_safe() {
        // TMR 120 % gives a wide margin: 5 % sigma ⇒ error ≪ 1e-2.
        let r = sensing_error_rates(&MtjParams::default(), 0.05, 200_000, 3);
        assert!(r.single_cell < 1e-2, "{}", r.single_cell);
    }

    #[test]
    fn errors_grow_with_variation() {
        let lo = sensing_error_rates(&MtjParams::default(), 0.05, 200_000, 5);
        let hi = sensing_error_rates(&MtjParams::default(), 0.15, 200_000, 5);
        assert!(hi.single_cell > lo.single_cell);
    }
}
