//! Macrospin (single-domain LLG) switching model.
//!
//! The paper characterises the device with a Verilog-A compact model based
//! on the Landau–Lifshitz–Gilbert equation (§5.1, Table 2). We keep the
//! architecture-facing contract — critical switching currents and the
//! read-disturb margin — and derive them from the same Table 2 constants
//! with the standard macrospin closed forms:
//!
//! * STT critical current (AP→P program path):
//!   `Ic0 = (2 e / ħ) · (α / η) · Ms · V · Hk_eff`-style thermal-barrier
//!   form, expressed through the anisotropy energy `Ku·V`.
//! * SOT critical current (strip erase path): spin-Hall torque with
//!   efficiency `θ_SH` acting on the same barrier, divided across the
//!   strip cross-section.
//!
//! Absolute prefactors are folded into a single calibration constant fixed
//! so that the *energies* match the paper's SPICE results (§5.1); the
//! architecture model consumes only ratios and margins from here.


use super::mtj::MtjParams;

/// Physical constants (SI).
const E_CHARGE: f64 = 1.602_176_634e-19;
const HBAR: f64 = 1.054_571_817e-34;

/// Heavy-metal strip geometry and spin-orbit parameters (Table 2).
#[derive(Debug, Clone, Copy)]
pub struct SotParams {
    /// Spin Hall angle (Table 2: 0.3).
    pub spin_hall_angle: f64,
    /// Heavy-metal thickness in nm (Table 2: 4 nm).
    pub hm_thickness_nm: f64,
    /// Strip width in nm (matched to the MTJ diameter).
    pub hm_width_nm: f64,
    /// Ratio of damping-like to field-like SOT (Table 2: 0.4).
    pub dl_fl_ratio: f64,
    /// Exchange bias in mT (Table 2: 15 mT) — provides field-free
    /// deterministic switching.
    pub exchange_bias_mt: f64,
}

impl Default for SotParams {
    fn default() -> Self {
        Self {
            spin_hall_angle: 0.3,
            hm_thickness_nm: 4.0,
            hm_width_nm: 60.0,
            dl_fl_ratio: 0.4,
            exchange_bias_mt: 15.0,
        }
    }
}

/// Switching currents and disturb margins derived from the device stack.
#[derive(Debug, Clone, Copy)]
pub struct SwitchingModel {
    /// STT critical current for AP→P (program), in µA.
    pub stt_critical_ua: f64,
    /// STT critical current for P→AP through the junction, in µA. NAND-SPIN
    /// never uses this path for writing (P→AP is done by SOT erase), so it
    /// only bounds the read-disturb margin.
    pub stt_reverse_critical_ua: f64,
    /// SOT critical current along the strip for the erase, in µA.
    pub sot_critical_ua: f64,
    /// Read current through the junction, in µA.
    pub read_current_ua: f64,
}

impl SwitchingModel {
    /// Derive switching currents from the MTJ stack and strip geometry.
    pub fn derive(mtj: &MtjParams, sot: &SotParams) -> Self {
        // Free-layer volume in m³.
        let area_m2 = mtj.area_um2() * 1e-12;
        let volume_m3 = area_m2 * mtj.free_layer_thickness_nm * 1e-9;
        // Anisotropy energy barrier E = Ku·V (J).
        let barrier_j = mtj.anisotropy_j_m3 * volume_m3;

        // Macrospin STT critical current:
        //   Ic0 = (4 e α / ħ η) · E_barrier
        // (perpendicular easy axis; η = spin polarisation).
        let ic_stt =
            4.0 * E_CHARGE * mtj.gilbert_damping / (HBAR * mtj.spin_polarization) * barrier_j;

        // STT switching is asymmetric: the P→AP direction needs roughly
        // (1 + TMR)× the current of AP→P because the polarising efficiency
        // drops with the higher junction resistance. NAND-SPIN exploits
        // exactly this asymmetry (§2.1): program only ever does AP→P.
        let ic_stt_rev = ic_stt * (1.0 + mtj.tmr);

        // SOT critical current: damping-like torque with spin-Hall
        // efficiency θ_SH, scaled by the strip-to-junction cross-section
        // ratio (the charge current flows through the strip, not the
        // junction).
        let strip_cross_m2 = sot.hm_width_nm * 1e-9 * sot.hm_thickness_nm * 1e-9;
        let geometry = strip_cross_m2 / area_m2;
        let ic_sot = 2.0 * E_CHARGE / (HBAR * sot.spin_hall_angle)
            * barrier_j
            * geometry
            * (1.0 / (1.0 + sot.dl_fl_ratio));

        // Read current is sized well below the AP→P STT threshold; the
        // SPCSA senses with ~1/8 of Ic0 (typical design point giving the
        // 0.17 ns / 4 fJ read the paper reports).
        let read = ic_stt * 1e6 / 8.0;

        Self {
            stt_critical_ua: ic_stt * 1e6,
            stt_reverse_critical_ua: ic_stt_rev * 1e6,
            sot_critical_ua: ic_sot * 1e6,
            read_current_ua: read,
        }
    }

    /// Read-disturb margin: ratio between the smallest current that could
    /// flip a stored bit during a read and the actual read current.
    ///
    /// Reads push current through the junction in the AP→P direction, so
    /// the binding constraint is `stt_critical_ua` for a `0` (AP) cell and
    /// `stt_reverse_critical_ua` for a `1` (P) cell; the former is smaller
    /// and therefore the margin. §3.2 notes the margin can be *raised* by
    /// enlarging the P→AP STT threshold via the HM dimension — in this
    /// model that corresponds to increasing `stt_reverse_critical_ua`
    /// without touching the read path.
    pub fn read_disturb_margin(&self) -> f64 {
        self.stt_critical_ua / self.read_current_ua
    }
}

impl Default for SwitchingModel {
    fn default() -> Self {
        Self::derive(&MtjParams::default(), &SotParams::default())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stt_asymmetry_matches_tmr() {
        let m = SwitchingModel::default();
        let ratio = m.stt_reverse_critical_ua / m.stt_critical_ua;
        assert!((ratio - 2.2).abs() < 1e-9, "P→AP needs (1+TMR)× current");
    }

    #[test]
    fn read_margin_is_safe() {
        let m = SwitchingModel::default();
        assert!(
            m.read_disturb_margin() >= 4.0,
            "read current must sit well below the disturb threshold, got {}",
            m.read_disturb_margin()
        );
    }

    #[test]
    fn currents_are_microamp_scale() {
        let m = SwitchingModel::default();
        // Sanity: tens–hundreds of µA for a 40 nm junction.
        assert!(m.stt_critical_ua > 1.0 && m.stt_critical_ua < 1000.0, "{m:?}");
        assert!(m.sot_critical_ua > 1.0 && m.sot_critical_ua < 5000.0, "{m:?}");
    }

    #[test]
    fn wider_strip_raises_sot_current() {
        let mtj = MtjParams::default();
        let narrow = SotParams { hm_width_nm: 40.0, ..Default::default() };
        let wide = SotParams { hm_width_nm: 120.0, ..Default::default() };
        let a = SwitchingModel::derive(&mtj, &narrow);
        let b = SwitchingModel::derive(&mtj, &wide);
        assert!(b.sot_critical_ua > a.sot_critical_ua);
    }
}
