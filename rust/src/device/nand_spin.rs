//! NAND-SPIN multi-bit device: a heavy-metal strip carrying
//! [`MTJS_PER_DEVICE`] MTJs, organised like a NAND flash string (Fig. 1d).
//!
//! Write is two-step (§2.1):
//! 1. **Erase** — PT+NT conduct, a SOT current along the strip resets every
//!    MTJ to AP (stored `0`).
//! 2. **Program** — per selected MTJ, WL + PT conduct and the STT current
//!    through the junction switches AP→P (stored `1`). A blocked column
//!    signal leaves the bit at `0`.


use super::mtj::{Mtj, MtjParams};

/// MTJs per heavy-metal strip — fixed at 8 in the paper's design
/// (`M×N = 128×8` bits per device row, §3.2).
pub const MTJS_PER_DEVICE: usize = 8;

/// One NAND-SPIN device: 8 MTJs sharing a heavy-metal strip.
#[derive(Debug, Clone, Copy, Default)]
pub struct NandSpinDevice {
    mtjs: [Mtj; MTJS_PER_DEVICE],
}

impl NandSpinDevice {
    /// SOT erase of the full strip: all MTJs → AP (`0`).
    pub fn erase(&mut self) {
        for m in &mut self.mtjs {
            m.erase();
        }
    }

    /// STT program of MTJ `pos`: AP→P (`1`). Unipolar — never clears.
    ///
    /// # Panics
    /// If `pos >= MTJS_PER_DEVICE`.
    pub fn program(&mut self, pos: usize) {
        self.mtjs[pos].program();
    }

    /// Read the stored bit at `pos`.
    pub fn read(&self, pos: usize) -> bool {
        self.mtjs[pos].bit()
    }

    /// Write the whole strip: erase then program the `1` bits of `byte`
    /// (bit `i` of `byte` → MTJ `i`). Returns the number of programmed
    /// (switched) bits, which determines program energy.
    pub fn write_byte(&mut self, byte: u8) -> u32 {
        self.erase();
        for pos in 0..MTJS_PER_DEVICE {
            if byte >> pos & 1 == 1 {
                self.program(pos);
            }
        }
        byte.count_ones()
    }

    /// Read the whole strip as a byte (bit `i` ← MTJ `i`).
    pub fn read_byte(&self) -> u8 {
        let mut b = 0u8;
        for pos in 0..MTJS_PER_DEVICE {
            b |= (self.read(pos) as u8) << pos;
        }
        b
    }

    /// Resistance seen by the sense path when MTJ `pos` is selected.
    pub fn path_resistance_ohm(&self, pos: usize, params: &MtjParams) -> f64 {
        self.mtjs[pos].resistance_ohm(params)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn byte_roundtrip() {
        let mut d = NandSpinDevice::default();
        for byte in [0x00u8, 0xff, 0xa5, 0x5a, 0x01, 0x80] {
            let switched = d.write_byte(byte);
            assert_eq!(d.read_byte(), byte);
            assert_eq!(switched, byte.count_ones());
        }
    }

    #[test]
    fn erase_clears_all() {
        let mut d = NandSpinDevice::default();
        d.write_byte(0xff);
        d.erase();
        assert_eq!(d.read_byte(), 0);
    }

    #[test]
    fn program_without_erase_accumulates_ones() {
        // The unipolar property: programming can only add 1s. Overwriting
        // without an erase ORs the patterns — the reason the controller
        // always erases first.
        let mut d = NandSpinDevice::default();
        d.write_byte(0x0f);
        for pos in 4..8 {
            d.program(pos);
        }
        assert_eq!(d.read_byte(), 0xff);
    }

    #[test]
    fn path_resistance_tracks_state() {
        let p = MtjParams::default();
        let mut d = NandSpinDevice::default();
        d.write_byte(0b0000_0001);
        assert!(d.path_resistance_ohm(0, &p) < d.path_resistance_ohm(1, &p));
    }
}
