//! Separated pre-charge sense amplifier (SPCSA, Fig. 4b) — the central
//! functional unit of the subarray. One SA per column performs both read
//! and AND operations by comparing the discharge speed of the selected
//! cell path against a reference branch of resistance `(R_H + R_L)/2`.
//!
//! Truth table (Fig. 4c / Table 1, complementary data encoding):
//!
//! | op   | FU          | MTJ state | R_path vs R_ref | OUT        |
//! |------|-------------|-----------|-----------------|------------|
//! | read | 1 (always)  | P (D=1)   | lower           | 1          |
//! | read | 1 (always)  | AP (D=0)  | higher          | 0          |
//! | AND  | W           | P (D=1)   | lower iff W=1   | W AND D    |
//! | AND  | W = 0       | any       | path cut → high | 0          |


use super::mtj::MtjParams;

/// Functional + electrical model of one SPCSA.
#[derive(Debug, Clone, Copy)]
pub struct Spcsa {
    /// Reference branch resistance, Ω.
    pub r_ref_ohm: f64,
}

impl Spcsa {
    /// Build the SA with the reference set to `(R_H + R_L)/2` (§3.2).
    pub fn new(params: &MtjParams) -> Self {
        Self { r_ref_ohm: params.r_ref_ohm() }
    }

    /// Electrical decision: output `1` iff the cell path resistance is
    /// below the reference (fast discharge branch wins the latch race).
    #[inline]
    pub fn sense(&self, r_path_ohm: f64) -> bool {
        r_path_ohm < self.r_ref_ohm
    }

    /// Read operation: `FU` held high; output is the stored bit.
    #[inline]
    pub fn read(&self, params: &MtjParams, stored_bit: bool) -> bool {
        let r = if stored_bit { params.r_low_ohm() } else { params.r_high_ohm() };
        self.sense(r)
    }

    /// AND operation (Fig. 5d): `FU` carries operand `w`; a low `FU` cuts
    /// the discharge path so `R_path` is effectively infinite and the SA
    /// outputs `0`; a high `FU` reduces to a read.
    #[inline]
    pub fn and(&self, params: &MtjParams, stored_bit: bool, w: bool) -> bool {
        if !w {
            // Discharge path blocked: V_path stays high, reference wins.
            return false;
        }
        self.read(params, stored_bit)
    }
}

impl Default for Spcsa {
    fn default() -> Self {
        Self::new(&MtjParams::default())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn read_recovers_stored_bit() {
        let p = MtjParams::default();
        let sa = Spcsa::new(&p);
        assert!(sa.read(&p, true));
        assert!(!sa.read(&p, false));
    }

    #[test]
    fn and_truth_table() {
        let p = MtjParams::default();
        let sa = Spcsa::new(&p);
        for (d, w) in [(false, false), (false, true), (true, false), (true, true)] {
            assert_eq!(sa.and(&p, d, w), d & w, "AND({d},{w})");
        }
    }

    #[test]
    fn reference_sits_between_states() {
        let p = MtjParams::default();
        let sa = Spcsa::new(&p);
        assert!(p.r_low_ohm() < sa.r_ref_ohm && sa.r_ref_ohm < p.r_high_ohm());
    }
}
