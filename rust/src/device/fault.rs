//! Deterministic, seeded fault injection for the device layer.
//!
//! The paper's reliability argument (§3.2/§4.1) is quantified by
//! [`variation`](crate::device::variation) as a sensing error *rate*;
//! this module turns those rates into concrete, replayable fault
//! events. A [`FaultPlan`] carries a seed plus per-operation
//! probabilities for the three modelled failure modes:
//!
//! * **STT program failures** — one intended bit of a program step
//!   fails to switch (transient write error, recovered by the
//!   subarray's write-verify-retry loop);
//! * **SPCSA read / AND decision flips** — one bit of a sensed word is
//!   returned inverted (the stored cell is untouched);
//! * **stuck-at cells** — a cell that can never be set (unipolar STT
//!   programming only *sets* bits, so a defective cell manifests as
//!   stuck-at-0); unrecoverable rows are spared with a charged remap.
//!
//! Every draw is a **pure function** of `(seed, context, op index,
//! salt)` through the same SplitMix64 finalizer the repo's PRNG uses:
//! no mutable RNG state is shared between workers, so fault events are
//! bit-identical at any host worker count and across runs. A plan with
//! all-zero rates is *inactive* and injects nothing — the zero-rate
//! execution is bit-identical to a fault-free one.

use crate::device::mtj::MtjParams;
use crate::device::variation;

/// Stateless SplitMix64 finalizer: the mixing function behind every
/// fault draw. Identical constants to [`crate::util::Rng`], applied as
/// a pure hash instead of a stateful stream.
#[inline]
pub fn mix(z: u64) -> u64 {
    let mut z = z.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Fold a word list into one context id (order-sensitive).
#[inline]
pub fn fault_ctx(words: &[u64]) -> u64 {
    words.iter().fold(0x5EED_FA17_0000_0001, |acc, &w| mix(acc ^ w))
}

/// Per-operation fault probabilities.
///
/// `program_fail` and `read_flip` are probabilities **per device
/// operation** (one program step / one read or AND sense of a whole
/// row); `stuck_at` is the probability **per row** that one of its
/// cells is stuck at 0.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultRates {
    /// Per program step: one intended bit fails to switch.
    pub program_fail: f64,
    /// Per read/AND sense: one returned bit is flipped.
    pub read_flip: f64,
    /// Per row: one cell is stuck at 0 (never programs).
    pub stuck_at: f64,
}

impl FaultRates {
    /// All-zero rates (no faults).
    pub fn zero() -> Self {
        Self { program_fail: 0.0, read_flip: 0.0, stuck_at: 0.0 }
    }

    /// One uniform per-op rate for the transient modes, with stuck-at
    /// two orders of magnitude rarer (hard defects are much rarer than
    /// transient sensing/switching errors).
    pub fn uniform(rate: f64) -> Self {
        Self { program_fail: rate, read_flip: rate, stuck_at: rate / 100.0 }
    }

    /// Rates derived from the SPCSA Monte-Carlo of
    /// [`variation::sensing_error_rates`] at resistance-variation
    /// `sigma`: the per-cell decision error rate is lifted to a per-op
    /// (128-column row) rate, and stuck-at defects are taken two
    /// orders of magnitude rarer.
    pub fn from_sensing(params: &MtjParams, sigma: f64) -> Self {
        let e = variation::sensing_error_rates(params, sigma, 100_000, 0xFA17).single_cell;
        let per_op = 1.0 - (1.0 - e).powi(128);
        Self { program_fail: per_op, read_flip: per_op, stuck_at: per_op / 100.0 }
    }

    /// True when every rate is exactly zero.
    pub fn is_zero(&self) -> bool {
        self.program_fail == 0.0 && self.read_flip == 0.0 && self.stuck_at == 0.0
    }

    /// Reject non-finite or out-of-range probabilities.
    pub fn validate(&self) -> Result<(), String> {
        for (name, v) in [
            ("program_fail", self.program_fail),
            ("read_flip", self.read_flip),
            ("stuck_at", self.stuck_at),
        ] {
            if !v.is_finite() || !(0.0..=1.0).contains(&v) {
                return Err(format!("fault rate {name} must be in [0, 1], got {v}"));
            }
        }
        Ok(())
    }
}

/// A seeded fault-injection plan: which faults happen is a pure
/// function of `(seed, context, op index)`, so any run with the same
/// plan replays the same faults bit-for-bit.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultPlan {
    /// Root seed; [`FaultPlan::for_chip`] derives per-chip seeds.
    pub seed: u64,
    /// Per-op fault probabilities.
    pub rates: FaultRates,
    /// Bounded write-verify retries before a row is spared.
    pub write_retry_limit: u32,
}

/// Default bounded retry attempts of the write-verify loop.
pub const DEFAULT_WRITE_RETRY_LIMIT: u32 = 3;

impl FaultPlan {
    /// Plan with the given seed and rates and the default retry bound.
    pub fn new(seed: u64, rates: FaultRates) -> Self {
        Self { seed, rates, write_retry_limit: DEFAULT_WRITE_RETRY_LIMIT }
    }

    /// Inactive plan: zero rates, injects nothing.
    pub fn disabled() -> Self {
        Self::new(0, FaultRates::zero())
    }

    /// True when any rate is nonzero — an inactive plan's execution is
    /// bit-identical to no plan at all.
    pub fn is_active(&self) -> bool {
        !self.rates.is_zero()
    }

    /// Same rates under a chip-specific seed, so a pool of chips
    /// sharing one plan still draws independent fault streams.
    pub fn for_chip(&self, chip: usize) -> Self {
        Self { seed: mix(self.seed ^ mix(0xC41F ^ chip as u64)), ..*self }
    }

    #[inline]
    fn hash(&self, ctx: u64, op: u64, salt: u64) -> u64 {
        mix(self.seed ^ mix(ctx ^ mix(op ^ salt)))
    }

    /// Uniform draw in `[0, 1)` for `(ctx, op, salt)` — the standard
    /// 53-mantissa-bit u64 → f64 construction.
    #[inline]
    pub fn unit(&self, ctx: u64, op: u64, salt: u64) -> f64 {
        (self.hash(ctx, op, salt) >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform index in `0..n` for `(ctx, op, salt)`.
    ///
    /// # Panics
    /// If `n` is 0.
    #[inline]
    pub fn pick(&self, ctx: u64, op: u64, salt: u64, n: u32) -> u32 {
        assert!(n > 0, "pick needs a non-empty range");
        (self.hash(ctx, op, salt) % n as u64) as u32
    }
}

/// The `k`-th (0-based) set bit of `w` as a one-hot mask.
///
/// # Panics
/// If `w` has fewer than `k + 1` set bits.
#[inline]
pub fn nth_set_bit(mut w: u128, mut k: u32) -> u128 {
    assert!(w.count_ones() > k, "nth_set_bit out of range");
    loop {
        let b = w & w.wrapping_neg();
        if k == 0 {
            return b;
        }
        w ^= b;
        k -= 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn draws_are_pure_functions_of_the_key() {
        let p = FaultPlan::new(42, FaultRates::uniform(0.5));
        assert_eq!(p.unit(1, 2, 3).to_bits(), p.unit(1, 2, 3).to_bits());
        assert_eq!(p.pick(7, 8, 9, 128), p.pick(7, 8, 9, 128));
        // Different keys decorrelate.
        assert_ne!(p.unit(1, 2, 3).to_bits(), p.unit(1, 2, 4).to_bits());
        assert_ne!(
            p.unit(1, 2, 3).to_bits(),
            FaultPlan::new(43, FaultRates::uniform(0.5)).unit(1, 2, 3).to_bits()
        );
    }

    #[test]
    fn unit_draws_are_roughly_uniform() {
        let p = FaultPlan::new(7, FaultRates::uniform(1.0));
        let n = 10_000;
        let below: usize = (0..n).filter(|&i| p.unit(0, i as u64, 0) < 0.25).count();
        assert!((n / 4 - n / 20..=n / 4 + n / 20).contains(&below), "{below}");
    }

    #[test]
    fn zero_rates_are_inactive() {
        assert!(!FaultPlan::disabled().is_active());
        assert!(FaultPlan::new(1, FaultRates::uniform(1e-6)).is_active());
        assert!(FaultRates::zero().is_zero());
    }

    #[test]
    fn per_chip_seeds_differ_but_rates_are_shared() {
        let p = FaultPlan::new(99, FaultRates::uniform(0.01));
        let a = p.for_chip(0);
        let b = p.for_chip(1);
        assert_ne!(a.seed, b.seed);
        assert_ne!(a.seed, p.seed);
        assert_eq!(a.rates, p.rates);
        assert_eq!(a.write_retry_limit, p.write_retry_limit);
        // Deterministic derivation.
        assert_eq!(p.for_chip(0).seed, a.seed);
    }

    #[test]
    fn sensing_derived_rates_scale_with_variation() {
        let lo = FaultRates::from_sensing(&MtjParams::default(), 0.05);
        let hi = FaultRates::from_sensing(&MtjParams::default(), 0.15);
        assert!(lo.validate().is_ok() && hi.validate().is_ok());
        assert!(hi.read_flip > lo.read_flip);
        assert!(lo.stuck_at < lo.read_flip, "hard defects are the rare mode");
        // No variation, no faults.
        assert!(FaultRates::from_sensing(&MtjParams::default(), 0.0).is_zero());
    }

    #[test]
    fn validate_rejects_bad_probabilities() {
        assert!(FaultRates::uniform(0.5).validate().is_ok());
        assert!(FaultRates { program_fail: -0.1, ..FaultRates::zero() }.validate().is_err());
        assert!(FaultRates { read_flip: 1.5, ..FaultRates::zero() }.validate().is_err());
        assert!(FaultRates { stuck_at: f64::NAN, ..FaultRates::zero() }.validate().is_err());
    }

    #[test]
    fn nth_set_bit_walks_set_bits_in_order() {
        let w: u128 = 0b1011_0100;
        assert_eq!(nth_set_bit(w, 0), 0b100);
        assert_eq!(nth_set_bit(w, 1), 0b1_0000);
        assert_eq!(nth_set_bit(w, 2), 0b10_0000);
        assert_eq!(nth_set_bit(w, 3), 0b1000_0000);
        let hi = 1u128 << 127;
        assert_eq!(nth_set_bit(hi, 0), hi);
    }
}
