//! Magnetic tunnel junction (MTJ) state machine and resistance model.
//!
//! An MTJ stores one bit in the relative orientation of its free and
//! pinned layers: parallel (P, low resistance) or anti-parallel (AP, high
//! resistance). The paper stores data *complementarily*: an MTJ in the AP
//! state represents binary `0`, the P state represents binary `1`
//! (Fig. 4c) — `P` is reached by the STT program step, `AP` by the SOT
//! erase step.


/// Magnetisation state of the free layer relative to the pinned layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MtjState {
    /// Parallel: low resistance, stores logic `1` in the paper's
    /// complementary encoding.
    Parallel,
    /// Anti-parallel: high resistance, stores logic `0`. This is the
    /// post-erase default.
    AntiParallel,
}

impl MtjState {
    /// The stored logic bit under the paper's complementary encoding.
    #[inline]
    pub fn bit(self) -> bool {
        matches!(self, MtjState::Parallel)
    }

    /// State representing a stored logic bit.
    #[inline]
    pub fn from_bit(bit: bool) -> Self {
        if bit {
            MtjState::Parallel
        } else {
            MtjState::AntiParallel
        }
    }
}

/// Electrical / magnetic constants of the MTJ stack (paper Table 2 plus the
/// standard derived quantities used by the sensing model).
#[derive(Debug, Clone, Copy)]
pub struct MtjParams {
    /// Resistance-area product in Ω·µm² (Table 2: 5 Ω·µm²).
    pub ra_product_ohm_um2: f64,
    /// Tunnel magnetoresistance ratio (Table 2: 120 % → 1.2).
    pub tmr: f64,
    /// MTJ diameter in nm (typical perpendicular MTJ, 40 nm).
    pub diameter_nm: f64,
    /// Tunnelling spin polarisation (Table 2: 0.62).
    pub spin_polarization: f64,
    /// Gilbert damping constant (Table 2: 0.02).
    pub gilbert_damping: f64,
    /// Saturation magnetisation in kA/m (Table 2: 1150 kA/m).
    pub saturation_magnetization_ka_m: f64,
    /// Uniaxial anisotropy constant in J/m³ (Table 2: 1.16e6).
    pub anisotropy_j_m3: f64,
    /// Free-layer thickness in nm (typical 1.1 nm CoFeB).
    pub free_layer_thickness_nm: f64,
}

impl Default for MtjParams {
    fn default() -> Self {
        Self {
            ra_product_ohm_um2: 5.0,
            tmr: 1.2,
            diameter_nm: 40.0,
            spin_polarization: 0.62,
            gilbert_damping: 0.02,
            saturation_magnetization_ka_m: 1150.0,
            anisotropy_j_m3: 1.16e6,
            free_layer_thickness_nm: 1.1,
        }
    }
}

impl MtjParams {
    /// Junction area in µm².
    pub fn area_um2(&self) -> f64 {
        let r_um = self.diameter_nm * 1e-3 / 2.0;
        std::f64::consts::PI * r_um * r_um
    }

    /// Low (parallel) resistance in Ω: `R_L = RA / A`.
    pub fn r_low_ohm(&self) -> f64 {
        self.ra_product_ohm_um2 / self.area_um2()
    }

    /// High (anti-parallel) resistance in Ω: `R_H = R_L (1 + TMR)`.
    pub fn r_high_ohm(&self) -> f64 {
        self.r_low_ohm() * (1.0 + self.tmr)
    }

    /// SPCSA reference resistance `(R_H + R_L) / 2` (paper §3.2).
    pub fn r_ref_ohm(&self) -> f64 {
        0.5 * (self.r_high_ohm() + self.r_low_ohm())
    }
}

/// A single MTJ: one bit of NAND-SPIN storage.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Mtj {
    state: MtjState,
}

impl Default for Mtj {
    fn default() -> Self {
        // Power-on state is undefined in silicon; we model the post-erase
        // default so fresh arrays behave like erased ones.
        Self { state: MtjState::AntiParallel }
    }
}

impl Mtj {
    /// Current magnetisation state.
    #[inline]
    pub fn state(&self) -> MtjState {
        self.state
    }

    /// Stored logic bit (complementary encoding, Fig. 4c).
    #[inline]
    pub fn bit(&self) -> bool {
        self.state.bit()
    }

    /// SOT erase: unconditionally switch to AP (stored `0`).
    /// Paper §2.1 step 1 — the current along the heavy-metal strip resets
    /// every MTJ on the strip regardless of prior state.
    #[inline]
    pub fn erase(&mut self) {
        self.state = MtjState::AntiParallel;
    }

    /// STT program: AP→P switching (stored `1`).
    ///
    /// Programming is *unipolar* in NAND-SPIN: the program current only
    /// performs the AP→P transition; a P-state MTJ stays P. Writing a `0`
    /// is achieved by *not* programming after the erase (column signal
    /// `Cx = 0` blocks the current — Table 1).
    #[inline]
    pub fn program(&mut self) {
        self.state = MtjState::Parallel;
    }

    /// Resistance of this MTJ in Ω under `params`.
    pub fn resistance_ohm(&self, params: &MtjParams) -> f64 {
        match self.state {
            MtjState::Parallel => params.r_low_ohm(),
            MtjState::AntiParallel => params.r_high_ohm(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn complementary_encoding_matches_fig4c() {
        assert!(!MtjState::AntiParallel.bit(), "AP stores 0");
        assert!(MtjState::Parallel.bit(), "P stores 1");
        assert_eq!(MtjState::from_bit(true), MtjState::Parallel);
        assert_eq!(MtjState::from_bit(false), MtjState::AntiParallel);
    }

    #[test]
    fn erase_then_program_writes_one() {
        let mut m = Mtj::default();
        m.erase();
        assert!(!m.bit());
        m.program();
        assert!(m.bit());
    }

    #[test]
    fn program_is_unipolar() {
        let mut m = Mtj::default();
        m.program();
        m.program(); // idempotent
        assert!(m.bit());
        m.erase();
        assert!(!m.bit());
    }

    #[test]
    fn resistance_ratio_is_tmr() {
        let p = MtjParams::default();
        let hi = p.r_high_ohm();
        let lo = p.r_low_ohm();
        assert!((hi / lo - 2.2).abs() < 1e-9, "TMR 120% → R_H/R_L = 2.2");
        assert!((p.r_ref_ohm() - 0.5 * (hi + lo)).abs() < 1e-9);
    }

    #[test]
    fn default_resistance_values_are_physical() {
        let p = MtjParams::default();
        // 40 nm MTJ with RA = 5 Ω·µm² → R_L ≈ 4 kΩ, R_H ≈ 8.75 kΩ.
        assert!(p.r_low_ohm() > 1e3 && p.r_low_ohm() < 1e4);
        assert!(p.r_high_ohm() > p.r_low_ohm());
    }
}
