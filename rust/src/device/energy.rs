//! Calibrated per-operation latency/energy scalars.
//!
//! These are the numbers the paper's circuit-level SPICE simulation
//! produced (§5.1) and that its architecture simulator consumed:
//!
//! * erase: 180 fJ per NAND-SPIN device (8 MTJs), average 0.3 ns per MTJ
//!   → 2.4 ns per strip erase;
//! * program: 840 fJ per device, 5 ns per bit;
//! * read: 0.17 ns and 4.0 fJ per bit.
//!
//! Values the paper does not state explicitly (bit-counter, buffer and bus
//! energies) are derived from typical 45 nm post-synthesis figures and
//! flagged `ASSUMED` — see EXPERIMENTS.md for the sensitivity discussion.


use super::nand_spin::MTJS_PER_DEVICE;

/// Per-operation cost scalars for the NAND-SPIN array and its periphery.
///
/// Energies in femtojoules, latencies in nanoseconds.
#[derive(Debug, Clone, Copy)]
pub struct DeviceCosts {
    /// SOT strip erase: energy per NAND-SPIN device (8 MTJs). Paper: 180 fJ.
    pub erase_energy_per_device_fj: f64,
    /// SOT strip erase latency (whole strip; 0.3 ns × 8 MTJs). Paper-derived.
    pub erase_latency_ns: f64,
    /// STT program: energy per device when all 8 bits switch. Paper: 840 fJ.
    pub program_energy_per_device_fj: f64,
    /// STT program latency per bit-position step. Paper: 5 ns.
    pub program_latency_per_bit_ns: f64,
    /// Read latency per row access. Paper: 0.17 ns.
    pub read_latency_ns: f64,
    /// Read energy per bit. Paper: 4.0 fJ.
    pub read_energy_per_bit_fj: f64,
    /// AND op latency — same sensing path as a read (Fig. 5d).
    pub and_latency_ns: f64,
    /// AND op energy per bit — read path + FU driver. Slightly above read.
    pub and_energy_per_bit_fj: f64,
    /// Bit-counter accumulate per column per op. ASSUMED: 45 nm
    /// post-synthesis ripple-count stage, pipelined under the sense latency.
    pub bitcount_energy_per_bit_fj: f64,
    /// Bit-counter latency when not hidden (standalone count/shift step).
    pub bitcount_latency_ns: f64,
    /// Subarray weight-buffer access energy per bit (small SRAM row).
    /// ASSUMED.
    pub buffer_energy_per_bit_fj: f64,
    /// Subarray weight-buffer access latency.
    pub buffer_latency_ns: f64,
    /// In-mat bus energy per bit per hop. ASSUMED: short on-chip wire.
    pub bus_energy_per_bit_fj: f64,
    /// Off-chip (DRAM) access energy per bit for loading weights/inputs.
    /// ASSUMED: ~40 pJ/bit, standard DDR access energy — this is what
    /// makes "load data" ≈ 1/3 of inference energy (Fig. 16b).
    pub offchip_energy_per_bit_fj: f64,
    /// Inter-mat (global) bus energy per bit. ASSUMED: long on-chip wire.
    pub global_bus_energy_per_bit_fj: f64,
    /// Bus clock period (control logic @ 1 GHz).
    pub bus_cycle_ns: f64,
    /// Array static/leakage power in µW per subarray (NVM arrays have
    /// near-zero cell leakage; this is periphery only). ASSUMED.
    pub leakage_uw_per_subarray: f64,
}

impl Default for DeviceCosts {
    fn default() -> Self {
        Self {
            erase_energy_per_device_fj: 180.0,
            erase_latency_ns: 0.3 * MTJS_PER_DEVICE as f64,
            program_energy_per_device_fj: 840.0,
            program_latency_per_bit_ns: 5.0,
            read_latency_ns: 0.17,
            read_energy_per_bit_fj: 4.0,
            and_latency_ns: 0.17,
            and_energy_per_bit_fj: 4.4,
            bitcount_energy_per_bit_fj: 1.2,
            bitcount_latency_ns: 0.25,
            buffer_energy_per_bit_fj: 0.8,
            buffer_latency_ns: 0.2,
            bus_energy_per_bit_fj: 20.0,
            global_bus_energy_per_bit_fj: 120.0,
            offchip_energy_per_bit_fj: 40_000.0,
            bus_cycle_ns: 1.0,
            leakage_uw_per_subarray: 2.0,
        }
    }
}

impl DeviceCosts {
    /// Energy to program a single bit (AP→P switch). The paper's 840 fJ is
    /// for a whole device (8 MTJs): 105 fJ per switched bit.
    pub fn program_energy_per_bit_fj(&self) -> f64 {
        self.program_energy_per_device_fj / MTJS_PER_DEVICE as f64
    }

    /// Total latency to write one full row of NAND-SPIN devices: one strip
    /// erase plus [`MTJS_PER_DEVICE`] program steps (§3.2 memory mode).
    pub fn row_write_latency_ns(&self) -> f64 {
        self.erase_latency_ns + MTJS_PER_DEVICE as f64 * self.program_latency_per_bit_ns
    }

    /// Energy to erase one full row of `devices` NAND-SPIN strips.
    pub fn row_erase_energy_fj(&self, devices: usize) -> f64 {
        self.erase_energy_per_device_fj * devices as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_scalars_are_pinned() {
        let c = DeviceCosts::default();
        assert_eq!(c.erase_energy_per_device_fj, 180.0);
        assert_eq!(c.program_energy_per_device_fj, 840.0);
        assert_eq!(c.read_latency_ns, 0.17);
        assert_eq!(c.read_energy_per_bit_fj, 4.0);
        assert!((c.erase_latency_ns - 2.4).abs() < 1e-12);
    }

    #[test]
    fn row_write_is_erase_plus_eight_programs() {
        let c = DeviceCosts::default();
        assert!((c.row_write_latency_ns() - (2.4 + 40.0)).abs() < 1e-9);
    }

    #[test]
    fn per_bit_program_energy() {
        let c = DeviceCosts::default();
        assert!((c.program_energy_per_bit_fj() - 105.0).abs() < 1e-9);
    }

    #[test]
    fn write_dominates_read() {
        // §3.2: writes are the expensive asymmetric op; reads are cheap.
        let c = DeviceCosts::default();
        assert!(c.row_write_latency_ns() > 100.0 * c.read_latency_ns);
        assert!(c.program_energy_per_bit_fj() > 10.0 * c.read_energy_per_bit_fj);
    }
}
