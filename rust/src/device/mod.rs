//! Device layer: MTJ, NAND-SPIN strip, SPCSA sense amplifier, switching
//! margins and the calibrated per-operation latency/energy scalars.
//!
//! The paper characterises the hybrid CMOS/MTJ circuit with a Verilog-A
//! LLG compact model in Cadence Spectre/SPICE (45 nm PDK) and feeds the
//! resulting per-op scalars into a modified NVSim plus an architecture
//! simulator. We reproduce the same split: [`llg`] re-derives the switching
//! currents/margins from the Table 2 device constants, [`energy`] pins the
//! per-op scalars to the values the paper reports from SPICE, and the
//! functional models ([`mtj`], [`nand_spin`], [`spcsa`]) implement the
//! Table 1 signal semantics bit-accurately.

// The device layer underpins every charged operation: a panicking
// `.unwrap()` here would take down a whole serve. Use `expect` with a
// reason, or handle the case.
#![deny(clippy::unwrap_used)]

pub mod energy;
pub mod fault;
pub mod llg;
pub mod mtj;
pub mod nand_spin;
pub mod spcsa;
pub mod variation;

pub use energy::DeviceCosts;
pub use fault::{FaultPlan, FaultRates};
pub use mtj::{Mtj, MtjState};
pub use nand_spin::{NandSpinDevice, MTJS_PER_DEVICE};
pub use spcsa::Spcsa;
