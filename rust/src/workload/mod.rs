//! Synthetic workload generation: ImageNet-shaped inputs and batched
//! inference traces for the benchmark harness.

use crate::cnn::network::Network;
use crate::cnn::tensor::QTensor;
use crate::util::Rng;

/// A batch of synthetic input images for a network.
#[derive(Debug, Clone)]
pub struct ImageBatch {
    /// Input tensors (CHW, quantized).
    pub images: Vec<QTensor>,
}

impl ImageBatch {
    /// Deterministic batch of `n` synthetic images matching `net`'s input.
    pub fn synthetic(net: &Network, n: usize, seed: u64) -> Self {
        let (c, h, w) = net.input;
        let mut rng = Rng::seed_from_u64(seed);
        let images =
            (0..n).map(|_| QTensor::random(c, h, w, net.input_bits, rng.gen_seed())).collect();
        Self { images }
    }

    /// Number of images.
    pub fn len(&self) -> usize {
        self.images.len()
    }

    /// True if empty.
    pub fn is_empty(&self) -> bool {
        self.images.is_empty()
    }
}

/// The paper's evaluation grid: ⟨W:I⟩ precision pairs of Figs. 14–15.
pub const PRECISION_GRID: [(u8, u8); 4] = [(1, 1), (2, 2), (4, 4), (8, 8)];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::network::small_cnn;

    #[test]
    fn batch_is_deterministic_and_shaped() {
        let net = small_cnn(4);
        let a = ImageBatch::synthetic(&net, 3, 9);
        let b = ImageBatch::synthetic(&net, 3, 9);
        assert_eq!(a.images, b.images);
        assert_eq!(a.len(), 3);
        assert_eq!((a.images[0].c, a.images[0].h, a.images[0].w), net.input);
    }
}
