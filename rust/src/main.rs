//! `nandspin` CLI — the L3 entrypoint.
//!
//! Subcommands map one-to-one onto the paper's evaluation:
//!
//! * `breakdown`      — Fig. 16 latency/energy breakdown
//! * `compare`        — Figs. 14–15 + Table 3 vs the five baselines
//! * `sweep-capacity` — Fig. 13a
//! * `sweep-bus`      — Fig. 13b
//! * `area`           — Fig. 17 + §5.3 area overhead
//! * `inspect-device` — §5.1 device/circuit numbers
//! * `verify`         — bit-exact functional run vs golden executor
//! * `run`            — batched synthetic inference with FPS report
//! * `serve`          — batched multi-chip serving runtime (per-network
//!   SLO batching lanes → cost-aware shard router → weight-resident
//!   engine pools over a possibly heterogeneous chip pool) with
//!   per-chip, per-network and aggregate latency/energy accounting
//!
//! Argument parsing is hand-rolled (the build is offline; see
//! Cargo.toml).

use std::env;
use std::process::ExitCode;

use nandspin::arch::area::AreaModel;
use nandspin::arch::config::ArchConfig;
use nandspin::arch::stats::Phase;
use nandspin::baselines::designs::BaselineKind;
use nandspin::cnn::layer::Layer;
use nandspin::cnn::network::{preset, resnet50, small_cnn, Network, PRESET_NAMES};
use nandspin::cnn::ref_exec::{self, ModelParams};
use nandspin::cnn::tensor::QTensor;
use nandspin::coordinator::{
    serve_pool, Coordinator, EngineKind, EngineMode, PoolSpec, Request, ServeConfig,
    ServedNetwork, SloPolicy,
};
use nandspin::device::llg::SwitchingModel;
use nandspin::device::mtj::MtjParams;
use nandspin::device::{DeviceCosts, FaultPlan, FaultRates};
use nandspin::mapping::TilePlan;
use nandspin::nvsim::NvSimModel;
use nandspin::workload::{ImageBatch, PRECISION_GRID};

fn usage() -> ExitCode {
    eprintln!(
        "usage: nandspin <command> [options]\n\
         commands:\n\
           breakdown       [--model resnet50|alexnet|vgg19] [--wbits N] [--ibits N]\n\
           compare         [--metric perf|energy] [--table3]\n\
           sweep-capacity  [--model ...]\n\
           sweep-bus       [--model ...]\n\
           area\n\
           inspect-device\n\
           verify          [--seed N]\n\
           run             [--batch N] [--seed N] [--chips N] [--workers N]\n\
           serve           [--engine functional|analytic|hybrid]\n\
                           [--network alexnet|vgg19|resnet50|small|small_resnet|micro|wide,\n\
                            '+'-separated for a mixed stream, e.g. alexnet+small]\n\
                           [--bits N] [--check-every N] [--verbose]\n\
                           [--chips N | --chip-config CAP[:BUS],CAP[:BUS],...]\n\
                           [--batch N] [--deadline-us F] [--slo-us NAME=F,... or F,...]\n\
                           [--requests N (per network)] [--arrival-ns F] [--queue N]\n\
                           [--workers N] [--seed N]\n\
                           [--fault-rate F|auto] [--fault-seed N] [--retry-budget N]\n\
                           [--trace FILE (Chrome/Perfetto JSON)] [--trace-jsonl FILE]\n\
                           [--metrics-out FILE (Prometheus text)]"
    );
    ExitCode::FAILURE
}

/// Minimal flag parser: `--key value` pairs after the subcommand.
fn flags(args: &[String]) -> impl Fn(&str, &str) -> String + '_ {
    move |key: &str, default: &str| {
        args.windows(2)
            .find(|w| w[0] == format!("--{key}"))
            .map(|w| w[1].clone())
            .unwrap_or_else(|| default.to_string())
    }
}

fn model_by_name(name: &str, bits: u8) -> Network {
    preset(name, bits).unwrap_or_else(|| {
        eprintln!("unknown model '{name}', using resnet50");
        resnet50(bits)
    })
}

fn cmd_breakdown(args: &[String]) {
    let get = flags(args);
    let wbits: u8 = get("wbits", "8").parse().unwrap_or(8);
    let ibits: u8 = get("ibits", "8").parse().unwrap_or(8);
    let net = model_by_name(&get("model", "resnet50"), ibits);
    let coord = Coordinator::paper();
    let st = coord.analytic_stats(&net, wbits);
    let m = coord.analytic_metrics(&net, wbits);
    println!("== Fig. 16 breakdown: {} ⟨{wbits}:{ibits}⟩ @ 64 MB ==", net.name);
    println!(
        "latency {:.3} ms ({:.1} FPS), energy {:.3} mJ, {:.1} GOPS, {:.2} GOPS/mm²",
        m.latency_ms,
        m.fps(),
        m.energy_mj,
        m.gops(),
        m.gops_per_mm2()
    );
    println!("{st}");
}

fn cmd_compare(args: &[String]) {
    let get = flags(args);
    let table3 = args.iter().any(|a| a == "--table3");
    let coord = Coordinator::paper();
    if table3 {
        println!("== Table 3: comparison with related in-memory CNN accelerators ==");
        println!(
            "{:<12} {:<10} {:>12} {:>10} {:>10}",
            "Accelerator", "Technology", "FPS", "Cap (MB)", "Area (mm²)"
        );
        let net = resnet50(8);
        for kind in BaselineKind::ALL {
            let b = kind.model();
            let m = b.metrics(&net, 8);
            println!(
                "{:<12} {:<10} {:>12.1} {:>10} {:>10.1}",
                b.name,
                b.technology,
                m.fps(),
                64,
                b.area_mm2
            );
        }
        let m = coord.analytic_metrics(&net, 8);
        println!(
            "{:<12} {:<10} {:>12.1} {:>10} {:>10.1}",
            "Proposed",
            "NAND-SPIN",
            m.fps(),
            64,
            m.area_mm2
        );
        return;
    }
    let metric = get("metric", "perf");
    let models = ["alexnet", "vgg19", "resnet50"];
    println!(
        "== Fig. {}: {} normalised to area ==",
        if metric == "energy" { 14 } else { 15 },
        if metric == "energy" { "energy efficiency (GOPS/W/mm²)" } else { "performance (GOPS/mm²)" }
    );
    print!("{:<22}", "design/model");
    for (w, i) in PRECISION_GRID {
        print!("{:>12}", format!("<{w}:{i}>"));
    }
    println!();
    for name in models {
        for kind in BaselineKind::ALL {
            let b = kind.model();
            print!("{:<22}", format!("{}/{}", b.name, name));
            for (w, i) in PRECISION_GRID {
                let m = b.metrics(&model_by_name(name, i), w);
                let v =
                    if metric == "energy" { m.efficiency_per_mm2() } else { m.gops_per_mm2() };
                print!("{v:>12.3}");
            }
            println!();
        }
        print!("{:<22}", format!("Proposed/{name}"));
        for (w, i) in PRECISION_GRID {
            let m = coord.analytic_metrics(&model_by_name(name, i), w);
            let v = if metric == "energy" { m.efficiency_per_mm2() } else { m.gops_per_mm2() };
            print!("{v:>12.3}");
        }
        println!();
    }
}

fn cmd_sweep_capacity(args: &[String]) {
    let get = flags(args);
    let net = model_by_name(&get("model", "resnet50"), 8);
    println!("== Fig. 13a: capacity vs peak performance / energy efficiency ==");
    println!(
        "{:>9} {:>12} {:>14} {:>16} {:>12}",
        "cap (MB)", "FPS", "GOPS/mm²", "GOPS/W/mm²", "area (mm²)"
    );
    for cap in [8usize, 16, 32, 64, 128, 256] {
        let mut cfg = ArchConfig::paper();
        cfg.capacity_mb = cap;
        let coord = Coordinator::new(cfg);
        let m = coord.analytic_metrics(&net, 8);
        println!(
            "{:>9} {:>12.1} {:>14.3} {:>16.3} {:>12.1}",
            cap,
            m.fps(),
            m.gops_per_mm2(),
            m.efficiency_per_mm2(),
            m.area_mm2
        );
    }
}

fn cmd_sweep_bus(args: &[String]) {
    let get = flags(args);
    let net = model_by_name(&get("model", "resnet50"), 8);
    println!("== Fig. 13b: bus width vs peak performance / utilisation ==");
    println!("{:>10} {:>12} {:>14} {:>14}", "bus (bit)", "FPS", "GOPS/mm²", "util (%)");
    for bus in [32usize, 64, 128, 256, 512] {
        let mut cfg = ArchConfig::paper();
        cfg.bus_width_bits = bus;
        let coord = Coordinator::new(cfg);
        let m = coord.analytic_metrics(&net, 8);
        // Utilisation: fraction of time the compute units are busy.
        let st = coord.analytic_stats(&net, 8);
        // Utilisation: fraction of time the compute units are busy, i.e.
        // not stalled on data delivery (loads + inter-layer transfer).
        let stalled = st[Phase::LoadData].latency_ns + st[Phase::DataTransfer].latency_ns;
        let util = 1.0 - stalled / st.total_latency_ns();
        println!(
            "{:>10} {:>12.1} {:>14.3} {:>14.1}",
            bus,
            m.fps(),
            m.gops_per_mm2(),
            util * 100.0
        );
    }
}

fn cmd_area() {
    let cfg = ArchConfig::paper();
    let area = AreaModel::default();
    let b = area.breakdown(&cfg);
    println!("== Fig. 17 / §5.3 area ==");
    println!("base memory array : {:>8.2} mm²", b.base_mm2());
    println!(
        "PIM add-on        : {:>8.2} mm²  ({:.1} % overhead)",
        b.addon_mm2(),
        100.0 * b.overhead_ratio()
    );
    for s in area.fig17_slices(&cfg) {
        println!("  {:<18}: {:>6.2} mm²  ({:>4.1} %)", s.name, s.mm2, 100.0 * s.fraction);
    }
    println!("total             : {:>8.2} mm²  (Table 3: 64.5 mm²)", b.total_mm2());
    println!("leakage           : {:>8.2} mW", NvSimModel::default().leakage_mw(&cfg));
}

fn cmd_inspect_device() {
    let costs = DeviceCosts::default();
    let sw = SwitchingModel::default();
    println!("== §5.1 device / circuit operating point ==");
    println!(
        "erase  : {:>7.1} fJ/device, {:>5.2} ns/strip",
        costs.erase_energy_per_device_fj, costs.erase_latency_ns
    );
    println!(
        "program: {:>7.1} fJ/device, {:>5.2} ns/bit",
        costs.program_energy_per_device_fj, costs.program_latency_per_bit_ns
    );
    println!(
        "read   : {:>7.1} fJ/bit,    {:>5.2} ns",
        costs.read_energy_per_bit_fj, costs.read_latency_ns
    );
    println!("row write latency: {:.1} ns (erase + 8 programs)", costs.row_write_latency_ns());
    println!("STT critical (AP→P): {:>8.1} µA", sw.stt_critical_ua);
    println!("STT critical (P→AP): {:>8.1} µA", sw.stt_reverse_critical_ua);
    println!("SOT critical (strip): {:>7.1} µA", sw.sot_critical_ua);
    println!(
        "read current: {:>8.1} µA (disturb margin {:.1}×)",
        sw.read_current_ua,
        sw.read_disturb_margin()
    );
    println!("\nSPCSA sensing error rate under resistance variation (Monte-Carlo):");
    println!("{:>8} {:>16} {:>16}", "sigma", "single-cell", "dual-cell (prior)");
    for (sigma, r) in nandspin::device::variation::margin_sweep(
        &nandspin::device::mtj::MtjParams::default(),
        1,
    ) {
        println!("{:>7.0}% {:>16.2e} {:>16.2e}", sigma * 100.0, r.single_cell, r.dual_cell);
    }
}

fn cmd_verify(args: &[String]) {
    let get = flags(args);
    let seed: u64 = get("seed", "42").parse().unwrap_or(42);
    let net = small_cnn(4);
    let params = ModelParams::random(&net, 4, seed);
    let input = QTensor::random(net.input.0, net.input.1, net.input.2, net.input_bits, seed + 1);
    let golden = ref_exec::execute(&net, &params, &input);
    let (outs, stats) = Coordinator::paper().functional_run(&net, &params, &input);
    let ok = outs.iter().zip(&golden).all(|(a, b)| a == b);
    println!("== functional verification: {} (seed {seed}) ==", net.name);
    println!(
        "PIM simulator vs golden executor: {}",
        if ok { "BIT-EXACT MATCH" } else { "MISMATCH" }
    );
    println!(
        "ops: {} ANDs, {} reads, {} program steps, {} erases",
        stats.ops.ands, stats.ops.reads, stats.ops.program_steps, stats.ops.erases
    );
    println!("{stats}");
    if !ok {
        std::process::exit(1);
    }
}

/// Build synthetic requests for `net`.
fn synthetic_requests(net: &Network, n: usize, seed: u64) -> Vec<Request> {
    Request::stream(ImageBatch::synthetic(net, n, seed).images)
}

/// Validate a serve configuration or exit with a clean error.
fn checked(scfg: ServeConfig) -> ServeConfig {
    if let Err(e) = scfg.validate() {
        eprintln!("invalid serve configuration: {e}");
        std::process::exit(2);
    }
    scfg
}

/// Parse `value` for `--flag`, rejecting malformed input with an
/// explicit error instead of silently falling back to a default.
fn parse_flag<T: std::str::FromStr>(flag: &str, value: &str) -> Result<T, String> {
    value.trim().parse::<T>().map_err(|_| format!("invalid value for --{flag}: '{value}'"))
}

/// Look `--flag` up (with `default`) and parse it, exiting with an
/// explicit error on malformed input.
fn parse_or_exit<T: std::str::FromStr>(
    get: &impl Fn(&str, &str) -> String,
    flag: &str,
    default: &str,
) -> T {
    parse_flag(flag, &get(flag, default)).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    })
}

/// Parse a `--fault-rate` spec: a per-op probability in [0, 1], or
/// `auto` to derive the rates from the SPCSA sensing Monte-Carlo at
/// 10 % resistance variation.
fn parse_fault_rates(spec: &str) -> Result<FaultRates, String> {
    if spec.trim() == "auto" {
        return Ok(FaultRates::from_sensing(&MtjParams::default(), 0.10));
    }
    let rate: f64 = parse_flag("fault-rate", spec)?;
    let rates = FaultRates::uniform(rate);
    rates.validate().map_err(|e| format!("invalid value for --fault-rate: {e}"))?;
    Ok(rates)
}

/// Assemble the serve fault plan from `--fault-rate` / `--fault-seed`
/// (`None` when no rate was given — the exact fault-free path).
fn fault_flags(get: &impl Fn(&str, &str) -> String) -> Option<FaultPlan> {
    let spec = get("fault-rate", "");
    if spec.is_empty() {
        return None;
    }
    let rates = parse_fault_rates(&spec).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    });
    let seed: u64 = parse_or_exit(get, "fault-seed", "7");
    Some(FaultPlan::new(seed, rates))
}

/// Parse an optional `--workers N` host budget (`None` = automatic).
fn host_workers_flag(get: &impl Fn(&str, &str) -> String) -> Option<usize> {
    let arg = get("workers", "");
    if arg.is_empty() {
        return None;
    }
    match arg.parse::<usize>() {
        Ok(n) => Some(n),
        Err(_) => {
            eprintln!("invalid --workers '{arg}' (expected a thread count)");
            std::process::exit(2);
        }
    }
}

fn cmd_run(args: &[String]) {
    let get = flags(args);
    let batch: usize = parse_or_exit(&get, "batch", "8");
    let seed: u64 = parse_or_exit(&get, "seed", "1");
    let chips: usize = parse_or_exit(&get, "chips", "4");
    let host_workers = host_workers_flag(&get);
    if batch == 0 {
        eprintln!("invalid serve configuration: need at least one request (--batch)");
        std::process::exit(2);
    }
    let net = small_cnn(4);
    let params = ModelParams::random(&net, 4, seed);
    // Split the closed burst so every chip gets work.
    let scfg = checked(ServeConfig {
        chips,
        max_batch: batch.div_ceil(chips.max(1)).max(1),
        host_workers,
        ..ServeConfig::default()
    });
    let report = nandspin::coordinator::serve(
        &ArchConfig::paper(),
        &scfg,
        &net,
        Some(&params),
        synthetic_requests(&net, batch, seed),
    );
    report.verify().expect("serve aggregation identities");
    let sim_ms: f64 =
        report.completions.iter().map(|c| c.stats.total_latency_ms()).sum();
    println!("== served {batch} requests on {chips} simulated PIM chips ==");
    println!(
        "simulated: {:.4} ms/img, {:.4} mJ/img, {:.1} FPS aggregate",
        sim_ms / batch as f64,
        report.total_energy_mj() / batch as f64,
        report.sim_fps()
    );
    println!(
        "host wall-clock: {:.2} s ({:.1} img/s simulation speed)",
        report.wall_seconds,
        batch as f64 / report.wall_seconds
    );
}

/// Print the functional engine's multi-tile conv mapping (§4.2, Fig. 9)
/// for `net` on the paper subarray geometry: one line per conv layer
/// with its tile grid and the per-bit-plane halo overlap the tiled
/// execution re-sends through the bank buffer.
fn print_tiling_plan(net: &Network, bits: u8) {
    let cfg = ArchConfig::paper();
    println!(
        "== tiling plan: {} on {}x{} subarrays ({bits}-bit activations) ==",
        net.name, cfg.rows, cfg.cols
    );
    for (i, node) in net.nodes.iter().enumerate() {
        let Layer::Conv { out_c, kh, kw, stride, pad } = node.layer else { continue };
        let (c, h, w) = net.in_shape(i);
        let (ph, pw) = (h + 2 * pad, w + 2 * pad);
        match TilePlan::new(ph, pw, kh, kw, stride, cfg.rows, cfg.cols) {
            Some(p) => println!(
                "  node {i:>2}: conv {out_c}x{kh}x{kw} s{stride} on {c}x{ph}x{pw} -> \
                 {}x{} tile grid ({} slabs/bit-plane, halo {} elems/plane)",
                p.tiles_h,
                p.tiles_w,
                p.count(),
                p.halo_elems()
            ),
            None => println!(
                "  node {i:>2}: conv {out_c}x{kh}x{kw} s{stride} on {c}x{ph}x{pw} -> \
                 window exceeds one subarray (functional engine rejects)"
            ),
        }
    }
}

/// Parse a `--chip-config CAP[:BUS],CAP[:BUS],...` heterogeneous pool
/// description into one `ArchConfig` per chip (base: the paper point).
fn parse_chip_configs(spec: &str) -> Vec<ArchConfig> {
    spec.split(',')
        .map(|chip| {
            let chip = chip.trim();
            let mut cfg = ArchConfig::paper();
            let (cap, bus) = match chip.split_once(':') {
                Some((c, b)) => (c, Some(b)),
                None => (chip, None),
            };
            cfg.capacity_mb = cap.trim().parse().unwrap_or_else(|_| {
                eprintln!("invalid --chip-config capacity '{cap}' (expected MB, e.g. 64)");
                std::process::exit(2);
            });
            if let Some(bus) = bus {
                cfg.bus_width_bits = bus.trim().parse().unwrap_or_else(|_| {
                    eprintln!("invalid --chip-config bus width '{bus}' (expected bits, e.g. 128)");
                    std::process::exit(2);
                });
            }
            cfg
        })
        .collect()
}

/// Parse `--slo-us` per-network deadlines: either positional
/// (`500,50` — network order) or named against the `--network` tokens
/// (`alexnet=500,small=50`).
fn parse_slo(spec: &str, net_tokens: &[&str]) -> SloPolicy {
    let mut slo = SloPolicy::global();
    for (pos, tok) in spec.split(',').enumerate() {
        let tok = tok.trim();
        if tok.is_empty() {
            continue;
        }
        let (idx, val) = match tok.split_once('=') {
            Some((name, v)) => {
                let Some(idx) = net_tokens.iter().position(|t| *t == name.trim()) else {
                    eprintln!("--slo-us names unknown network '{name}' (serving {net_tokens:?})");
                    std::process::exit(2);
                };
                (idx, v)
            }
            None => (pos, tok),
        };
        if idx >= net_tokens.len() {
            eprintln!("--slo-us has more deadlines than --network entries");
            std::process::exit(2);
        }
        let us: f64 = val.trim().parse().unwrap_or_else(|_| {
            eprintln!("invalid --slo-us deadline '{val}' (expected µs)");
            std::process::exit(2);
        });
        slo = slo.with_deadline_us(idx, us);
    }
    slo
}

fn cmd_serve(args: &[String]) {
    let get = flags(args);
    let network = get("network", "small");
    let net_tokens: Vec<&str> = network.split('+').map(str::trim).filter(|t| !t.is_empty()).collect();
    if net_tokens.is_empty() {
        eprintln!("--network needs at least one preset (use one of {PRESET_NAMES:?})");
        std::process::exit(2);
    }
    let small_preset = net_tokens.iter().all(|t| {
        matches!(
            *t,
            "small" | "small_cnn" | "small_resnet" | "micro" | "micro_cnn" | "wide" | "wide_cnn"
        )
    });
    let check_every: usize = parse_or_exit(&get, "check-every", "4");
    let engine = match get("engine", "functional").as_str() {
        "functional" => EngineMode::Functional,
        "analytic" => EngineMode::Analytic,
        "hybrid" => EngineMode::Hybrid { check_every },
        other => {
            eprintln!("unknown engine '{other}' (use functional|analytic|hybrid)");
            std::process::exit(2);
        }
    };
    // A bit-accurate full-size run is implied for `--engine functional`
    // and for the hybrid replay.
    let bit_accurate = engine != EngineMode::Analytic;
    // Small functional-mode presets default to the 4-bit operating
    // point (the historical serve default); full-size benchmarks to the
    // paper's ⟨8:8⟩ — except when they will actually execute on the
    // bit-accurate engine, where the default drops to ⟨2:2⟩ so a bare
    // `serve --engine functional --network alexnet` finishes in minutes
    // (the multi-tile mapping and op stream are identical at any
    // precision, only narrower).
    let default_bits: u8 = if small_preset {
        4
    } else if bit_accurate {
        2
    } else {
        8
    };
    let bits: u8 = parse_or_exit(&get, "bits", &default_bits.to_string());
    let nets: Vec<Network> = net_tokens
        .iter()
        .map(|t| {
            preset(t, bits).unwrap_or_else(|| {
                eprintln!("unknown network '{t}' (use one of {PRESET_NAMES:?})");
                std::process::exit(2);
            })
        })
        .collect();

    // Chip pool: homogeneous `--chips N` at the paper point, or a
    // heterogeneous `--chip-config` list (one operating point per chip).
    let chip_spec = get("chip-config", "");
    let chip_cfgs: Vec<ArchConfig> = if chip_spec.is_empty() {
        let chips: usize = parse_or_exit(&get, "chips", "4");
        vec![ArchConfig::paper(); chips.max(1)]
    } else {
        parse_chip_configs(&chip_spec)
    };

    // Observability exporters: any export flag turns the deterministic
    // trace recorder on (the trace rides on the simulated clock, so
    // recording never perturbs the serve itself).
    let trace_path = get("trace", "");
    let trace_jsonl_path = get("trace-jsonl", "");
    let metrics_path = get("metrics-out", "");
    let trace_on =
        !trace_path.is_empty() || !trace_jsonl_path.is_empty() || !metrics_path.is_empty();

    let scfg = checked(ServeConfig {
        chips: chip_cfgs.len(),
        max_batch: parse_or_exit(&get, "batch", "8"),
        deadline_us: parse_or_exit(&get, "deadline-us", "50"),
        slo: parse_slo(&get("slo-us", ""), &net_tokens),
        queue_depth: parse_or_exit(&get, "queue", "2"),
        arrival_interval_ns: parse_or_exit(&get, "arrival-ns", "0"),
        engine,
        host_workers: host_workers_flag(&get),
        fault: fault_flags(&get),
        retry_budget: parse_or_exit(&get, "retry-budget", "1"),
        trace: trace_on,
        ..ServeConfig::default()
    });
    // Bit-accurate full-size serving simulates every device op of a
    // many-layer network per request; default to a short burst there
    // (the analytic engine keeps the long-stream default).
    let default_requests = if bit_accurate && !small_preset { 4 } else { 32 };
    let requests: usize = parse_or_exit(&get, "requests", &default_requests.to_string());
    let seed: u64 = parse_or_exit(&get, "seed", "1");
    let verbose = args.iter().any(|a| a == "--verbose");
    if verbose {
        for net in &nets {
            print_tiling_plan(net, bits);
        }
    }

    // Model parameters are only materialised for networks a functional
    // engine will actually run: all of them for `--engine functional`,
    // and for the hybrid replay those that fit some chip's bit-accurate
    // path. (Randomising full-size weights for an analytic-only serve
    // would cost hundreds of MB for nothing.)
    let params: Vec<Option<ModelParams>> = nets
        .iter()
        .enumerate()
        .map(|(i, net)| {
            let supported = chip_cfgs.iter().any(|cfg| {
                Coordinator::new(cfg.clone()).engine_factory(EngineKind::Functional).plan(net).supported
            });
            if engine == EngineMode::Functional && !supported {
                eprintln!(
                    "network '{}' cannot run on the functional engine; \
                     use --engine analytic or hybrid",
                    net.name,
                );
                std::process::exit(2);
            }
            let needs_params = engine == EngineMode::Functional
                || (matches!(engine, EngineMode::Hybrid { .. }) && supported);
            if needs_params {
                Some(ModelParams::random(net, bits, seed + i as u64))
            } else {
                None
            }
        })
        .collect();

    let lanes: Vec<String> = nets
        .iter()
        .enumerate()
        .map(|(i, net)| {
            format!("{} (SLO {} µs)", net.name, scfg.slo.deadline_us(i, scfg.deadline_us))
        })
        .collect();
    println!(
        "== serving {} requests each of [{}] on {} chips (engine {}, batch {}, queue {}) ==",
        requests,
        lanes.join(", "),
        scfg.chips,
        scfg.engine.label(),
        scfg.max_batch,
        scfg.queue_depth
    );
    let streams: Vec<Vec<QTensor>> = nets
        .iter()
        .enumerate()
        .map(|(i, net)| ImageBatch::synthetic(net, requests, seed + i as u64).images)
        .collect();
    let pool = PoolSpec::heterogeneous(chip_cfgs, scfg.engine.serving_kind());
    let served: Vec<ServedNetwork> = nets
        .iter()
        .zip(&params)
        .map(|(net, p)| ServedNetwork { net, params: p.as_ref() })
        .collect();
    let report = serve_pool(&pool, &scfg, &served, Request::interleave(streams));
    report.verify().expect("serve aggregation identities");
    println!("{report}");
    if verbose {
        print_host_profiles(&report);
    }
    if trace_on {
        export_telemetry(&report, &trace_path, &trace_jsonl_path, &metrics_path);
    }
}

/// Write the requested serve telemetry exports (`--trace`,
/// `--trace-jsonl`, `--metrics-out`). Paths that were not given are
/// empty strings and skipped.
fn export_telemetry(
    report: &nandspin::coordinator::ServeReport,
    trace_path: &str,
    jsonl_path: &str,
    metrics_path: &str,
) {
    use nandspin::trace::export;
    let Some(trace) = &report.trace else {
        eprintln!("serve produced no trace (internal error)");
        std::process::exit(1);
    };
    let mut write = |path: &str, what: &str, body: String| {
        if path.is_empty() {
            return;
        }
        match std::fs::write(path, body) {
            Ok(()) => println!("wrote {what} to {path}"),
            Err(e) => {
                eprintln!("cannot write {what} to {path}: {e}");
                std::process::exit(1);
            }
        }
    };
    write(trace_path, "Chrome trace (load in ui.perfetto.dev)", export::to_chrome_json(trace));
    write(jsonl_path, "JSONL event log", export::to_jsonl(trace));
    write(metrics_path, "Prometheus metrics", trace.metrics.to_prometheus());
}

/// Per-layer host wall-time profile accumulated across each chip's
/// whole bit-accurate request stream (`serve --verbose`). Wall-clock
/// diagnostics of the simulator itself — not simulated device cost.
/// `pass` is the wall time of the whole filter fan-out; `conv`/`acc`
/// are summed across its workers, so with several workers they exceed
/// `pass`.
fn print_host_profiles(report: &nandspin::coordinator::ServeReport) {
    let ms = |ns: u64| ns as f64 / 1e6;
    for chip in &report.chips {
        let Some(profile) = &chip.host_profile else { continue };
        if profile.is_empty() {
            continue;
        }
        println!("host profile, chip {} (whole stream, wall-clock):", chip.chip);
        println!(
            "  {:>4}  {:<16} {:>7} {:>5} {:>9} {:>9} {:>9} {:>9}",
            "node", "layer", "workers", "tiles", "load ms", "pass ms", "conv ms", "acc ms"
        );
        let (mut load, mut pass, mut conv, mut acc) = (0u64, 0u64, 0u64, 0u64);
        for l in profile {
            println!(
                "  {:>4}  {:<16} {:>7} {:>5} {:>9.3} {:>9.3} {:>9.3} {:>9.3}",
                l.node,
                l.label,
                l.workers,
                l.tiles,
                ms(l.load_ns),
                ms(l.pass_ns),
                ms(l.conv_ns),
                ms(l.acc_ns)
            );
            load += l.load_ns;
            pass += l.pass_ns;
            conv += l.conv_ns;
            acc += l.acc_ns;
        }
        println!(
            "  {:>4}  {:<16} {:>7} {:>5} {:>9.3} {:>9.3} {:>9.3} {:>9.3}",
            "",
            "total",
            "",
            "",
            ms(load),
            ms(pass),
            ms(conv),
            ms(acc)
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // One test per flag family: the count flags (usize), the time
    // flags (f64), the seed/bits flags (u64/u8) and the fault spec.
    // Malformed values must produce an explicit per-flag error, never
    // a silent fall-back to the default.

    #[test]
    fn count_flags_reject_garbage() {
        assert_eq!(parse_flag::<usize>("batch", "8"), Ok(8));
        assert_eq!(parse_flag::<usize>("chips", " 4 "), Ok(4), "whitespace is trimmed");
        assert!(parse_flag::<usize>("batch", "eight").is_err());
        assert!(parse_flag::<usize>("chips", "-1").is_err());
        assert!(parse_flag::<usize>("queue", "2.5").is_err());
        let err = parse_flag::<usize>("requests", "lots").unwrap_err();
        assert!(err.contains("--requests") && err.contains("lots"), "{err}");
    }

    #[test]
    fn time_flags_reject_garbage() {
        assert_eq!(parse_flag::<f64>("deadline-us", "50"), Ok(50.0));
        assert_eq!(parse_flag::<f64>("arrival-ns", "12.5"), Ok(12.5));
        assert!(parse_flag::<f64>("deadline-us", "soon").is_err());
        assert!(parse_flag::<f64>("arrival-ns", "10ns").is_err());
    }

    #[test]
    fn seed_and_bits_flags_reject_garbage() {
        assert_eq!(parse_flag::<u64>("seed", "42"), Ok(42));
        assert!(parse_flag::<u64>("seed", "0x2a").is_err(), "seeds are decimal");
        assert_eq!(parse_flag::<u8>("bits", "4"), Ok(4));
        assert!(parse_flag::<u8>("bits", "300").is_err(), "bits must fit u8");
        assert!(parse_flag::<u8>("bits", "four").is_err());
    }

    #[test]
    fn fault_rate_flag_parses_numbers_and_auto() {
        let r = parse_fault_rates("1e-3").expect("explicit rate");
        assert!((r.program_fail - 1e-3).abs() < 1e-15);
        assert!((r.stuck_at - 1e-5).abs() < 1e-15, "stuck-at is two orders rarer");
        let auto = parse_fault_rates("auto").expect("derived rates");
        assert!(auto.validate().is_ok());
        assert!(parse_fault_rates("broken").is_err());
        assert!(parse_fault_rates("1.5").is_err(), "out-of-range rates are rejected");
        assert!(parse_fault_rates("-0.1").is_err());
    }
}

fn main() -> ExitCode {
    let args: Vec<String> = env::args().skip(1).collect();
    let Some(cmd) = args.first() else { return usage() };
    let rest = &args[1..];
    match cmd.as_str() {
        "breakdown" => cmd_breakdown(rest),
        "compare" => cmd_compare(rest),
        "sweep-capacity" => cmd_sweep_capacity(rest),
        "sweep-bus" => cmd_sweep_bus(rest),
        "area" => cmd_area(),
        "inspect-device" => cmd_inspect_device(),
        "verify" => cmd_verify(rest),
        "run" => cmd_run(rest),
        "serve" => cmd_serve(rest),
        _ => return usage(),
    }
    ExitCode::SUCCESS
}
