//! Artifact runtime: load the AOT-lowered JAX/Pallas artifacts
//! (`artifacts/*.hlo.txt`) and execute them from Rust.
//!
//! This is the golden numeric path of the three-layer architecture:
//! Python runs once at build time to author + lower the model; the Rust
//! coordinator loads the HLO text and executes it with concrete inputs —
//! Python is never on the inference path.
//!
//! ## Backends
//!
//! Executing HLO requires a PJRT client (the `xla` FFI crate), which the
//! default build deliberately does not link: the build is fully offline
//! and dependency-free (see `Cargo.toml`). The module therefore splits
//! into:
//!
//! * the stable, dependency-free surface — [`Runtime`], [`Artifact`],
//!   [`ArgI32`], [`RuntimeError`] — which callers program against, and
//! * an execution backend behind [`Artifact::run_i32`]. Without a linked
//!   backend, [`Runtime::load`] still checks that the artifact file
//!   exists (so missing-artifact errors stay precise) and then reports
//!   [`RuntimeError::BackendUnavailable`].
//!
//! Callers treat `BackendUnavailable` as "skip the PJRT leg": the
//! cross-check examples and tests fall back to the two-way comparison
//! (golden executor vs PIM simulator) and say so, keeping every target
//! runnable in the offline build.

use std::error::Error;
use std::fmt;
use std::path::{Path, PathBuf};

use crate::cnn::ref_exec::WideTensor;
use crate::cnn::tensor::{Kernel4, QTensor};

/// Errors from the artifact runtime.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RuntimeError {
    /// The requested `.hlo.txt` artifact does not exist (run
    /// `make artifacts` to lower the JAX/Pallas model first).
    MissingArtifact(PathBuf),
    /// No PJRT execution backend is linked into this build.
    BackendUnavailable {
        /// Name of the artifact whose execution was requested.
        artifact: String,
    },
}

impl fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RuntimeError::MissingArtifact(p) => {
                write!(f, "artifact not found: {} (run `make artifacts`)", p.display())
            }
            RuntimeError::BackendUnavailable { artifact } => write!(
                f,
                "no PJRT backend linked in this offline build (cannot execute '{artifact}')"
            ),
        }
    }
}

impl Error for RuntimeError {}

/// The artifact runtime: resolves artifact files under one directory.
pub struct Runtime {
    dir: PathBuf,
}

/// A loaded artifact, ready to execute on a linked backend.
pub struct Artifact {
    name: String,
}

impl Runtime {
    /// Runtime rooted at the artifact directory.
    pub fn new(artifact_dir: impl AsRef<Path>) -> Result<Self, RuntimeError> {
        Ok(Self { dir: artifact_dir.as_ref().to_path_buf() })
    }

    /// Platform string (diagnostics).
    pub fn platform(&self) -> String {
        "stub (no PJRT backend linked)".to_string()
    }

    /// Locate `<name>.hlo.txt` and prepare it for execution.
    ///
    /// In the offline build this reports [`RuntimeError::MissingArtifact`]
    /// if the file is absent and [`RuntimeError::BackendUnavailable`]
    /// otherwise — it never returns a runnable [`Artifact`]; callers are
    /// expected to skip the PJRT leg on error.
    pub fn load(&self, name: &str) -> Result<Artifact, RuntimeError> {
        let path = self.dir.join(format!("{name}.hlo.txt"));
        if !path.exists() {
            return Err(RuntimeError::MissingArtifact(path));
        }
        Err(RuntimeError::BackendUnavailable { artifact: name.to_string() })
    }
}

impl Artifact {
    /// Execute with int32 literals; returns the result-tuple elements as
    /// flat i32 vectors.
    pub fn run_i32(&self, _inputs: &[ArgI32]) -> Result<Vec<Vec<i32>>, RuntimeError> {
        Err(RuntimeError::BackendUnavailable { artifact: self.name.clone() })
    }
}

/// A shaped int32 argument.
#[derive(Debug, Clone)]
pub struct ArgI32 {
    /// Flat row-major data.
    pub data: Vec<i32>,
    /// Dimensions.
    pub dims: Vec<usize>,
}

impl ArgI32 {
    /// From a quantized activation tensor (CHW).
    pub fn from_qtensor(t: &QTensor) -> Self {
        Self {
            data: t.data().iter().map(|&v| v as i32).collect(),
            dims: vec![t.c, t.h, t.w],
        }
    }

    /// From a kernel tensor (OIHW).
    pub fn from_kernel(k: &Kernel4) -> Self {
        Self {
            data: k.data().iter().map(|&v| v as i32).collect(),
            dims: vec![k.oc, k.ic, k.kh, k.kw],
        }
    }

    /// From a wide tensor (values must fit i32).
    pub fn from_wide(t: &WideTensor) -> Self {
        Self {
            data: t.data.iter().map(|&v| i32::try_from(v).expect("value fits i32")).collect(),
            dims: vec![t.c, t.h, t.w],
        }
    }

    /// A flat vector.
    pub fn vec(data: Vec<i32>) -> Self {
        let dims = vec![data.len()];
        Self { data, dims }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_reports_missing_artifact_precisely() {
        let rt = Runtime::new("definitely-not-a-dir").unwrap();
        match rt.load("cnn_forward") {
            Err(RuntimeError::MissingArtifact(p)) => {
                assert!(p.to_string_lossy().ends_with("cnn_forward.hlo.txt"));
            }
            other => panic!("expected MissingArtifact, got {other:?}"),
        }
    }

    #[test]
    fn arg_shapes_round_trip() {
        let q = QTensor::random(2, 3, 4, 3, 7);
        let a = ArgI32::from_qtensor(&q);
        assert_eq!(a.dims, vec![2, 3, 4]);
        assert_eq!(a.data.len(), 24);
        let v = ArgI32::vec(vec![1, 2, 3]);
        assert_eq!(v.dims, vec![3]);
    }
}
