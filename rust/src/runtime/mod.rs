//! PJRT runtime: load the AOT-lowered JAX/Pallas artifacts
//! (`artifacts/*.hlo.txt`) and execute them from Rust.
//!
//! This is the golden numeric path of the three-layer architecture:
//! Python runs once at build time to author + lower the model; the Rust
//! coordinator loads the HLO text, compiles it on the PJRT CPU client,
//! and executes it with concrete inputs — Python is never on the
//! inference path.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::cnn::ref_exec::WideTensor;
use crate::cnn::tensor::{Kernel4, QTensor};

/// A compiled artifact ready to execute.
pub struct Artifact {
    exe: xla::PjRtLoadedExecutable,
    name: String,
}

/// The PJRT runtime: one CPU client, many compiled artifacts.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
}

impl Runtime {
    /// CPU PJRT client rooted at the artifact directory.
    pub fn new(artifact_dir: impl AsRef<Path>) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self { client, dir: artifact_dir.as_ref().to_path_buf() })
    }

    /// Platform string (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile `<name>.hlo.txt`.
    pub fn load(&self, name: &str) -> Result<Artifact> {
        let path = self.dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compiling {name}"))?;
        Ok(Artifact { exe, name: name.to_string() })
    }
}

impl Artifact {
    /// Execute with int32 literals; returns the tuple elements as flat
    /// i32 vectors.
    pub fn run_i32(&self, inputs: &[ArgI32]) -> Result<Vec<Vec<i32>>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|a| {
                let lit = xla::Literal::vec1(&a.data);
                let dims: Vec<i64> = a.dims.iter().map(|&d| d as i64).collect();
                lit.reshape(&dims).context("reshaping literal")
            })
            .collect::<Result<_>>()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing {}", self.name))?[0][0]
            .to_literal_sync()?;
        // Lowered with return_tuple=True: unpack every element.
        let tuple = result.to_tuple()?;
        tuple.into_iter().map(|l| Ok(l.to_vec::<i32>()?)).collect()
    }
}

/// A shaped int32 argument.
#[derive(Debug, Clone)]
pub struct ArgI32 {
    /// Flat row-major data.
    pub data: Vec<i32>,
    /// Dimensions.
    pub dims: Vec<usize>,
}

impl ArgI32 {
    /// From a quantized activation tensor (CHW).
    pub fn from_qtensor(t: &QTensor) -> Self {
        Self {
            data: t.data().iter().map(|&v| v as i32).collect(),
            dims: vec![t.c, t.h, t.w],
        }
    }

    /// From a kernel tensor (OIHW).
    pub fn from_kernel(k: &Kernel4) -> Self {
        Self {
            data: k.data().iter().map(|&v| v as i32).collect(),
            dims: vec![k.oc, k.ic, k.kh, k.kw],
        }
    }

    /// From a wide tensor (values must fit i32).
    pub fn from_wide(t: &WideTensor) -> Self {
        Self {
            data: t.data.iter().map(|&v| i32::try_from(v).expect("value fits i32")).collect(),
            dims: vec![t.c, t.h, t.w],
        }
    }

    /// A flat vector.
    pub fn vec(data: Vec<i32>) -> Self {
        let dims = vec![data.len()];
        Self { data, dims }
    }
}
