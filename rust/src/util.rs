//! Small self-contained utilities: a deterministic PRNG (the build is
//! fully offline, so we avoid external crates) used for synthetic
//! workloads and property-style test sweeps, and the packed 128×128
//! bit-matrix transpose the word-parallel host representation is built
//! on (vertical-layout pack/unpack without per-element loops).

/// SplitMix64: tiny, fast, well-distributed PRNG. Deterministic per seed;
/// NOT cryptographic — used only for synthetic data and test-case
/// generation.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Seeded PRNG.
    pub fn seed_from_u64(seed: u64) -> Self {
        Self { state: seed.wrapping_add(0x9e37_79b9_7f4a_7c15) }
    }

    /// Next 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e37_79b9_7f4a_7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^ (z >> 31)
    }

    /// Fresh sub-seed (for nested generators).
    pub fn gen_seed(&mut self) -> u64 {
        self.next_u64()
    }

    /// Uniform u32 in `0..=max` (unbiased enough for workloads: 64-bit
    /// modulo over ≤ 32-bit ranges).
    pub fn gen_range_inclusive(&mut self, max: u32) -> u32 {
        (self.next_u64() % (max as u64 + 1)) as u32
    }

    /// Uniform usize in `lo..hi` (half-open, `hi > lo`).
    pub fn gen_usize(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + (self.next_u64() % (hi - lo) as u64) as usize
    }

    /// Random bool.
    pub fn gen_bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }
}

/// In-place transpose of a 128×128 bit matrix stored row-major: bit `c`
/// of `m[r]` is element (r, c); afterwards bit `r` of `m[c]` holds the
/// same element. LSB-first convention throughout (column 0 = bit 0).
///
/// This is the recursive block-swap transpose (Hacker's Delight §7-3
/// adapted to LSB-first indexing): 7 rounds of masked field exchanges,
/// ~64 word ops per round — no per-bit loops. It is its own inverse.
///
/// The simulator uses it to convert between the *horizontal* host
/// representation (one value per word) and the subarray's *vertical*
/// layout (one bit-position per row word) in O(1) word ops per matrix
/// instead of O(bits × cols) single-bit extracts.
pub fn transpose128(m: &mut [u128; 128]) {
    let mut j = 64usize;
    let mut mask: u128 = u128::MAX >> 64; // low half of each 2j block
    while j != 0 {
        let mut k = 0usize;
        while k < 128 {
            // Exchange the high-column block of row k with the
            // low-column block of row k+j (LSB-first transpose step).
            let t = (m[k + j] ^ (m[k] >> j)) & mask;
            m[k + j] ^= t;
            m[k] ^= t << j;
            k = (k + j + 1) & !j;
        }
        j >>= 1;
        if j != 0 {
            mask ^= mask << j;
        }
    }
}

/// Pack `values[col]` (non-negative, each < 2^128) into vertical bit
/// planes: word `b` of the result has bit `col` = bit `b` of
/// `values[col]`. At most 128 values.
///
/// # Panics
/// If more than 128 values are given (debug: or any value is negative).
pub fn pack_columns(values: &[i64]) -> [u128; 128] {
    assert!(values.len() <= 128);
    let mut m = [0u128; 128];
    for (col, &v) in values.iter().enumerate() {
        debug_assert!(v >= 0, "vertical layout is unsigned");
        m[col] = v as u128;
    }
    transpose128(&mut m);
    m
}

/// Inverse of [`pack_columns`]: given row words `rows[b]` (bit `col` =
/// bit `b` of column `col`'s value, `rows.len() <= 128` bit positions),
/// reconstruct the first `cols` column values.
pub fn unpack_columns(rows: &[u128], cols: usize) -> Vec<i64> {
    assert!(rows.len() <= 128 && cols <= 128);
    let mut m = [0u128; 128];
    m[..rows.len()].copy_from_slice(rows);
    transpose128(&mut m);
    m[..cols].iter().map(|&v| v as i64).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::seed_from_u64(1);
        let mut b = Rng::seed_from_u64(1);
        for _ in 0..10 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::seed_from_u64(2);
        assert_ne!(Rng::seed_from_u64(1).next_u64(), c.next_u64());
    }

    #[test]
    fn range_bounds_respected() {
        let mut r = Rng::seed_from_u64(3);
        for _ in 0..1000 {
            assert!(r.gen_range_inclusive(15) <= 15);
            let v = r.gen_usize(10, 20);
            assert!((10..20).contains(&v));
        }
    }

    #[test]
    fn transpose_matches_scalar_bit_walk() {
        let mut rng = Rng::seed_from_u64(0x7123);
        for _ in 0..10 {
            let mut m = [0u128; 128];
            for row in m.iter_mut() {
                *row = (rng.next_u64() as u128) << 64 | rng.next_u64() as u128;
            }
            let orig = m;
            transpose128(&mut m);
            for r in 0..128 {
                for c in 0..128 {
                    assert_eq!(
                        (m[c] >> r) & 1,
                        (orig[r] >> c) & 1,
                        "element ({r},{c})"
                    );
                }
            }
            // Self-inverse.
            transpose128(&mut m);
            assert_eq!(m, orig);
        }
    }

    #[test]
    fn pack_unpack_columns_roundtrip() {
        let mut rng = Rng::seed_from_u64(0x7124);
        for &cols in &[1usize, 7, 64, 127, 128] {
            let values: Vec<i64> =
                (0..cols).map(|_| (rng.next_u64() >> 1) as i64).collect();
            let planes = pack_columns(&values);
            // Scalar cross-check of the plane words.
            for b in 0..64 {
                let mut expect = 0u128;
                for (col, &v) in values.iter().enumerate() {
                    expect |= ((v as u128 >> b) & 1) << col;
                }
                assert_eq!(planes[b], expect, "plane {b} cols {cols}");
            }
            assert_eq!(unpack_columns(&planes[..63], cols), values);
        }
    }

    #[test]
    fn roughly_uniform() {
        let mut r = Rng::seed_from_u64(4);
        let mut counts = [0u32; 8];
        for _ in 0..8000 {
            counts[r.gen_range_inclusive(7) as usize] += 1;
        }
        for c in counts {
            assert!(c > 800 && c < 1200, "{counts:?}");
        }
    }
}
