//! Small self-contained utilities: a deterministic PRNG (the build is
//! fully offline, so we avoid external crates) used for synthetic
//! workloads and property-style test sweeps.

/// SplitMix64: tiny, fast, well-distributed PRNG. Deterministic per seed;
/// NOT cryptographic — used only for synthetic data and test-case
/// generation.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Seeded PRNG.
    pub fn seed_from_u64(seed: u64) -> Self {
        Self { state: seed.wrapping_add(0x9e37_79b9_7f4a_7c15) }
    }

    /// Next 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e37_79b9_7f4a_7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^ (z >> 31)
    }

    /// Fresh sub-seed (for nested generators).
    pub fn gen_seed(&mut self) -> u64 {
        self.next_u64()
    }

    /// Uniform u32 in `0..=max` (unbiased enough for workloads: 64-bit
    /// modulo over ≤ 32-bit ranges).
    pub fn gen_range_inclusive(&mut self, max: u32) -> u32 {
        (self.next_u64() % (max as u64 + 1)) as u32
    }

    /// Uniform usize in `lo..hi` (half-open, `hi > lo`).
    pub fn gen_usize(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + (self.next_u64() % (hi - lo) as u64) as usize
    }

    /// Random bool.
    pub fn gen_bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::seed_from_u64(1);
        let mut b = Rng::seed_from_u64(1);
        for _ in 0..10 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::seed_from_u64(2);
        assert_ne!(Rng::seed_from_u64(1).next_u64(), c.next_u64());
    }

    #[test]
    fn range_bounds_respected() {
        let mut r = Rng::seed_from_u64(3);
        for _ in 0..1000 {
            assert!(r.gen_range_inclusive(15) <= 15);
            let v = r.gen_usize(10, 20);
            assert!((10..20).contains(&v));
        }
    }

    #[test]
    fn roughly_uniform() {
        let mut r = Rng::seed_from_u64(4);
        let mut counts = [0u32; 8];
        for _ in 0..8000 {
            counts[r.gen_range_inclusive(7) as usize] += 1;
        }
        for c in counts {
            assert!(c > 800 && c < 1200, "{counts:?}");
        }
    }
}
