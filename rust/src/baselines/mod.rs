//! Analytic cost models for the comparison accelerators of §5.3:
//! DRISA (DRAM), PRIME (ReRAM), STT-CiM and MRIMA (STT-MRAM), IMCE
//! (SOT-MRAM).
//!
//! Each model keeps the *structure* that differentiates the design —
//! bit-serial vs analog-parallel multiply, per-op energies, write costs,
//! ADC/DAC overheads, array parallelism and cell density — and is
//! calibrated to its published Table-3 operating point (64 MB, ResNet50
//! class, FPS and area). Precision ⟨W:I⟩ scaling then *emerges* from the
//! structure: bit-serial designs scale with N·M, PRIME's analog MACs
//! scale with DAC sweeps + ADC resolution, etc. See DESIGN.md §7.

pub mod designs;

pub use designs::{all_baselines, BaselineKind};

use crate::arch::stats::{Phase, Stats};
use crate::cnn::layer::Layer;
use crate::cnn::network::Network;
use crate::metrics::Metrics;

/// Structural parameters of one comparison accelerator.
#[derive(Debug, Clone)]
pub struct BaselineModel {
    /// Display name (Table 3 row).
    pub name: &'static str,
    /// Memory technology label.
    pub technology: &'static str,
    /// Chip area at 64 MB (Table 3), mm².
    pub area_mm2: f64,
    /// Parallel MAC-lanes equivalent at the 64 MB operating point.
    pub lanes: f64,
    /// ns per primitive bit-op per lane (bit-serial designs) or per
    /// analog MAC sweep (PRIME).
    pub ns_per_bitop: f64,
    /// fJ per primitive bit-op per lane.
    pub fj_per_bitop: f64,
    /// How ⟨W:I⟩ precision scales the per-MAC bit-op count.
    pub precision: PrecisionScaling,
    /// Write cost entering the array, ns per bit (amortised, serialised
    /// over the design's write bandwidth).
    pub write_ns_per_bit: f64,
    /// Write energy, fJ per bit.
    pub write_fj_per_bit: f64,
    /// Fixed per-element overhead for the auxiliary layers (pooling, BN,
    /// quantization), as bit-ops per element per bit.
    pub aux_bitops_per_elem_bit: f64,
    /// Off-chip load cycles per bit (shared 128-bit 1 GHz interface).
    pub load_cycles_per_bit: f64,
}

/// Precision-scaling law of the design's MAC primitive.
#[derive(Debug, Clone, Copy)]
pub enum PrecisionScaling {
    /// Bit-serial AND/majority: cost ∝ N·M (DRISA, STT-CiM, MRIMA, IMCE,
    /// and the proposed design).
    BitSerial,
    /// Analog crossbar: DAC sweeps ∝ N, ADC passes grow with output
    /// resolution; net cost ∝ N · (1 + M/4) (PRIME).
    AnalogCrossbar,
}

impl BaselineModel {
    /// Bit-ops per MAC at ⟨wbits:ibits⟩.
    fn bitops_per_mac(&self, wbits: u8, ibits: u8) -> f64 {
        match self.precision {
            PrecisionScaling::BitSerial => wbits as f64 * ibits as f64,
            PrecisionScaling::AnalogCrossbar => ibits as f64 * (1.0 + wbits as f64 / 4.0),
        }
    }

    /// Inference stats for `net` at ⟨wbits⟩ (activations from the net).
    pub fn network_stats(&self, net: &Network, wbits: u8) -> Stats {
        let ibits = net.input_bits;
        let macs = net.total_macs() as f64;
        let mut st = Stats::default();

        // Compute: MACs × bit-ops, spread over the lanes.
        let bitops = macs * self.bitops_per_mac(wbits, ibits);
        st.record(
            Phase::Convolution,
            bitops * self.fj_per_bitop,
            bitops * self.ns_per_bitop / self.lanes,
        );

        // Loads: weights + input over the shared interface, then written
        // into the array at the design's write cost.
        let weight_bits = net.total_weights() as f64 * wbits as f64;
        let (c, h, w) = net.input;
        let input_bits = (c * h * w) as f64 * ibits as f64;
        let load_bits = weight_bits + input_bits;
        st.record(
            Phase::LoadData,
            load_bits * (40_000.0 + self.write_fj_per_bit),
            load_bits * self.load_cycles_per_bit / 128.0 + load_bits * self.write_ns_per_bit,
        );

        // Aux layers (pooling / BN / quant) + inter-layer transfer.
        let shapes = net.shapes();
        for (i, node) in net.nodes.iter().enumerate() {
            let (oc, oh, ow) = shapes[i];
            let elems = (oc * oh * ow) as f64;
            let aux = elems * ibits as f64 * self.aux_bitops_per_elem_bit;
            // Aux passes run with the array parallelism of the compute
            // path, with a 10× scheduling penalty for the serially
            // dependent pooling comparisons.
            let aux_lat = aux * self.ns_per_bitop * 10.0 / self.lanes;
            match node.layer {
                Layer::MaxPool { .. } | Layer::AvgPool { .. } => {
                    st.record(Phase::Pooling, aux * self.fj_per_bitop, aux_lat);
                }
                Layer::BatchNorm => {
                    st.record(Phase::BatchNorm, aux * self.fj_per_bitop, aux_lat / 10.0);
                }
                Layer::Quantize { .. } => {
                    st.record(Phase::Quantization, aux * self.fj_per_bitop, aux_lat / 10.0);
                }
                Layer::Conv { .. } if i > 0 => {
                    let bits = elems * ibits as f64;
                    st.record(Phase::DataTransfer, bits * 120.0, bits / 128.0);
                }
                _ => {}
            }
        }
        st
    }

    /// Evaluation metrics for `net` at ⟨wbits⟩.
    pub fn metrics(&self, net: &Network, wbits: u8) -> Metrics {
        let st = self.network_stats(net, wbits);
        Metrics::from_stats(
            format!("{}/{}/w{}i{}", self.name, net.name, wbits, net.input_bits),
            net.total_ops() as f64,
            &st,
            self.area_mm2,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::network::resnet50;

    #[test]
    fn all_baselines_produce_metrics() {
        let net = resnet50(8);
        for b in all_baselines() {
            let m = b.metrics(&net, 8);
            assert!(m.fps() > 0.1 && m.fps() < 10_000.0, "{}: fps {}", b.name, m.fps());
        }
    }

    #[test]
    fn precision_scaling_differs_by_structure() {
        let net1 = resnet50(2);
        let net8 = resnet50(8);
        for b in all_baselines() {
            let lo = b.metrics(&net1, 2).latency_ms;
            let hi = b.metrics(&net8, 8).latency_ms;
            assert!(hi > lo, "{}: precision must cost", b.name);
        }
    }
}
