//! The five comparison designs with their structural parameters.
//!
//! Per-op energies/latencies come from each design's paper (DRAM
//! tRC-class multi-cycle logic for DRISA; ADC-limited analog MACs for
//! PRIME; SA-based bit-line addition for STT-CiM; bulk bitwise MRAM ops
//! for MRIMA; SOT bit-wise convolution for IMCE). The `lanes` value is
//! the Table-3 calibration pin: it is solved so that ResNet50 ⟨8:8⟩ at
//! 64 MB reproduces each design's published throughput (checked by the
//! `table3_calibration` test within ±25 %).

use super::{BaselineModel, PrecisionScaling};

/// Identifier for the comparison designs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BaselineKind {
    /// DRISA: DRAM-based reconfigurable in-situ accelerator.
    Drisa,
    /// PRIME: ReRAM crossbar PIM.
    Prime,
    /// STT-CiM: compute-in STT-MRAM via sensing.
    SttCim,
    /// MRIMA: MRAM-based in-memory accelerator.
    Mrima,
    /// IMCE: SOT-MRAM bit-wise convolution engine.
    Imce,
}

impl BaselineKind {
    /// Build the calibrated model.
    pub fn model(self) -> BaselineModel {
        match self {
            // Multi-cycle in-DRAM AND/OR/shift logic: huge row
            // parallelism, slow per-op (3T1C / tRC-class timing), cheap
            // writes, DRAM-density area.
            BaselineKind::Drisa => BaselineModel {
                name: "DRISA",
                technology: "DRAM",
                area_mm2: 117.2,
                lanes: 6.004e+04,
                ns_per_bitop: 4.0,
                fj_per_bitop: 130.0,
                precision: PrecisionScaling::BitSerial,
                write_ns_per_bit: 2.0e-4,
                write_fj_per_bit: 20.0,
                aux_bitops_per_elem_bit: 8.0,
                load_cycles_per_bit: 1.0,
            },
            // Analog crossbar MACs gated by DAC sweeps and ADC
            // conversions; few effective lanes, expensive per-op, and
            // the ADC/DAC dominate energy.
            BaselineKind::Prime => BaselineModel {
                name: "PRIME",
                technology: "ReRAM",
                area_mm2: 78.2,
                lanes: 9.052e+04,
                ns_per_bitop: 100.0,
                fj_per_bitop: 3400.0,
                precision: PrecisionScaling::AnalogCrossbar,
                write_ns_per_bit: 1.0e-3,
                write_fj_per_bit: 2000.0,
                aux_bitops_per_elem_bit: 4.0,
                load_cycles_per_bit: 1.0,
            },
            // Bit-line addition in sense amps @1 GHz; STT writes are the
            // expensive part (no SOT erase assist).
            BaselineKind::SttCim => BaselineModel {
                name: "STT-CiM",
                technology: "STT-RAM",
                area_mm2: 57.7,
                lanes: 1.428e+04,
                ns_per_bitop: 1.0,
                fj_per_bitop: 165.0,
                precision: PrecisionScaling::BitSerial,
                write_ns_per_bit: 6.0e-4,
                write_fj_per_bit: 500.0,
                aux_bitops_per_elem_bit: 4.0,
                load_cycles_per_bit: 2.0,
            },
            // Bulk bitwise in-MRAM ops; similar sensing path to STT-CiM
            // with somewhat better scheduling.
            BaselineKind::Mrima => BaselineModel {
                name: "MRIMA",
                technology: "STT-RAM",
                area_mm2: 55.6,
                lanes: 1.698e+04,
                ns_per_bitop: 1.0,
                fj_per_bitop: 150.0,
                precision: PrecisionScaling::BitSerial,
                write_ns_per_bit: 6.0e-4,
                write_fj_per_bit: 450.0,
                aux_bitops_per_elem_bit: 4.0,
                load_cycles_per_bit: 2.0,
            },
            // SOT-MRAM convolution engine: two-transistor cells halve the
            // density (biggest area), moderate speed, no weight-reuse
            // buffer (more data movement → fewer effective lanes).
            BaselineKind::Imce => BaselineModel {
                name: "IMCE",
                technology: "SOT-RAM",
                area_mm2: 128.3,
                lanes: 9.083e+03,
                ns_per_bitop: 1.5,
                fj_per_bitop: 136.0,
                precision: PrecisionScaling::BitSerial,
                write_ns_per_bit: 4.0e-4,
                write_fj_per_bit: 300.0,
                aux_bitops_per_elem_bit: 6.0,
                load_cycles_per_bit: 2.0,
            },
        }
    }

    /// Published Table-3 throughput (FPS) — the calibration pin.
    pub fn table3_fps(self) -> f64 {
        match self {
            BaselineKind::Drisa => 51.7,
            BaselineKind::Prime => 9.4,
            BaselineKind::SttCim => 45.6,
            BaselineKind::Mrima => 52.3,
            BaselineKind::Imce => 21.8,
        }
    }

    /// All kinds in Table-3 order.
    pub const ALL: [BaselineKind; 5] = [
        BaselineKind::Drisa,
        BaselineKind::Prime,
        BaselineKind::SttCim,
        BaselineKind::Mrima,
        BaselineKind::Imce,
    ];
}

/// All five calibrated baseline models (Table-3 order).
pub fn all_baselines() -> Vec<BaselineModel> {
    BaselineKind::ALL.iter().map(|k| k.model()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::network::resnet50;

    #[test]
    fn table3_calibration() {
        let net = resnet50(8);
        for kind in BaselineKind::ALL {
            let m = kind.model().metrics(&net, 8);
            let target = kind.table3_fps();
            let ratio = m.fps() / target;
            assert!(
                (0.75..=1.33).contains(&ratio),
                "{}: fps {:.1} vs Table-3 {:.1} (ratio {:.2})",
                kind.model().name,
                m.fps(),
                target,
                ratio
            );
        }
    }

    #[test]
    fn area_matches_table3() {
        assert_eq!(BaselineKind::Drisa.model().area_mm2, 117.2);
        assert_eq!(BaselineKind::Imce.model().area_mm2, 128.3);
    }
}
