//! Quickstart: store data in a NAND-SPIN subarray, read it back, run a
//! compute-mode AND, and execute one bitwise convolution — the minimal
//! tour of the public API.
//!
//! Run: `cargo run --release --example quickstart`

use nandspin::arch::stats::{Phase, Stats};
use nandspin::device::energy::DeviceCosts;
use nandspin::subarray::conv::{bitplane_conv_counts, window_sums, BitKernel, ConvGeometry};
use nandspin::subarray::Subarray;

fn main() {
    let mut stats = Stats::default();
    // A paper-sized subarray: 256 MTJ rows x 128 columns, 16-row buffer.
    let mut sub = Subarray::new(256, 128, 16, DeviceCosts::default());

    // --- memory mode: write a strip (erase + program), read it back.
    let data: [u128; 8] = [0xDEAD, 0xBEEF, 0x1234, 0x5678, 0x9ABC, 0xDEF0, 0x0F0F, 0xF0F0];
    sub.write_strip(0, &data, &mut stats, Phase::LoadData);
    for (pos, &expect) in data.iter().enumerate() {
        assert_eq!(sub.read_row(pos, &mut stats, Phase::Other), expect);
    }
    println!("memory mode: strip write + read-back OK");

    // --- compute mode: row-parallel AND against a buffer operand.
    sub.buffer_write(0, 0xFF00, &mut stats, Phase::LoadData);
    sub.and_count(0, 0, &mut stats, Phase::Convolution);
    println!("compute mode: AND(0xDEAD, 0xFF00) counted {} ones per-column", 
        sub.counters.values().iter().sum::<u32>());

    // --- bitwise convolution (Fig. 8): 2x2 kernel over a 2x5 bit matrix,
    // the paper's own worked example size.
    sub.counters.reset();
    let mut conv_sub = Subarray::new(256, 128, 16, DeviceCosts::default());
    conv_sub.write_row(0, 0b10110, &mut stats, Phase::LoadData);
    conv_sub.write_row(1, 0b01101, &mut stats, Phase::LoadData);
    let kernel = BitKernel::new(2, 2, vec![true, false, true, true]);
    let geo = ConvGeometry { in_h: 2, in_w: 5, stride: 1 };
    let counts = bitplane_conv_counts(&mut conv_sub, 0, geo, &kernel, &mut stats, Phase::Convolution);
    let sums = window_sums(&counts, geo, &kernel);
    println!("bitwise conv output row: {:?}", sums[0]);

    println!("\naccumulated cost statistics:\n{stats}");
}
