//! Design-space exploration: sweep capacity, bus width and subarray
//! geometry jointly; report the FPS / area / efficiency Pareto points
//! (the exploration behind the paper's 64 MB + 128-bit choice, 5.2).
//!
//! Run: `cargo run --release --example design_space`

use nandspin::arch::config::ArchConfig;
use nandspin::cnn::network::resnet50;
use nandspin::coordinator::Coordinator;

fn main() {
    let net = resnet50(8);
    println!("{:>9} {:>10} {:>8} {:>10} {:>12} {:>14} {:>12}",
        "cap (MB)", "bus (bit)", "rows", "FPS", "area (mm²)", "GOPS/mm²", "GOPS/W/mm²");
    let mut best: Option<(f64, String)> = None;
    for cap in [16usize, 64, 128] {
        for bus in [64usize, 128, 256] {
            for rows in [128usize, 256, 512] {
                let mut cfg = ArchConfig::paper();
                cfg.capacity_mb = cap;
                cfg.bus_width_bits = bus;
                cfg.rows = rows;
                if cfg.validate().is_err() {
                    continue;
                }
                let m = Coordinator::new(cfg).analytic_metrics(&net, 8);
                let line = format!(
                    "{:>9} {:>10} {:>8} {:>10.1} {:>12.1} {:>14.3} {:>12.3}",
                    cap, bus, rows, m.fps(), m.area_mm2, m.gops_per_mm2(), m.efficiency_per_mm2()
                );
                println!("{line}");
                let score = m.gops_per_mm2();
                if best.as_ref().map(|(s, _)| score > *s).unwrap_or(true) {
                    best = Some((score, line));
                }
            }
        }
    }
    if let Some((_, line)) = best {
        println!("\nbest GOPS/mm² point:\n{line}");
        println!("(the paper selects 64 MB / 128-bit as its operating point, 5.2)");
    }
}
