//! End-to-end cross-check: run a quantized CNN on real synthetic data
//! through the layers of the stack and prove they agree bit-for-bit:
//!
//! 1. **Golden** — pure-Rust integer executor (`cnn::ref_exec`);
//! 2. **PIM simulator** — bit-accurate NAND-SPIN functional engine
//!    (every conv/pool/BN/quant executed with erase/program/AND/count
//!    ops on simulated subarrays), producing latency/energy stats;
//! 3. **PJRT artifact** — the JAX/Pallas model AOT-lowered at build time
//!    (`artifacts/cnn_forward.hlo.txt`). This leg needs a linked PJRT
//!    backend; the default offline build has none, so it is skipped
//!    with a note (see `nandspin::runtime`).
//!
//! For batched *throughput* (batching, sharding, weight residency) see
//! the `serving` example — this one is purely about numerical agreement.
//!
//! Run: `cargo run --release --example cnn_inference`

use std::process::ExitCode;

use nandspin::cnn::network::small_cnn;
use nandspin::cnn::ref_exec::{self, ModelParams};
use nandspin::coordinator::Coordinator;
use nandspin::runtime::{ArgI32, Artifact, Runtime};
use nandspin::workload::ImageBatch;

fn main() -> ExitCode {
    let batch = 4usize;
    let seed = 7u64;
    let net = small_cnn(4);
    let params = ModelParams::random(&net, 4, seed);
    let images = ImageBatch::synthetic(&net, batch, seed + 100);
    let coord = Coordinator::paper();

    // --- try to load the AOT artifact (L2/L1 lowered to HLO text).
    let runtime = Runtime::new("artifacts").expect("runtime");
    println!("PJRT platform: {}", runtime.platform());
    let artifact: Option<Artifact> = match runtime.load("cnn_forward") {
        Ok(a) => Some(a),
        Err(e) => {
            println!("PJRT leg skipped: {e}");
            None
        }
    };

    // Pack the model parameters the way the artifact expects.
    let w1 = ArgI32::from_kernel(&params.conv_weights[0]);
    let w2 = ArgI32::from_kernel(&params.conv_weights[1]);
    let bn = &params.bn[0];
    let bn_mul = ArgI32::vec(bn.mul.iter().map(|&v| v as i32).collect());
    let bn_add = ArgI32::vec(bn.add.iter().map(|&v| v as i32).collect());
    let q = |p: &nandspin::cnn::quantize::QuantParams| {
        ArgI32::vec(vec![
            p.mul as i32,
            p.add as i32,
            p.shift as i32,
            ((1u32 << p.bits) - 1) as i32,
        ])
    };
    let q1 = q(&params.quant[0]);
    let q2 = q(&params.quant[1]);

    let mut sim_ms = 0.0f64;
    let mut sim_mj = 0.0f64;
    let mut legs = 2usize;

    for (i, img) in images.images.iter().enumerate() {
        // 1) golden executor.
        let golden = ref_exec::execute(&net, &params, img);
        let golden_out = golden.last().unwrap();

        // 2) bit-accurate PIM functional simulation.
        let (pim_outs, stats) = coord.functional_run(&net, &params, img);
        let pim_out = pim_outs.last().unwrap();
        if pim_out != golden_out {
            eprintln!("image {i}: PIM simulator diverged from golden executor");
            return ExitCode::FAILURE;
        }
        sim_ms += stats.total_latency_ms();
        sim_mj += stats.total_energy_mj();

        // 3) PJRT execution of the AOT JAX/Pallas artifact, if runnable.
        if let Some(artifact) = &artifact {
            let outs = match artifact.run_i32(&[
                ArgI32::from_qtensor(img),
                w1.clone(),
                bn_mul.clone(),
                bn_add.clone(),
                q1.clone(),
                w2.clone(),
                q2.clone(),
            ]) {
                Ok(o) => o,
                Err(e) => {
                    eprintln!("image {i}: PJRT execution failed: {e}");
                    return ExitCode::FAILURE;
                }
            };
            let pjrt_out: Vec<i64> = outs[0].iter().map(|&v| v as i64).collect();
            if pjrt_out != golden_out.data {
                eprintln!("image {i}: PJRT artifact diverged from golden executor");
                return ExitCode::FAILURE;
            }
            legs = 3;
        }
        println!("image {i}: golden == PIM-sim{}  (output {:?})",
            if legs == 3 { " == PJRT" } else { "" },
            &golden_out.data);
    }

    println!("\n== {legs}-way bit-exact agreement on {batch} images ==");
    println!(
        "simulated PIM latency: {:.4} ms/img, energy {:.4} mJ/img",
        sim_ms / batch as f64,
        sim_mj / batch as f64
    );
    println!("for batched serving throughput, run the `serving` example");
    ExitCode::SUCCESS
}
