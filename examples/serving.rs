//! Batched multi-chip serving: the deployment topology end to end.
//!
//! A stream of requests arrives at a fixed rate; the dynamic batcher
//! groups them (size target or deadline, whichever first), the shard
//! router spreads batches across four simulated PIM chips, and each
//! chip serves its queue on a weight-resident engine — weights cross
//! chip I/O once per chip and are then reused by every request (the
//! Table 3 serving condition). The serve pool is generic over the
//! `InferenceEngine` trait, so the same stream is served three ways:
//!
//! * **functional** — bit-accurate; outputs cross-checked against the
//!   golden executor, whichever chip served them;
//! * **analytic** — per-request stats synthesized from the closed-form
//!   op streams (the path that scales to AlexNet/VGG19/ResNet50);
//! * **hybrid** — analytic serving with every K-th request replayed on
//!   a functional engine and the stat ratios spot-checked.
//!
//! Run: `cargo run --release --example serving`

use nandspin::arch::config::ArchConfig;
use nandspin::cnn::network::small_cnn;
use nandspin::cnn::ref_exec::{self, ModelParams};
use nandspin::cnn::tensor::QTensor;
use nandspin::coordinator::serve::{serve, EngineMode, Request, ServeConfig};
use nandspin::workload::ImageBatch;

fn main() {
    let seed = 11u64;
    let net = small_cnn(4);
    let params = ModelParams::random(&net, 4, seed);
    let n = 32usize;
    let images: Vec<QTensor> = ImageBatch::synthetic(&net, n, seed + 1).images;
    let requests: Vec<Request> = Request::stream(images.clone());

    // An open-loop stream: one request every 20 simulated µs, batches of
    // up to 8 with a 100 µs batching deadline, 4 chips, 2-deep queues.
    let scfg = ServeConfig {
        chips: 4,
        max_batch: 8,
        deadline_us: 100.0,
        queue_depth: 2,
        arrival_interval_ns: 20_000.0,
        engine: EngineMode::Functional,
        ..ServeConfig::default()
    };
    println!(
        "serving {n} requests of {} on {} chips (batch ≤ {}, deadline {} µs)\n",
        net.name, scfg.chips, scfg.max_batch, scfg.deadline_us
    );
    let report = serve(&ArchConfig::paper(), &scfg, &net, Some(&params), requests);

    // Every aggregate must be the fold of its per-request parts.
    report.verify().expect("aggregation identities");

    // Spot-check bit-exactness against the golden executor.
    for c in report.completions.iter().take(3) {
        let golden = ref_exec::execute(&net, &params, &images[c.id as usize]);
        let output = c.output.as_ref().expect("functional mode carries outputs");
        assert_eq!(output, golden.last().unwrap(), "request {}", c.id);
    }
    println!("outputs bit-exact vs golden executor (spot-checked)\n");

    // A few per-request lines, then the per-chip and aggregate view.
    println!(
        "{:>4} {:>5} {:>6} {:>12} {:>12} {:>12}",
        "req", "chip", "batch", "wait (µs)", "exec (µs)", "latency (µs)"
    );
    for c in report.completions.iter().take(8) {
        println!(
            "{:>4} {:>5} {:>6} {:>12.2} {:>12.2} {:>12.2}",
            c.id,
            c.chip,
            c.batch,
            c.queue_wait_ns() * 1e-3,
            c.service_ns() * 1e-3,
            c.latency_ns() * 1e-3
        );
    }
    println!("  ... ({} more)\n", report.served().saturating_sub(8));
    println!("{report}");

    // The serving payoff: amortised weight streaming. Compare against a
    // one-request run on a cold chip.
    let cold = serve(
        &ArchConfig::paper(),
        &ServeConfig { chips: 1, max_batch: 1, ..scfg.clone() },
        &net,
        Some(&params),
        vec![Request { id: 0, net: 0, image: images[0].clone() }],
    );
    let cold_mj = cold.total_energy_mj();
    let warm_mj = report.total_energy_mj() / report.served() as f64;
    println!(
        "\nweight residency: {:.4} mJ cold single-shot vs {:.4} mJ/req served ({:.2}× energy)",
        cold_mj,
        warm_mj,
        cold_mj / warm_mj
    );

    // The same stream on the analytic engine: identical batching and
    // routing laws, closed-form per-request stats, no output tensors —
    // the path that serves the paper's full-size networks.
    let analytic = serve(
        &ArchConfig::paper(),
        &ServeConfig { engine: EngineMode::Analytic, ..scfg.clone() },
        &net,
        None,
        Request::stream(images.clone()),
    );
    analytic.verify().expect("analytic aggregation identities");
    println!(
        "\nanalytic engine, same stream: {:.1} FPS, {:.4} mJ/req (synthesized stats)",
        analytic.sim_fps(),
        analytic.total_energy_mj() / analytic.served() as f64
    );

    // Hybrid: serve analytically, replay every 8th request functionally
    // and cross-check the stat ratios.
    let hybrid = serve(
        &ArchConfig::paper(),
        &ServeConfig { engine: EngineMode::Hybrid { check_every: 8 }, ..scfg },
        &net,
        Some(&params),
        Request::stream(images.clone()),
    );
    hybrid.verify().expect("hybrid aggregation identities");
    let sc = hybrid.spot_check.expect("small network => functional spot-check runs");
    println!(
        "hybrid spot-check: {} functional replays, latency ratio {:.3}–{:.3}×, energy ratio {:.3}–{:.3}×",
        sc.checked,
        sc.latency_ratio.0,
        sc.latency_ratio.1,
        sc.energy_ratio.0,
        sc.energy_ratio.1
    );
}
