//! Memory-mode deep dive: the two-step NAND-SPIN write (SOT erase +
//! STT program), the Table 1 control signals, read-disturb margins, and
//! the write/read asymmetry the paper's §3.2 discusses.
//!
//! Run: `cargo run --release --example memory_mode`

use nandspin::arch::stats::{Phase, Stats};
use nandspin::bank::controller::{Controller, OpClass};
use nandspin::device::energy::DeviceCosts;
use nandspin::device::llg::{SotParams, SwitchingModel};
use nandspin::device::mtj::MtjParams;
use nandspin::device::NandSpinDevice;
use nandspin::subarray::Subarray;

fn main() {
    // Device level: one 8-MTJ strip.
    let mut dev = NandSpinDevice::default();
    let switched = dev.write_byte(0b1011_0010);
    println!("device write 0xB2: {} MTJs switched AP->P, read back {:#04x}", switched, dev.read_byte());

    // Controller: Table 1 signal sets.
    let mut ctrl = Controller::default();
    for (op, data) in [(OpClass::Erase, false), (OpClass::Program, true), (OpClass::Read, true), (OpClass::And, false)] {
        let sig = ctrl.issue(op, data);
        println!("{op:?}: WE={} ER={} Cx={} Ry={} FU={} REF={}", sig.we, sig.er, sig.cx, sig.ry, sig.fu, sig.refb);
    }

    // Switching margins from the Table 2 stack.
    let sw = SwitchingModel::derive(&MtjParams::default(), &SotParams::default());
    println!("\nswitching: STT(AP->P) {:.1} uA, STT(P->AP) {:.1} uA, SOT {:.1} uA",
        sw.stt_critical_ua, sw.stt_reverse_critical_ua, sw.sot_critical_ua);
    println!("read disturb margin: {:.1}x", sw.read_disturb_margin());

    // Subarray level: write/read asymmetry (paper section 3.2).
    let mut stats = Stats::default();
    let mut sub = Subarray::new(256, 128, 16, DeviceCosts::default());
    let data = [u128::MAX; 8];
    sub.write_strip(0, &data, &mut stats, Phase::LoadData);
    let write_ns = stats[Phase::LoadData].latency_ns;
    let mut rstats = Stats::default();
    for r in 0..8 {
        sub.read_row(r, &mut rstats, Phase::Other);
    }
    let read_ns = rstats[Phase::Other].latency_ns;
    println!("\nrow-of-devices write: {write_ns:.1} ns (1024 bits)  vs  8 row reads: {read_ns:.2} ns");
    println!("write/read latency asymmetry: {:.0}x", write_ns / read_ns);
}
